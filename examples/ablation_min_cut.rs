//! Ablation: RPC's minimum-cutoff C (paper §4 "Minimum-cutoff RPC" +
//! App. B.2) — the design choice DESIGN.md calls out.
//!
//! Sweeps C and reports: selected-token ratio (theory 1/2 + C/2T), plateau
//! reward, gradient-norm stability, and learner time — the compute/variance
//! trade-off the paper describes (larger C = more compute, tamer HT weights).
//!
//! ```bash
//! cargo run --release --example ablation_min_cut -- tiny 2
//! ```

use std::path::Path;

use anyhow::Result;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::exp::aggregate::{step_mean_then_ci, tail_mean_then_ci};
use nat_rl::metrics::Recorder;
use nat_rl::runtime::{Checkpoint, OptState, ParamStore, Runtime};
use nat_rl::tasks::Tier;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("tiny").to_string();
    let seeds: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let rt = Runtime::load(Path::new(&format!("artifacts/{model}")))?;
    rt.warmup(&rt.manifest.dims.buckets.clone())?;
    let ckpt = format!("checkpoints/{model}_sft.bin");
    anyhow::ensure!(
        Path::new(&ckpt).exists(),
        "run `nat pretrain --model {model}` first (needs {ckpt})"
    );
    let base: ParamStore = Checkpoint::load(Path::new(&ckpt), &rt.manifest)?.0;

    println!(
        "{:<8} {:>10} {:>12} {:>14} {:>14} {:>12}",
        "C", "sel-ratio", "reward", "grad-norm", "learn s/step", "mem GB"
    );
    for c in [1usize, 4, 8, 16, 32] {
        let mut recs: Vec<Recorder> = Vec::new();
        for seed in 0..seeds {
            let mut cfg = RunConfig::default();
            cfg.model = model.clone();
            cfg.method = Method::Rpc { min_cut: c };
            cfg.seed = seed;
            cfg.rl.steps = 30;
            cfg.rl.prompts_per_step = 2;
            if model == "tiny" {
                cfg.rl.tiers = vec![Tier::Easy];
            }
            let mut tr =
                Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
            tr.train(30, false)?;
            recs.push(tr.recorder);
        }
        let r: Vec<&Recorder> = recs.iter().collect();
        let sel = step_mean_then_ci(&r, "selected_ratio");
        let rew = tail_mean_then_ci(&r, "reward", 0.3);
        let gn = tail_mean_then_ci(&r, "grad_norm", 0.5);
        let t = step_mean_then_ci(&r, "t_learn_s");
        let mem = step_mean_then_ci(&r, "mem_gb");
        println!(
            "{:<8} {:>10.3} {:>12} {:>14} {:>14.3} {:>12.4}",
            c,
            sel.mean,
            format!("{:.3}±{:.3}", rew.mean, rew.ci95),
            format!("{:.2}±{:.2}", gn.mean, gn.ci95),
            t.mean,
            mem.mean
        );
    }
    println!("\ntheory: sel-ratio = 1/2 + C/(2*T_mean); larger C trades compute for\nbounded HT weights (gradient-norm stability).");
    Ok(())
}
