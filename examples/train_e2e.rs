//! END-TO-END DRIVER (DESIGN.md / EXPERIMENTS.md §E2E): the full system on
//! a real workload, proving all three layers compose.
//!
//! Pipeline: SFT-pretrain the `small` policy (~0.8M params) on the synthetic
//! math corpus -> NAT RL (RPC) for a few hundred optimizer steps across all
//! task tiers -> before/after Acc@16 / pass@16 on the three benchmarks,
//! logging the reward/entropy/memory/time curves to results/e2e/.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_e2e                 # full run
//! cargo run --release --example train_e2e -- --fast       # short CI run
//! ```

use std::path::Path;

use anyhow::Result;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::coordinator::{evaluator, pretrainer};
use nat_rl::runtime::{Checkpoint, OptState, ParamStore, Runtime};

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let model = "small";
    let rt = Runtime::load(Path::new(&format!("artifacts/{model}")))?;
    println!(
        "e2e driver: model={} ({} params), fast={}",
        model, rt.manifest.param_count, fast
    );

    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.method = Method::Rpc { min_cut: 8 };
    cfg.rl.steps = if fast { 10 } else { 150 };
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 8;
    cfg.pretrain.steps = if fast { 50 } else { 2200 };
    cfg.pretrain.corpus_size = if fast { 512 } else { 8192 };
    cfg.pretrain.noise = 0.15;
    cfg.eval.tasks_per_tier = if fast { 8 } else { 16 };
    cfg.eval.k = 16;

    // --- base model: reuse the cached SFT checkpoint when present ---------
    let ckpt = format!("checkpoints/{model}_sft.bin");
    let base: ParamStore = if Path::new(&ckpt).exists() && !fast {
        println!("loading base checkpoint {ckpt}");
        Checkpoint::load(Path::new(&ckpt), &rt.manifest)?.0
    } else {
        println!("SFT phase: {} steps ...", cfg.pretrain.steps);
        let res = pretrainer::pretrain(&rt, &cfg, true)?;
        if !fast {
            Checkpoint::save(Path::new(&ckpt), &rt.manifest, &res.params, None)?;
        }
        res.params
    };

    println!("\nevaluating base model ...");
    // Both evals use the fixed engine (None) so the recorded before->after
    // delta reflects training, not a change of eval sampling stream.
    let before = evaluator::evaluate_all_tiers(
        &rt,
        &base,
        cfg.eval.tasks_per_tier,
        cfg.eval.k,
        1.0,
        0,
        None,
        0,
    )?;
    for e in &before {
        println!(
            "  base {:<10} Acc@{} {:.3}  pass@{} {:.3}",
            e.tier.benchmark_name(),
            e.k,
            e.acc_at_k,
            e.k,
            e.pass_at_k
        );
    }

    // --- NAT RL phase ------------------------------------------------------
    println!("\nNAT RL: {} for {} steps ...", cfg.method.label(), cfg.rl.steps);
    rt.warmup(&rt.manifest.dims.buckets.clone())?;
    if cfg.rollout.engine == nat_rl::config::RolloutEngine::Bucketed {
        rt.warmup_generate_buckets()?;
    }
    let steps = cfg.rl.steps;
    let k = cfg.eval.k;
    let tasks_per_tier = cfg.eval.tasks_per_tier;
    let mut tr = Trainer::new(&rt, cfg, base, OptState::zeros(&rt.manifest));
    tr.train(steps, true)?;

    println!("\nevaluating trained model ...");
    let after =
        evaluator::evaluate_all_tiers(&rt, &tr.params, tasks_per_tier, k, 1.0, 0, None, 0)?;
    println!("\n=== E2E RESULT (record in EXPERIMENTS.md) ===");
    println!("benchmark     Acc@{k} before -> after | pass@{k} before -> after");
    for (b, a) in before.iter().zip(&after) {
        println!(
            "{:<12} {:.3} -> {:.3}          | {:.3} -> {:.3}",
            b.tier.benchmark_name(),
            b.acc_at_k,
            a.acc_at_k,
            b.pass_at_k,
            a.pass_at_k
        );
    }
    let r = &tr.recorder;
    println!(
        "\ncurves: reward {:.3} -> {:.3} (tail) | entropy tail {:.3} | sel ratio {:.3} | \
         learner {:.2}s/step | mem {:.4} GB",
        r.values("reward").first().copied().unwrap_or(0.0),
        r.tail_mean("reward", 0.1).unwrap_or(0.0),
        r.tail_mean("entropy", 0.1).unwrap_or(0.0),
        r.tail_mean("selected_ratio", 1.0).unwrap_or(0.0),
        r.tail_mean("t_learn_s", 1.0).unwrap_or(0.0),
        r.tail_mean("mem_gb", 1.0).unwrap_or(0.0),
    );
    r.write_csv(Path::new("results/e2e/train_e2e_small_rpc.csv"))?;
    r.write_json(Path::new("results/e2e/train_e2e_small_rpc.json"))?;
    Checkpoint::save(
        Path::new("checkpoints/small_rpc_e2e.bin"),
        &rt.manifest,
        &tr.params,
        None,
    )?;
    println!("\nmetrics -> results/e2e/train_e2e_small_rpc.csv");
    println!("e2e driver OK");
    Ok(())
}
