//! Regenerate every paper table and figure in one command (the programmatic
//! equivalent of `nat repro --what all`).
//!
//! ```bash
//! cargo run --release --example reproduce_paper            # tiny, 5 seeds
//! cargo run --release --example reproduce_paper -- small 3 # model, seeds
//! ```

use anyhow::Result;

use nat_rl::config::RunConfig;
use nat_rl::exp::tables::{
    figures_summary, paper_methods, run_sweep, table1, table2, table3, write_figures,
};
use nat_rl::runtime::Runtime;
use nat_rl::tasks::Tier;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("tiny").to_string();
    let seeds: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5);

    let mut cfg = RunConfig::default();
    cfg.model = model.clone();
    if model == "tiny" {
        cfg.rl.tiers = vec![Tier::Easy];
        cfg.rl.steps = 60;
        cfg.rl.prompts_per_step = 4;
        cfg.pretrain.steps = 1500;
        cfg.pretrain.corpus_size = 4096;
        cfg.pretrain.noise = 0.15;
        cfg.eval.tasks_per_tier = 16;
    } else {
        cfg.rl.steps = 60;
        cfg.rl.prompts_per_step = 2;
        cfg.pretrain.steps = 2200;
        cfg.pretrain.corpus_size = 8192;
        cfg.pretrain.noise = 0.15;
        cfg.eval.tasks_per_tier = 16;
    }

    let rt = Runtime::load(&cfg.artifact_dir())?;
    println!("{}", table1());
    let sweep = run_sweep(&rt, &cfg, &paper_methods(8), seeds)?;
    println!("{}", table2(&sweep));
    println!("{}", table3(&sweep));
    println!("{}", write_figures(&sweep)?);
    println!("{}", figures_summary(&sweep));
    Ok(())
}
