//! Quickstart: the smallest end-to-end NAT run.
//!
//! Loads the `tiny` artifacts, SFT-pretrains a base model for a few hundred
//! steps, then runs NAT RL with Random Prefix Cutting and prints the metric
//! stream — all through the AOT PJRT path, no Python at runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use anyhow::Result;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::coordinator::{evaluator, pretrainer};
use nat_rl::runtime::{OptState, Runtime};
use nat_rl::tasks::Tier;

fn main() -> Result<()> {
    // 1. Load the AOT artifact set (built once by `make artifacts`).
    let rt = Runtime::load(Path::new("artifacts/tiny"))?;
    println!(
        "loaded {} ({} params, buckets {:?})",
        rt.manifest.dims.name, rt.manifest.param_count, rt.manifest.dims.buckets
    );

    // 2. Configure: tiny model, easy tier, RPC with a minimum cutoff.
    let mut cfg = RunConfig::default();
    cfg.model = "tiny".into();
    cfg.method = Method::Rpc { min_cut: 4 };
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.steps = 30;
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 8;
    cfg.pretrain.steps = 400;
    cfg.pretrain.corpus_size = 2048;
    cfg.pretrain.noise = 0.15;

    // 3. SFT base model (the stand-in for a pretrained checkpoint).
    println!("\n--- SFT base model ({} steps) ---", cfg.pretrain.steps);
    let base = pretrainer::pretrain(&rt, &cfg, false)?;
    println!("final SFT loss: {:.3}", base.final_loss);

    // Both evals use the fixed engine (None) so the before->after delta
    // reflects training, not a change of eval sampling stream.
    let before = evaluator::evaluate_all_tiers(&rt, &base.params, 8, 8, 1.0, 0, None, 0)?;

    // 4. NAT RL: only ~55% of tokens backpropagate, yet the gradient is an
    //    unbiased estimate of the full-token GRPO gradient (HT reweighting).
    println!("\n--- NAT RL: {} ---", cfg.method.label());
    let mut tr = Trainer::new(&rt, cfg, base.params, OptState::zeros(&rt.manifest));
    tr.train(30, true)?;

    // 5. Before/after evaluation.
    let after = evaluator::evaluate_all_tiers(&rt, &tr.params, 8, 8, 1.0, 0, None, 0)?;
    println!("\nbenchmark     Acc@8 before -> after");
    for (b, a) in before.iter().zip(&after) {
        println!(
            "{:<12} {:.3} -> {:.3}",
            b.tier.benchmark_name(),
            b.acc_at_k,
            a.acc_at_k
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
