//! Estimator study (paper Prop. 1 + Appendix B) on the REAL model gradient:
//! demonstrates through the PJRT grad artifact that
//!   * URS and RPC HT-corrected gradients are unbiased estimates of the
//!     full-token GRPO gradient (cosine -> 1, relative error -> small as
//!     mask draws accumulate), with variance that grows as p shrinks;
//!   * deterministic truncation converges to the WRONG gradient (persistent
//!     bias that averaging cannot remove).
//!
//! ```bash
//! cargo run --release --example bias_demo
//! ```

use std::path::Path;

use anyhow::Result;

use nat_rl::config::Method;
use nat_rl::coordinator::batcher::{pack, LearnItem};
use nat_rl::coordinator::masking;
use nat_rl::coordinator::rollout::run_group_rollouts;
use nat_rl::runtime::{GradAccum, ParamStore, Runtime};
use nat_rl::tasks::{TaskMix, TaskSampler, Tier};
use nat_rl::tokenizer::Tokenizer;
use nat_rl::util::rng::Rng;

fn grad_for_items(rt: &Runtime, params: &ParamStore, items: &[LearnItem]) -> Result<Vec<f32>> {
    let d = &rt.manifest.dims;
    let mbs = pack(items, &d.buckets, d.prompt_len, d.batch_train)?;
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    for mb in &mbs {
        rt.grad(mb, params, &mut acc)?;
    }
    Ok(acc.flat)
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-30)
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-30)
}

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts/tiny"))?;
    let params = ParamStore::load_init(&rt.manifest)?;
    let tok = Tokenizer::new();
    let mut rng = Rng::new(0);

    // A fixed batch of rollouts with synthetic advantages.
    let mut sampler =
        TaskSampler::new(1, TaskMix { tiers: vec![Tier::Easy], ..Default::default() });
    let tasks = sampler.batch(2);
    let seqs = run_group_rollouts(&rt, &params, &tok, &tasks, 4, 1.0, &mut rng)?;
    let base_items: Vec<LearnItem> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| LearnItem {
            tokens: s.tokens.clone(),
            pad_len: s.pad_len,
            resp_len: s.resp_len,
            ht_w: vec![1.0; s.resp_len],
            learn_len: s.resp_len,
            adv: if i % 2 == 0 { 1.0 } else { -0.7 },
            old_lp: s.old_lp.clone(),
        })
        .collect();

    println!("computing full-token GRPO reference gradient ...");
    let g_full = grad_for_items(&rt, &params, &base_items)?;

    let n_draws = 40;
    println!("\n{:<16} {:>8} {:>10} {:>12}", "estimator", "draws", "cosine", "rel-error");
    for method in [
        Method::Urs { p: 0.5 },
        Method::Urs { p: 0.25 },
        Method::Rpc { min_cut: 4 },
        Method::DetTrunc { frac: 0.5 },
    ] {
        let mut acc = vec![0.0f64; g_full.len()];
        let mut singles_err = 0.0;
        for draw in 0..n_draws {
            let items: Vec<LearnItem> = base_items
                .iter()
                .map(|it| {
                    let m = masking::sample(&method, it.resp_len, &mut rng);
                    LearnItem { ht_w: m.ht_w, learn_len: m.learn_len, ..it.clone() }
                })
                .collect();
            let g = grad_for_items(&rt, &params, &items)?;
            for (a, &x) in acc.iter_mut().zip(&g) {
                *a += x as f64;
            }
            singles_err += rel_err(&g, &g_full);
            if draw == 0 {
                let g32: Vec<f32> = g.to_vec();
                println!(
                    "{:<16} {:>8} {:>10.4} {:>12.4}   (single draw)",
                    method.label(),
                    1,
                    cosine(&g32, &g_full),
                    rel_err(&g32, &g_full)
                );
            }
        }
        let mean: Vec<f32> = acc.iter().map(|&x| (x / n_draws as f64) as f32).collect();
        println!(
            "{:<16} {:>8} {:>10.4} {:>12.4}   (averaged; single-draw mean err {:.3})",
            method.label(),
            n_draws,
            cosine(&mean, &g_full),
            rel_err(&mean, &g_full),
            singles_err / n_draws as f64
        );
    }
    println!(
        "\nReading: URS/RPC averaged gradients converge toward the full gradient\n\
         (unbiased, Prop. 1); smaller p gives larger single-draw error (1/p\n\
         second-moment inflation); Det. Trunc. stays biased no matter how many\n\
         draws are averaged (its 'error' is pure bias, App. B.5)."
    );
    Ok(())
}
