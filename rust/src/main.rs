//! `nat` — launcher for the NAT token-efficient RL stack.
//!
//! Subcommands:
//!   info      — print model/artifact information
//!   pretrain  — SFT base-model phase; writes a checkpoint
//!   train     — NAT×GRPO RL from a checkpoint
//!   eval      — Acc@16 / pass@16 on the benchmark tiers
//!   repro     — regenerate paper tables/figures (see rust/src/exp)
//!   trace     — analyze an --obs.trace NDJSON file (stage table + savings)
//!   lint      — static analysis for the determinism/HT contracts
//!   golden    — compute/write/check the golden-trace fixture
//!
//! Common options: --model tiny|small|base|xl|sim, --config configs/x.toml,
//! plus any dotted config key as --key value (e.g. --rl.steps 100).

use std::path::Path;

use anyhow::{bail, Result};

use nat_rl::config::{Packer, RolloutEngine, RunConfig};
use nat_rl::coordinator::bucket_tuner::TunerState;
use nat_rl::coordinator::pipeline::PipelineTrainer;
use nat_rl::coordinator::rollout::scheduler::RolloutScheduler;
use nat_rl::coordinator::{evaluator, pretrainer, trainer::Trainer};
use nat_rl::exp;
use nat_rl::metrics::Recorder;
use nat_rl::obs::{analyze, Tracer};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{Checkpoint, OptState, ParamStore, Runtime, TrainMeta};
use nat_rl::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.subcommand.as_str() {
        "info" => cmd_info(&args),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "repro" => exp::cmd_repro(&args),
        "trace" => analyze::cmd_trace(&args),
        "lint" => nat_rl::analysis::cmd_lint(&args),
        "golden" => nat_rl::golden::cmd_golden(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try: nat help)"),
    }
}

fn print_help() {
    println!(
        "nat — NAT: token-efficient RL (Rust + JAX + Pallas reproduction)\n\n\
         USAGE: nat <subcommand> [--key value ...]\n\n\
         SUBCOMMANDS:\n\
           info      print model/artifact information (--model tiny)\n\
           pretrain  SFT base model -> checkpoint (--model small --pretrain.steps 300)\n\
           train     NAT RL from a checkpoint\n\
                     (--method rpc|urs|det_trunc|grpo|saliency|stratified|poisson)\n\
           eval      Acc@16/pass@16 over MATH-S/AIME24-S/AIME25-S (--ckpt path)\n\
           repro     regenerate paper tables and figures (--what table2|table3|figures|all)\n\
           trace     analyze an --obs.trace NDJSON file (--in trace.ndjson [--check])\n\
           lint      static analysis enforcing the determinism & HT-unbiasedness\n\
                     contracts ([--root DIR] [--json] [--check]); see README\n\
           golden    compute the golden seed trace (--write saves the fixture,\n\
                     --check is the CI drift gate)\n\n\
         CONFIG: --config configs/file.toml, then dotted overrides, e.g.\n\
           --model base --method urs --method.p 0.5 --rl.steps 100 --seed 3\n\n\
         PIPELINE / RESUME (train):\n\
           --pipeline.workers N       async rollout workers (0 = serial,\n\
                                      1 = pipelined-synchronous, >=2 overlapped)\n\
           --pipeline.queue_depth Q   bounded rollout-group queue (default 2)\n\
           --pipeline.max_staleness S max optimizer-step lag per group (default 1)\n\
           --rl.ckpt_every N          write a resumable checkpoint every N steps\n\
           --resume path.bin          continue a mid-run checkpoint exactly\n\n\
         ROLLOUT (train/eval):\n\
           --rollout.engine E         bucketed (default) = length-bucketed\n\
                                      continuous batching with per-slot seeds\n\
                                      derived from (seed, step, flat_id) —\n\
                                      scheduling-invariant rollouts; fixed =\n\
                                      legacy full-window chunked generate\n\
                                      (auto-fallback for legacy artifacts)\n\
           --rollout.prefix_cache B   on (default) = prefill each distinct\n\
                                      prompt once per parameter snapshot and\n\
                                      decode all G group siblings from the\n\
                                      cached KV block (bit-identical on/off;\n\
                                      needs the prefill/decode artifact split,\n\
                                      auto-fallback to fused generate without)\n\
           --rollout.cache_mb M       KV cache byte budget in MiB (default 64;\n\
                                      0 = degrade to uncached prefill); LRU\n\
                                      eviction in deterministic epoch order\n\n\
         SELECTION (train):\n\
           --method.p / .frac / .min_cut / .k   per-scheme keep parameters\n\
           --rl.sal_floor F           saliency floor (dedicated flag; the old\n\
                                      --method.p overload still works)\n\
           --train.budget_mode M      none (default) = method literals as-is;\n\
                                      batch = re-solve keep parameters per step\n\
                                      so expected selected tokens hit\n\
                                      --train.token_budget (HT stays unbiased);\n\
                                      neyman = variance-optimal per-sequence\n\
                                      rates from |advantage| x surprisal at the\n\
                                      same expected budget (selection v2)\n\
           --train.pi_floor F         floor every budget-solved inclusion\n\
                                      probability at F (default 1e-3; 0 = off)\n\
                                      so HT weights stay <= 1/F by construction\n\
                                      (`nat trace --check` gates this)\n\n\
         PACKING (train):\n\
           --train.packer P           budget (default) = token-budget packing in\n\
                                      the 2-D (bucket x rows) artifact grid;\n\
                                      fixed = legacy full-row micro-batches\n\
           --train.token_budget B     max rows*(P+bucket) tokens per micro-batch\n\
                                      (0 = auto: batch_train*(P+top bucket));\n\
                                      under budget_mode batch/neyman: the\n\
                                      step's expected selected-token target\n\
           --train.auto_buckets true  EMA-tune bucket routing edges to the\n\
                                      observed learn_len distribution (state\n\
                                      is checkpointed; resume is exact)\n\
           --train.shards K           data-parallel learner shards: packed\n\
                                      micro-batches split across K concurrent\n\
                                      grad workers, recombined by a fixed-order\n\
                                      tree reduction keyed by micro-batch id —\n\
                                      bit-identical to K=1 for every K (resume\n\
                                      across different K is exact)\n\n\
         OBSERVABILITY (train):\n\
           --obs.trace path.ndjson    structured spans (rollout, select, pack,\n\
                                      shard grad, reduce, apply) + per-step\n\
                                      savings-ledger events; read with\n\
                                      `nat trace --in path.ndjson`\n\
           --obs.chrome path.json     same spans as a Chrome/Perfetto trace\n\
           --obs.ledger false         drop ledger series from the recorder\n\
                                      (the ledger itself always computes;\n\
                                      tracing never changes training output)"
    );
}

fn config_from_args(args: &Args) -> Result<RunConfig> {
    RunConfig::from_args(args)
}

/// `--model sim` maps to the in-process simulated runtime (no artifacts on
/// disk) — the same backend the deterministic test-suite and CI smoke lanes
/// run against; every other model name loads compiled artifacts.
fn load_runtime(cfg: &RunConfig) -> Result<Runtime> {
    if cfg.model == "sim" {
        Ok(Runtime::sim(sim_manifest()))
    } else {
        Runtime::load(&cfg.artifact_dir())
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = load_runtime(&cfg)?;
    let d = &rt.manifest.dims;
    println!("model: {} ({} params)", d.name, rt.manifest.param_count);
    println!(
        "dims: d_model={} layers={} heads={} d_ff={} vocab={}",
        d.d_model, d.n_layers, d.n_heads, d.d_ff, d.vocab
    );
    println!(
        "windows: prompt={} max_resp={} buckets={:?}",
        d.prompt_len, d.max_resp, d.buckets
    );
    println!(
        "batches: rollout={} train={} pretrain={}x{}",
        d.batch_rollout, d.batch_train, d.batch_pretrain, d.pretrain_len
    );
    println!("artifacts: {}", rt.manifest.dir.display());
    println!("method: {}", cfg.method.label());
    Ok(())
}

fn default_ckpt(cfg: &RunConfig) -> String {
    format!("{}/{}_sft.bin", cfg.checkpoints_dir, cfg.model)
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::load(&cfg.artifact_dir())?;
    let out = args.get_or("out", &default_ckpt(&cfg)).to_string();
    println!(
        "pretraining {} for {} steps (corpus {}, noise {}) -> {out}",
        cfg.model, cfg.pretrain.steps, cfg.pretrain.corpus_size, cfg.pretrain.noise
    );
    let res = pretrainer::pretrain(&rt, &cfg, true)?;
    Checkpoint::save(Path::new(&out), &rt.manifest, &res.params, None)?;
    res.recorder
        .write_csv(Path::new(&cfg.results_dir).join("sft_loss.csv").as_path())?;
    println!("final SFT loss: {:.4}; checkpoint: {out}", res.final_loss);
    Ok(())
}

fn load_ckpt_or_init(args: &Args, cfg: &RunConfig, rt: &Runtime) -> Result<ParamStore> {
    match args.get("ckpt") {
        Some(p) => Ok(Checkpoint::load(Path::new(p), &rt.manifest)?.0),
        None => {
            let default = default_ckpt(cfg);
            if Path::new(&default).exists() {
                println!("using checkpoint {default}");
                Ok(Checkpoint::load(Path::new(&default), &rt.manifest)?.0)
            } else if cfg.model == "sim" {
                println!("sim model: deterministic synthetic init");
                Ok(init_params(&rt.manifest))
            } else {
                println!("no checkpoint found; starting from random init");
                ParamStore::load_init(&rt.manifest)
            }
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = load_runtime(&cfg)?;
    let tracer = Tracer::from_cfg(&cfg.obs)?;
    if tracer.enabled() {
        println!(
            "tracing: spans -> {}{}",
            if cfg.obs.trace.is_empty() { "(none)" } else { &cfg.obs.trace },
            if cfg.obs.chrome.is_empty() {
                String::new()
            } else {
                format!(", chrome -> {}", cfg.obs.chrome)
            }
        );
    }

    // Starting state: --resume beats --ckpt beats the default SFT checkpoint.
    let (params, opt, start_step, tuner0): (_, _, u64, Option<TunerState>) =
        match args.get("resume") {
            Some(p) => {
                let (params, opt, train) = Checkpoint::load_full(Path::new(p), &rt.manifest)?;
                let opt = opt.unwrap_or_else(|| OptState::zeros(&rt.manifest));
                let (start, tuner0) = match train {
                    Some(t) => {
                        if t.seed != cfg.seed {
                            println!(
                                "WARNING: checkpoint was trained with seed {} but this run \
                                 uses seed {}; the continuation will not reproduce the \
                                 original stream (pass --seed {} to match)",
                                t.seed, cfg.seed, t.seed
                            );
                        }
                        if t.shards != cfg.train.shards {
                            println!(
                                "note: checkpoint was written with train.shards {} and \
                                 this run uses {}; the continuation is still exact — the \
                                 shard reduction order derives from the step plan, not \
                                 from K",
                                t.shards, cfg.train.shards
                            );
                        }
                        (t.step, t.tuner)
                    }
                    None => {
                        println!(
                            "note: {p} has no training state (params-only checkpoint); \
                             starting from step 0"
                        );
                        (0, None)
                    }
                };
                // Exact-resume contract check for the auto-bucket tuner
                // (mirrors the seed-mismatch warning above): silently
                // dropping or cold-starting the EMA state would make the
                // continuation diverge from the uninterrupted run.
                let uses_tuner =
                    cfg.train.auto_buckets && cfg.train.packer == Packer::Budget;
                if uses_tuner && tuner0.is_none() && start > 0 {
                    println!(
                        "WARNING: --train.auto_buckets is on but {p} carries no tuner \
                         state; the tuner cold-starts and the continuation will not \
                         reproduce the original run's routing"
                    );
                } else if !uses_tuner && tuner0.is_some() {
                    println!(
                        "WARNING: {p} carries auto-bucket tuner state but this run \
                         does not use it; routing reverts to static edges (pass \
                         --train.auto_buckets true to continue the original run)"
                    );
                }
                println!("resuming from {p} at step {start}");
                (params, opt, start, tuner0)
            }
            None => {
                (load_ckpt_or_init(args, &cfg, &rt)?, OptState::zeros(&rt.manifest), 0, None)
            }
        };

    let remaining = (cfg.rl.steps as u64).saturating_sub(start_step) as usize;
    println!(
        "RL: model={} method={} steps={} (from {start_step}) prompts/step={} G={} seed={} \
         pipeline={} shards={}",
        cfg.model,
        cfg.method.label(),
        cfg.rl.steps,
        cfg.rl.prompts_per_step,
        cfg.rl.group_size,
        cfg.seed,
        if cfg.pipeline.workers > 0 {
            format!("{}w", cfg.pipeline.workers)
        } else {
            "off".into()
        },
        cfg.train.shards
    );
    if remaining == 0 {
        println!("nothing to do: checkpoint already at {} >= rl.steps", start_step);
    }

    let results_dir = cfg.results_dir.clone();
    let method_id = cfg.method.id();
    let model = cfg.model.clone();
    let seed = cfg.seed;
    let shards = cfg.train.shards;
    let eval_cfg = cfg.eval.clone();
    let temperature = cfg.rl.temperature;
    let rollout_cfg = cfg.rollout;
    let engine = rollout_cfg.engine;

    // Serial and pipelined trainers share the stage functions and metric
    // series; which one runs is purely a scheduling choice.
    let (final_params, final_opt, recorder, tuner_fin): (
        ParamStore,
        OptState,
        Recorder,
        Option<TunerState>,
    ) = if cfg.pipeline.workers > 0 {
        let mut tr = PipelineTrainer::new(&rt, cfg, params, opt);
        tr.set_tracer(tracer.clone());
        tr.set_start_step(start_step);
        tr.restore_tuner(tuner0.as_ref());
        tr.train(remaining, true)?;
        let ts = tr.tuner_state();
        (tr.params, tr.opt, tr.recorder, ts)
    } else {
        let mut tr = Trainer::new(&rt, cfg, params, opt);
        tr.set_tracer(tracer.clone());
        tr.set_start_step(start_step);
        tr.restore_tuner(tuner0.as_ref());
        tr.train(remaining, true)?;
        let ts = tr.tuner_state();
        (tr.params, tr.opt, tr.recorder, ts)
    };
    tracer.flush()?;

    // A continuation only holds steps start+1.., so it must not clobber the
    // original run's metric files (and an already-complete run writes none).
    let base = if start_step == 0 {
        format!("{results_dir}/train_{model}_{method_id}_s{seed}")
    } else {
        format!("{results_dir}/train_{model}_{method_id}_s{seed}_from{start_step}")
    };
    if remaining > 0 {
        recorder.write_csv(Path::new(&format!("{base}.csv")))?;
        recorder.write_json(Path::new(&format!("{base}.json")))?;
        println!("metrics: {base}.csv");
    }
    if let Some(out) = args.get("out") {
        // Full training state (including tuner EMA), so `--resume <out>`
        // continues rather than replaying from step 0 on top of trained
        // params.
        Checkpoint::save_train(
            Path::new(out),
            &rt.manifest,
            &final_params,
            &final_opt,
            &TrainMeta {
                step: start_step + remaining as u64,
                seed,
                tuner: tuner_fin,
                shards,
            },
        )?;
        println!("saved trained checkpoint to {out}");
    }
    // final eval (skipped for the synthetic sim runtime: benchmark prompts
    // are not guaranteed to fit its tiny prompt window, and its rewards are
    // synthetic anyway — the smoke lanes only need the training path)
    if model == "sim" {
        println!("sim model: skipping final benchmark eval");
        return Ok(());
    }
    let eval_sched = (engine == RolloutEngine::Bucketed)
        .then(|| RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &rollout_cfg));
    let evals = evaluator::evaluate_all_tiers(
        &rt,
        &final_params,
        eval_cfg.tasks_per_tier,
        eval_cfg.k,
        temperature,
        seed,
        eval_sched.as_ref(),
        start_step + remaining as u64,
    )?;
    for e in evals {
        println!(
            "{:>9}: Acc@{} {:.3}  pass@{} {:.3}  (len {:.1}, {} tasks)",
            e.tier.benchmark_name(), e.k, e.acc_at_k, e.k, e.pass_at_k, e.mean_resp_len, e.tasks
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = load_runtime(&cfg)?;
    let params = load_ckpt_or_init(args, &cfg, &rt)?;
    let sched = (cfg.rollout.engine == RolloutEngine::Bucketed)
        .then(|| RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &cfg.rollout));
    // One fixed parameter snapshot for the whole eval — version 0.
    let evals = evaluator::evaluate_all_tiers(
        &rt,
        &params,
        cfg.eval.tasks_per_tier,
        cfg.eval.k,
        cfg.rl.temperature,
        cfg.seed,
        sched.as_ref(),
        0,
    )?;
    println!("benchmark     Acc@{:<3} pass@{:<3} len", cfg.eval.k, cfg.eval.k);
    for e in evals {
        println!(
            "{:<12} {:.3}   {:.3}    {:.1}",
            e.tier.benchmark_name(),
            e.acc_at_k,
            e.pass_at_k,
            e.mean_resp_len
        );
    }
    Ok(())
}
