//! Character-level tokenizer over the synthetic-math alphabet.
//!
//! The task suite (rust/src/tasks) renders prompts and chain-of-thought
//! solutions from a closed alphabet so a small vocab (64, matching the
//! tiny/small/base model configs) suffices. Special tokens:
//!   PAD=0 (left padding / unused), BOS=1 (pretraining sequences),
//!   EOS=2 (end of response — the verifier reads up to the first EOS).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Printable alphabet starting at id 3. Order is part of the artifact
/// contract (changing it invalidates pretrained checkpoints).
const ALPHABET: &str = "0123456789+-*%()=,.:#> abcdefghijklmnopqrstuvwxyz\n";

#[derive(Clone, Debug)]
pub struct Tokenizer {
    to_id: [i32; 128],
    to_char: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = [-1i32; 128];
        let mut to_char = vec!['\0', '\u{1}', '\u{2}']; // PAD/BOS/EOS placeholders
        for (i, c) in ALPHABET.chars().enumerate() {
            let id = 3 + i as i32;
            to_id[c as usize] = id;
            to_char.push(c);
        }
        Tokenizer { to_id, to_char }
    }

    pub fn vocab_size(&self) -> usize {
        self.to_char.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .map(|c| {
                let i = c as usize;
                assert!(i < 128 && self.to_id[i] >= 0, "untokenizable char {c:?}");
                self.to_id[i]
            })
            .collect()
    }

    pub fn try_encode(&self, text: &str) -> Option<Vec<i32>> {
        text.chars()
            .map(|c| {
                let i = c as usize;
                if i < 128 && self.to_id[i] >= 0 {
                    Some(self.to_id[i])
                } else {
                    None
                }
            })
            .collect()
    }

    /// Decode, stopping at EOS; PAD/BOS are skipped.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS {
                break;
            }
            if id == PAD || id == BOS {
                continue;
            }
            if let Some(&c) = self.to_char.get(id as usize) {
                out.push(c);
            }
        }
        out
    }

    pub fn id_of(&self, c: char) -> i32 {
        let i = c as usize;
        assert!(i < 128 && self.to_id[i] >= 0);
        self.to_id[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_model_configs() {
        let t = Tokenizer::new();
        assert!(t.vocab_size() <= 64, "{}", t.vocab_size());
        assert!(t.vocab_size() > 40);
    }

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "(3+5)*2%7=\n16%7\n#2";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn decode_stops_at_eos_and_skips_pad() {
        let t = Tokenizer::new();
        let mut ids = vec![PAD, PAD, BOS];
        ids.extend(t.encode("ab"));
        ids.push(EOS);
        ids.extend(t.encode("junk"));
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn ids_are_stable() {
        // contract: artifact checkpoints depend on this mapping
        let t = Tokenizer::new();
        assert_eq!(t.id_of('0'), 3);
        assert_eq!(t.id_of('9'), 12);
        assert_eq!(t.id_of('+'), 13);
        assert_eq!(t.id_of('#'), 23);
        assert_eq!(t.id_of('\n'), (3 + ALPHABET.len() - 1) as i32);
    }

    #[test]
    fn try_encode_rejects_unknown() {
        let t = Tokenizer::new();
        assert!(t.try_encode("ABC").is_none()); // uppercase not in alphabet
        assert!(t.try_encode("3+4").is_some());
    }

    #[test]
    fn all_alphabet_chars_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in ALPHABET.chars() {
            assert!(seen.insert(c), "duplicate char {c:?}");
        }
    }
}
