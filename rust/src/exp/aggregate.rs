//! Cross-seed aggregation of metric curves and final statistics.

use crate::metrics::Recorder;
use crate::stats::MeanCi;

/// Mean ± 95% CI per step across runs (the shaded curves of Figs. 2-6).
pub fn curve_mean_ci(recorders: &[&Recorder], series: &str) -> Vec<(u64, MeanCi)> {
    let mut steps: Vec<u64> = recorders
        .iter()
        .flat_map(|r| r.get(series).iter().map(|&(s, _)| s))
        .collect();
    steps.sort();
    steps.dedup();
    steps
        .into_iter()
        .filter_map(|step| {
            let vals: Vec<f64> = recorders
                .iter()
                .filter_map(|r| {
                    r.get(series).iter().find(|&&(s, _)| s == step).map(|&(_, v)| v)
                })
                .collect();
            if vals.is_empty() {
                None
            } else {
                Some((step, MeanCi::of(&vals)))
            }
        })
        .collect()
}

/// Scalar per run (series mean over all steps), aggregated across runs —
/// Table 3's "averaged over training steps, mean ± CI across 5 runs".
pub fn step_mean_then_ci(recorders: &[&Recorder], series: &str) -> MeanCi {
    let per_run: Vec<f64> = recorders
        .iter()
        .filter_map(|r| {
            let v = r.values(series);
            if v.is_empty() {
                None
            } else {
                Some(v.iter().sum::<f64>() / v.len() as f64)
            }
        })
        .collect();
    MeanCi::of(&per_run)
}

/// Tail-plateau statistic per run, aggregated (Fig. 1 bar heights).
pub fn tail_mean_then_ci(recorders: &[&Recorder], series: &str, frac: f64) -> MeanCi {
    let per_run: Vec<f64> =
        recorders.iter().filter_map(|r| r.tail_mean(series, frac)).collect();
    MeanCi::of(&per_run)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(vals: &[(u64, f64)]) -> Recorder {
        let mut r = Recorder::new();
        for &(s, v) in vals {
            r.push("x", s, v);
        }
        r
    }

    #[test]
    fn curve_aggregation() {
        let a = rec(&[(1, 1.0), (2, 2.0)]);
        let b = rec(&[(1, 3.0), (2, 4.0)]);
        let c = curve_mean_ci(&[&a, &b], "x");
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].0, 1);
        assert!((c[0].1.mean - 2.0).abs() < 1e-12);
        assert!((c[1].1.mean - 3.0).abs() < 1e-12);
        assert_eq!(c[0].1.n, 2);
    }

    #[test]
    fn missing_steps_are_skipped_per_run() {
        let a = rec(&[(1, 1.0)]);
        let b = rec(&[(2, 4.0)]);
        let c = curve_mean_ci(&[&a, &b], "x");
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].1.n, 1);
    }

    #[test]
    fn step_mean_then_ci_averages_within_runs_first() {
        let a = rec(&[(1, 1.0), (2, 3.0)]); // run mean 2
        let b = rec(&[(1, 4.0), (2, 6.0)]); // run mean 5
        let m = step_mean_then_ci(&[&a, &b], "x");
        assert!((m.mean - 3.5).abs() < 1e-12);
        assert_eq!(m.n, 2);
    }

    #[test]
    fn tail_statistic() {
        let a = rec(&[(1, 0.0), (2, 0.0), (3, 10.0), (4, 10.0)]);
        let m = tail_mean_then_ci(&[&a], "x", 0.5);
        assert!((m.mean - 10.0).abs() < 1e-12);
    }
}
