//! Paper table/figure regeneration (DESIGN.md §5).
//!
//! `nat repro --what table1|table2|table3|figures|all --model tiny --seeds 5`
//! runs the 4-method × N-seed sweep from a shared SFT base checkpoint and
//! renders:
//!   Table 1  — method property matrix (validated empirically elsewhere)
//!   Table 2  — Acc@16 / pass@16 ± 95% CI on the three benchmark tiers,
//!              with the paper's CI-overlap colouring vs GRPO
//!   Table 3  — peak memory / train time w/o inference / total time ± CI
//!   Fig 1    — bar data: plateau reward, entropy, grad-norm, learn time
//!   Fig 2-6  — entropy / selected-ratio / grad-norm / time / memory curves
//! Outputs land in results/repro/<model>/ as .txt (pretty) + .csv (data).

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{Method, RolloutEngine, RunConfig};
use crate::coordinator::pretrainer;
use crate::exp::aggregate::{curve_mean_ci, step_mean_then_ci, tail_mean_then_ci};
use crate::exp::runs::{run_rl, RunResult};
use crate::metrics::Recorder;
use crate::runtime::{Checkpoint, ParamStore, Runtime};
use crate::stats::MeanCi;
use crate::tasks::Tier;
use crate::util::cli::Args;

/// The paper's four compared algorithms (§5.1), with our scaled-down RPC
/// minimum cutoff (paper: C=100 at T~3000; ours: C=8 at T<=192 keeps the
/// same C/T regime and the same Fig. 3 ratio prediction).
pub fn paper_methods(min_cut: usize) -> Vec<Method> {
    vec![
        Method::Grpo,
        Method::Urs { p: 0.5 },
        Method::DetTrunc { frac: 0.5 },
        Method::Rpc { min_cut },
    ]
}

pub struct Sweep {
    pub model: String,
    pub results: Vec<RunResult>,
    pub out_dir: PathBuf,
}

impl Sweep {
    pub fn recorders_for(&self, method: Method) -> Vec<&Recorder> {
        self.results
            .iter()
            .filter(|r| r.method == method)
            .map(|r| &r.recorder)
            .collect()
    }

    pub fn runs_for(&self, method: Method) -> Vec<&RunResult> {
        self.results.iter().filter(|r| r.method == method).collect()
    }

    pub fn methods(&self) -> Vec<Method> {
        let mut out: Vec<Method> = Vec::new();
        for r in &self.results {
            if !out.contains(&r.method) {
                out.push(r.method);
            }
        }
        out
    }
}

/// Ensure a shared SFT base checkpoint exists; pretrain if missing.
pub fn ensure_base(rt: &Runtime, cfg: &RunConfig) -> Result<ParamStore> {
    let path = PathBuf::from(&cfg.checkpoints_dir).join(format!("{}_sft.bin", cfg.model));
    if path.exists() {
        println!("[repro] base checkpoint: {}", path.display());
        return Ok(Checkpoint::load(&path, &rt.manifest)?.0);
    }
    println!(
        "[repro] pretraining base model ({} steps, corpus {}, noise {})",
        cfg.pretrain.steps, cfg.pretrain.corpus_size, cfg.pretrain.noise
    );
    let res = pretrainer::pretrain(rt, cfg, true)?;
    Checkpoint::save(&path, &rt.manifest, &res.params, None)?;
    Ok(res.params)
}

/// Run the full sweep: methods × seeds from the shared base.
pub fn run_sweep(
    rt: &Runtime,
    base_cfg: &RunConfig,
    methods: &[Method],
    seeds: u64,
) -> Result<Sweep> {
    let base = ensure_base(rt, base_cfg)?;
    // Compile every executable the sweep will touch BEFORE timing anything:
    // first-use compilation would otherwise pollute the first run's Table 3
    // timings (GRPO is swept first and would absorb the cost).
    // natlint: allow(wallclock, reason = "progress-line timing for the repro harness; table values come from the Recorder, not this clock")
    let t0 = std::time::Instant::now();
    rt.warmup(&rt.manifest.dims.buckets.clone())?;
    if base_cfg.rollout.engine == RolloutEngine::Bucketed {
        rt.warmup_generate_buckets()?;
    }
    println!("[repro] artifact warmup: {:.1}s", t0.elapsed().as_secs_f64());
    let mut results = Vec::new();
    let total = methods.len() as u64 * seeds;
    let mut done = 0;
    for &method in methods {
        for seed in 0..seeds {
            let mut cfg = base_cfg.clone();
            cfg.method = method;
            cfg.seed = seed;
            // natlint: allow(wallclock, reason = "progress-line timing for the repro harness; table values come from the Recorder, not this clock")
            let t0 = std::time::Instant::now();
            let r = run_rl(rt, &base, &cfg, false)?;
            done += 1;
            println!(
                "[repro] {}/{} {} seed {} done in {:.1}s (reward tail {:.3})",
                done,
                total,
                method.label(),
                seed,
                t0.elapsed().as_secs_f64(),
                r.recorder.tail_mean("reward", 0.2).unwrap_or(f64::NAN)
            );
            results.push(r);
        }
    }
    let out_dir = PathBuf::from(&base_cfg.results_dir).join("repro").join(&base_cfg.model);
    std::fs::create_dir_all(&out_dir)?;
    Ok(Sweep { model: base_cfg.model.clone(), results, out_dir })
}

fn ci_cell(m: &MeanCi) -> String {
    format!("{:.3}±{:.3}", m.mean, m.ci95)
}

/// Overlap marker vs the GRPO baseline (the paper's colour coding).
fn mark(cell: &MeanCi, baseline: &MeanCi) -> &'static str {
    if cell.overlaps(baseline) {
        "=" // green: CI overlap with GRPO
    } else if cell.mean < baseline.mean {
        "v" // red: significantly below
    } else {
        "^"
    }
}

// ---------------------------------------------------------------- Table 1

pub fn table1() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Table 1: Comparison of token-efficient methods");
    let _ = writeln!(
        s,
        "{:<12} {:<10} {:<16} {:<17} {}",
        "Method", "Unbiased?", "Forward Savings", "Backward Savings", "Key Property"
    );
    let rows = [
        ("URS", "Yes", "No", "Yes", "Simple, constant p sampling"),
        ("Det. Trunc.", "No", "Yes", "Yes", "Systematic bias, ignores late tokens"),
        ("RPC", "Yes", "Yes", "Yes", "Structured, preserves causal context"),
    ];
    for (m, u, f, b, k) in rows {
        let _ = writeln!(s, "{m:<12} {u:<10} {f:<16} {b:<17} {k}");
    }
    let _ = writeln!(
        s,
        "\n(unbiasedness: python/tests/test_ht.py + rust masking MC tests;\n \
         fwd/bwd savings: bucket routing in coordinator::batcher + Table 3)"
    );
    s
}

// ---------------------------------------------------------------- Table 2

pub fn table2(sweep: &Sweep) -> String {
    let methods = sweep.methods();
    let tiers = Tier::ALL;
    // per (method, tier): acc list + pass list across seeds
    let cell = |m: Method, tier: Tier| -> (MeanCi, MeanCi) {
        let accs: Vec<f64> = sweep
            .runs_for(m)
            .iter()
            .flat_map(|r| r.evals.iter().filter(|e| e.tier == tier).map(|e| e.acc_at_k))
            .collect();
        let passes: Vec<f64> = sweep
            .runs_for(m)
            .iter()
            .flat_map(|r| r.evals.iter().filter(|e| e.tier == tier).map(|e| e.pass_at_k))
            .collect();
        (MeanCi::of(&accs), MeanCi::of(&passes))
    };
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Table 2: Acc@16 / pass@16 (mean ± 95% CI across seeds), model {}\n\
         markers vs GRPO: '=' CI overlap, 'v' significantly below, '^' above",
        sweep.model
    );
    let _ = write!(s, "{:<14}", "Method");
    for t in tiers {
        let _ = write!(s, " | {:^27}", t.benchmark_name());
    }
    let _ = writeln!(s);
    let _ = write!(s, "{:<14}", "");
    for _ in tiers {
        let _ = write!(s, " | {:^13} {:^13}", "Acc@16", "pass@16");
    }
    let _ = writeln!(s);
    let base: Vec<(MeanCi, MeanCi)> =
        tiers.iter().map(|&t| cell(Method::Grpo, t)).collect();
    for &m in &methods {
        let _ = write!(s, "{:<14}", m.label());
        for (i, &t) in tiers.iter().enumerate() {
            let (acc, pass) = cell(m, t);
            let _ = i;
            let (ma, mp) = if m == Method::Grpo {
                (" ".into(), " ".into())
            } else {
                (mark(&acc, &base[i].0).to_string(), mark(&pass, &base[i].1).to_string())
            };
            let _ = write!(s, " | {:>11}{} {:>11}{}", ci_cell(&acc), ma, ci_cell(&pass), mp);
        }
        let _ = writeln!(s);
    }
    s
}

// ---------------------------------------------------------------- Table 3

pub fn table3(sweep: &Sweep) -> String {
    let methods = sweep.methods();
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Table 3: system efficiency (mean ± 95% CI across seeds), model {}\n\
         peak memory is the analytic activation model (DESIGN.md §7);\n\
         times are measured wall-clock on this host",
        sweep.model
    );
    let _ = writeln!(
        s,
        "{:<14} {:>22} {:>26} {:>22}",
        "Method", "Peak Mem (GB)", "Train Time/Step (s) w/o inf", "Total Time/Step (s)"
    );
    let base_learn = step_mean_then_ci(&sweep.recorders_for(Method::Grpo), "t_learn_s");
    let base_mem = step_mean_then_ci(&sweep.recorders_for(Method::Grpo), "mem_gb");
    for &m in &methods {
        let recs = sweep.recorders_for(m);
        let mem = step_mean_then_ci(&recs, "mem_gb");
        let learn = step_mean_then_ci(&recs, "t_learn_s");
        let total = step_mean_then_ci(&recs, "t_total_s");
        let _ = writeln!(
            s,
            "{:<14} {:>18}{} {:>24}{} {:>22}",
            m.label(),
            format!("{:.4}±{:.4}", mem.mean, mem.ci95),
            if m == Method::Grpo { " " } else { mark(&mem, &base_mem) },
            format!("{:.3}±{:.3}", learn.mean, learn.ci95),
            if m == Method::Grpo { " " } else { mark(&learn, &base_learn) },
            format!("{:.3}±{:.3}", total.mean, total.ci95),
        );
    }
    // headline ratios (paper: RPC saves ~18% memory, ~29% learner time)
    for &m in &methods {
        if m == Method::Grpo {
            continue;
        }
        let recs = sweep.recorders_for(m);
        let mem = step_mean_then_ci(&recs, "mem_gb").mean / base_mem.mean;
        let t = step_mean_then_ci(&recs, "t_learn_s").mean / base_learn.mean;
        let _ = writeln!(
            s,
            "  {} vs GRPO: memory x{:.2} ({:+.0}%), learner time x{:.2} ({:+.0}%)",
            m.label(),
            mem,
            (mem - 1.0) * 100.0,
            t,
            (t - 1.0) * 100.0
        );
    }
    s
}

// ---------------------------------------------------------------- Figures

const FIG_SERIES: [(&str, &str); 6] = [
    ("fig2_entropy", "entropy"),
    ("fig3_selected_ratio", "selected_ratio"),
    ("fig4_grad_norm", "grad_norm"),
    ("fig5_time_per_step", "t_learn_s"),
    ("fig6_memory", "mem_gb"),
    // savings-ledger curve (empty for runs recorded with --obs.ledger off;
    // the aggregators skip runs missing a series)
    ("fig7_flop_saving", "flop_saving"),
];

pub fn write_figures(sweep: &Sweep) -> Result<String> {
    let mut summary = String::new();
    // Fig. 1: bar data (plateau tail means)
    {
        let mut csv = String::from("method,metric,mean,ci95,n\n");
        for &m in &sweep.methods() {
            let recs = sweep.recorders_for(m);
            for (metric, series, frac) in [
                ("reward", "reward", 0.2),
                ("entropy", "entropy", 0.2),
                ("grad_norm", "grad_norm", 0.2),
                ("train_time_s", "t_learn_s", 1.0),
                ("total_time_s", "t_total_s", 1.0),
                ("mem_gb", "mem_gb", 1.0),
                ("peak_mem_gb", "peak_mem_gb", 1.0),
                // savings-ledger headline bars (`--obs.ledger`, on by
                // default): what selection saved vs full-token GRPO
                ("flop_saving", "flop_saving", 1.0),
                ("mem_saving", "mem_saving", 1.0),
                ("ht_ess", "ht_ess", 0.2),
            ] {
                let v = tail_mean_then_ci(&recs, series, frac);
                let _ = writeln!(csv, "{},{},{},{},{}", m.id(), metric, v.mean, v.ci95, v.n);
            }
        }
        let path = sweep.out_dir.join("fig1_bars.csv");
        std::fs::write(&path, csv)?;
        let _ = writeln!(summary, "fig1 -> {}", path.display());
    }
    // Figs. 2-6: per-step curves, mean ± CI per method
    for (fig, series) in FIG_SERIES {
        let mut csv = String::from("method,step,mean,ci95,n\n");
        for &m in &sweep.methods() {
            let recs = sweep.recorders_for(m);
            for (step, v) in curve_mean_ci(&recs, series) {
                let _ = writeln!(csv, "{},{step},{},{},{}", m.id(), v.mean, v.ci95, v.n);
            }
        }
        let path = sweep.out_dir.join(format!("{fig}.csv"));
        std::fs::write(&path, csv)?;
        let _ = writeln!(summary, "{fig} -> {}", path.display());
    }
    Ok(summary)
}

/// Short textual rendering of the key figure claims.
pub fn figures_summary(sweep: &Sweep) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Figure headline checks:");
    if let Some(rpc) = sweep.methods().iter().find(|m| matches!(m, Method::Rpc { .. })) {
        let r = tail_mean_then_ci(&sweep.recorders_for(*rpc), "selected_ratio", 1.0);
        let _ = writeln!(
            s,
            "  Fig3 RPC selected-token ratio: {:.3} (paper: ~0.54-0.56, formula 1/2+C/2T)",
            r.mean
        );
    }
    for &m in &sweep.methods() {
        let e = tail_mean_then_ci(&sweep.recorders_for(m), "entropy", 0.2);
        let g = tail_mean_then_ci(&sweep.recorders_for(m), "grad_norm", 0.2);
        let _ = writeln!(
            s,
            "  Fig2/4 {}: plateau entropy {:.3}±{:.3}, grad norm {:.3}±{:.3}",
            m.label(),
            e.mean,
            e.ci95,
            g.mean,
            g.ci95
        );
    }
    s
}

// ---------------------------------------------------------------- driver

pub fn cmd_repro(args: &Args) -> Result<()> {
    let what = args.get_or("what", "all").to_string();
    if what == "table1" {
        println!("{}", table1());
        return Ok(());
    }
    let cfg = RunConfig::from_args(args)?;
    let seeds: u64 = args.parse_or("seeds", 5)?;
    let min_cut: usize = args.parse_or("min-cut", 8)?;
    let rt = Runtime::load(&cfg.artifact_dir())
        .with_context(|| format!("loading artifacts for {}", cfg.model))?;
    println!(
        "[repro] model={} seeds={} steps={} what={}",
        cfg.model, seeds, cfg.rl.steps, what
    );
    let sweep = run_sweep(&rt, &cfg, &paper_methods(min_cut), seeds)?;

    let mut report = String::new();
    report.push_str(&table1());
    report.push('\n');
    if what == "table2" || what == "all" {
        report.push_str(&table2(&sweep));
        report.push('\n');
    }
    if what == "table3" || what == "all" {
        report.push_str(&table3(&sweep));
        report.push('\n');
    }
    if what == "figures" || what == "all" {
        report.push_str(&write_figures(&sweep)?);
        report.push_str(&figures_summary(&sweep));
    }
    println!("{report}");
    let path = sweep.out_dir.join("report.txt");
    std::fs::write(&path, &report)?;
    // dump every run's full recorder for offline plotting
    for r in &sweep.results {
        let p = sweep.out_dir.join(format!("run_{}_s{}.json", r.method.id(), r.seed));
        r.recorder.write_json(Path::new(&p))?;
    }
    println!("[repro] report written to {}", path.display());
    Ok(())
}
