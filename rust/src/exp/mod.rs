//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §5). Placeholder populated incrementally.
use anyhow::Result;

use crate::util::cli::Args;

pub mod aggregate;
pub mod runs;
pub mod tables;

pub fn cmd_repro(args: &Args) -> Result<()> {
    tables::cmd_repro(args)
}
