//! One RL run = (model, method, seed) -> metric recorder + final evals.
use anyhow::Result;

use crate::config::{Method, RunConfig};
use crate::coordinator::evaluator::{self, EvalResult};
use crate::coordinator::trainer::Trainer;
use crate::metrics::Recorder;
use crate::runtime::{OptState, ParamStore, Runtime};

pub struct RunResult {
    pub method: Method,
    pub seed: u64,
    pub recorder: Recorder,
    pub evals: Vec<EvalResult>,
}

/// Execute one full RL run from a shared base checkpoint.
pub fn run_rl(
    rt: &Runtime,
    base: &ParamStore,
    cfg: &RunConfig,
    verbose: bool,
) -> Result<RunResult> {
    let mut tr = Trainer::new(rt, cfg.clone(), base.clone(), OptState::zeros(&rt.manifest));
    tr.train(cfg.rl.steps, verbose)?;
    let evals = evaluator::evaluate_all_tiers(
        rt,
        &tr.params,
        cfg.eval.tasks_per_tier,
        cfg.eval.k,
        cfg.rl.temperature,
        cfg.seed,
        tr.eval_sched(),
        // final-params snapshot: one version past the last optimizer step
        cfg.rl.steps as u64,
    )?;
    Ok(RunResult { method: cfg.method, seed: cfg.seed, recorder: tr.recorder, evals })
}
