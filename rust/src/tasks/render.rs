//! Chain-of-thought renderers: gold solutions for the SFT (base-model)
//! phase and for measuring oracle response lengths.
//!
//! The CoT formats deliberately put the final answer at the END of the
//! response (`#<answer>` then EOS) — the paper's argument against
//! deterministic truncation rests on late tokens carrying answer formation,
//! and these renderers preserve that structure.

use crate::util::rng::Rng;

use super::gen::imod;
use super::{Kind, Task};

/// Render the gold chain-of-thought (without prompt, without EOS).
pub fn render_cot(task: &Task) -> String {
    match task.kind {
        Kind::Expr => render_expr_cot(task),
        Kind::Add => render_add_cot(task),
        Kind::Sort => render_sort_cot(task),
    }
}

fn render_expr_cot(task: &Task) -> String {
    let body = task.prompt.strip_prefix("e:").unwrap().strip_suffix('=').unwrap();
    let (chain, m) = body.rsplit_once('%').unwrap();
    let m: i64 = m.parse().unwrap();
    let mut operands: Vec<i64> = Vec::new();
    let mut ops: Vec<char> = Vec::new();
    let mut cur = String::new();
    for c in chain.chars() {
        if c.is_ascii_digit() {
            cur.push(c);
        } else {
            operands.push(cur.parse().unwrap());
            cur.clear();
            ops.push(c);
        }
    }
    operands.push(cur.parse().unwrap());
    let mut out = String::new();
    let mut acc = operands[0];
    for (i, &op) in ops.iter().enumerate() {
        let b = operands[i + 1];
        let next = match op {
            '+' => acc + b,
            '-' => acc - b,
            '*' => acc * b,
            _ => unreachable!(),
        };
        out.push_str(&format!("{acc}{op}{b}={next}\n"));
        acc = next;
    }
    let r = imod(acc, m);
    out.push_str(&format!("{acc}%{m}={r}\n#{r}"));
    out
}

fn render_add_cot(task: &Task) -> String {
    let body = task.prompt.strip_prefix("a:").unwrap().strip_suffix('=').unwrap();
    let (a, b) = body.split_once('+').unwrap();
    let (a, b): (i64, i64) = (a.parse().unwrap(), b.parse().unwrap());
    let (da, db) = (digits_rev(a), digits_rev(b));
    let mut out = String::new();
    let mut carry = 0i64;
    let n = da.len().max(db.len());
    for i in 0..n {
        let x = da.get(i).copied().unwrap_or(0);
        let y = db.get(i).copied().unwrap_or(0);
        let s = x + y + carry;
        out.push_str(&format!("{x}+{y}+{carry}={s}\n"));
        carry = s / 10;
    }
    out.push_str(&format!("#{}", a + b));
    out
}

fn digits_rev(mut x: i64) -> Vec<i64> {
    let mut out = Vec::new();
    while x > 0 {
        out.push(x % 10);
        x /= 10;
    }
    if out.is_empty() {
        out.push(0);
    }
    out
}

fn render_sort_cot(task: &Task) -> String {
    // Progressive selection sort: each line is the sorted prefix built so
    // far (short enough that Hard-tier 8-digit tasks fit the response
    // budget of the small config, yet still multi-step).
    let body = task.prompt.strip_prefix("s:").unwrap().strip_suffix('=').unwrap();
    let mut rest: Vec<char> = body.chars().collect();
    let mut out = String::new();
    let mut picked = String::new();
    while !rest.is_empty() {
        let (mi, &mc) = rest.iter().enumerate().min_by_key(|(_, c)| **c).unwrap();
        rest.remove(mi);
        picked.push(mc);
        out.push_str(&picked);
        out.push('\n');
    }
    out.push_str(&format!("#{picked}"));
    out
}

/// Corrupt a gold CoT with probability `noise`: the SFT corpus is
/// deliberately imperfect so the base model leaves headroom for RL (the
/// paper's base models are likewise not task-saturated).
pub fn maybe_corrupt(rng: &mut Rng, task: &Task, cot: &str, noise: f64) -> String {
    if !rng.bernoulli(noise) {
        return cot.to_string();
    }
    // Replace the final answer with a plausible wrong one (digit nudge).
    if let Some(pos) = cot.rfind('#') {
        let (head, ans) = cot.split_at(pos);
        let ans = &ans[1..];
        let wrong = nudge_answer(rng, ans);
        if wrong != ans {
            return format!("{head}#{wrong}");
        }
    }
    let _ = task;
    cot.to_string()
}

fn nudge_answer(rng: &mut Rng, ans: &str) -> String {
    let mut chars: Vec<char> = ans.chars().collect();
    if chars.is_empty() {
        return "0".into();
    }
    let i = rng.below(chars.len() as u64) as usize;
    if let Some(d) = chars[i].to_digit(10) {
        let nd = (d + 1 + rng.below(8) as u32) % 10;
        chars[i] = char::from_digit(nd, 10).unwrap();
    }
    chars.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::super::gen::{gen_task, tier_params};
    use super::super::{Kind, Tier};
    use super::*;
    use crate::tasks::verify::extract_answer;
    use crate::tokenizer::Tokenizer;

    #[test]
    fn cot_ends_with_correct_answer() {
        let mut rng = Rng::new(0);
        for tier in Tier::ALL {
            for kind in Kind::ALL {
                for i in 0..50 {
                    let t = gen_task(&mut rng, kind, tier, i);
                    let cot = render_cot(&t);
                    assert_eq!(extract_answer(&cot), Some(t.answer.clone()),
                        "{} -> {cot}", t.prompt);
                }
            }
        }
    }

    #[test]
    fn cot_is_tokenizable() {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(1);
        for tier in Tier::ALL {
            for kind in Kind::ALL {
                let t = gen_task(&mut rng, kind, tier, 0);
                assert!(tok.try_encode(&render_cot(&t)).is_some());
                assert!(tok.try_encode(&t.prompt).is_some());
            }
        }
    }

    #[test]
    fn cot_fits_response_budget_small_config() {
        // small/base configs have max_resp >= 128; CoTs must fit with EOS.
        let mut rng = Rng::new(2);
        let mut max_len = 0;
        for tier in Tier::ALL {
            for kind in Kind::ALL {
                for i in 0..200 {
                    let t = gen_task(&mut rng, kind, tier, i);
                    let len = render_cot(&t).chars().count() + 1; // + EOS
                    max_len = max_len.max(len);
                    assert!(len <= 127, "{} chars for {}", len, t.prompt);
                }
            }
        }
        assert!(max_len > 30, "suspiciously short CoTs: {max_len}");
    }

    #[test]
    fn corruption_changes_answers_at_high_noise() {
        let mut rng = Rng::new(3);
        let t = gen_task(&mut rng, Kind::Add, Tier::Easy, 0);
        let cot = render_cot(&t);
        let mut changed = 0;
        for _ in 0..100 {
            let c = maybe_corrupt(&mut rng, &t, &cot, 1.0);
            if extract_answer(&c) != Some(t.answer.clone()) {
                changed += 1;
            }
        }
        assert!(changed > 80, "{changed}");
        // zero noise never corrupts
        for _ in 0..20 {
            assert_eq!(maybe_corrupt(&mut rng, &t, &cot, 0.0), cot);
        }
    }

    #[test]
    fn hard_tier_cots_are_longer_on_average() {
        let mut rng = Rng::new(4);
        let mut avg = |tier| -> f64 {
            let mut s = 0usize;
            for i in 0..100u64 {
                let t = gen_task(&mut rng.fork(i), Kind::Sort, tier, i);
                s += render_cot(&t).len();
            }
            s as f64 / 100.0
        };
        assert!(avg(Tier::Hard) > avg(Tier::Easy) + 10.0);
        let _ = tier_params(Tier::Easy);
    }
}
