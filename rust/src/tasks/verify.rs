//! Exact-match verifier — the RLVR reward function.
//!
//! Rewards are computed on the FULL decoded response (never on the NAT-cut
//! prefix): the paper's framework changes only which tokens backpropagate,
//! not how rewards are produced.

use crate::tokenizer::Tokenizer;

use super::Task;

/// Extract the answer: text after the LAST '#', up to newline/end, trimmed.
pub fn extract_answer(response: &str) -> Option<String> {
    let pos = response.rfind('#')?;
    let tail = &response[pos + 1..];
    let ans: &str = tail.split('\n').next().unwrap_or("");
    let ans = ans.trim();
    if ans.is_empty() {
        None
    } else {
        Some(ans.to_string())
    }
}

/// Binary verifiable reward.
pub fn reward_text(task: &Task, response: &str) -> f32 {
    match extract_answer(response) {
        Some(a) if a == task.answer => 1.0,
        _ => 0.0,
    }
}

/// Decode response token ids (stops at EOS) and verify.
pub fn reward_tokens(tok: &Tokenizer, task: &Task, resp_ids: &[i32]) -> f32 {
    reward_text(task, &tok.decode(resp_ids))
}

#[cfg(test)]
mod tests {
    use super::super::{Kind, Tier};
    use super::*;
    use crate::tokenizer::EOS;

    fn task(ans: &str) -> Task {
        Task {
            id: 0,
            tier: Tier::Easy,
            kind: Kind::Expr,
            prompt: "e:1+1%5=".into(),
            answer: ans.into(),
        }
    }

    #[test]
    fn extracts_after_last_hash() {
        assert_eq!(extract_answer(""), None);
        assert_eq!(extract_answer("1+1=2\n#2"), Some("2".into()));
        assert_eq!(extract_answer("#3\nmore\n#7"), Some("7".into()));
        assert_eq!(extract_answer("#  42  "), Some("42".into()));
        assert_eq!(extract_answer("no marker"), None);
        assert_eq!(extract_answer("#"), None);
        assert_eq!(extract_answer("#12\ntrailing"), Some("12".into()));
    }

    #[test]
    fn reward_is_exact_match() {
        let t = task("7");
        assert_eq!(reward_text(&t, "steps\n#7"), 1.0);
        assert_eq!(reward_text(&t, "steps\n#17"), 0.0);
        assert_eq!(reward_text(&t, "steps\n# 7"), 1.0); // trimmed
        assert_eq!(reward_text(&t, "7"), 0.0); // needs the marker
    }

    #[test]
    fn reward_tokens_stops_at_eos() {
        let tok = Tokenizer::new();
        let t = task("2");
        let mut ids = tok.encode("#2");
        ids.push(EOS);
        ids.extend(tok.encode("#9")); // garbage after EOS must be ignored
        assert_eq!(reward_tokens(&tok, &t, &ids), 1.0);
    }

    #[test]
    fn empty_and_degenerate_responses() {
        let tok = Tokenizer::new();
        let t = task("2");
        assert_eq!(reward_tokens(&tok, &t, &[]), 0.0);
        assert_eq!(reward_tokens(&tok, &t, &[EOS]), 0.0);
    }
}
