//! Task instance generators, one per [`Kind`], parameterised by [`Tier`].

use crate::util::rng::Rng;

use super::{Kind, Task, Tier};

/// Difficulty knobs per tier.
#[derive(Clone, Copy, Debug)]
pub struct TierParams {
    /// Number of binary ops in an expression chain.
    pub expr_ops: usize,
    /// Max operand value in expression chains.
    pub expr_max: i64,
    /// Digits per addend.
    pub add_digits: usize,
    /// Number of digits to sort.
    pub sort_len: usize,
}

pub fn tier_params(tier: Tier) -> TierParams {
    match tier {
        Tier::Easy => TierParams { expr_ops: 2, expr_max: 9, add_digits: 2, sort_len: 4 },
        Tier::Medium => TierParams { expr_ops: 3, expr_max: 9, add_digits: 3, sort_len: 6 },
        Tier::Hard => TierParams { expr_ops: 4, expr_max: 12, add_digits: 4, sort_len: 8 },
    }
}

/// Evaluate a left-to-right chain: ((a0 op0 a1) op1 a2) ...
pub fn eval_chain(operands: &[i64], ops: &[char]) -> i64 {
    let mut acc = operands[0];
    for (i, &op) in ops.iter().enumerate() {
        let b = operands[i + 1];
        acc = match op {
            '+' => acc + b,
            '-' => acc - b,
            '*' => acc * b,
            _ => unreachable!("bad op {op}"),
        };
    }
    acc
}

/// Mathematical modulus (result always in [0, m)).
pub fn imod(x: i64, m: i64) -> i64 {
    ((x % m) + m) % m
}

pub fn gen_expr(rng: &mut Rng, tier: Tier, id: u64) -> Task {
    let p = tier_params(tier);
    loop {
        let n = p.expr_ops + 1;
        let operands: Vec<i64> =
            (0..n).map(|_| rng.range_inclusive(1, p.expr_max as u64) as i64).collect();
        let ops: Vec<char> = (0..p.expr_ops)
            .map(|_| *rng.choose(&['+', '-', '*']))
            .collect();
        // Keep intermediates small so CoT stays within the response budget
        // and the char-level model sees bounded digit counts.
        let mut acc = operands[0];
        let mut ok = true;
        for (i, &op) in ops.iter().enumerate() {
            let b = operands[i + 1];
            acc = match op {
                '+' => acc + b,
                '-' => acc - b,
                '*' => acc * b,
                _ => unreachable!(),
            };
            if acc.abs() > 999 {
                ok = false;
                break;
            }
        }
        if !ok {
            continue;
        }
        let m = rng.range_inclusive(5, 13) as i64;
        let result = imod(acc, m);
        let mut prompt = String::from("e:");
        for (i, v) in operands.iter().enumerate() {
            if i > 0 {
                prompt.push(ops[i - 1]);
            }
            prompt.push_str(&v.to_string());
        }
        prompt.push('%');
        prompt.push_str(&m.to_string());
        prompt.push('=');
        return Task { id, tier, kind: Kind::Expr, prompt, answer: result.to_string() };
    }
}

pub fn gen_add(rng: &mut Rng, tier: Tier, id: u64) -> Task {
    let p = tier_params(tier);
    let lo = 10i64.pow(p.add_digits as u32 - 1);
    let hi = 10i64.pow(p.add_digits as u32) - 1;
    let a = rng.range_inclusive(lo as u64, hi as u64) as i64;
    let b = rng.range_inclusive(lo as u64, hi as u64) as i64;
    Task {
        id,
        tier,
        kind: Kind::Add,
        prompt: format!("a:{a}+{b}="),
        answer: (a + b).to_string(),
    }
}

pub fn gen_sort(rng: &mut Rng, tier: Tier, id: u64) -> Task {
    let p = tier_params(tier);
    let digits: Vec<u8> = (0..p.sort_len).map(|_| rng.below(10) as u8).collect();
    let prompt: String = digits.iter().map(|d| (b'0' + d) as char).collect();
    let mut sorted = digits.clone();
    sorted.sort();
    let answer: String = sorted.iter().map(|d| (b'0' + d) as char).collect();
    Task { id, tier, kind: Kind::Sort, prompt: format!("s:{prompt}="), answer }
}

pub fn gen_task(rng: &mut Rng, kind: Kind, tier: Tier, id: u64) -> Task {
    match kind {
        Kind::Expr => gen_expr(rng, tier, id),
        Kind::Add => gen_add(rng, tier, id),
        Kind::Sort => gen_sort(rng, tier, id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_answer_is_correct_mod() {
        let mut rng = Rng::new(0);
        for i in 0..200 {
            let t = gen_expr(&mut rng, Tier::Hard, i);
            // re-parse the prompt and recompute
            let body = t.prompt.strip_prefix("e:").unwrap().strip_suffix('=').unwrap();
            let (chain, m) = body.rsplit_once('%').unwrap();
            let m: i64 = m.parse().unwrap();
            let mut operands = Vec::new();
            let mut ops = Vec::new();
            let mut cur = String::new();
            for c in chain.chars() {
                if c.is_ascii_digit() {
                    cur.push(c);
                } else {
                    operands.push(cur.parse::<i64>().unwrap());
                    cur.clear();
                    ops.push(c);
                }
            }
            operands.push(cur.parse().unwrap());
            let want = imod(eval_chain(&operands, &ops), m);
            assert_eq!(t.answer, want.to_string(), "{}", t.prompt);
            assert!((0..m).contains(&want));
        }
    }

    #[test]
    fn add_answer_is_sum() {
        let mut rng = Rng::new(1);
        for i in 0..100 {
            let t = gen_add(&mut rng, Tier::Medium, i);
            let body = t.prompt.strip_prefix("a:").unwrap().strip_suffix('=').unwrap();
            let (a, b) = body.split_once('+').unwrap();
            let want: i64 = a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap();
            assert_eq!(t.answer, want.to_string());
        }
    }

    #[test]
    fn sort_answer_is_sorted_multiset() {
        let mut rng = Rng::new(2);
        for i in 0..100 {
            let t = gen_sort(&mut rng, Tier::Hard, i);
            let body = t.prompt.strip_prefix("s:").unwrap().strip_suffix('=').unwrap();
            let mut digs: Vec<char> = body.chars().collect();
            digs.sort();
            assert_eq!(t.answer, digs.into_iter().collect::<String>());
            let mut sorted_chars: Vec<char> = t.answer.chars().collect();
            let is_sorted = sorted_chars.windows(2).all(|w| w[0] <= w[1]);
            assert!(is_sorted);
            sorted_chars.dedup();
        }
    }

    #[test]
    fn prompts_fit_the_smallest_prompt_window() {
        let mut rng = Rng::new(3);
        for tier in Tier::ALL {
            for kind in Kind::ALL {
                for i in 0..100 {
                    let t = gen_task(&mut rng, kind, tier, i);
                    assert!(t.prompt.len() <= 32, "{} ({:?})", t.prompt, tier);
                }
            }
        }
    }

    #[test]
    fn imod_is_nonnegative() {
        assert_eq!(imod(-3, 7), 4);
        assert_eq!(imod(10, 7), 3);
        assert_eq!(imod(-14, 7), 0);
    }

    #[test]
    fn difficulty_increases_with_tier() {
        let e = tier_params(Tier::Easy);
        let h = tier_params(Tier::Hard);
        assert!(h.expr_ops > e.expr_ops);
        assert!(h.add_digits > e.add_digits);
        assert!(h.sort_len > e.sort_len);
    }
}
