//! Dataset plumbing: training-prompt sampling, fixed eval sets, and the
//! SFT corpus builder for the base-model phase.

use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::{xor_stream, Rng};

use super::gen::gen_task;
use super::render::{maybe_corrupt, render_cot};
use super::{Kind, Task, Tier};

/// Mixture weights over (kind, tier) for training-prompt sampling.
#[derive(Clone, Debug)]
pub struct TaskMix {
    pub kinds: Vec<Kind>,
    pub tiers: Vec<Tier>,
}

impl Default for TaskMix {
    fn default() -> Self {
        TaskMix { kinds: Kind::ALL.to_vec(), tiers: Tier::ALL.to_vec() }
    }
}

/// Stream of fresh training tasks (the DAPO-17K stand-in: effectively
/// unbounded, sampled i.i.d. from the generator).
pub struct TaskSampler {
    rng: Rng,
    mix: TaskMix,
    next_id: u64,
}

impl TaskSampler {
    pub fn new(seed: u64, mix: TaskMix) -> Self {
        // Offset the stream so ids never collide with eval sets (eval ids
        // live in the top half of the u64 space).
        // natlint: allow(rng-discipline, reason = "callers pass an already-mixed seed (trainer::plan_step mixes via util::rng::stream_seed); mixing again here would double-hash the trainer stream")
        TaskSampler { rng: Rng::new(seed), mix, next_id: 0 }
    }

    pub fn next_task(&mut self) -> Task {
        let kind_idx = self.rng.below(self.mix.kinds.len() as u64) as usize;
        let tier_idx = self.rng.below(self.mix.tiers.len() as u64) as usize;
        let id = self.next_id;
        self.next_id += 1;
        gen_task(&mut self.rng, self.mix.kinds[kind_idx], self.mix.tiers[tier_idx], id)
    }

    pub fn batch(&mut self, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.next_task()).collect()
    }
}

/// Fixed, seed-determined evaluation set for one tier (MATH-S / AIME24-S /
/// AIME25-S). Uses a seed space disjoint from training samplers.
pub struct EvalSet {
    pub tier: Tier,
    pub tasks: Vec<Task>,
}

impl EvalSet {
    pub fn build(tier: Tier, n: usize, seed: u64) -> EvalSet {
        let mut rng = xor_stream(seed, 0xE7A1_5E7D_0000_0000);
        let kinds = Kind::ALL;
        let tasks = (0..n)
            .map(|i| {
                let kind = kinds[i % kinds.len()];
                gen_task(&mut rng, kind, tier, (1 << 63) | i as u64)
            })
            .collect();
        EvalSet { tier, tasks }
    }
}

/// Tokenised SFT example in the ROLLOUT layout: prompt left-padded into the
/// fixed prompt window, CoT + EOS following it, right-padded to seq_len.
/// SFT and RL therefore see identical RoPE positions and attention masks.
pub struct SftExample {
    pub tokens: Vec<i32>,
    /// Loss mask over predicted positions (len = tokens.len() - 1): 1.0 on
    /// response tokens (CoT + EOS), 0.0 on prompt and padding.
    pub loss_mask: Vec<f32>,
    /// Left-pad length of the prompt window.
    pub pad_len: usize,
}

/// SFT corpus with controlled label noise (see render::maybe_corrupt).
pub struct SftCorpus {
    pub examples: Vec<SftExample>,
    pub noise: f64,
}

impl SftCorpus {
    pub fn build(
        tok: &Tokenizer,
        n: usize,
        prompt_window: usize,
        seq_len: usize,
        noise: f64,
        seed: u64,
        mix: &TaskMix,
    ) -> SftCorpus {
        let mut rng = xor_stream(seed, 0x5F7C_0000_0000_0000);
        let mut examples = Vec::with_capacity(n);
        while examples.len() < n {
            let kind = mix.kinds[rng.below(mix.kinds.len() as u64) as usize];
            let tier = mix.tiers[rng.below(mix.tiers.len() as u64) as usize];
            let task = gen_task(&mut rng, kind, tier, examples.len() as u64);
            let cot = maybe_corrupt(&mut rng, &task, &render_cot(&task), noise);
            if let Some(ex) = Self::tokenize(tok, &task, &cot, prompt_window, seq_len) {
                examples.push(ex);
            }
        }
        SftCorpus { examples, noise }
    }

    fn tokenize(
        tok: &Tokenizer,
        task: &Task,
        cot: &str,
        prompt_window: usize,
        seq_len: usize,
    ) -> Option<SftExample> {
        let prompt_ids = tok.try_encode(&task.prompt)?;
        let cot_ids = tok.try_encode(cot)?;
        if prompt_ids.len() > prompt_window
            || prompt_window + cot_ids.len() + 1 > seq_len
        {
            return None;
        }
        let pad_len = prompt_window - prompt_ids.len();
        let mut tokens = vec![PAD; pad_len];
        tokens.extend_from_slice(&prompt_ids);
        debug_assert_eq!(tokens.len(), prompt_window);
        let resp_start = prompt_window; // responses always begin at P
        tokens.extend_from_slice(&cot_ids);
        tokens.push(EOS);
        let resp_end = tokens.len();
        tokens.resize(seq_len, PAD);
        // loss over predictions of positions 1..seq_len (shifted by one)
        let mut loss_mask = vec![0.0f32; seq_len - 1];
        for t in resp_start..resp_end {
            loss_mask[t - 1] = 1.0;
        }
        Some(SftExample { tokens, loss_mask, pad_len })
    }

    /// Pack examples into [B, seq_len] batches (tokens, loss mask, pad_len).
    pub fn batches(&self, batch: usize, rng: &mut Rng) -> Vec<(Vec<i32>, Vec<f32>, Vec<i32>)> {
        let mut order: Vec<usize> = (0..self.examples.len()).collect();
        rng.shuffle(&mut order);
        order
            .chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|chunk| {
                let seq_len = self.examples[0].tokens.len();
                let mut toks = Vec::with_capacity(batch * seq_len);
                let mut mask = Vec::with_capacity(batch * (seq_len - 1));
                let mut pads = Vec::with_capacity(batch);
                for &i in chunk {
                    toks.extend_from_slice(&self.examples[i].tokens);
                    mask.extend_from_slice(&self.examples[i].loss_mask);
                    pads.push(self.examples[i].pad_len as i32);
                }
                (toks, mask, pads)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::verify::reward_text;

    #[test]
    fn sampler_is_deterministic_per_seed() {
        let mix = TaskMix::default();
        let a: Vec<String> =
            TaskSampler::new(9, mix.clone()).batch(20).into_iter().map(|t| t.prompt).collect();
        let b: Vec<String> =
            TaskSampler::new(9, mix.clone()).batch(20).into_iter().map(|t| t.prompt).collect();
        assert_eq!(a, b);
        let c: Vec<String> =
            TaskSampler::new(10, mix).batch(20).into_iter().map(|t| t.prompt).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn eval_sets_are_fixed_and_tiered() {
        let e1 = EvalSet::build(Tier::Easy, 30, 1);
        let e2 = EvalSet::build(Tier::Easy, 30, 1);
        assert_eq!(e1.tasks.len(), 30);
        for (a, b) in e1.tasks.iter().zip(&e2.tasks) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.answer, b.answer);
        }
        assert!(e1.tasks.iter().all(|t| t.tier == Tier::Easy));
        // all three kinds represented
        for kind in Kind::ALL {
            assert!(e1.tasks.iter().any(|t| t.kind == kind));
        }
    }

    #[test]
    fn sft_examples_are_well_formed() {
        let tok = Tokenizer::new();
        let corpus = SftCorpus::build(&tok, 50, 48, 176, 0.0, 3, &TaskMix::default());
        assert_eq!(corpus.examples.len(), 50);
        for ex in &corpus.examples {
            assert_eq!(ex.tokens.len(), 176);
            assert_eq!(ex.loss_mask.len(), 175);
            // rollout layout: left pad, then prompt filling the window
            assert!(ex.tokens[..ex.pad_len].iter().all(|&t| t == PAD));
            assert_ne!(ex.tokens[ex.pad_len], PAD);
            assert!(ex.tokens.contains(&EOS));
            // response (and its loss) starts exactly at the prompt window
            assert_eq!(ex.loss_mask[..47].iter().filter(|&&m| m > 0.0).count(), 0);
            assert!(ex.loss_mask[47] > 0.0);
            // mask covers exactly the response span
            let n_masked = ex.loss_mask.iter().filter(|&&m| m > 0.0).count();
            assert!(n_masked > 5);
            // masked positions predict non-pad tokens
            for (i, &m) in ex.loss_mask.iter().enumerate() {
                if m > 0.0 {
                    assert_ne!(ex.tokens[i + 1], PAD);
                }
            }
        }
    }

    #[test]
    fn noiseless_corpus_decodes_to_correct_answers() {
        let tok = Tokenizer::new();
        let corpus = SftCorpus::build(&tok, 30, 48, 176, 0.0, 4, &TaskMix::default());
        // decode each example's response text; the '#answer' must be present
        for ex in &corpus.examples {
            let text = tok.decode(&ex.tokens);
            assert!(text.contains('#'), "{text}");
        }
        let _ = reward_text; // (full reward check exercised in render tests)
    }

    #[test]
    fn batches_have_fixed_shape_and_cover_corpus() {
        let tok = Tokenizer::new();
        let corpus = SftCorpus::build(&tok, 33, 32, 96, 0.1, 5, &TaskMix::default());
        let mut rng = Rng::new(0);
        let batches = corpus.batches(8, &mut rng);
        assert_eq!(batches.len(), 4); // 33 / 8 -> 4 full batches
        for (t, m, p) in &batches {
            assert_eq!(t.len(), 8 * 96);
            assert_eq!(m.len(), 8 * 95);
            assert_eq!(p.len(), 8);
        }
    }
}
