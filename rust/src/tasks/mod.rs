//! Synthetic verifiable math task suite — the reproduction's stand-in for
//! DAPO-Math-17K (training) and MATH / AIME24 / AIME25 (evaluation).
//!
//! Three task families with tiered difficulty produce prompts whose
//! solutions require multi-step chain-of-thought and admit an exact-match
//! verifier, preserving the RLVR structure the paper depends on
//! (full-response reward, response-length variability, late "answer
//! formation" tokens that deterministic truncation destroys).
//!
//! Benchmark naming (DESIGN.md §2): `MATH-S` = Easy, `AIME24-S` = Medium,
//! `AIME25-S` = Hard.

pub mod dataset;
pub mod gen;
pub mod render;
pub mod verify;

pub use dataset::{EvalSet, SftCorpus, TaskMix, TaskSampler};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tier {
    Easy,
    Medium,
    Hard,
}

impl Tier {
    pub const ALL: [Tier; 3] = [Tier::Easy, Tier::Medium, Tier::Hard];

    /// Paper-facing benchmark label.
    pub fn benchmark_name(self) -> &'static str {
        match self {
            Tier::Easy => "MATH-S",
            Tier::Medium => "AIME24-S",
            Tier::Hard => "AIME25-S",
        }
    }

    pub fn from_str(s: &str) -> Option<Tier> {
        match s {
            "easy" | "MATH-S" => Some(Tier::Easy),
            "medium" | "AIME24-S" => Some(Tier::Medium),
            "hard" | "AIME25-S" => Some(Tier::Hard),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    /// Left-to-right arithmetic chain with a final modulus:
    /// `e:3+5*2%7=` means ((3+5)*2) mod 7.
    Expr,
    /// Multi-digit addition: `a:372+85=`.
    Add,
    /// Digit sorting: `s:52961=`.
    Sort,
}

impl Kind {
    pub const ALL: [Kind; 3] = [Kind::Expr, Kind::Add, Kind::Sort];
}

/// One verifiable problem instance.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub tier: Tier,
    pub kind: Kind,
    /// Prompt text, e.g. "e:3+5*2%7=". Encoded and LEFT-padded by the
    /// rollout scheduler.
    pub prompt: String,
    /// Canonical answer string the verifier matches exactly.
    pub answer: String,
}
