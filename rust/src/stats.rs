//! Statistics substrate: streaming moments, Student-t 95% CIs, bootstrap.
//!
//! Every paper table/figure reports mean ± 95% CI across 5 runs; this module
//! provides exactly that aggregation (plus bootstrap CIs for pass@k, whose
//! per-run distribution is far from normal at small n).

use crate::util::rng::Rng;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Two-sided 95% Student-t critical values for df = 1..=30 (then normal).
const T95: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

pub fn t95(df: u64) -> f64 {
    if df == 0 {
        f64::INFINITY
    } else if df <= 30 {
        T95[(df - 1) as usize]
    } else {
        1.96
    }
}

/// Mean and 95% CI half-width of a sample (the paper's `x ± ci` cells).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanCi {
    pub mean: f64,
    pub ci95: f64,
    pub n: u64,
}

impl MeanCi {
    pub fn of(xs: &[f64]) -> MeanCi {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        let df = w.count().saturating_sub(1);
        MeanCi {
            mean: w.mean(),
            ci95: if df == 0 { 0.0 } else { t95(df) * w.sem() },
            n: w.count(),
        }
    }

    /// The paper's CI-overlap colouring heuristic (Table 2).
    pub fn overlaps(&self, other: &MeanCi) -> bool {
        (self.mean - other.mean).abs() <= self.ci95 + other.ci95
    }
}

impl std::fmt::Display for MeanCi {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}±{:.3}", self.mean, self.ci95)
    }
}

/// Percentile-bootstrap 95% CI of the mean.
pub fn bootstrap_ci(xs: &[f64], resamples: usize, rng: &mut Rng) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut means: Vec<f64> = (0..resamples)
        .map(|_| {
            let mut s = 0.0;
            for _ in 0..xs.len() {
                s += xs[rng.below(xs.len() as u64) as usize];
            }
            s / xs.len() as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = means[(resamples as f64 * 0.025) as usize];
    let hi = means[((resamples as f64 * 0.975) as usize).min(resamples - 1)];
    (lo, hi)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 4.0;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn welford_degenerate() {
        let mut w = Welford::new();
        assert_eq!(w.var(), 0.0);
        w.push(3.0);
        assert_eq!(w.var(), 0.0);
        assert_eq!(w.mean(), 3.0);
    }

    #[test]
    fn t_table_monotone_and_tails() {
        assert!(t95(1) > t95(2));
        assert!((t95(4) - 2.776).abs() < 1e-9); // 5 runs => df 4, the paper's case
        assert!((t95(1000) - 1.96).abs() < 1e-9);
        assert!(t95(0).is_infinite());
    }

    #[test]
    fn mean_ci_of_five_runs() {
        let xs = [0.61, 0.60, 0.62, 0.59, 0.63];
        let ci = MeanCi::of(&xs);
        assert!((ci.mean - 0.61).abs() < 1e-12);
        assert!(ci.ci95 > 0.0 && ci.ci95 < 0.05);
        assert_eq!(ci.n, 5);
    }

    #[test]
    fn overlap_heuristic() {
        let a = MeanCi { mean: 0.5, ci95: 0.05, n: 5 };
        let b = MeanCi { mean: 0.56, ci95: 0.02, n: 5 };
        let c = MeanCi { mean: 0.60, ci95: 0.02, n: 5 };
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
    }

    #[test]
    fn bootstrap_brackets_mean() {
        let xs: Vec<f64> = (0..100).map(|i| (i % 10) as f64).collect();
        let mut rng = Rng::new(0);
        let (lo, hi) = bootstrap_ci(&xs, 500, &mut rng);
        let m = mean(&xs);
        assert!(lo <= m && m <= hi, "{lo} {m} {hi}");
        assert!(hi - lo < 2.0);
    }
}
