use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::tasks::{Kind, TaskMix, Tier};
use crate::util::json::Json;
use crate::util::tomlite;

/// NAT token-selection strategy (paper §3-4). Each variant names a
/// [`Selector`](crate::coordinator::selection::Selector) implementation in
/// `coordinator::selection`; the enum is only the *configuration* of a
/// scheme, the sampling logic lives in the per-scheme modules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Vanilla GRPO: every response token backpropagates.
    Grpo,
    /// Uniform Random Sampling: Bernoulli(p) per token, HT weight 1/p.
    Urs { p: f64 },
    /// Deterministic prefix truncation (biased baseline): keep first frac.
    DetTrunc { frac: f64 },
    /// Random Prefix Cutting: L ~ Uniform({min_cut..T}), HT weights 1/p_t.
    Rpc { min_cut: usize },
    /// Information-aware selection (paper §7 future work, implemented):
    /// inclusion probability p_t = floor + (1-floor) * normalized behaviour
    /// surprisal, HT-corrected. Allocates compute to high-information
    /// tokens; forward savings only past the last scored token (like URS).
    Saliency { floor: f64 },
    /// Systematic (stratified) sampling at rate p: one uniform grid offset
    /// per sequence fixes the realized sample size to ⌊p·T⌋ or ⌈p·T⌉, so the
    /// per-token marginal inclusion stays exactly p (HT weight 1/p) while
    /// the selected-count variance collapses versus URS — at *lower* host
    /// cost (one RNG draw per sequence instead of T).
    Stratified { p: f64 },
    /// Length-aware Poisson sampling: independent Bernoulli with per-token
    /// rate min(1, k / T), so every sequence contributes ~k selected tokens
    /// regardless of length (long CoTs are thinned harder), HT weight T/k.
    Poisson { k: usize },
}

impl Method {
    /// `sal_floor` is the dedicated saliency-floor argument; `None` falls
    /// back to the deprecated legacy spelling that overloaded the URS `p`
    /// slot (still accepted — callers print the deprecation note).
    pub fn parse(
        name: &str,
        p: f64,
        frac: f64,
        min_cut: usize,
        sal_floor: Option<f64>,
        k: usize,
    ) -> Result<Method> {
        Ok(match name {
            "grpo" | "full" => Method::Grpo,
            "urs" => Method::Urs { p },
            "det" | "det_trunc" => Method::DetTrunc { frac },
            "rpc" => Method::Rpc { min_cut },
            "saliency" | "sal" => Method::Saliency { floor: sal_floor.unwrap_or(p) },
            "stratified" | "strat" => Method::Stratified { p },
            "poisson" => Method::Poisson { k },
            other => bail!(
                "unknown method '{other}' \
                 (grpo|urs|det_trunc|rpc|saliency|stratified|poisson)"
            ),
        })
    }

    pub fn label(&self) -> String {
        match self {
            Method::Grpo => "GRPO".into(),
            Method::Urs { p } => format!("URS(p={p})"),
            Method::DetTrunc { frac } => format!("DetTrunc({frac})"),
            Method::Rpc { min_cut } => format!("RPC(C={min_cut})"),
            Method::Saliency { floor } => format!("SAL(floor={floor})"),
            Method::Stratified { p } => format!("STRAT(p={p})"),
            Method::Poisson { k } => format!("POI(k={k})"),
        }
    }

    /// Short id used in file names.
    pub fn id(&self) -> &'static str {
        match self {
            Method::Grpo => "grpo",
            Method::Urs { .. } => "urs",
            Method::DetTrunc { .. } => "det",
            Method::Rpc { .. } => "rpc",
            Method::Saliency { .. } => "sal",
            Method::Stratified { .. } => "strat",
            Method::Poisson { .. } => "poisson",
        }
    }
}

/// Micro-batch packing strategy (`coordinator::batcher`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Packer {
    /// Legacy layout: every micro-batch allocates exactly `batch_train`
    /// rows in its sequence bucket — the parity/compat mode. Bit-identical
    /// to the pre-budget-packer trainer for prefix methods (GRPO, DetTrunc,
    /// RPC); URS/Saliency bucket routing changed with the tighter
    /// `learn_len`, so their runs are estimator- but not bit-equivalent.
    Fixed,
    /// Cost-based token-budget packing into the 2-D (sequence bucket ×
    /// row bucket) artifact grid; minimises padded-token waste under
    /// `rows × (P + bucket) <= train.token_budget`.
    Budget,
}

impl Packer {
    pub fn parse(name: &str) -> Result<Packer> {
        Ok(match name {
            "fixed" => Packer::Fixed,
            "budget" => Packer::Budget,
            other => bail!("unknown packer '{other}' (fixed|budget)"),
        })
    }

    pub fn id(&self) -> &'static str {
        match self {
            Packer::Fixed => "fixed",
            Packer::Budget => "budget",
        }
    }
}

/// Rollout scheduling engine (`coordinator::rollout::scheduler`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutEngine {
    /// Legacy path: every generate call runs the full `batch_rollout ×
    /// (P + max_resp)` window with one scalar seed drawn per chunk, and
    /// tail chunks are padded with duplicate rows. Kept selectable for
    /// parity with pre-scheduler runs.
    Fixed,
    /// Length-bucketed continuous batching: prompts are routed into the
    /// shortest viable `generate_T<b>` artifact by an EMA response-length
    /// predictor, finished rows are refilled with pending slots instead of
    /// duplicate padding, and overflow rows escalate to the next bucket.
    /// Per-slot RNG seeds derive from `(seed, step, flat_id)`, so rollout
    /// output is a pure function of the plan — bit-identical across batch
    /// sizes, bucket routing, and refill interleavings.
    Bucketed,
}

impl RolloutEngine {
    pub fn parse(name: &str) -> Result<RolloutEngine> {
        Ok(match name {
            "fixed" => RolloutEngine::Fixed,
            "bucketed" => RolloutEngine::Bucketed,
            other => bail!("unknown rollout engine '{other}' (fixed|bucketed)"),
        })
    }

    pub fn id(&self) -> &'static str {
        match self {
            RolloutEngine::Fixed => "fixed",
            RolloutEngine::Bucketed => "bucketed",
        }
    }
}

/// Rollout configuration (`--rollout.*`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RolloutCfg {
    /// Engine selection. `Bucketed` (default) falls back to the fixed path
    /// when the artifact set predates the `generate_buckets` grid.
    pub engine: RolloutEngine,
    /// Shared-prefix prefill cache (default on): prefill each distinct
    /// `(param version, prompt)` once and decode group siblings from the
    /// cached KV block. Requires the manifest's prefill/decode split —
    /// without it the scheduler silently keeps fused generate. Cache on/off
    /// is bit-identical by contract; only cost changes.
    pub prefix_cache: bool,
    /// Prefix-cache byte budget in MiB (LRU-evicted above it). 0 is legal:
    /// every entry is oversized and the engine degrades to per-call
    /// prefill.
    pub cache_mb: usize,
}

impl Default for RolloutCfg {
    fn default() -> Self {
        RolloutCfg { engine: RolloutEngine::Bucketed, prefix_cache: true, cache_mb: 64 }
    }
}

/// Batch-level adaptive token-budget controller
/// (`coordinator::selection::budget`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetMode {
    /// Selection keep-parameters are whatever the method literal says
    /// (URS `p`, RPC `min_cut`, ...) — the legacy, bit-exact behaviour.
    None,
    /// Per optimizer step, the controller re-solves the method's keep
    /// parameter from the batch's actual response lengths so the *expected*
    /// selected-token count hits `--train.token_budget`, recomputing the
    /// inclusion probabilities (and with them the HT weights) so the
    /// estimator stays exactly unbiased.
    Batch,
    /// Variance-optimal (Neyman) allocation: per-sequence systematic
    /// sampling rates proportional to an estimated contribution scale
    /// (|advantage| × RMS behaviour surprisal), clamped into
    /// `[pi_floor, 1]` and re-solved each step so the expected selected
    /// count hits `--train.token_budget` — minimizing HT-estimator variance
    /// at equal budget (`coordinator::selection::neyman`).
    Neyman,
}

impl BudgetMode {
    pub fn parse(name: &str) -> Result<BudgetMode> {
        Ok(match name {
            "none" => BudgetMode::None,
            "batch" => BudgetMode::Batch,
            "neyman" => BudgetMode::Neyman,
            other => bail!("unknown budget mode '{other}' (none|batch|neyman)"),
        })
    }

    pub fn id(&self) -> &'static str {
        match self {
            BudgetMode::None => "none",
            BudgetMode::Batch => "batch",
            BudgetMode::Neyman => "neyman",
        }
    }
}

/// Learner batching configuration (`--train.*`).
/// (`Eq` is off: `pi_floor` is an f64 threshold, compared via `PartialEq`.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainCfg {
    pub packer: Packer,
    /// Under `budget_mode = none` (default): max allocated learner tokens
    /// per micro-batch, `rows × (P + bucket)`; 0 = auto, the fixed packer's
    /// allocation `batch_train × (P + top bucket)`; only consulted by the
    /// budget packer. Under `budget_mode = batch` the SAME flag is
    /// repurposed as the batch-level expected selected-token target the
    /// selection controller solves for (the packer then runs on its auto
    /// cap) and must be > 0.
    pub token_budget: usize,
    /// Batch-level adaptive budget controller (`--train.budget_mode`).
    pub budget_mode: BudgetMode,
    /// Low-probability guard (`--train.pi_floor`, default 1e-3): every
    /// budget-*solved* inclusion probability is clamped to at least this
    /// value at selection time, so realized 1/π HT weights are bounded by
    /// `1/pi_floor` by construction and no single rare token can dominate a
    /// step. Sampling uses the floored probability, so the estimator stays
    /// exactly HT-unbiased. 0 disables the guard (legacy tiny clamps).
    /// Only budget-solved selectors are floored — `budget_mode none` keeps
    /// the method literal's bit-exact legacy behaviour, and RPC's
    /// prefix-survival weights are bounded by `t_i − C + 1` without it.
    pub pi_floor: f64,
    /// Auto-tune the sequence-bucket routing edges from an EMA histogram of
    /// observed `learn_len` (`coordinator::bucket_tuner`). Budget packer
    /// only. The tuner's EMA state is serialized into resumable checkpoints
    /// (`TrainMeta`), so `--resume` continuations reproduce the
    /// uninterrupted run's routing exactly.
    pub auto_buckets: bool,
    /// Data-parallel learner shards: each optimizer step's packed
    /// micro-batches are split across this many concurrent grad workers and
    /// recombined with a fixed-order tree reduction keyed by micro-batch id
    /// (`runtime::shard`). Because the reduction order is a pure function of
    /// the step plan, `shards = K` is bit-identical to `shards = 1` for
    /// every K. 1 = the single-threaded learn stage.
    pub shards: usize,
    /// Gather-compacted grad layout: when true (default), the budget packer
    /// may re-key a micro-batch by KEPT-token count instead of prefix
    /// length, routing scattered selection plans (URS/stratified/Poisson/
    /// saliency) into the `grad_K<k>_B<r>` artifact family whenever that is
    /// strictly cheaper. Prefix-shaped plans (GRPO/DetTrunc/RPC) always
    /// stay on the legacy grid, so those runs are bit-identical under
    /// either setting. Requires the manifest's `grad_compact` grid (absent
    /// → the packer silently keeps everything on the prefix grid).
    pub compact: bool,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            packer: Packer::Budget,
            token_budget: 0,
            budget_mode: BudgetMode::None,
            pi_floor: 1e-3,
            auto_buckets: false,
            shards: 1,
            compact: true,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RlCfg {
    /// Task tiers sampled during training (tiny configs: easy only — the
    /// hard tiers' CoTs do not fit its 64-token response window).
    pub tiers: Vec<Tier>,
    pub steps: usize,
    /// Prompts per optimizer step; each gets `group_size` rollouts.
    pub prompts_per_step: usize,
    /// G — group size for group-relative advantages.
    pub group_size: usize,
    pub temperature: f32,
    /// Optimizer epochs over each rollout batch (DAPO-style mini-batching;
    /// epochs >= 2 exercise the off-policy clipping path, ratio != 1).
    pub ppo_epochs: usize,
    /// Write a resumable checkpoint (params + opt state + step) every this
    /// many optimizer steps; 0 disables mid-run checkpointing.
    pub ckpt_every: usize,
}

/// Async rollout/learner pipeline configuration (`coordinator::pipeline`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PipelineCfg {
    /// Rollout worker threads. 0 = serial trainer (pipeline disabled);
    /// 1 = pipelined but synchronous (bit-identical to serial — the
    /// validation mode); >= 2 = overlapped rollout and learning.
    pub workers: usize,
    /// Bounded queue capacity: completed rollout groups buffered ahead of
    /// the learner before producers block.
    pub queue_depth: usize,
    /// Maximum optimizer-step lag allowed between the parameter snapshot a
    /// group was rolled out with and the parameters at consume time. The
    /// PPO clipped ratio corrects slightly-off-policy data, so 1 is the
    /// classic one-step pipeline. Forced to 0 when workers <= 1.
    pub max_staleness: u64,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg { workers: 0, queue_depth: 2, max_staleness: 1 }
    }
}

/// Observability configuration (`--obs.*`) — see `crate::obs`.
///
/// Tracing is strictly observational: with both paths empty the trainer
/// holds a no-op `Tracer` and takes the `None` branch before any clock
/// read or allocation, so golden traces and param hashes are bit-identical
/// to a build that never heard of tracing. `ledger` only gates whether the
/// per-step savings ledger is *exported* as Recorder series — the ledger
/// itself is always computed (it is deterministic and feeds `StepStats`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsCfg {
    /// NDJSON trace output path; empty = tracing off.
    pub trace: String,
    /// Chrome-trace (chrome://tracing / Perfetto) output path; empty = off.
    pub chrome: String,
    /// Export the savings ledger as Recorder series (`gen_tokens`,
    /// `flop_saving`, ...).
    pub ledger: bool,
}

impl Default for ObsCfg {
    fn default() -> Self {
        ObsCfg { trace: String::new(), chrome: String::new(), ledger: true }
    }
}

#[derive(Clone, Debug)]
pub struct PretrainCfg {
    pub steps: usize,
    pub corpus_size: usize,
    /// Label-noise rate of the SFT corpus (leaves RL headroom).
    pub noise: f64,
}

#[derive(Clone, Debug)]
pub struct EvalCfg {
    pub every: usize,
    pub tasks_per_tier: usize,
    /// k for Acc@k / pass@k (paper: 16).
    pub k: usize,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub artifacts_dir: String,
    pub results_dir: String,
    pub checkpoints_dir: String,
    pub method: Method,
    pub seed: u64,
    pub rl: RlCfg,
    pub rollout: RolloutCfg,
    pub train: TrainCfg,
    pub pretrain: PretrainCfg,
    pub eval: EvalCfg,
    pub pipeline: PipelineCfg,
    pub obs: ObsCfg,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
            checkpoints_dir: "checkpoints".into(),
            method: Method::Rpc { min_cut: 8 },
            seed: 0,
            rl: RlCfg {
                tiers: Tier::ALL.to_vec(),
                steps: 60,
                prompts_per_step: 2,
                group_size: 8,
                temperature: 1.0,
                ppo_epochs: 1,
                ckpt_every: 0,
            },
            rollout: RolloutCfg::default(),
            train: TrainCfg::default(),
            pretrain: PretrainCfg { steps: 300, corpus_size: 2048, noise: 0.25 },
            eval: EvalCfg { every: 0, tasks_per_tier: 16, k: 16 },
            pipeline: PipelineCfg::default(),
            obs: ObsCfg::default(),
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file over the defaults.
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let table = tomlite::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let mut cfg = RunConfig::default();
        let get = |sec: &str, key: &str| -> Option<&Json> {
            table.get(sec).and_then(|m| m.get(key))
        };
        if let Some(v) = get("", "model").or(get("run", "model")) {
            cfg.model = v.as_str().ok_or_else(|| anyhow!("model must be a string"))?.into();
        }
        if let Some(v) = get("run", "seed") {
            cfg.seed = v.as_i64().ok_or_else(|| anyhow!("seed"))? as u64;
        }
        for (key, slot) in [
            ("artifacts_dir", &mut cfg.artifacts_dir),
            ("results_dir", &mut cfg.results_dir),
            ("checkpoints_dir", &mut cfg.checkpoints_dir),
        ] {
            if let Some(v) = table.get("").and_then(|m| m.get(key)) {
                *slot = v.as_str().ok_or_else(|| anyhow!("{key} must be a string"))?.into();
            }
        }
        // method
        let name = get("method", "name").and_then(Json::as_str).unwrap_or("rpc");
        let p = get("method", "p").and_then(Json::as_f64).unwrap_or(0.5);
        let frac = get("method", "frac").and_then(Json::as_f64).unwrap_or(0.5);
        let min_cut = get("method", "min_cut").and_then(Json::as_usize).unwrap_or(8);
        // The saliency floor has its own key ([rl] sal_floor, or
        // [method] sal_floor); the legacy spelling overloading `p` is still
        // accepted with a deprecation note.
        let sal_floor = get("rl", "sal_floor")
            .and_then(Json::as_f64)
            .or_else(|| get("method", "sal_floor").and_then(Json::as_f64));
        let k = get("method", "k").and_then(Json::as_usize).unwrap_or(8);
        if matches!(name, "saliency" | "sal")
            && sal_floor.is_none()
            && get("method", "p").is_some()
        {
            eprintln!(
                "note: [method] p as the saliency floor is deprecated; \
                 use sal_floor ([rl] or [method] section)"
            );
        }
        cfg.method = Method::parse(name, p, frac, min_cut, sal_floor, k)?;
        // rl / pretrain / eval sections
        macro_rules! setnum {
            ($sec:literal, $key:literal, $slot:expr, $ty:ty) => {
                if let Some(v) = get($sec, $key).and_then(Json::as_f64) {
                    $slot = v as $ty;
                }
            };
        }
        if let Some(arr) = get("rl", "tiers").and_then(Json::as_arr) {
            cfg.rl.tiers = arr
                .iter()
                .filter_map(Json::as_str)
                .filter_map(Tier::from_str)
                .collect();
            if cfg.rl.tiers.is_empty() {
                bail!("rl.tiers resolved to an empty list");
            }
        }
        setnum!("rl", "steps", cfg.rl.steps, usize);
        setnum!("rl", "prompts_per_step", cfg.rl.prompts_per_step, usize);
        setnum!("rl", "group_size", cfg.rl.group_size, usize);
        setnum!("rl", "temperature", cfg.rl.temperature, f32);
        setnum!("rl", "ppo_epochs", cfg.rl.ppo_epochs, usize);
        setnum!("rl", "ckpt_every", cfg.rl.ckpt_every, usize);
        if let Some(name) = get("rollout", "engine").and_then(Json::as_str) {
            cfg.rollout.engine = RolloutEngine::parse(name)?;
        }
        if let Some(b) = get("rollout", "prefix_cache").and_then(Json::as_bool) {
            cfg.rollout.prefix_cache = b;
        }
        setnum!("rollout", "cache_mb", cfg.rollout.cache_mb, usize);
        if let Some(name) = get("train", "packer").and_then(Json::as_str) {
            cfg.train.packer = Packer::parse(name)?;
        }
        if let Some(name) = get("train", "budget_mode").and_then(Json::as_str) {
            cfg.train.budget_mode = BudgetMode::parse(name)?;
        }
        setnum!("train", "token_budget", cfg.train.token_budget, usize);
        setnum!("train", "pi_floor", cfg.train.pi_floor, f64);
        setnum!("train", "shards", cfg.train.shards, usize);
        if let Some(b) = get("train", "auto_buckets").and_then(Json::as_bool) {
            cfg.train.auto_buckets = b;
        }
        if let Some(b) = get("train", "compact").and_then(Json::as_bool) {
            cfg.train.compact = b;
        }
        setnum!("pipeline", "workers", cfg.pipeline.workers, usize);
        setnum!("pipeline", "queue_depth", cfg.pipeline.queue_depth, usize);
        setnum!("pipeline", "max_staleness", cfg.pipeline.max_staleness, u64);
        setnum!("pretrain", "steps", cfg.pretrain.steps, usize);
        setnum!("pretrain", "corpus_size", cfg.pretrain.corpus_size, usize);
        setnum!("pretrain", "noise", cfg.pretrain.noise, f64);
        setnum!("eval", "every", cfg.eval.every, usize);
        setnum!("eval", "tasks_per_tier", cfg.eval.tasks_per_tier, usize);
        setnum!("eval", "k", cfg.eval.k, usize);
        if let Some(v) = get("obs", "trace").and_then(Json::as_str) {
            cfg.obs.trace = v.into();
        }
        if let Some(v) = get("obs", "chrome").and_then(Json::as_str) {
            cfg.obs.chrome = v.into();
        }
        if let Some(b) = get("obs", "ledger").and_then(Json::as_bool) {
            cfg.obs.ledger = b;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply a single `--key value` override (dotted path) and re-validate.
    /// Transactional: a failed parse OR a failed validation leaves `self`
    /// untouched (the new cross-field invariants made the old
    /// mutate-then-validate order observable: a rejected key must not leave
    /// the config in the state it just rejected).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let mut next = self.clone();
        next.set_unvalidated(key, value)?;
        next.validate()?;
        *self = next;
        Ok(())
    }

    /// The override itself, without validation: `from_args` applies the
    /// whole override set through this and validates ONCE at the end, so
    /// cross-field invariants (e.g. `budget_mode batch` needs a positive
    /// `token_budget`) cannot fail on an intermediate state — the options
    /// map iterates in alphabetical, not command-line, order.
    fn set_unvalidated(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.into(),
            "seed" => self.seed = value.parse()?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "results_dir" => self.results_dir = value.into(),
            "checkpoints_dir" => self.checkpoints_dir = value.into(),
            "method" => {
                self.method = Method::parse(
                    value,
                    self.method_p(),
                    self.method_frac(),
                    self.method_min_cut(),
                    self.method_sal_floor(),
                    self.method_k(),
                )?
            }
            "method.p" => match self.method {
                Method::Urs { ref mut p } | Method::Stratified { ref mut p } => {
                    *p = value.parse()?;
                }
                // Legacy spelling: --method.p used to double as the saliency
                // floor. Still accepted, with a note.
                Method::Saliency { ref mut floor } => {
                    eprintln!(
                        "note: --method.p as the saliency floor is deprecated; \
                         use --rl.sal_floor"
                    );
                    *floor = value.parse()?;
                }
                _ => self.method = Method::Urs { p: value.parse()? },
            },
            "method.frac" => {
                if let Method::DetTrunc { ref mut frac } = self.method {
                    *frac = value.parse()?;
                } else {
                    self.method = Method::DetTrunc { frac: value.parse()? };
                }
            }
            "method.min_cut" => {
                if let Method::Rpc { ref mut min_cut } = self.method {
                    *min_cut = value.parse()?;
                } else {
                    self.method = Method::Rpc { min_cut: value.parse()? };
                }
            }
            "rl.tiers" => {
                let tiers: Vec<Tier> =
                    value.split(',').filter_map(|t| Tier::from_str(t.trim())).collect();
                if tiers.is_empty() {
                    bail!("--rl.tiers '{value}': no valid tiers (easy|medium|hard)");
                }
                self.rl.tiers = tiers;
            }
            "rl.steps" => self.rl.steps = value.parse()?,
            "rl.prompts_per_step" => self.rl.prompts_per_step = value.parse()?,
            "rl.group_size" => self.rl.group_size = value.parse()?,
            "rl.temperature" => self.rl.temperature = value.parse()?,
            "rl.ppo_epochs" => self.rl.ppo_epochs = value.parse()?,
            "rl.ckpt_every" => self.rl.ckpt_every = value.parse()?,
            "method.k" => {
                if let Method::Poisson { ref mut k } = self.method {
                    *k = value.parse()?;
                } else {
                    self.method = Method::Poisson { k: value.parse()? };
                }
            }
            // The saliency floor's dedicated flag (issue satellite): the new
            // spelling lives beside the other RL hyperparameters;
            // `method.sal_floor` is the `[method]`-section alias and
            // `method.floor` the pre-existing spelling.
            "rl.sal_floor" | "method.sal_floor" | "method.floor" => {
                if let Method::Saliency { ref mut floor } = self.method {
                    *floor = value.parse()?;
                } else {
                    self.method = Method::Saliency { floor: value.parse()? };
                }
            }
            "rollout.engine" => self.rollout.engine = RolloutEngine::parse(value)?,
            "rollout.prefix_cache" => {
                self.rollout.prefix_cache = match value {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("--rollout.prefix_cache '{other}' (true|false)"),
                }
            }
            "rollout.cache_mb" => self.rollout.cache_mb = value.parse()?,
            "train.packer" => self.train.packer = Packer::parse(value)?,
            "train.budget_mode" => self.train.budget_mode = BudgetMode::parse(value)?,
            "train.token_budget" => self.train.token_budget = value.parse()?,
            "train.pi_floor" => self.train.pi_floor = value.parse()?,
            "train.shards" => self.train.shards = value.parse()?,
            "train.auto_buckets" => {
                self.train.auto_buckets = match value {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("--train.auto_buckets '{other}' (true|false)"),
                }
            }
            "train.compact" => {
                self.train.compact = match value {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("--train.compact '{other}' (true|false)"),
                }
            }
            "pipeline.workers" => self.pipeline.workers = value.parse()?,
            "pipeline.queue_depth" => self.pipeline.queue_depth = value.parse()?,
            "pipeline.max_staleness" => self.pipeline.max_staleness = value.parse()?,
            "pretrain.steps" => self.pretrain.steps = value.parse()?,
            "pretrain.corpus_size" => self.pretrain.corpus_size = value.parse()?,
            "pretrain.noise" => self.pretrain.noise = value.parse()?,
            "eval.every" => self.eval.every = value.parse()?,
            "eval.tasks_per_tier" => self.eval.tasks_per_tier = value.parse()?,
            "eval.k" => self.eval.k = value.parse()?,
            "obs.trace" => self.obs.trace = value.into(),
            "obs.chrome" => self.obs.chrome = value.into(),
            "obs.ledger" => {
                self.obs.ledger = match value {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("--obs.ledger '{other}' (true|false)"),
                }
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    fn method_p(&self) -> f64 {
        match self.method {
            Method::Urs { p } | Method::Stratified { p } => p,
            _ => 0.5,
        }
    }

    fn method_sal_floor(&self) -> Option<f64> {
        match self.method {
            Method::Saliency { floor } => Some(floor),
            _ => None,
        }
    }

    fn method_k(&self) -> usize {
        match self.method {
            Method::Poisson { k } => k,
            _ => 8,
        }
    }

    fn method_frac(&self) -> f64 {
        match self.method {
            Method::DetTrunc { frac } => frac,
            _ => 0.5,
        }
    }

    fn method_min_cut(&self) -> usize {
        match self.method {
            Method::Rpc { min_cut } => min_cut,
            _ => 8,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.rl.group_size < 2 {
            bail!("group_size must be >= 2 for group-relative advantages");
        }
        if self.rl.prompts_per_step == 0 || self.rl.steps == 0 {
            bail!("rl.steps and rl.prompts_per_step must be positive");
        }
        if let Method::Urs { p } = self.method {
            if !(0.0 < p && p <= 1.0) {
                bail!("URS p must be in (0, 1], got {p}");
            }
        }
        if let Method::DetTrunc { frac } = self.method {
            if !(0.0 < frac && frac <= 1.0) {
                bail!("DetTrunc frac must be in (0, 1], got {frac}");
            }
        }
        if let Method::Saliency { floor } = self.method {
            if !(0.0 < floor && floor <= 1.0) {
                bail!("Saliency floor must be in (0, 1], got {floor}");
            }
        }
        if let Method::Stratified { p } = self.method {
            if !(0.0 < p && p <= 1.0) {
                bail!("Stratified p must be in (0, 1], got {p}");
            }
        }
        if let Method::Poisson { k } = self.method {
            if k == 0 {
                bail!("Poisson k must be >= 1");
            }
        }
        if matches!(self.train.budget_mode, BudgetMode::Batch | BudgetMode::Neyman) {
            let mode = self.train.budget_mode.id();
            if self.train.token_budget == 0 {
                bail!(
                    "train.budget_mode {mode} needs a positive --train.token_budget \
                     (the expected selected-token target)"
                );
            }
            // The fixed-cost baselines have no keep parameter to solve —
            // accepting them would silently ignore the configured budget.
            if matches!(self.method, Method::Grpo | Method::DetTrunc { .. }) {
                bail!(
                    "train.budget_mode {mode} cannot adapt {}: it has no keep \
                     parameter to solve (use urs|stratified|poisson|rpc|saliency)",
                    self.method.label()
                );
            }
        }
        // cache_mb = 0 is legal (graceful degrade to uncached prefill);
        // only absurd budgets are rejected — 64 GiB already exceeds any
        // host this runs on and catches unit mistakes (bytes vs MiB).
        if self.rollout.cache_mb > 65536 {
            bail!("rollout.cache_mb {} is over the 65536 MiB cap", self.rollout.cache_mb);
        }
        if !(0.0..=0.5).contains(&self.train.pi_floor) {
            bail!(
                "train.pi_floor must be in [0, 0.5] (0 disables the guard), got {}",
                self.train.pi_floor
            );
        }
        if self.rl.ppo_epochs == 0 {
            bail!("rl.ppo_epochs must be >= 1");
        }
        if self.pipeline.queue_depth == 0 {
            bail!("pipeline.queue_depth must be >= 1");
        }
        if self.train.shards == 0 || self.train.shards > 64 {
            bail!("train.shards must be in 1..=64, got {}", self.train.shards);
        }
        if self.pipeline.workers > 64 {
            bail!("pipeline.workers {} is unreasonable (max 64)", self.pipeline.workers);
        }
        Ok(())
    }

    /// Path the trainer's periodic mid-run checkpoint is written to
    /// (and `--resume` typically reads from).
    pub fn rolling_ckpt_path(&self) -> String {
        format!(
            "{}/{}_{}_s{}_auto.bin",
            self.checkpoints_dir,
            self.model,
            self.method.id(),
            self.seed
        )
    }

    pub fn artifact_dir(&self) -> std::path::PathBuf {
        Path::new(&self.artifacts_dir).join(&self.model)
    }

    /// Task mixture for training and pretraining.
    pub fn task_mix(&self) -> TaskMix {
        TaskMix { kinds: Kind::ALL.to_vec(), tiers: self.rl.tiers.clone() }
    }

    /// Build from `--config file` plus dotted CLI overrides. Keys consumed
    /// by subcommands themselves (ckpt/out/what/fig/seeds/verbose) are
    /// skipped here.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<RunConfig> {
        let mut cfg = match args.get("config") {
            Some(path) => RunConfig::from_file(Path::new(path))?,
            None => RunConfig::default(),
        };
        const SKIP: [&str; 9] =
            ["config", "ckpt", "out", "what", "fig", "seeds", "bench-json", "resume", "min-cut"];
        for (k, v) in &args.options {
            if SKIP.contains(&k.as_str()) {
                continue;
            }
            // Per-key application without validation: the options map
            // iterates alphabetically, so cross-field invariants (like
            // budget_mode ↔ token_budget) must only be checked once the
            // whole override set is in.
            cfg.set_unvalidated(k, v)
                .map_err(|e| anyhow!("applying override --{k} {v}: {e}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("grpo", 0.5, 0.5, 8, None, 8).unwrap(), Method::Grpo);
        assert_eq!(Method::parse("urs", 0.3, 0.5, 8, None, 8).unwrap(), Method::Urs { p: 0.3 });
        assert_eq!(
            Method::parse("det_trunc", 0.5, 0.4, 8, None, 8).unwrap(),
            Method::DetTrunc { frac: 0.4 }
        );
        assert_eq!(
            Method::parse("rpc", 0.5, 0.5, 100, None, 8).unwrap(),
            Method::Rpc { min_cut: 100 }
        );
        assert_eq!(
            Method::parse("stratified", 0.25, 0.5, 8, None, 8).unwrap(),
            Method::Stratified { p: 0.25 }
        );
        assert_eq!(
            Method::parse("poisson", 0.5, 0.5, 8, None, 12).unwrap(),
            Method::Poisson { k: 12 }
        );
        assert!(Method::parse("nope", 0.5, 0.5, 8, None, 8).is_err());
    }

    #[test]
    fn saliency_floor_prefers_dedicated_flag_over_legacy_p() {
        // New spelling wins when both are present...
        assert_eq!(
            Method::parse("saliency", 0.5, 0.5, 8, Some(0.2), 8).unwrap(),
            Method::Saliency { floor: 0.2 }
        );
        // ...and the legacy p-overload still works without it.
        assert_eq!(
            Method::parse("sal", 0.35, 0.5, 8, None, 8).unwrap(),
            Method::Saliency { floor: 0.35 }
        );
        let mut cfg = RunConfig::default();
        cfg.set("rl.sal_floor", "0.4").unwrap();
        assert_eq!(cfg.method, Method::Saliency { floor: 0.4 });
        cfg.set("method.sal_floor", "0.3").unwrap();
        assert_eq!(cfg.method, Method::Saliency { floor: 0.3 });
        // deprecated spelling mutates the floor in place instead of
        // switching the method to URS
        cfg.set("method.p", "0.25").unwrap();
        assert_eq!(cfg.method, Method::Saliency { floor: 0.25 });
        assert!(cfg.set("rl.sal_floor", "1.5").is_err());
    }

    #[test]
    fn new_selector_methods_parse_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.set("method", "stratified").unwrap();
        assert_eq!(cfg.method, Method::Stratified { p: 0.5 });
        cfg.set("method.p", "0.2").unwrap();
        assert_eq!(cfg.method, Method::Stratified { p: 0.2 });
        assert!(cfg.set("method.p", "1.5").is_err());
        cfg.set("method", "poisson").unwrap();
        assert_eq!(cfg.method, Method::Poisson { k: 8 });
        cfg.set("method.k", "16").unwrap();
        assert_eq!(cfg.method, Method::Poisson { k: 16 });
        assert!(cfg.set("method.k", "0").is_err());
        assert_eq!(Method::Stratified { p: 0.2 }.id(), "strat");
        assert_eq!(Method::Poisson { k: 16 }.id(), "poisson");
    }

    #[test]
    fn budget_mode_overrides_and_validation() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.train.budget_mode, BudgetMode::None);
        // batch mode without a target is a config error, and the failed set
        // is transactional — the rejected state must not stick
        assert!(cfg.set("train.budget_mode", "batch").is_err());
        assert_eq!(cfg.train.budget_mode, BudgetMode::None);
        // with a target set, batch mode is accepted
        cfg.set("train.token_budget", "512").unwrap();
        cfg.set("train.budget_mode", "batch").unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::Batch);
        // the fixed-cost baselines have nothing to solve: rejected, and the
        // config stays on its previous (valid) method
        assert!(cfg.set("method", "grpo").is_err());
        assert!(cfg.set("method", "det_trunc").is_err());
        assert_eq!(cfg.method, RunConfig::default().method);
        cfg.set("train.budget_mode", "none").unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::None);
        cfg.set("method", "grpo").unwrap();
        assert!(cfg.set("train.budget_mode", "bogus").is_err());
        assert_eq!(BudgetMode::Batch.id(), "batch");
        assert_eq!(BudgetMode::None.id(), "none");
        assert_eq!(BudgetMode::Neyman.id(), "neyman");
    }

    #[test]
    fn neyman_mode_and_pi_floor_overrides_and_validation() {
        let mut cfg = RunConfig::default();
        // pi_floor guard defaults on
        assert_eq!(cfg.train.pi_floor, 1e-3);
        // neyman mode shares batch's cross-field invariants: needs a target
        assert!(cfg.set("train.budget_mode", "neyman").is_err());
        assert_eq!(cfg.train.budget_mode, BudgetMode::None);
        cfg.set("train.token_budget", "512").unwrap();
        cfg.set("train.budget_mode", "neyman").unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::Neyman);
        // ...and rejects the fixed-cost baselines
        assert!(cfg.set("method", "grpo").is_err());
        assert!(cfg.set("method", "det_trunc").is_err());
        // pi_floor range: [0, 0.5], 0 = guard off
        cfg.set("train.pi_floor", "0.01").unwrap();
        assert_eq!(cfg.train.pi_floor, 0.01);
        cfg.set("train.pi_floor", "0").unwrap();
        assert_eq!(cfg.train.pi_floor, 0.0);
        assert!(cfg.set("train.pi_floor", "0.9").is_err());
        assert!(cfg.set("train.pi_floor", "-0.1").is_err());
        assert_eq!(cfg.train.pi_floor, 0.0);
    }

    #[test]
    fn neyman_and_pi_floor_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_neyman_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("n.toml");
        std::fs::write(
            &path,
            "[train]\nbudget_mode = \"neyman\"\ntoken_budget = 640\npi_floor = 0.005\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::Neyman);
        assert_eq!(cfg.train.token_budget, 640);
        assert_eq!(cfg.train.pi_floor, 0.005);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn budget_mode_is_order_independent_from_the_cli() {
        // Regression: `args.options` is a BTreeMap, so "train.budget_mode"
        // is always applied before "train.token_budget" regardless of the
        // flag order the user typed — from_args must therefore validate the
        // cross-field invariant only after ALL overrides are in.
        let argv: Vec<String> =
            ["train", "--train.budget_mode", "batch", "--train.token_budget", "4096"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = crate::util::cli::Args::parse(&argv).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::Batch);
        assert_eq!(cfg.train.token_budget, 4096);
        // ...while a genuinely inconsistent override set still fails.
        let argv: Vec<String> = ["train", "--train.budget_mode", "batch"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = crate::util::cli::Args::parse(&argv).unwrap();
        assert!(RunConfig::from_args(&args).is_err());
    }

    #[test]
    fn budget_mode_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_budget_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.toml");
        std::fs::write(&path, "[train]\nbudget_mode = \"batch\"\ntoken_budget = 640\n").unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.train.budget_mode, BudgetMode::Batch);
        assert_eq!(cfg.train.token_budget, 640);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn sal_floor_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_salfloor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.toml");
        std::fs::write(
            &path,
            "[method]\nname = \"saliency\"\np = 0.9\n[rl]\nsal_floor = 0.15\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.method, Method::Saliency { floor: 0.15 });
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn overrides() {
        let mut cfg = RunConfig::default();
        cfg.set("model", "base").unwrap();
        cfg.set("method", "urs").unwrap();
        cfg.set("method.p", "0.25").unwrap();
        cfg.set("rl.steps", "120").unwrap();
        assert_eq!(cfg.model, "base");
        assert_eq!(cfg.method, Method::Urs { p: 0.25 });
        assert_eq!(cfg.rl.steps, 120);
        assert!(cfg.set("bogus.key", "1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        let mut cfg = RunConfig::default();
        assert!(cfg.set("method.p", "1.5").is_err());
        assert!(cfg.set("rl.group_size", "1").is_err());
    }

    #[test]
    fn tier_overrides() {
        let mut cfg = RunConfig::default();
        cfg.set("rl.tiers", "easy").unwrap();
        assert_eq!(cfg.rl.tiers, vec![Tier::Easy]);
        cfg.set("rl.tiers", "easy, hard").unwrap();
        assert_eq!(cfg.rl.tiers, vec![Tier::Easy, Tier::Hard]);
        assert!(cfg.set("rl.tiers", "bogus").is_err());
    }

    #[test]
    fn train_packer_overrides_and_parsing() {
        let mut cfg = RunConfig::default();
        // budget packing is the default; fixed remains selectable for parity
        assert_eq!(
            cfg.train,
            TrainCfg {
                packer: Packer::Budget,
                token_budget: 0,
                budget_mode: BudgetMode::None,
                pi_floor: 1e-3,
                auto_buckets: false,
                shards: 1,
                compact: true
            }
        );
        cfg.set("train.packer", "fixed").unwrap();
        assert_eq!(cfg.train.packer, Packer::Fixed);
        cfg.set("train.packer", "budget").unwrap();
        cfg.set("train.token_budget", "4096").unwrap();
        cfg.set("train.auto_buckets", "true").unwrap();
        assert_eq!(cfg.train.token_budget, 4096);
        assert!(cfg.train.auto_buckets);
        assert!(cfg.set("train.packer", "bogus").is_err());
        assert!(cfg.set("train.auto_buckets", "maybe").is_err());
        // compacted grad layout: on by default, switchable both ways
        assert!(cfg.train.compact);
        cfg.set("train.compact", "false").unwrap();
        assert!(!cfg.train.compact);
        cfg.set("train.compact", "on").unwrap();
        assert!(cfg.train.compact);
        assert!(cfg.set("train.compact", "maybe").is_err());
    }

    #[test]
    fn train_compact_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_compact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.toml");
        std::fs::write(&path, "[train]\ncompact = false\n").unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert!(!cfg.train.compact);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn train_shards_overrides_and_validation() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.train.shards, 1, "single-threaded learn stage is the default");
        cfg.set("train.shards", "4").unwrap();
        assert_eq!(cfg.train.shards, 4);
        assert!(cfg.set("train.shards", "0").is_err());
        assert!(cfg.set("train.shards", "65").is_err());
        assert!(cfg.set("train.shards", "many").is_err());
    }

    #[test]
    fn train_shards_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_shards_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.toml");
        std::fs::write(&path, "[train]\nshards = 3\n").unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.train.shards, 3);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rollout_engine_overrides_and_parsing() {
        let mut cfg = RunConfig::default();
        // bucketed scheduling + prefix cache on are the defaults; fixed
        // remains the parity mode
        assert_eq!(
            cfg.rollout,
            RolloutCfg { engine: RolloutEngine::Bucketed, prefix_cache: true, cache_mb: 64 }
        );
        cfg.set("rollout.engine", "fixed").unwrap();
        assert_eq!(cfg.rollout.engine, RolloutEngine::Fixed);
        cfg.set("rollout.engine", "bucketed").unwrap();
        assert_eq!(cfg.rollout.engine, RolloutEngine::Bucketed);
        assert!(cfg.set("rollout.engine", "bogus").is_err());
        assert_eq!(RolloutEngine::Fixed.id(), "fixed");
        assert_eq!(RolloutEngine::Bucketed.id(), "bucketed");
    }

    #[test]
    fn rollout_prefix_cache_flags() {
        let mut cfg = RunConfig::default();
        cfg.set("rollout.prefix_cache", "off").unwrap();
        assert!(!cfg.rollout.prefix_cache);
        cfg.set("rollout.prefix_cache", "true").unwrap();
        assert!(cfg.rollout.prefix_cache);
        assert!(cfg.set("rollout.prefix_cache", "maybe").is_err());
        cfg.set("rollout.cache_mb", "128").unwrap();
        assert_eq!(cfg.rollout.cache_mb, 128);
        assert!(cfg.set("rollout.cache_mb", "lots").is_err());
        // 0 is valid (graceful degrade); absurd budgets are not
        cfg.set("rollout.cache_mb", "0").unwrap();
        cfg.validate().unwrap();
        cfg.set("rollout.cache_mb", "70000").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rollout_section_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_rollout_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.toml");
        std::fs::write(
            &path,
            "[rollout]\nengine = \"fixed\"\nprefix_cache = false\ncache_mb = 16\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.rollout.engine, RolloutEngine::Fixed);
        assert!(!cfg.rollout.prefix_cache);
        assert_eq!(cfg.rollout.cache_mb, 16);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn train_section_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_train_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.toml");
        std::fs::write(
            &path,
            "[train]\npacker = \"budget\"\ntoken_budget = 2048\nauto_buckets = true\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.train.packer, Packer::Budget);
        assert_eq!(cfg.train.token_budget, 2048);
        assert!(cfg.train.auto_buckets);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn pipeline_overrides_and_validation() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.pipeline, PipelineCfg { workers: 0, queue_depth: 2, max_staleness: 1 });
        cfg.set("pipeline.workers", "2").unwrap();
        cfg.set("pipeline.queue_depth", "4").unwrap();
        cfg.set("pipeline.max_staleness", "3").unwrap();
        cfg.set("rl.ckpt_every", "10").unwrap();
        assert_eq!(cfg.pipeline.workers, 2);
        assert_eq!(cfg.pipeline.queue_depth, 4);
        assert_eq!(cfg.pipeline.max_staleness, 3);
        assert_eq!(cfg.rl.ckpt_every, 10);
        assert!(cfg.set("pipeline.queue_depth", "0").is_err());
        assert!(cfg.set("pipeline.workers", "1000").is_err());
    }

    #[test]
    fn pipeline_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_pipe_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.toml");
        std::fs::write(
            &path,
            "[pipeline]\nworkers = 3\nqueue_depth = 5\nmax_staleness = 2\n\
             [rl]\nckpt_every = 25\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.pipeline.workers, 3);
        assert_eq!(cfg.pipeline.queue_depth, 5);
        assert_eq!(cfg.pipeline.max_staleness, 2);
        assert_eq!(cfg.rl.ckpt_every, 25);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn obs_overrides_and_defaults() {
        let mut cfg = RunConfig::default();
        // tracing is off by default; ledger series are on by default
        assert_eq!(cfg.obs, ObsCfg { trace: String::new(), chrome: String::new(), ledger: true });
        cfg.set("obs.trace", "out/t.ndjson").unwrap();
        cfg.set("obs.chrome", "out/t.json").unwrap();
        cfg.set("obs.ledger", "false").unwrap();
        assert_eq!(cfg.obs.trace, "out/t.ndjson");
        assert_eq!(cfg.obs.chrome, "out/t.json");
        assert!(!cfg.obs.ledger);
        cfg.set("obs.ledger", "on").unwrap();
        assert!(cfg.obs.ledger);
        assert!(cfg.set("obs.ledger", "maybe").is_err());
    }

    #[test]
    fn obs_from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("o.toml");
        std::fs::write(
            &path,
            "[obs]\ntrace = \"run.ndjson\"\nchrome = \"run.chrome.json\"\nledger = false\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.obs.trace, "run.ndjson");
        assert_eq!(cfg.obs.chrome, "run.chrome.json");
        assert!(!cfg.obs.ledger);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rolling_ckpt_path_is_run_scoped() {
        let mut cfg = RunConfig::default();
        cfg.model = "small".into();
        cfg.seed = 9;
        assert_eq!(cfg.rolling_ckpt_path(), "checkpoints/small_rpc_s9_auto.bin");
    }

    #[test]
    fn from_file() {
        let dir = std::env::temp_dir().join("nat_rl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.toml");
        std::fs::write(
            &path,
            "model = \"small\"\n[method]\nname = \"rpc\"\nmin_cut = 16\n\
             [rl]\nsteps = 42\ngroup_size = 4\n[pretrain]\nnoise = 0.3\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.model, "small");
        assert_eq!(cfg.method, Method::Rpc { min_cut: 16 });
        assert_eq!(cfg.rl.steps, 42);
        assert_eq!(cfg.rl.group_size, 4);
        assert_eq!(cfg.pretrain.noise, 0.3);
        let _ = std::fs::remove_dir_all(dir);
    }
}
