//! Run configuration: NAT method selection + RL/pretrain/eval hyperparameters.
//!
//! Layered like a real launcher: built-in defaults ← `configs/*.toml` file
//! ← command-line `--key value` overrides (see `util::cli` and main.rs).

mod run;

pub use run::{
    BudgetMode, EvalCfg, Method, ObsCfg, Packer, PipelineCfg, PretrainCfg, RlCfg, RolloutCfg,
    RolloutEngine, RunConfig, TrainCfg,
};
