//! # NAT-RL: Not All Tokens are Needed — token-efficient reinforcement learning
//!
//! Production-shaped reproduction of "Not All Tokens are Needed (NAT):
//! Token-Efficient Reinforcement Learning" (Sang et al., 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the RL coordinator: rollout scheduling, verifiable
//!   rewards, group-relative advantages, NAT token selection with
//!   Horvitz-Thompson reweighting, length-bucketed batching, gradient
//!   accumulation and optimiser stepping, evaluation, and the experiment
//!   harness regenerating every paper table and figure.
//! * **L2 (python/compile/model.py)** — the policy transformer and train
//!   computations, AOT-lowered to HLO text once per config.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the NAT loss and
//!   flash attention, fused into the same HLO.
//!
//! Python never runs at training time: the coordinator drives the AOT
//! artifacts through PJRT (`runtime` module).
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod golden;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod stats;
pub mod tasks;
pub mod tokenizer;
pub mod util;
