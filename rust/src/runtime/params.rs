//! Parameter / optimiser-state stores and gradient accumulation.
//!
//! Parameters live in ONE contiguous host `Vec<f32>` in manifest order
//! (exactly the layout of `artifacts/<cfg>/init_params.bin` and of
//! checkpoints), and are sliced into per-tensor literals at call time.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::bucket_tuner::TunerState;
use crate::model::Manifest;
use crate::util::json::{arr_f64, obj, Json};

#[derive(Clone, Debug)]
pub struct ParamStore {
    pub flat: Vec<f32>,
}

impl ParamStore {
    /// Load the python-initialised parameters shipped with the artifacts.
    pub fn load_init(manifest: &Manifest) -> Result<ParamStore> {
        let path = manifest.dir.join("init_params.bin");
        Self::from_bin(&path, manifest.param_count)
    }

    pub fn from_bin(path: &Path, expect: usize) -> Result<ParamStore> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != expect * 4 {
            bail!("{}: {} bytes, expected {}", path.display(), bytes.len(), expect * 4);
        }
        let flat = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(ParamStore { flat })
    }

    pub fn zeros_like(manifest: &Manifest) -> ParamStore {
        ParamStore { flat: vec![0.0; manifest.param_count] }
    }

    /// Per-tensor literals in manifest order.
    pub fn to_literals(&self, manifest: &Manifest) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let slice = &self.flat[p.offset..p.offset + p.size];
            let lit = xla::Literal::vec1(slice);
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            out.push(if dims.len() == 1 { lit } else { lit.reshape(&dims)? });
        }
        Ok(out)
    }

    /// Overwrite from per-tensor output literals (apply/pretrain results).
    pub fn from_literals(&mut self, manifest: &Manifest, lits: &[xla::Literal]) -> Result<()> {
        if lits.len() != manifest.params.len() {
            bail!("expected {} tensors, got {}", manifest.params.len(), lits.len());
        }
        for (p, lit) in manifest.params.iter().zip(lits) {
            let v: Vec<f32> = lit.to_vec()?;
            if v.len() != p.size {
                bail!("tensor {}: got {} elems, expected {}", p.name, v.len(), p.size);
            }
            self.flat[p.offset..p.offset + p.size].copy_from_slice(&v);
        }
        Ok(())
    }

    pub fn l2_norm(&self) -> f64 {
        // natlint: allow(float-accum, reason = "left-to-right f64 sum over one contiguous slice — the order is the slice order, a pure function of the layout, never of K or scheduling")
        self.flat.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Adam moments + step counter.
#[derive(Clone, Debug)]
pub struct OptState {
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: u64,
}

impl OptState {
    pub fn zeros(manifest: &Manifest) -> OptState {
        OptState {
            m: ParamStore::zeros_like(manifest),
            v: ParamStore::zeros_like(manifest),
            step: 0,
        }
    }
}

/// Host-side gradient accumulator across micro-batches.
#[derive(Clone, Debug)]
pub struct GradAccum {
    pub flat: Vec<f32>,
    pub sequences: usize,
}

impl GradAccum {
    pub fn zeros(param_count: usize) -> GradAccum {
        GradAccum { flat: vec![0.0; param_count], sequences: 0 }
    }

    pub fn reset(&mut self) {
        self.flat.iter_mut().for_each(|x| *x = 0.0);
        self.sequences = 0;
    }

    /// Add one micro-batch's per-tensor gradient literals in place.
    pub fn add_literals(
        &mut self,
        manifest: &Manifest,
        lits: &[xla::Literal],
        real_rows: usize,
    ) -> Result<()> {
        if lits.len() < manifest.params.len() {
            bail!("grad output too short: {}", lits.len());
        }
        for (p, lit) in manifest.params.iter().zip(lits) {
            let v: Vec<f32> = lit.to_vec()?;
            if v.len() != p.size {
                bail!("grad tensor {}: {} elems, expected {}", p.name, v.len(), p.size);
            }
            let dst = &mut self.flat[p.offset..p.offset + p.size];
            for (d, s) in dst.iter_mut().zip(&v) {
                *d += *s;
            }
        }
        self.sequences += real_rows;
        Ok(())
    }

    /// Element-wise combine with another accumulator — the reduction
    /// operator of the sharded learner's fixed-order tree
    /// (`runtime::shard::tree_reduce_into`).
    pub fn merge(&mut self, other: &GradAccum) {
        debug_assert_eq!(self.flat.len(), other.flat.len());
        for (d, s) in self.flat.iter_mut().zip(&other.flat) {
            *d += *s;
        }
        self.sequences += other.sequences;
    }

    /// 1 / sequences — the `scale` fed to the apply artifact.
    pub fn scale(&self) -> f32 {
        if self.sequences == 0 {
            0.0
        } else {
            1.0 / self.sequences as f32
        }
    }
}

/// Mid-run training state carried by a resumable checkpoint. All per-step
/// random streams are pure functions of `(seed, step)` (see
/// `coordinator::trainer::plan_step`), so the optimizer-step counter plus
/// the run seed is the complete RNG state. The one piece of cross-step
/// learner state that is NOT derivable from `(seed, step)` — the
/// `--train.auto_buckets` tuner's EMA histogram — rides along explicitly,
/// so resumed runs reproduce the uninterrupted routing exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainMeta {
    /// Completed optimizer steps.
    pub step: u64,
    /// The run seed the streams were derived from.
    pub seed: u64,
    /// `BucketTuner` EMA state at checkpoint time (None when the run does
    /// not use `--train.auto_buckets`).
    pub tuner: Option<TunerState>,
    /// `--train.shards` at checkpoint time. Informational: the sharded
    /// learner's reduction order is derived from the step plan, not from
    /// the shard count, so resuming under a different K is exact.
    pub shards: usize,
}

/// Checkpoint = params (+ optional opt state) + JSON sidecar.
pub struct Checkpoint;

impl Checkpoint {
    pub fn save(
        path: &Path,
        manifest: &Manifest,
        params: &ParamStore,
        opt: Option<&OptState>,
    ) -> Result<()> {
        Self::save_impl(path, manifest, params, opt, None)
    }

    /// Save a resumable mid-run checkpoint: params + optimizer state + the
    /// training step / seed needed to continue the exact run.
    pub fn save_train(
        path: &Path,
        manifest: &Manifest,
        params: &ParamStore,
        opt: &OptState,
        meta: &TrainMeta,
    ) -> Result<()> {
        Self::save_impl(path, manifest, params, Some(opt), Some(meta))
    }

    fn save_impl(
        path: &Path,
        manifest: &Manifest,
        params: &ParamStore,
        opt: Option<&OptState>,
        train: Option<&TrainMeta>,
    ) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut bytes: Vec<u8> = Vec::with_capacity(params.flat.len() * 4);
        for &x in &params.flat {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        if let Some(o) = opt {
            for store in [&o.m, &o.v] {
                for &x in &store.flat {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        std::fs::write(path, &bytes)?;
        let mut fields = vec![
            ("model", Json::Str(manifest.dims.name.clone())),
            ("param_count", Json::Num(manifest.param_count as f64)),
            ("has_opt", Json::Bool(opt.is_some())),
            ("opt_step", Json::Num(opt.map(|o| o.step).unwrap_or(0) as f64)),
        ];
        if let Some(t) = train {
            fields.push(("train_step", Json::Num(t.step as f64)));
            // Decimal string: a u64 seed does not survive an f64 JSON number
            // round-trip above 2^53.
            fields.push(("run_seed", Json::Str(t.seed.to_string())));
            fields.push(("train_shards", Json::Num(t.shards as f64)));
            if let Some(ts) = &t.tuner {
                // f64 values round-trip exactly: the JSON writer uses Rust's
                // shortest-roundtrip Display for non-integral floats.
                fields.push(("tuner_hist", arr_f64(&ts.hist)));
                fields.push(("tuner_items_per_step", Json::Num(ts.items_per_step)));
                fields.push(("tuner_alpha", Json::Num(ts.alpha)));
                fields.push(("tuner_steps", Json::Num(ts.steps as f64)));
            }
        }
        let meta = obj(fields);
        std::fs::write(path.with_extension("json"), meta.to_string())?;
        Ok(())
    }

    pub fn load(
        path: &Path,
        manifest: &Manifest,
    ) -> Result<(ParamStore, Option<OptState>)> {
        let (params, opt, _) = Self::load_full(path, manifest)?;
        Ok((params, opt))
    }

    /// Load a checkpoint including its training state, if present
    /// (checkpoints written by `save` have none — they load as fresh runs).
    pub fn load_full(
        path: &Path,
        manifest: &Manifest,
    ) -> Result<(ParamStore, Option<OptState>, Option<TrainMeta>)> {
        let meta_text = std::fs::read_to_string(path.with_extension("json"))
            .with_context(|| format!("checkpoint sidecar for {}", path.display()))?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow::anyhow!(e))?;
        let n = meta.get("param_count").and_then(Json::as_usize).unwrap_or(0);
        if n != manifest.param_count {
            bail!(
                "checkpoint is for {} params, manifest has {} (model {} vs {})",
                n,
                manifest.param_count,
                meta.get("model").and_then(Json::as_str).unwrap_or("?"),
                manifest.dims.name
            );
        }
        let has_opt = matches!(meta.get("has_opt"), Some(Json::Bool(true)));
        let bytes = std::fs::read(path)?;
        let expect = if has_opt { 3 * n * 4 } else { n * 4 };
        if bytes.len() != expect {
            bail!("checkpoint size {} != expected {expect}", bytes.len());
        }
        let read_store = |off: usize| -> ParamStore {
            ParamStore {
                flat: bytes[off..off + n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            }
        };
        let params = read_store(0);
        let opt = if has_opt {
            Some(OptState {
                m: read_store(n * 4),
                v: read_store(2 * n * 4),
                step: meta.get("opt_step").and_then(Json::as_i64).unwrap_or(0) as u64,
            })
        } else {
            None
        };
        let seed = meta.get("run_seed").and_then(|v| match v {
            Json::Str(s) => s.parse::<u64>().ok(),
            _ => v.as_i64().map(|x| x as u64),
        });
        let tuner = meta.get("tuner_hist").and_then(Json::as_arr).map(|a| TunerState {
            hist: a.iter().filter_map(Json::as_f64).collect(),
            items_per_step: meta
                .get("tuner_items_per_step")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            alpha: meta.get("tuner_alpha").and_then(Json::as_f64).unwrap_or(0.2),
            steps: meta.get("tuner_steps").and_then(Json::as_i64).unwrap_or(0) as u64,
        });
        let train = meta.get("train_step").and_then(Json::as_i64).map(|step| TrainMeta {
            step: step as u64,
            seed: seed.unwrap_or(0),
            tuner,
            // Legacy checkpoints predate the sharded learner: treat them as
            // written by the single-threaded learn stage.
            shards: meta.get("train_shards").and_then(Json::as_usize).unwrap_or(1),
        });
        Ok((params, opt, train))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn toy_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
          "config": {"name":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
            "d_ff":8,"prompt_len":4,"max_resp":8,"buckets":[4,8],
            "batch_rollout":2,"batch_train":2,"pretrain_len":12,
            "batch_pretrain":2,"lr":0.001,"clip_eps":0.2,"grad_clip":1.0,
            "pretrain_lr":0.001},
          "param_count": 40,
          "params": [
            {"name":"embed","shape":[8,4],"size":32,"offset":0},
            {"name":"head","shape":[4,2],"size":8,"offset":32}],
          "artifacts": {"generate":"g.txt","apply":"a.txt","pretrain":"p.txt",
            "grad":{"4":"g4.txt","8":"g8.txt"},"score":{"8":"s8.txt"}}
        }"#,
        )
        .unwrap();
        Manifest::from_json(Path::new("/tmp"), &j).unwrap()
    }

    #[test]
    fn literals_roundtrip() {
        let m = toy_manifest();
        let mut ps = ParamStore::zeros_like(&m);
        for (i, x) in ps.flat.iter_mut().enumerate() {
            *x = i as f32 * 0.5;
        }
        let lits = ps.to_literals(&m).unwrap();
        assert_eq!(lits.len(), 2);
        let mut ps2 = ParamStore::zeros_like(&m);
        ps2.from_literals(&m, &lits).unwrap();
        assert_eq!(ps.flat, ps2.flat);
    }

    #[test]
    fn grad_accum_sums_and_scales() {
        let m = toy_manifest();
        let mut acc = GradAccum::zeros(m.param_count);
        let mut ps = ParamStore::zeros_like(&m);
        ps.flat.iter_mut().for_each(|x| *x = 2.0);
        let lits = ps.to_literals(&m).unwrap();
        acc.add_literals(&m, &lits, 3).unwrap();
        acc.add_literals(&m, &lits, 2).unwrap();
        assert!(acc.flat.iter().all(|&x| (x - 4.0).abs() < 1e-7));
        assert_eq!(acc.sequences, 5);
        assert!((acc.scale() - 0.2).abs() < 1e-7);
        acc.reset();
        assert_eq!(acc.scale(), 0.0);
        assert!(acc.flat.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn checkpoint_roundtrip_with_opt() {
        let m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_test");
        let path = dir.join("test.bin");
        let mut ps = ParamStore::zeros_like(&m);
        ps.flat[7] = 1.25;
        let mut opt = OptState::zeros(&m);
        opt.m.flat[0] = -3.0;
        opt.step = 17;
        Checkpoint::save(&path, &m, &ps, Some(&opt)).unwrap();
        let (ps2, opt2) = Checkpoint::load(&path, &m).unwrap();
        assert_eq!(ps.flat, ps2.flat);
        let opt2 = opt2.unwrap();
        assert_eq!(opt2.m.flat[0], -3.0);
        assert_eq!(opt2.step, 17);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn checkpoint_train_state_roundtrip() {
        let m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_train_test");
        let path = dir.join("auto.bin");
        let mut ps = ParamStore::zeros_like(&m);
        ps.flat[3] = 0.75;
        let mut opt = OptState::zeros(&m);
        opt.step = 12;
        opt.v.flat[1] = 0.5;
        // seed above 2^53: must survive the JSON sidecar round-trip exactly
        let meta = TrainMeta { step: 6, seed: u64::MAX - 41, tuner: None, shards: 4 };
        Checkpoint::save_train(&path, &m, &ps, &opt, &meta).unwrap();
        let (ps2, opt2, train2) = Checkpoint::load_full(&path, &m).unwrap();
        assert_eq!(ps.flat, ps2.flat);
        let opt2 = opt2.unwrap();
        assert_eq!(opt2.step, 12);
        assert_eq!(opt2.v.flat[1], 0.5);
        assert_eq!(train2, Some(meta));
        // plain `save` checkpoints carry no train state and load as fresh
        let plain = dir.join("plain.bin");
        Checkpoint::save(&plain, &m, &ps, Some(&opt)).unwrap();
        let (_, _, train3) = Checkpoint::load_full(&plain, &m).unwrap();
        assert_eq!(train3, None);
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Satellite regression: the `--train.auto_buckets` EMA state must
    /// survive the checkpoint sidecar bit-exactly (f64 Display is
    /// shortest-roundtrip), so a `--resume` continuation's routing edges
    /// match the uninterrupted run.
    #[test]
    fn checkpoint_roundtrips_tuner_state_exactly() {
        use crate::coordinator::bucket_tuner::BucketTuner;

        let m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_tuner_test");
        let path = dir.join("auto.bin");
        let ps = ParamStore::zeros_like(&m);
        let opt = OptState::zeros(&m);
        // awkward non-dyadic EMA values via real observations
        let mut tuner = BucketTuner::new(8, 0.3);
        tuner.observe(&[1, 3, 3, 7]);
        tuner.observe(&[2, 5, 6]);
        tuner.observe(&[8, 8, 1, 4, 4, 4, 9]);
        let meta = TrainMeta { step: 3, seed: 17, tuner: Some(tuner.state()), shards: 1 };
        Checkpoint::save_train(&path, &m, &ps, &opt, &meta).unwrap();
        let (_, _, train2) = Checkpoint::load_full(&path, &m).unwrap();
        let train2 = train2.expect("train meta must survive");
        assert_eq!(train2.tuner, Some(tuner.state()), "tuner state drifted in the sidecar");
        // ...and a tuner rebuilt from it continues bit-identically
        let mut resumed = BucketTuner::from_state(train2.tuner.unwrap());
        tuner.observe(&[2, 2, 6]);
        resumed.observe(&[2, 2, 6]);
        assert_eq!(resumed.state(), tuner.state());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn grad_accum_merge_is_elementwise_add() {
        let mut a = GradAccum::zeros(4);
        let mut b = GradAccum::zeros(4);
        a.flat.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        a.sequences = 3;
        b.flat.copy_from_slice(&[0.5, -2.0, 0.25, 1.0]);
        b.sequences = 2;
        a.merge(&b);
        assert_eq!(a.flat, vec![1.5, 0.0, 3.25, 5.0]);
        assert_eq!(a.sequences, 5);
    }

    #[test]
    fn legacy_sidecar_without_shards_loads_as_one() {
        // Checkpoints written before the sharded learner carry no
        // `train_shards` field; they must load as shards = 1.
        let m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_legacy_shards_test");
        let path = dir.join("legacy.bin");
        let ps = ParamStore::zeros_like(&m);
        let opt = OptState::zeros(&m);
        let meta = TrainMeta { step: 2, seed: 5, tuner: None, shards: 3 };
        Checkpoint::save_train(&path, &m, &ps, &opt, &meta).unwrap();
        // strip the field from the sidecar to simulate a legacy checkpoint
        let side = path.with_extension("json");
        let text = std::fs::read_to_string(&side).unwrap();
        assert!(text.contains("train_shards"));
        let stripped = text.replace("\"train_shards\":3,", "").replace("\"train_shards\":3", "");
        std::fs::write(&side, stripped).unwrap();
        let (_, _, train) = Checkpoint::load_full(&path, &m).unwrap();
        assert_eq!(train.unwrap().shards, 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn checkpoint_without_opt() {
        let m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_test2");
        let path = dir.join("p.bin");
        let ps = ParamStore::zeros_like(&m);
        Checkpoint::save(&path, &m, &ps, None).unwrap();
        let (_, opt) = Checkpoint::load(&path, &m).unwrap();
        assert!(opt.is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn size_mismatch_rejected() {
        let _m = toy_manifest();
        let dir = std::env::temp_dir().join("nat_rl_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, vec![0u8; 7]).unwrap();
        assert!(ParamStore::from_bin(&path, 40).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
