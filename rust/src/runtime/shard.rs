//! Data-parallel learner shards with order-invariant gradient reduction.
//!
//! The learn stage packs one optimizer step into micro-batches; this module
//! executes them across `--train.shards K` concurrent workers and recombines
//! the results so that the floating-point summation order is a **pure
//! function of the step plan** — never of K, thread scheduling, or
//! completion order. That is the bit-identity contract: `shards = K`
//! produces the same `StepStats` and post-step parameters as `shards = 1`
//! for every K (proptested in `tests/sharding.rs`).
//!
//! Mechanics:
//!
//! 1. **Leaves.** Each micro-batch's gradient is computed into its own
//!    buffer ([`GradLeaf`]) instead of a shared accumulator. A leaf is a
//!    pure function of `(micro-batch, params)`, so it is identical no matter
//!    which shard worker computes it. Gather-compacted micro-batches
//!    (`MicroBatch::gather`) are ordinary leaves: the layout is resolved
//!    inside `grad_cached` (which routes to the `grad_K<k>_B<r>` artifact
//!    family), so shard planning and the id-keyed reduction are
//!    layout-oblivious and the `shards = K` bit-identity covers both grids.
//! 2. **Execution.** [`execute_shards`] runs the shard plan (from
//!    `coordinator::batcher::plan_shards`) on scoped threads — `Runtime` is
//!    `Sync`, the same property the pipelined rollout workers rely on — and
//!    scatters finished leaves into id-indexed slots.
//! 3. **Reduction.** [`tree_reduce_into`] combines the leaves with a
//!    fixed-order pairwise (binary-tree) reduction keyed by micro-batch id:
//!    level 0 merges (0,1), (2,3), …; level 1 merges the results pairwise;
//!    and so on. The association tree depends only on the leaf count, so
//!    the reduced gradient is bitwise identical for any K. Scalar
//!    [`GradMetrics`] fold in plain id order (one deterministic f64 chain).
//!
//! Memory: the reduction holds one `param_count` buffer per in-flight
//! micro-batch. At this repo's model sizes that is noise; at real scale the
//! same contract holds per shard-level segment tree without changing any
//! call site here.

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::MicroBatch;
use crate::obs::Tracer;

use super::{GradAccum, GradMetrics, Runtime};

/// One micro-batch's gradient contribution — a leaf of the reduction tree.
pub struct GradLeaf {
    pub acc: GradAccum,
    pub metrics: GradMetrics,
}

impl Runtime {
    /// Gradient of one micro-batch into a fresh buffer (a reduction leaf).
    pub fn grad_leaf(
        &self,
        mb: &MicroBatch,
        param_lits: &[xla::Literal],
    ) -> Result<GradLeaf> {
        let mut acc = GradAccum::zeros(self.manifest.param_count);
        let metrics = self.grad_cached(mb, param_lits, &mut acc)?;
        Ok(GradLeaf { acc, metrics })
    }
}

/// Execute a shard plan: `plan[k]` lists the micro-batch ids shard `k`
/// computes (every id exactly once). Returns the leaves in id order.
/// A single active shard runs inline on the caller's thread — the
/// `shards = 1` configuration has no thread overhead at all.
///
/// Tracing: each micro-batch emits a `shard.grad` span on thread id
/// `1 + shard` (tid 0 is the coordinator) carrying its id, bucket, and row
/// count — the Perfetto lane view of shard balance. Spans are observational
/// only: the off tracer skips every clock read, and the leaf values never
/// depend on tracing.
pub fn execute_shards(
    rt: &Runtime,
    mbs: &[MicroBatch],
    param_lits: &[xla::Literal],
    plan: &[Vec<usize>],
    tracer: &Tracer,
    step: u64,
) -> Result<Vec<GradLeaf>> {
    let traced_leaf = |i: usize, shard: usize| -> Result<GradLeaf> {
        // natlint: allow(hot-panic, reason = "i comes from the validated shard plan (every id < mbs.len() exactly once, checked by plan_shards)")
        let mb = &mbs[i];
        let mut sp = tracer.span("shard.grad", step);
        sp.set_tid(1 + shard as u64);
        sp.arg("mb", i as f64);
        sp.arg("bucket", mb.bucket as f64);
        sp.arg("rows", mb.rows as f64);
        rt.grad_leaf(mb, param_lits)
    };
    let mut slots: Vec<Option<GradLeaf>> = Vec::new();
    slots.resize_with(mbs.len(), || None);
    let active: Vec<&Vec<usize>> = plan.iter().filter(|ids| !ids.is_empty()).collect();
    if active.len() <= 1 {
        for ids in active {
            for &i in ids {
                // natlint: allow(hot-panic, reason = "slot ids are the plan's micro-batch ids, all < slots.len() by construction")
                slots[i] = Some(traced_leaf(i, 0)?);
            }
        }
    } else {
        let results: Vec<Result<Vec<(usize, GradLeaf)>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = active
                .iter()
                .enumerate()
                .map(|(shard, ids)| {
                    let traced_leaf = &traced_leaf;
                    scope.spawn(move || -> Result<Vec<(usize, GradLeaf)>> {
                        ids.iter().map(|&i| Ok((i, traced_leaf(i, shard)?))).collect()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("learner shard worker panicked")))
                })
                .collect()
        });
        for r in results {
            for (i, leaf) in r? {
                // natlint: allow(hot-panic, reason = "slot ids are the plan's micro-batch ids, all < slots.len() by construction")
                debug_assert!(slots[i].is_none(), "micro-batch {i} computed twice");
                // natlint: allow(hot-panic, reason = "slot ids are the plan's micro-batch ids, all < slots.len() by construction")
                slots[i] = Some(leaf);
            }
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("micro-batch {i} missing from the shard plan")))
        .collect()
}

/// Combine leaves into `acc` (gradients + sequence counts) and fold their
/// scalar metrics into `metrics`, both in an order derived purely from the
/// leaf ids. `acc` must hold exact zeros in `flat` (the post-`reset` state;
/// `sequences` may already carry dropped-row counts), so merging the tree
/// root into it is exact.
pub fn tree_reduce_into(acc: &mut GradAccum, metrics: &mut GradMetrics, leaves: Vec<GradLeaf>) {
    let mut bufs: Vec<GradAccum> = Vec::with_capacity(leaves.len());
    for leaf in leaves {
        // id-order f64 chain — the exact order the pre-shard learn stage
        // summed per-micro-batch metrics in.
        metrics.add(&leaf.metrics);
        bufs.push(leaf.acc);
    }
    while bufs.len() > 1 {
        let mut next: Vec<GradAccum> = Vec::with_capacity(bufs.len().div_ceil(2));
        let mut pending: Option<GradAccum> = None;
        for buf in bufs {
            match pending.take() {
                None => pending = Some(buf),
                Some(mut a) => {
                    a.merge(&buf);
                    next.push(a);
                }
            }
        }
        if let Some(odd) = pending {
            // odd leaf carries up unchanged — still purely count-derived
            next.push(odd);
        }
        bufs = next;
    }
    if let Some(root) = bufs.pop() {
        acc.merge(&root);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(n: usize, fill: f32, rows: usize, metrics_tokens: f64) -> GradLeaf {
        let mut acc = GradAccum::zeros(n);
        acc.flat.iter_mut().enumerate().for_each(|(i, g)| *g = fill + i as f32 * 0.125);
        acc.sequences = rows;
        GradLeaf {
            acc,
            metrics: GradMetrics { tokens: metrics_tokens, ..Default::default() },
        }
    }

    #[test]
    fn tree_reduce_handles_every_leaf_count() {
        for n_leaves in 0..9usize {
            let leaves: Vec<GradLeaf> =
                (0..n_leaves).map(|i| leaf(4, i as f32, i + 1, i as f64)).collect();
            let mut acc = GradAccum::zeros(4);
            let mut met = GradMetrics::default();
            tree_reduce_into(&mut acc, &mut met, leaves);
            let expect_rows: usize = (1..=n_leaves).sum();
            assert_eq!(acc.sequences, expect_rows, "{n_leaves} leaves");
            let expect0: f32 = (0..n_leaves).map(|i| i as f32).sum();
            assert!((acc.flat[0] - expect0).abs() < 1e-5, "{n_leaves} leaves");
            let expect_tokens: f64 = (0..n_leaves).map(|i| i as f64).sum();
            assert_eq!(met.tokens, expect_tokens);
        }
    }

    #[test]
    fn tree_reduce_order_is_a_function_of_leaf_ids_only() {
        // Adversarial float values where summation order matters: the tree
        // total must be reproducible run-to-run (same leaves => same bits),
        // which is the property the shard proptest leans on.
        let vals = [1.0e7f32, -1.0e7, 3.25, -7.5, 1.0e-3, 2.0e7, -2.0e7, 0.125, 9.0];
        let build = || -> Vec<GradLeaf> {
            vals.iter()
                .map(|&v| {
                    let mut acc = GradAccum::zeros(2);
                    acc.flat[0] = v;
                    acc.flat[1] = v * 0.5;
                    acc.sequences = 1;
                    GradLeaf { acc, metrics: GradMetrics::default() }
                })
                .collect()
        };
        let mut a = GradAccum::zeros(2);
        let mut b = GradAccum::zeros(2);
        let mut m = GradMetrics::default();
        tree_reduce_into(&mut a, &mut m, build());
        tree_reduce_into(&mut b, &mut m, build());
        assert_eq!(a.flat[0].to_bits(), b.flat[0].to_bits());
        assert_eq!(a.flat[1].to_bits(), b.flat[1].to_bits());
    }

    #[test]
    fn dropped_row_counts_survive_reduction() {
        let mut acc = GradAccum::zeros(3);
        acc.sequences = 2; // dropped zero-contribution rows, pre-seeded
        let mut met = GradMetrics::default();
        tree_reduce_into(&mut acc, &mut met, vec![leaf(3, 1.0, 4, 5.0)]);
        assert_eq!(acc.sequences, 6);
        assert!((acc.scale() - 1.0 / 6.0).abs() < 1e-7);
    }
}
