//! Deterministic host-side execution engine for [`Runtime`](super::Runtime).
//!
//! The offline build cannot execute PJRT artifacts, yet the learner-side
//! orchestration — packing, shard planning, concurrent grad execution, the
//! fixed-order tree reduction, AdamW bookkeeping — is exactly the code whose
//! correctness properties (bit-identity across `--train.shards`, golden-trace
//! stability, HT unbiasedness through the full path) must hold in tier-1.
//! This module mirrors the rollout scheduler's `SimBackend` precedent one
//! level down: a simulated kernel set behind the same `Runtime` entry points
//! (`generate`, `generate_bucketed`, `grad_cached`, `apply`), so trainers,
//! the pipeline, benches and tests drive the REAL coordinator code paths
//! end-to-end with no device.
//!
//! Contracts the simulation preserves:
//!
//! * **Purity.** Every kernel is a pure function of its inputs. Rollout
//!   rows derive from a per-row key (prompt ⊕ seed), so bucketed generation
//!   is scheduling-invariant exactly like the real `generate_T<b>` grid.
//! * **Inertness.** Rows with all-zero HT weights or zero advantage
//!   contribute exactly 0.0 to the gradient, like the real NAT loss.
//! * **Cross-platform bit-stability.** Only IEEE-exact float operations
//!   (+, −, ×, ÷, sqrt) and integer mixing are used — no transcendentals —
//!   so committed golden traces replay bit-identically on any host.
//! * **Sensitivity.** The gradient depends on every micro-batch field
//!   (tokens, HT weights, advantages, behaviour logprobs, inverse lengths)
//!   and on the parameters, so semantic drift anywhere in the
//!   mask → pack → shard → reduce → apply chain changes the trace.
//!
//! The first parameter's gradient is *linear* in the HT weights
//! (`grad[0] = Σ_rows adv · inv_len · Σ_t w_t · (old_lp_t + tok_t/1024)`),
//! which is what lets the Monte-Carlo test assert Horvitz-Thompson
//! unbiasedness through the full packing/sharding/reduction path against a
//! closed-form expectation.

use std::hint::black_box;
use std::path::Path;

use anyhow::{bail, Result};

use crate::coordinator::batcher::MicroBatch;
use crate::model::Manifest;
use crate::tokenizer::{EOS, PAD};
use crate::util::json::Json;

use super::{GenerateOut, GradAccum, GradMetrics, KvBlock, OptState, ParamStore};

/// Simulated-kernel knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimSpec {
    /// Busy-work iterations per allocated learner token in [`grad`] — models
    /// device forward/backward cost so shard-speedup benches have something
    /// real to overlap. 0 (the default) keeps tests fast.
    pub spin_per_token: u64,
}

/// SplitMix64 finalizer: full avalanche over one word.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform in [0, 1) from a key, via an exact power-of-two divide.
fn frac(key: u64) -> f32 {
    ((mix(key) >> 40) as f32) / 16_777_216.0
}

/// Deterministic busy-work (shared shape with `benches/bench_pipeline.rs`).
fn spin(units: u64) -> u64 {
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    for i in 0..units {
        x = x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(i);
    }
    black_box(x)
}

/// Token id of '#' in the fixed alphabet (answer marker the verifier reads).
const HASH_TOK: i32 = 23;

/// The manifest the sim runtime executes against: a small 2-tensor model
/// with the full artifact surface (3 sequence buckets × {1, 2, full} row
/// grid, per-bucket generate artifacts), so every routing path the real
/// manifests exercise exists here too. File names are never opened.
pub fn sim_manifest() -> Manifest {
    let j = Json::parse(
        r#"{
      "config": {"name":"sim","vocab":64,"d_model":8,"n_layers":1,"n_heads":1,
        "d_ff":16,"prompt_len":32,"max_resp":16,"buckets":[4,8,16],
        "batch_rollout":4,"batch_train":4,"pretrain_len":16,
        "batch_pretrain":2,"lr":0.01,"clip_eps":0.2,"grad_clip":1.0,
        "pretrain_lr":0.01},
      "param_count": 96,
      "params": [
        {"name":"w0","shape":[8,8],"size":64,"offset":0},
        {"name":"w1","shape":[8,4],"size":32,"offset":64}],
      "artifacts": {
        "generate":"sim://generate",
        "generate_buckets":{"4":"sim://gen4","8":"sim://gen8","16":"sim://gen16"},
        "prefill":"sim://prefill",
        "decode_buckets":{"4":"sim://dec4","8":"sim://dec8","16":"sim://dec16"},
        "apply":"sim://apply",
        "pretrain":"sim://pretrain",
        "grad":{"4":"sim://g4","8":"sim://g8","16":"sim://g16"},
        "grad_rows":{"4x1":"sim://g4r1","4x2":"sim://g4r2",
                     "8x1":"sim://g8r1","8x2":"sim://g8r2",
                     "16x1":"sim://g16r1","16x2":"sim://g16r2"},
        "grad_compact":{"4x1":"sim://k4r1","4x2":"sim://k4r2","4x4":"sim://k4r4",
                        "8x1":"sim://k8r1","8x2":"sim://k8r2","8x4":"sim://k8r4",
                        "16x1":"sim://k16r1","16x2":"sim://k16r2","16x4":"sim://k16r4"},
        "score":{"16":"sim://s16"}
      }
    }"#,
    )
    // natlint: allow(hot-panic, reason = "parses a compile-time-constant embedded manifest; failure is a build defect caught by every test, not a runtime condition")
    .expect("sim manifest JSON is well-formed");
    // natlint: allow(hot-panic, reason = "parses a compile-time-constant embedded manifest; failure is a build defect caught by every test, not a runtime condition")
    Manifest::from_json(Path::new("sim://"), &j).expect("sim manifest is consistent")
}

/// Deterministic non-trivial initial parameters (the sim counterpart of
/// `artifacts/<cfg>/init_params.bin`).
pub fn init_params(manifest: &Manifest) -> ParamStore {
    let flat = (0..manifest.param_count)
        .map(|i| (frac(0x494E_4954 ^ i as u64) - 0.5) * 0.2)
        .collect();
    ParamStore { flat }
}

/// Per-row sampling key: a pure mix of the prompt row and the row's seed —
/// independent of batch placement, matching the `generate_T<b>` contract.
fn row_key(prompt: &[i32], seed: i64) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64 ^ seed as u64;
    for &t in prompt {
        h = mix(h ^ t as u64);
    }
    h
}

/// Simulated response length in `1..=(top + top/2)`: the overflow tail
/// (length > top bucket) exercises escalation and the no-EOS path.
fn row_len(key: u64, top: usize) -> usize {
    1 + (mix(key ^ 0x4C45_4E) % (top as u64 + top as u64 / 2)) as usize
}

/// Token at response position `t` of a row stream. The last three positions
/// spell `# <digit> EOS` so a deterministic fraction of rollouts parse as
/// answers and verifiable rewards vary within groups; body tokens stay in
/// the printable alphabet and never collide with EOS.
fn row_token(key: u64, t: usize, len: usize) -> i32 {
    if t + 1 == len {
        EOS
    } else if t + 2 == len {
        3 + (mix(key ^ 0x414E_53) % 10) as i32 // digit 0-9
    } else if t + 3 == len {
        HASH_TOK
    } else {
        3 + (mix(key ^ (t as u64).wrapping_mul(0x9E37_79B9)) % 50) as i32
    }
}

/// Behaviour logprob at response position `t` (in [-1.02, -0.02)).
fn row_lp(key: u64, t: usize) -> f32 {
    -0.02 - frac(key ^ (t as u64).wrapping_mul(0xA24B_AED4) ^ 0x4C50)
}

/// Fill one row's `[P + window]` token slice and `[window]` logprob slice.
/// The row's true length derives from `top` (the model's full response
/// window), NEVER from the calling bucket — that is what keeps a row's
/// stream bit-identical under any bucket cap that covers it.
fn fill_row(
    tokens: &mut [i32],
    lp: &mut [f32],
    prompt: &[i32],
    key: u64,
    window: usize,
    top: usize,
) {
    let p = prompt.len();
    tokens[..p].copy_from_slice(prompt);
    let len = row_len(key, top.max(1));
    for t in 0..window.min(len) {
        tokens[p + t] = row_token(key, t, len);
        lp[t] = row_lp(key, t);
    }
}

/// Bucketed generate: per-row seeds, `[B, P + bucket]` window. Each row is
/// a pure function of `(prompt, seed)` — the scheduling-invariance contract.
pub fn generate_bucket(
    manifest: &Manifest,
    bucket: usize,
    prompts: &[i32],
    _pads: &[i32],
    seeds: &[i32],
    _temp: f32,
) -> Result<GenerateOut> {
    let d = &manifest.dims;
    let (b, p) = (d.batch_rollout, d.prompt_len);
    let s = p + bucket;
    let mut tokens = vec![PAD; b * s];
    let mut lp = vec![0.0f32; b * bucket];
    for row in 0..b {
        let prompt = &prompts[row * p..(row + 1) * p];
        let key = row_key(prompt, seeds[row] as i64);
        fill_row(
            &mut tokens[row * s..(row + 1) * s],
            &mut lp[row * bucket..(row + 1) * bucket],
            prompt,
            key,
            bucket,
            d.max_resp,
        );
    }
    Ok(GenerateOut { tokens, lp })
}

/// Prefill split, host-side: one prompt forward pass producing the per-row
/// decode state. The sim keeps no hidden state — a row's sampling stream
/// re-derives from `(prompt, seed)` — so the block carries the prompt
/// tokens and an EXACT prefill-step cost model (`prefill_steps = P`, one
/// token-step per prompt position, matching what the fused generate pays
/// for its prompt window). That cost model is what makes the prefix
/// cache's saving measurable and gateable in tier-1 with no device.
pub fn prefill(manifest: &Manifest, prompt: &[i32], pad: i32) -> Result<KvBlock> {
    let d = &manifest.dims;
    if prompt.len() != d.prompt_len {
        bail!("sim prefill: prompt of {} tokens, window {}", prompt.len(), d.prompt_len);
    }
    Ok(KvBlock {
        prompt: prompt.to_vec(),
        pad,
        kv: Vec::new(),
        bytes: d.kv_block_bytes(),
        prefill_steps: d.prompt_len,
    })
}

/// Bucketed decode from cached prefill blocks, host-side. Materializes the
/// `[B, P]` prompt matrix from the blocks and delegates to
/// [`generate_bucket`] — decode-from-KV is bit-identical to fused generate
/// *by construction*, which is the determinism contract the prefix cache
/// rides on (cache on/off can change cost, never output).
pub fn decode_bucket_kv(
    manifest: &Manifest,
    bucket: usize,
    kvs: &[&KvBlock],
    seeds: &[i32],
    temp: f32,
) -> Result<GenerateOut> {
    let d = &manifest.dims;
    let (b, p) = (d.batch_rollout, d.prompt_len);
    if kvs.len() != b {
        bail!("sim decode_T{bucket}: {} kv blocks, batch {b}", kvs.len());
    }
    let mut prompts = Vec::with_capacity(b * p);
    let mut pads = Vec::with_capacity(b);
    for block in kvs {
        if block.prompt.len() != p {
            bail!("sim decode_T{bucket}: kv block prompt of {} tokens, window {p}", block.prompt.len());
        }
        prompts.extend_from_slice(&block.prompt);
        pads.push(block.pad);
    }
    generate_bucket(manifest, bucket, &prompts, &pads, seeds, temp)
}

/// Legacy fixed-engine generate: full `[B, P + max_resp]` window with ONE
/// scalar seed per call; rows decorrelate via their batch position, exactly
/// like the legacy artifact's batched sampling streams.
pub fn generate_fixed(
    manifest: &Manifest,
    prompts: &[i32],
    _pads: &[i32],
    seed: i32,
    _temp: f32,
) -> Result<GenerateOut> {
    let d = &manifest.dims;
    let (b, p, t_max) = (d.batch_rollout, d.prompt_len, d.max_resp);
    let s = p + t_max;
    let mut tokens = vec![PAD; b * s];
    let mut lp = vec![0.0f32; b * t_max];
    for row in 0..b {
        let prompt = &prompts[row * p..(row + 1) * p];
        let key = mix(row_key(prompt, seed as i64) ^ (row as u64).wrapping_mul(0xBF58_476D));
        fill_row(
            &mut tokens[row * s..(row + 1) * s],
            &mut lp[row * t_max..(row + 1) * t_max],
            prompt,
            key,
            t_max,
            t_max,
        );
    }
    Ok(GenerateOut { tokens, lp })
}

/// Simulated NAT grad over one micro-batch, accumulated into `acc` with the
/// same contract as the artifact path (`GradAccum::add_literals`): gradient
/// sums plus `sequences += real_rows`. See the module docs for the formula;
/// padding rows (zero weights, zero advantage) contribute exactly 0.0.
pub fn grad(
    manifest: &Manifest,
    spec: &SimSpec,
    mb: &MicroBatch,
    param_lits: &[xla::Literal],
    acc: &mut GradAccum,
) -> Result<GradMetrics> {
    let d = &manifest.dims;
    let (rows, p, t) = (mb.rows, d.prompt_len, mb.bucket);
    let s = p + t;
    let n = manifest.param_count;
    let mut params_flat: Vec<f32> = Vec::with_capacity(n);
    for lit in param_lits {
        params_flat.extend(lit.to_vec::<f32>()?);
    }
    if params_flat.len() != n {
        bail!("sim grad: {} param values, expected {n}", params_flat.len());
    }
    if spec.spin_per_token > 0 {
        spin(spec.spin_per_token * (rows * s) as u64);
    }
    let mut grads = vec![0.0f32; n];
    let mut met = GradMetrics::default();
    for r in 0..rows {
        let row_toks = &mb.tokens[r * s..(r + 1) * s];
        let key = row_key(row_toks, mb.pad_len[r] as i64);
        let mut row_acc = 0.0f32;
        for tt in 0..t {
            let w = mb.ht_w[r * t + tt];
            if w == 0.0 {
                continue;
            }
            // Compacted layout: slot `tt` holds the token gathered from
            // original response position `gather[tt]`; per-token hashes key
            // on that ORIGINAL position, so the sim stays sensitive to the
            // scatter indices while the legacy path (gather == None, where
            // pos == tt) is bit-untouched.
            let pos = match &mb.gather {
                Some(g) => {
                    let pos = g[r * t + tt];
                    if pos < 0 {
                        continue;
                    }
                    pos as u64
                }
                None => tt as u64,
            };
            let tok = row_toks[p + tt] as f32;
            let lp = mb.old_lp[r * t + tt];
            row_acc += w * (lp + tok / 1024.0);
            met.tokens += 1.0;
            met.entropy_sum += frac(key ^ pos ^ 0x454E_54) as f64;
            met.kl_sum += (lp * lp / 1024.0) as f64;
            if mix(key ^ pos ^ 0x434C_50) % 100 < 5 {
                met.clip_sum += 1.0;
            }
        }
        let g_r = mb.adv[r] * mb.inv_len[r] * row_acc;
        met.loss_sum += (g_r * g_r) as f64;
        if g_r == 0.0 {
            continue;
        }
        grads[0] += g_r;
        for (j, slot) in grads.iter_mut().enumerate().skip(1) {
            let basis = frac(key ^ (j as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) - 0.5;
            *slot += g_r * (basis + params_flat[j] / 128.0);
        }
    }
    for (dst, g) in acc.flat.iter_mut().zip(&grads) {
        *dst += *g;
    }
    acc.sequences += mb.real_rows;
    Ok(met)
}

/// `x^n` by square-and-multiply: deterministic (fixed multiplication tree
/// per `n`), no transcendental `powf`.
fn powi(x: f32, mut n: u64) -> f32 {
    let mut base = x;
    let mut out = 1.0f32;
    while n > 0 {
        if n & 1 == 1 {
            out *= base;
        }
        base *= base;
        n >>= 1;
    }
    out
}

/// Simulated AdamW apply, matching the artifact contract: consumes the
/// host-accumulated gradient (scaled by `1/sequences`), updates params and
/// both moments in place, and returns the PRE-clip gradient norm.
pub fn apply(
    manifest: &Manifest,
    params: &mut ParamStore,
    opt: &mut OptState,
    acc: &GradAccum,
) -> Result<f64> {
    let d = &manifest.dims;
    let n = manifest.param_count;
    if acc.flat.len() != n {
        bail!("sim apply: {} grad values, expected {n}", acc.flat.len());
    }
    let scale = acc.scale();
    let mut sq = 0.0f64;
    for &g in &acc.flat {
        let gs = (g * scale) as f64;
        sq += gs * gs;
    }
    let norm = sq.sqrt();
    let clip = if norm > d.grad_clip && norm > 0.0 { (d.grad_clip / norm) as f32 } else { 1.0 };
    let (b1, b2, eps, wd) = (0.9f32, 0.999f32, 1e-8f32, 0.01f32);
    let lr = d.lr as f32;
    let bc1 = 1.0 - powi(b1, opt.step);
    let bc2 = 1.0 - powi(b2, opt.step);
    for i in 0..n {
        let g = acc.flat[i] * scale * clip;
        let m = b1 * opt.m.flat[i] + (1.0 - b1) * g;
        let v = b2 * opt.v.flat[i] + (1.0 - b2) * g * g;
        opt.m.flat[i] = m;
        opt.v.flat[i] = v;
        let update = (m / bc1) / ((v / bc2).sqrt() + eps);
        params.flat[i] -= lr * (update + wd * params.flat[i]);
    }
    Ok(norm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    #[test]
    fn sim_manifest_has_full_artifact_surface() {
        let m = sim_manifest();
        assert_eq!(m.dims.buckets, vec![4, 8, 16]);
        assert_eq!(m.row_grid(), vec![1, 2, 4]);
        assert_eq!(m.param_count, 96);
        assert!(m.generate_file_for(4).is_ok());
        // prefill/decode split: full bucket grid plus the prefill artifact
        assert!(m.has_prefill_split());
        assert!(m.prefill_file.is_some());
        for b in [4usize, 8, 16] {
            assert!(m.decode_file_for(b).is_ok(), "missing decode bucket {b}");
        }
        assert!(m.decode_file_for(5).is_err());
        assert!(m.grad_file_for(8, 2).is_ok());
        assert!(m.grad_file_for(8, 3).is_err());
        // compacted grid: every kept-bucket × row-grid cell, full rows
        // included explicitly (no legacy-grad fallback for this family)
        assert!(m.has_compact());
        for k in [4usize, 8, 16] {
            for r in [1usize, 2, 4] {
                assert!(m.grad_compact_file_for(k, r).is_ok(), "missing {k}x{r}");
            }
        }
        assert!(m.grad_compact_file_for(8, 3).is_err());
    }

    #[test]
    fn bucketed_rows_are_pure_functions_of_prompt_and_seed() {
        let m = sim_manifest();
        let d = m.dims.clone();
        let p = d.prompt_len;
        let prompt_a: Vec<i32> = (0..p as i32).map(|t| 3 + t % 40).collect();
        let prompt_b: Vec<i32> = (0..p as i32).map(|t| 5 + t % 30).collect();
        // prompt A in row 0 of one batch, row 2 of another; same seed.
        let mk_batch = |slot: usize| -> (Vec<i32>, Vec<i32>) {
            let mut prompts = Vec::new();
            let mut seeds = Vec::new();
            for row in 0..d.batch_rollout {
                if row == slot {
                    prompts.extend_from_slice(&prompt_a);
                    seeds.push(77);
                } else {
                    prompts.extend_from_slice(&prompt_b);
                    seeds.push(100 + row as i32);
                }
            }
            (prompts, seeds)
        };
        let pads = vec![0i32; d.batch_rollout];
        for bucket in [8usize, 16] {
            let (pr0, sd0) = mk_batch(0);
            let (pr2, sd2) = mk_batch(2);
            let a = generate_bucket(&m, bucket, &pr0, &pads, &sd0, 1.0).unwrap();
            let b = generate_bucket(&m, bucket, &pr2, &pads, &sd2, 1.0).unwrap();
            let s = p + bucket;
            assert_eq!(
                a.tokens[..s],
                b.tokens[2 * s..3 * s],
                "row stream depends on batch placement (bucket {bucket})"
            );
            assert_eq!(a.lp[..bucket], b.lp[2 * bucket..3 * bucket]);
        }
        // ...and a longer bucket extends the stream with an identical prefix.
        let (pr, sd) = mk_batch(0);
        let short = generate_bucket(&m, 8, &pr, &pads, &sd, 1.0).unwrap();
        let long = generate_bucket(&m, 16, &pr, &pads, &sd, 1.0).unwrap();
        let resp_s = &short.tokens[p..p + 8];
        let resp_l = &long.tokens[p..p + 8];
        if !resp_s.contains(&EOS) {
            assert_eq!(resp_s, resp_l, "bucket cap changed the sampled prefix");
        }
    }

    #[test]
    fn decode_from_kv_is_bit_identical_to_fused_generate() {
        // The prefix cache's whole determinism contract: prefill + decode
        // must reproduce the fused generate stream bit-for-bit for the
        // same (prompt, seed) rows, under every bucket.
        let m = sim_manifest();
        let d = m.dims.clone();
        let p = d.prompt_len;
        let mut prompts = Vec::new();
        let mut pads = Vec::new();
        let mut seeds = Vec::new();
        for row in 0..d.batch_rollout {
            let prompt: Vec<i32> = (0..p as i32).map(|t| 3 + (t + row as i32) % 40).collect();
            prompts.extend_from_slice(&prompt);
            pads.push(row as i32 % 3);
            seeds.push(1000 + 7 * row as i32);
        }
        for bucket in [4usize, 8, 16] {
            let fused = generate_bucket(&m, bucket, &prompts, &pads, &seeds, 1.0).unwrap();
            let blocks: Vec<KvBlock> = (0..d.batch_rollout)
                .map(|r| prefill(&m, &prompts[r * p..(r + 1) * p], pads[r]).unwrap())
                .collect();
            assert!(blocks.iter().all(|b| b.prefill_steps == p && b.bytes > 0));
            let refs: Vec<&KvBlock> = blocks.iter().collect();
            let split = decode_bucket_kv(&m, bucket, &refs, &seeds, 1.0).unwrap();
            assert_eq!(fused.tokens, split.tokens, "bucket {bucket}");
            assert_eq!(
                fused.lp.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                split.lp.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "bucket {bucket}"
            );
        }
    }

    #[test]
    fn grad_is_inert_for_zero_weight_rows_and_linear_probe_matches() {
        let m = sim_manifest();
        let rt = Runtime::sim(sim_manifest());
        let d = m.dims.clone();
        let (p, t) = (d.prompt_len, 8usize);
        let s = p + t;
        let rows = 2usize;
        let mut mb = MicroBatch {
            bucket: t,
            rows,
            real_rows: 1,
            tokens: (0..(rows * s) as i32).map(|x| 3 + x % 40).collect(),
            ht_w: vec![0.0; rows * t],
            adv: vec![0.0; rows],
            old_lp: vec![-0.5; rows * t],
            inv_len: vec![0.0; rows],
            pad_len: vec![4; rows],
            gather: None,
        };
        // row 0 scores three tokens; row 1 is inert padding
        mb.ht_w[0] = 2.0;
        mb.ht_w[1] = 1.0;
        mb.ht_w[3] = 4.0;
        mb.adv[0] = 0.5;
        mb.inv_len[0] = 1.0 / 8.0;
        let params = init_params(&m);
        let lits = params.to_literals(&m).unwrap();
        let mut acc = GradAccum::zeros(m.param_count);
        let met = rt.grad_cached(&mb, &lits, &mut acc).unwrap();
        assert_eq!(met.tokens, 3.0);
        assert_eq!(acc.sequences, 1);
        // linear probe: grad[0] = adv * inv_len * Σ w (lp + tok/1024)
        let expect: f32 = {
            let row = &mb.tokens[..s];
            let terms = [(0usize, 2.0f32), (1, 1.0), (3, 4.0)];
            let mut sum = 0.0f32;
            for (tt, w) in terms {
                sum += w * (mb.old_lp[tt] + row[p + tt] as f32 / 1024.0);
            }
            0.5 * (1.0 / 8.0) * sum
        };
        assert!((acc.flat[0] - expect).abs() < 1e-6, "{} vs {expect}", acc.flat[0]);
        assert!(acc.flat.iter().skip(1).any(|&g| g != 0.0));

        // all-inert micro-batch contributes exactly nothing
        mb.ht_w.iter_mut().for_each(|w| *w = 0.0);
        mb.adv[0] = 0.0;
        let mut acc0 = GradAccum::zeros(m.param_count);
        rt.grad_cached(&mb, &lits, &mut acc0).unwrap();
        assert!(acc0.flat.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn compacted_grad_probe_is_bit_identical_to_prefix_layout() {
        // The same kept set {1, 7, 12} of one 16-token response, laid out
        // two ways: prefix-packed in the 16-bucket vs gather-compacted in
        // the 4-bucket. grad[0] (the HT linear probe) must agree BITWISE —
        // it sums w·(lp + tok/1024) over kept tokens in ascending original
        // position under both layouts, which is what keeps the MC
        // HT-unbiasedness property layout-independent.
        let m = sim_manifest();
        let rt = Runtime::sim(sim_manifest());
        let p = m.dims.prompt_len;
        let (t_pref, t_comp) = (16usize, 4usize);
        let kept = [1usize, 7, 12];
        let toks: Vec<i32> = (0..(p + t_pref) as i32).map(|x| 3 + x % 40).collect();
        let lp_at = |pos: usize| -0.1 - 0.05 * (pos % 3) as f32;
        let w_at = |i: usize| 1.5 + i as f32;

        let mut pref = MicroBatch {
            bucket: t_pref,
            rows: 1,
            real_rows: 1,
            tokens: toks.clone(),
            ht_w: vec![0.0; t_pref],
            adv: vec![0.75],
            old_lp: vec![0.0; t_pref],
            inv_len: vec![1.0 / 16.0],
            pad_len: vec![4],
            gather: None,
        };
        let mut comp = MicroBatch {
            bucket: t_comp,
            rows: 1,
            real_rows: 1,
            tokens: toks[..p + t_comp].to_vec(),
            ht_w: vec![0.0; t_comp],
            adv: vec![0.75],
            old_lp: vec![0.0; t_comp],
            inv_len: vec![1.0 / 16.0],
            pad_len: vec![4],
            gather: Some(vec![-1; t_comp]),
        };
        for (j, &pos) in kept.iter().enumerate() {
            pref.ht_w[pos] = w_at(j);
            pref.old_lp[pos] = lp_at(pos);
            comp.ht_w[j] = w_at(j);
            comp.old_lp[j] = lp_at(pos);
            comp.tokens[p + j] = toks[p + pos];
            comp.gather.as_mut().unwrap()[j] = pos as i32;
        }
        let params = init_params(&m);
        let lits = params.to_literals(&m).unwrap();
        let mut acc_p = GradAccum::zeros(m.param_count);
        let mut acc_c = GradAccum::zeros(m.param_count);
        let met_p = rt.grad_cached(&pref, &lits, &mut acc_p).unwrap();
        let met_c = rt.grad_cached(&comp, &lits, &mut acc_c).unwrap();
        assert_eq!(acc_p.flat[0].to_bits(), acc_c.flat[0].to_bits());
        assert_eq!(met_p.tokens, met_c.tokens);
        assert_eq!(met_p.tokens, 3.0);
        assert_eq!((acc_p.sequences, acc_c.sequences), (1, 1));
        // the compacted row hashes a different slice, so the sim gradient
        // is NOT globally identical — only the linear probe is (by design)
        assert!(acc_p.flat.iter().skip(1).any(|&g| g != 0.0));
        assert!(acc_c.flat.iter().skip(1).any(|&g| g != 0.0));
    }

    #[test]
    fn apply_is_deterministic_and_moves_params() {
        let m = sim_manifest();
        let rt = Runtime::sim(sim_manifest());
        let run = || {
            let mut params = init_params(&m);
            let mut opt = OptState::zeros(&m);
            let mut acc = GradAccum::zeros(m.param_count);
            acc.flat.iter_mut().enumerate().for_each(|(i, g)| *g = 0.01 * (i as f32 - 40.0));
            acc.sequences = 4;
            let n1 = rt.apply(&mut params, &mut opt, &acc).unwrap();
            let n2 = rt.apply(&mut params, &mut opt, &acc).unwrap();
            (params.flat, opt.step, n1, n2)
        };
        let (pa, step_a, n1, n2) = run();
        let (pb, step_b, m1, m2) = run();
        assert_eq!(pa, pb);
        assert_eq!((step_a, step_b), (2, 2));
        assert_eq!(n1.to_bits(), m1.to_bits());
        assert_eq!(n2.to_bits(), m2.to_bits());
        assert!(n1 > 0.0);
        assert_ne!(pa, init_params(&m).flat);
    }
}
