//! PJRT runtime: load AOT HLO-text artifacts and drive them from Rust.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Executables are compiled lazily on first
//! use and cached (GRPO never touches the short grad buckets; DetTrunc
//! never touches the long ones). HLO *text* is the interchange format —
//! see python/compile/aot.py for why.

pub mod params;
pub mod shard;
pub mod sim;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::batcher::MicroBatch;
use crate::model::Manifest;
pub use params::{Checkpoint, GradAccum, OptState, ParamStore, TrainMeta};
pub use sim::SimSpec;

/// Scalar metrics returned by one grad micro-batch (sums over the batch).
#[derive(Clone, Copy, Debug, Default)]
pub struct GradMetrics {
    pub loss_sum: f64,
    pub tokens: f64,
    pub entropy_sum: f64,
    pub clip_sum: f64,
    pub kl_sum: f64,
}

impl GradMetrics {
    pub fn add(&mut self, other: &GradMetrics) {
        self.loss_sum += other.loss_sum;
        self.tokens += other.tokens;
        self.entropy_sum += other.entropy_sum;
        self.clip_sum += other.clip_sum;
        self.kl_sum += other.kl_sum;
    }

    pub fn mean_entropy(&self) -> f64 {
        if self.tokens > 0.0 { self.entropy_sum / self.tokens } else { 0.0 }
    }

    pub fn clip_frac(&self) -> f64 {
        if self.tokens > 0.0 { self.clip_sum / self.tokens } else { 0.0 }
    }
}

/// Rollout output: token matrix and behaviour logprobs.
pub struct GenerateOut {
    /// [B, P + T] row-major.
    pub tokens: Vec<i32>,
    /// [B, T] row-major, temperature-1 logprobs of sampled tokens.
    pub lp: Vec<f32>,
}

/// One prompt's prefill result: the per-prompt KV state the bucketed decode
/// artifacts consume. Produced once per `(param_version, prompt)` by
/// [`Runtime::prefill`] and shared (ref-counted) across all G group
/// siblings, refill rounds, and escalation re-decodes by the scheduler's
/// prefix cache.
pub struct KvBlock {
    /// The [P] left-padded prompt row the block was prefilled from. Decode
    /// artifacts re-take the tokens (sampling keys mix seed and prompt), so
    /// the block carries them alongside the KV.
    pub prompt: Vec<i32>,
    /// Left-pad length of `prompt`.
    pub pad: i32,
    /// Host copy of the prompt-window KV from the prefill artifact,
    /// [layers, 2, heads, P, head_dim] flattened; empty under the sim
    /// engine, which re-derives decode state from the prompt tokens.
    pub kv: Vec<f32>,
    /// Modeled resident footprint used for the cache's byte-budget LRU
    /// (`ModelDims::kv_block_bytes`, or the actual host KV size when the
    /// artifact returned one).
    pub bytes: usize,
    /// Token-steps the prefill paid (= P). What a cache hit saves.
    pub prefill_steps: usize,
}

/// Execution engine behind [`Runtime`]: real PJRT artifacts, or the
/// deterministic host-side simulation (`runtime::sim`) used by tests and
/// benches in builds with no device.
enum Engine {
    Pjrt(xla::PjRtClient),
    Sim(sim::SimSpec),
}

/// Shareable across threads: the pipelined trainer hands `&Runtime` to N
/// rollout workers plus the learner (and the sharded learn stage hands it
/// to K grad workers), so the lazily-populated executable cache is behind a
/// `Mutex` and entries are `Arc`s (the lock covers lookup and compile;
/// execution runs on the cloned handle outside the lock).
pub struct Runtime {
    engine: Engine,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { engine: Engine::Pjrt(client), manifest, exes: Mutex::new(HashMap::new()) })
    }

    /// A runtime over the deterministic host-side simulated kernels — no
    /// artifacts, no PJRT. See `runtime::sim` for the contracts it keeps.
    pub fn sim(manifest: Manifest) -> Runtime {
        Runtime::sim_with(manifest, sim::SimSpec::default())
    }

    /// [`Runtime::sim`] with explicit sim knobs (benches set per-token
    /// busy-work so shard overlap has real cost to hide).
    pub fn sim_with(manifest: Manifest, spec: sim::SimSpec) -> Runtime {
        Runtime { engine: Engine::Sim(spec), manifest, exes: Mutex::new(HashMap::new()) }
    }

    /// True when this runtime executes the simulated kernel set.
    pub fn is_sim(&self) -> bool {
        matches!(self.engine, Engine::Sim(_))
    }

    fn exe(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let Engine::Pjrt(client) = &self.engine else {
            bail!("sim runtime has no compiled executables (requested {file})");
        };
        // natlint: allow(hot-panic, reason = "lock poisoning means a compile already panicked on another thread; propagating the poison is the policy, there is no recoverable state")
        let mut exes = self.exes.lock().expect("executable cache poisoned");
        if let Some(e) = exes.get(file) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            client.compile(&comp).with_context(|| format!("compiling {file}"))?,
        );
        exes.insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (startup warmup; avoids first-step
    /// compile latency polluting timing benchmarks).
    pub fn warmup(&self, grad_buckets: &[usize]) -> Result<()> {
        if self.is_sim() {
            return Ok(());
        }
        self.exe(&self.manifest.generate_file.clone())?;
        self.exe(&self.manifest.apply_file.clone())?;
        for &(b, ref f) in &self.manifest.grad_files.clone() {
            if grad_buckets.contains(&b) {
                self.exe(f)?;
            }
        }
        // Row-grid variants of the same buckets: only the cells the budget
        // packer can actually route into (rows in the usable grid — rows
        // compiled for some buckets but not all are never allocated).
        let grid = self.manifest.row_grid();
        for &((b, r), ref f) in &self.manifest.grad_row_files.clone() {
            if grad_buckets.contains(&b) && grid.contains(&r) {
                self.exe(f)?;
            }
        }
        // Gather-compacted cells: a scattered plan's kept count can land in
        // ANY kept-bucket at or below its sequence bucket, so warm every
        // cell whose rows the packer can allocate.
        for &((_, r), ref f) in &self.manifest.grad_compact_files.clone() {
            if grid.contains(&r) || r == self.manifest.dims.batch_train {
                self.exe(f)?;
            }
        }
        Ok(())
    }

    /// Pre-compile the bucketed rollout grid (`generate_T<b>`, absent in
    /// legacy manifests). Separate from [`Runtime::warmup`] so runs on
    /// `--rollout.engine fixed` never pay compilations they will not use.
    pub fn warmup_generate_buckets(&self) -> Result<()> {
        if self.is_sim() {
            return Ok(());
        }
        for (_, f) in &self.manifest.generate_files {
            self.exe(f)?;
        }
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        // natlint: allow(hot-panic, reason = "lock poisoning means a compile already panicked on another thread; propagating the poison is the policy, there is no recoverable state")
        self.exes.lock().expect("executable cache poisoned").len()
    }

    fn run(&self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(file)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    fn run_refs(&self, file: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(file)?;
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Rollout: sample up to `max_resp` tokens per row (early-exit decode).
    /// prompts: [B, P] left-padded; pad_len: [B].
    pub fn generate(
        &self,
        params: &ParamStore,
        prompts: &[i32],
        pad_len: &[i32],
        seed: i32,
        temp: f32,
    ) -> Result<GenerateOut> {
        let file = self.manifest.generate_file.clone();
        self.generate_with(&file, params, prompts, pad_len, seed, temp)
    }

    /// Fixed-trip-count rollout (perf A/B baseline for §Perf opt-1).
    pub fn generate_full(
        &self,
        params: &ParamStore,
        prompts: &[i32],
        pad_len: &[i32],
        seed: i32,
        temp: f32,
    ) -> Result<GenerateOut> {
        let file = self
            .manifest
            .generate_full_file
            .clone()
            .context("no generate_full artifact (rebuild artifacts)")?;
        self.generate_with(&file, params, prompts, pad_len, seed, temp)
    }

    /// Bucketed rollout: sample up to `bucket` tokens per row with PER-ROW
    /// seeds. Each row's sampling stream is a pure function of its own seed
    /// (and the step index), so a slot's output is identical in any batch
    /// placement and under any bucket cap that covers it — the
    /// scheduling-invariance contract the rollout scheduler relies on.
    /// prompts: [B, P] left-padded; pad_len/seeds: [B].
    pub fn generate_bucketed(
        &self,
        params: &ParamStore,
        bucket: usize,
        prompts: &[i32],
        pad_len: &[i32],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut> {
        let d = &self.manifest.dims;
        let (b, p) = (d.batch_rollout, d.prompt_len);
        if prompts.len() != b * p || pad_len.len() != b || seeds.len() != b {
            bail!(
                "generate_T{bucket}: bad input shapes ({} prompts, {} pads, {} seeds)",
                prompts.len(),
                pad_len.len(),
                seeds.len()
            );
        }
        let file = self.manifest.generate_file_for(bucket)?.to_string();
        if let Engine::Sim(_) = &self.engine {
            return sim::generate_bucket(&self.manifest, bucket, prompts, pad_len, seeds, temp);
        }
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.push(xla::Literal::vec1(prompts).reshape(&[b as i64, p as i64])?);
        inputs.push(xla::Literal::vec1(pad_len));
        inputs.push(xla::Literal::vec1(seeds));
        inputs.push(xla::Literal::from(temp));
        let outs = self.run(&file, &inputs)?;
        if outs.len() != 2 {
            bail!("generate_T{bucket}: expected 2 outputs, got {}", outs.len());
        }
        Ok(GenerateOut { tokens: outs[0].to_vec()?, lp: outs[1].to_vec()? })
    }

    /// Prefill one prompt: run the prompt-window forward pass once and
    /// return its KV block. `prompt`: [P] left-padded. The block is a pure
    /// function of `(params, prompt)` — no seed, no temperature — which is
    /// what lets the prefix cache share it across group siblings without
    /// touching the per-slot sampling contract.
    pub fn prefill(&self, params: &ParamStore, prompt: &[i32], pad: i32) -> Result<KvBlock> {
        let d = &self.manifest.dims;
        if prompt.len() != d.prompt_len {
            bail!("prefill: prompt of {} tokens, window {}", prompt.len(), d.prompt_len);
        }
        if let Engine::Sim(_) = &self.engine {
            return sim::prefill(&self.manifest, prompt, pad);
        }
        let file = self
            .manifest
            .prefill_file
            .clone()
            .context("no prefill artifact (rebuild artifacts with the prefill split)")?;
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.push(xla::Literal::vec1(prompt).reshape(&[1, d.prompt_len as i64])?);
        inputs.push(xla::Literal::vec1(&[pad]));
        let outs = self.run(&file, &inputs)?;
        if outs.len() != 1 {
            bail!("prefill: expected 1 output, got {}", outs.len());
        }
        let kv: Vec<f32> = outs[0].to_vec()?;
        let bytes = kv.len() * 4 + prompt.len() * 4;
        Ok(KvBlock {
            prompt: prompt.to_vec(),
            pad,
            kv,
            bytes,
            prefill_steps: d.prompt_len,
        })
    }

    /// Bucketed decode from cached prefill state: sample up to `bucket`
    /// tokens per row, with each row's prompt context supplied as a
    /// [`KvBlock`] instead of being re-prefilled in the fused generate.
    /// Keeps the scheduling-invariance contract of [`Runtime::generate_bucketed`]:
    /// row output is a pure function of `(prompt, seed)`, so decode-from-KV
    /// is bit-identical to fused generate for the same rows.
    /// kvs/seeds: [B].
    pub fn generate_bucketed_kv(
        &self,
        params: &ParamStore,
        bucket: usize,
        kvs: &[&KvBlock],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut> {
        let d = &self.manifest.dims;
        let (b, p) = (d.batch_rollout, d.prompt_len);
        if kvs.len() != b || seeds.len() != b {
            bail!(
                "decode_T{bucket}: bad input shapes ({} kv blocks, {} seeds)",
                kvs.len(),
                seeds.len()
            );
        }
        let file = self.manifest.decode_file_for(bucket)?.to_string();
        if let Engine::Sim(_) = &self.engine {
            return sim::decode_bucket_kv(&self.manifest, bucket, kvs, seeds, temp);
        }
        let mut prompts = Vec::with_capacity(b * p);
        let mut pads = Vec::with_capacity(b);
        let mut kv_flat = Vec::new();
        for block in kvs {
            prompts.extend_from_slice(&block.prompt);
            pads.push(block.pad);
            kv_flat.extend_from_slice(&block.kv);
        }
        let per_row = kv_flat.len() / b;
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.push(xla::Literal::vec1(&prompts).reshape(&[b as i64, p as i64])?);
        inputs.push(xla::Literal::vec1(&pads));
        inputs.push(xla::Literal::vec1(&kv_flat).reshape(&[b as i64, per_row as i64])?);
        inputs.push(xla::Literal::vec1(seeds));
        inputs.push(xla::Literal::from(temp));
        let outs = self.run(&file, &inputs)?;
        if outs.len() != 2 {
            bail!("decode_T{bucket}: expected 2 outputs, got {}", outs.len());
        }
        Ok(GenerateOut { tokens: outs[0].to_vec()?, lp: outs[1].to_vec()? })
    }

    fn generate_with(
        &self,
        file: &str,
        params: &ParamStore,
        prompts: &[i32],
        pad_len: &[i32],
        seed: i32,
        temp: f32,
    ) -> Result<GenerateOut> {
        let d = &self.manifest.dims;
        let (b, p) = (d.batch_rollout, d.prompt_len);
        if prompts.len() != b * p || pad_len.len() != b {
            bail!("generate: bad input shapes ({} vs {})", prompts.len(), b * p);
        }
        if let Engine::Sim(_) = &self.engine {
            return sim::generate_fixed(&self.manifest, prompts, pad_len, seed, temp);
        }
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.push(xla::Literal::vec1(prompts).reshape(&[b as i64, p as i64])?);
        inputs.push(xla::Literal::vec1(pad_len));
        inputs.push(xla::Literal::from(seed));
        inputs.push(xla::Literal::from(temp));
        let outs = self.run(file, &inputs)?;
        if outs.len() != 2 {
            bail!("generate: expected 2 outputs, got {}", outs.len());
        }
        Ok(GenerateOut { tokens: outs[0].to_vec()?, lp: outs[1].to_vec()? })
    }

    /// NAT learner gradient over one micro-batch; accumulates into `acc`.
    pub fn grad(
        &self,
        mb: &MicroBatch,
        params: &ParamStore,
        acc: &mut GradAccum,
    ) -> Result<GradMetrics> {
        let lits = params.to_literals(&self.manifest)?;
        self.grad_cached(mb, &lits, acc)
    }

    /// Grad with pre-built parameter literals (§Perf opt-2: the trainer
    /// builds them once per optimizer step and shares them across all
    /// bucket micro-batches instead of re-slicing the whole parameter
    /// store per call).
    pub fn grad_cached(
        &self,
        mb: &MicroBatch,
        param_lits: &[xla::Literal],
        acc: &mut GradAccum,
    ) -> Result<GradMetrics> {
        let d = &self.manifest.dims;
        // The micro-batch addresses one cell of a 2-D artifact grid: the
        // legacy (bucket × rows) prefix grid, or — when `gather` is set —
        // the (kept-bucket × rows) gather-compacted grid, whose artifacts
        // take the scatter index matrix as an extra operand. The fixed
        // packer always produces rows == batch_train on the legacy grid.
        let (b, p, t) = (mb.rows, d.prompt_len, mb.bucket);
        let file = if mb.gather.is_some() {
            self.manifest.grad_compact_file_for(t, b)?.to_string()
        } else {
            self.manifest.grad_file_for(t, b)?.to_string()
        };
        if let Engine::Sim(spec) = &self.engine {
            return sim::grad(&self.manifest, spec, mb, param_lits, acc);
        }
        let s = (p + t) as i64;
        let mut batch_lits = vec![
            xla::Literal::vec1(&mb.tokens).reshape(&[b as i64, s])?,
            xla::Literal::vec1(&mb.ht_w).reshape(&[b as i64, t as i64])?,
            xla::Literal::vec1(&mb.adv),
            xla::Literal::vec1(&mb.old_lp).reshape(&[b as i64, t as i64])?,
            xla::Literal::vec1(&mb.inv_len),
            xla::Literal::vec1(&mb.pad_len),
        ];
        if let Some(g) = &mb.gather {
            batch_lits.push(xla::Literal::vec1(g).reshape(&[b as i64, t as i64])?);
        }
        let inputs: Vec<&xla::Literal> =
            param_lits.iter().chain(batch_lits.iter()).collect();
        let outs = self.run_refs(&file, &inputs)?;
        let n = self.manifest.params.len();
        if outs.len() != n + 1 {
            bail!("grad: expected {} outputs, got {}", n + 1, outs.len());
        }
        acc.add_literals(&self.manifest, &outs[..n], mb.real_rows)?;
        let met: Vec<f32> = outs[n].to_vec()?;
        Ok(GradMetrics {
            loss_sum: met[0] as f64,
            tokens: met[1] as f64,
            entropy_sum: met[2] as f64,
            clip_sum: met[3] as f64,
            kl_sum: met[4] as f64,
        })
    }

    /// AdamW update from accumulated gradients. Returns pre-clip grad norm.
    pub fn apply(
        &self,
        params: &mut ParamStore,
        opt: &mut OptState,
        acc: &GradAccum,
    ) -> Result<f64> {
        opt.step += 1;
        if let Engine::Sim(_) = &self.engine {
            return sim::apply(&self.manifest, params, opt, acc);
        }
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.extend(opt.m.to_literals(&self.manifest)?);
        inputs.extend(opt.v.to_literals(&self.manifest)?);
        inputs.push(xla::Literal::from(opt.step as f32));
        let grads = ParamStore { flat: acc.flat.clone() };
        inputs.extend(grads.to_literals(&self.manifest)?);
        inputs.push(xla::Literal::from(acc.scale()));
        let file = self.manifest.apply_file.clone();
        let outs = self.run(&file, &inputs)?;
        let n = self.manifest.params.len();
        if outs.len() != 3 * n + 1 {
            bail!("apply: expected {} outputs, got {}", 3 * n + 1, outs.len());
        }
        params.from_literals(&self.manifest, &outs[..n])?;
        opt.m.from_literals(&self.manifest, &outs[n..2 * n])?;
        opt.v.from_literals(&self.manifest, &outs[2 * n..3 * n])?;
        let met: Vec<f32> = outs[3 * n].to_vec()?;
        Ok(met[0] as f64)
    }

    /// Fused SFT step in the rollout layout. tokens: [B, pretrain_len];
    /// mask: [B, pretrain_len-1]; pad_len: [B]. Returns (loss, grad_norm).
    pub fn pretrain_step(
        &self,
        params: &mut ParamStore,
        opt: &mut OptState,
        tokens: &[i32],
        loss_mask: &[f32],
        pad_len: &[i32],
    ) -> Result<(f64, f64)> {
        let d = &self.manifest.dims;
        let (b, s) = (d.batch_pretrain, d.pretrain_len);
        if tokens.len() != b * s || loss_mask.len() != b * (s - 1) || pad_len.len() != b {
            bail!("pretrain: bad input shapes");
        }
        if self.is_sim() {
            bail!("pretrain_step is not implemented by the sim runtime");
        }
        opt.step += 1;
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.extend(opt.m.to_literals(&self.manifest)?);
        inputs.extend(opt.v.to_literals(&self.manifest)?);
        inputs.push(xla::Literal::from(opt.step as f32));
        inputs.push(xla::Literal::vec1(tokens).reshape(&[b as i64, s as i64])?);
        inputs.push(xla::Literal::vec1(loss_mask).reshape(&[b as i64, (s - 1) as i64])?);
        inputs.push(xla::Literal::vec1(pad_len));
        let file = self.manifest.pretrain_file.clone();
        let outs = self.run(&file, &inputs)?;
        let n = self.manifest.params.len();
        if outs.len() != 3 * n + 1 {
            bail!("pretrain: expected {} outputs, got {}", 3 * n + 1, outs.len());
        }
        params.from_literals(&self.manifest, &outs[..n])?;
        opt.m.from_literals(&self.manifest, &outs[n..2 * n])?;
        opt.v.from_literals(&self.manifest, &outs[2 * n..3 * n])?;
        let met: Vec<f32> = outs[3 * n].to_vec()?;
        Ok((met[0] as f64, met[1] as f64))
    }

    /// Score tokens with the current policy (diagnostics / tests).
    /// tokens: [B_rollout, P + bucket]. Returns (logprobs, entropy) [B, bucket].
    pub fn score(
        &self,
        params: &ParamStore,
        tokens: &[i32],
        pad_len: &[i32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.score_impl(params, tokens, pad_len, bucket, false)
    }

    /// Scorer whose forward pass runs the L1 Pallas flash-attention kernel.
    pub fn score_pallas(
        &self,
        params: &ParamStore,
        tokens: &[i32],
        pad_len: &[i32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.score_impl(params, tokens, pad_len, bucket, true)
    }

    fn score_impl(
        &self,
        params: &ParamStore,
        tokens: &[i32],
        pad_len: &[i32],
        bucket: usize,
        pallas: bool,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if self.is_sim() {
            bail!("score is not implemented by the sim runtime");
        }
        let d = &self.manifest.dims;
        let (b, p) = (d.batch_rollout, d.prompt_len);
        let files =
            if pallas { &self.manifest.score_pallas_files } else { &self.manifest.score_files };
        let file = files
            .iter()
            .find(|(bk, _)| *bk == bucket)
            .map(|(_, f)| f.clone())
            .with_context(|| format!("no score artifact for bucket {bucket}"))?;
        let mut inputs = params.to_literals(&self.manifest)?;
        inputs.push(xla::Literal::vec1(tokens).reshape(&[b as i64, (p + bucket) as i64])?);
        inputs.push(xla::Literal::vec1(pad_len));
        let outs = self.run(&file, &inputs)?;
        Ok((outs[0].to_vec()?, outs[1].to_vec()?))
    }
}
