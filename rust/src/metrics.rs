//! Metric recording: named step-series with CSV/JSON export.
//!
//! Every figure in the paper is a per-step series aggregated over seeds;
//! the trainer pushes into a `Recorder`, the experiment harness merges
//! recorders across runs and renders figure data files.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr_f64, obj, Json};

#[derive(Clone, Debug, Default)]
pub struct Recorder {
    /// series name -> (step, value) pairs in push order.
    series: BTreeMap<String, Vec<(u64, f64)>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: &str, step: u64, value: f64) {
        self.series.entry(name.to_string()).or_default().push((step, value));
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> &[(u64, f64)] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn values(&self, name: &str) -> Vec<f64> {
        self.get(name).iter().map(|&(_, v)| v).collect()
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.get(name).last().map(|&(_, v)| v)
    }

    /// Mean over a whole series (e.g. the pipeline's realized `staleness`).
    pub fn mean(&self, name: &str) -> Option<f64> {
        self.tail_mean(name, 1.0)
    }

    /// Mean of the final `frac` fraction of a series (plateau statistic).
    pub fn tail_mean(&self, name: &str, frac: f64) -> Option<f64> {
        let vals = self.values(name);
        if vals.is_empty() {
            return None;
        }
        let k = ((vals.len() as f64 * frac).ceil() as usize).clamp(1, vals.len());
        Some(vals[vals.len() - k..].iter().sum::<f64>() / k as f64)
    }

    pub fn to_json(&self) -> Json {
        let mut items = Vec::new();
        for (name, pts) in &self.series {
            items.push(obj(vec![
                ("name", Json::Str(name.clone())),
                ("steps", Json::Arr(pts.iter().map(|&(s, _)| Json::Num(s as f64)).collect())),
                ("values", arr_f64(&pts.iter().map(|&(_, v)| v).collect::<Vec<_>>())),
            ]));
        }
        Json::Arr(items)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Wide CSV: step, series1, series2, ... (missing cells empty).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let names: Vec<&String> = self.series.keys().collect();
        let mut steps: Vec<u64> = self
            .series
            .values()
            .flat_map(|v| v.iter().map(|&(s, _)| s))
            .collect();
        steps.sort();
        steps.dedup();
        let mut f = std::fs::File::create(path)?;
        write!(f, "step")?;
        for n in &names {
            write!(f, ",{n}")?;
        }
        writeln!(f)?;
        for s in steps {
            write!(f, "{s}")?;
            for n in &names {
                match self.series[*n].iter().find(|&&(st, _)| st == s) {
                    Some(&(_, v)) => write!(f, ",{v}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_query() {
        let mut r = Recorder::new();
        r.push("reward", 0, 0.1);
        r.push("reward", 1, 0.3);
        r.push("entropy", 0, 2.0);
        assert_eq!(r.values("reward"), vec![0.1, 0.3]);
        assert_eq!(r.last("reward"), Some(0.3));
        assert_eq!(r.last("missing"), None);
        assert_eq!(r.names(), vec!["entropy", "reward"]);
    }

    #[test]
    fn tail_mean() {
        let mut r = Recorder::new();
        for i in 0..10 {
            r.push("x", i, i as f64);
        }
        assert_eq!(r.tail_mean("x", 0.2), Some(8.5)); // mean of 8, 9
        assert_eq!(r.tail_mean("x", 1.0), Some(4.5));
        assert_eq!(r.tail_mean("none", 0.5), None);
        assert_eq!(r.mean("x"), Some(4.5));
        assert_eq!(r.mean("none"), None);
    }

    #[test]
    fn tail_mean_edge_cases() {
        let mut r = Recorder::new();
        // empty recorder / missing series: no statistic, not a panic
        assert_eq!(r.tail_mean("x", 0.5), None);
        assert_eq!(r.mean("x"), None);
        for i in 0..4 {
            r.push("x", i, i as f64); // 0 1 2 3
        }
        // frac = 0 clamps to a single (last) sample
        assert_eq!(r.tail_mean("x", 0.0), Some(3.0));
        // frac > 1 clamps to the whole series
        assert_eq!(r.tail_mean("x", 2.5), Some(1.5));
        // negative frac saturates to the single-sample floor
        assert_eq!(r.tail_mean("x", -1.0), Some(3.0));
        // a tiny positive frac still averages at least one sample
        assert_eq!(r.tail_mean("x", 1e-9), Some(3.0));
        // single-point series: every frac yields that point
        r.push("y", 0, 7.0);
        for frac in [0.0, 0.5, 1.0, 10.0] {
            assert_eq!(r.tail_mean("y", frac), Some(7.0));
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Recorder::new();
        r.push("a", 0, 1.5);
        r.push("a", 2, 2.5);
        let j = r.to_json();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        assert_eq!(j2.idx(0).unwrap().get("name").unwrap().as_str(), Some("a"));
    }

    #[test]
    fn json_file_roundtrip_recovers_series() {
        let mut r = Recorder::new();
        r.push("reward", 0, 0.25);
        r.push("reward", 1, 0.5);
        r.push("flop_saving", 1, 0.62);
        let dir = std::env::temp_dir()
            .join(format!("nat_rl_metrics_json_{}", std::process::id()));
        let path = dir.join("m.json");
        r.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // series come back sorted by name with aligned steps/values
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("flop_saving"));
        let rewards = &arr[1];
        assert_eq!(rewards.get("name").unwrap().as_str(), Some("reward"));
        let steps: Vec<i64> = rewards
            .get("steps")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.as_i64().unwrap())
            .collect();
        let vals: Vec<f64> = rewards
            .get("values")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(steps, vec![0, 1]);
        assert_eq!(vals, vec![0.25, 0.5]);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn csv_roundtrip_recovers_values() {
        let mut r = Recorder::new();
        r.push("a", 0, 1.5);
        r.push("a", 1, -2.0);
        r.push("b", 0, 0.125);
        let dir = std::env::temp_dir()
            .join(format!("nat_rl_metrics_csv_{}", std::process::id()));
        let path = dir.join("m.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("step,a,b"));
        let mut r2 = Recorder::new();
        for line in lines {
            let cells: Vec<&str> = line.split(',').collect();
            let step: u64 = cells[0].parse().unwrap();
            for (name, cell) in ["a", "b"].into_iter().zip(&cells[1..]) {
                if !cell.is_empty() {
                    r2.push(name, step, cell.parse().unwrap());
                }
            }
        }
        assert_eq!(r2.get("a"), r.get("a"));
        assert_eq!(r2.get("b"), r.get("b"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn csv_layout() {
        let mut r = Recorder::new();
        r.push("a", 0, 1.0);
        r.push("b", 1, 2.0);
        let dir = std::env::temp_dir().join("nat_rl_metrics_test");
        let path = dir.join("m.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,a,b");
        assert_eq!(lines[1], "0,1,");
        assert_eq!(lines[2], "1,,2");
        let _ = std::fs::remove_dir_all(dir);
    }
}
