//! Golden-trace machinery shared by the tier-1 regression test and the
//! `nat golden` subcommand.
//!
//! The golden lane pins a 3-step training trace from the seed configuration
//! (sim runtime, seed 0, RPC(C=8), budget packer) as one canonical line per
//! step: every non-timing `StepStats` field in shortest-roundtrip decimal
//! plus an FNV-1a hash of the post-step parameter bits. The committed
//! fixture at `tests/golden/sim_trace_v1.txt` must replay bit-exactly —
//! any refactor that silently changes training semantics fails tier-1
//! instead of shipping. The sim kernels use only IEEE-exact float ops (no
//! transcendentals), so the fixture is portable across hosts.
//!
//! `nat golden --write` (re)generates the fixture; `nat golden --check`
//! exits nonzero on drift or a missing fixture (the CI drift gate);
//! `tests/golden_trace.rs` wraps the same functions.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::pipeline::PipelineTrainer;
use crate::coordinator::trainer::{StepStats, Trainer};
use crate::runtime::sim::{init_params, sim_manifest};
use crate::runtime::{OptState, Runtime};
use crate::tasks::Tier;
use crate::util::cli::Args;

/// FNV-1a over parameter bit patterns — THE param-hash contract used by the
/// sharding proptest, the golden-trace lines, and `nat golden`; one
/// definition means they can never disagree about what "identical
/// parameters" means.
pub fn fnv1a(flat: &[f32]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &x in flat {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01B3);
        }
    }
    h
}

/// The seed config of the trace (kept independent of `RunConfig` default
/// drift for the documented fields: any change here invalidates the
/// fixture on purpose).
pub fn trace_cfg(shards: usize, workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "sim".into();
    cfg.seed = 0;
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 4;
    cfg.train.shards = shards;
    cfg.pipeline.workers = workers;
    cfg
}

/// One canonical fixture line: every non-timing stat plus the param hash.
pub fn stat_line(s: &StepStats, param_hash: u64) -> String {
    format!(
        "step {} hash {:016x} reward {} entropy {} clip {} kl {} gnorm {} sel {} btgt {} \
         breal {} svar {} rlen {} waste {} mem {} peak {} mb {} seqs {}",
        s.step,
        param_hash,
        s.reward_mean,
        s.entropy,
        s.clip_frac,
        s.kl,
        s.grad_norm,
        s.selected_ratio,
        s.budget_target,
        s.budget_realized,
        s.sel_var,
        s.resp_len_mean,
        s.padding_waste,
        s.mem_gb,
        s.peak_mem_gb,
        s.micro_batches,
        s.sequences
    )
}

/// Run the 3-step serial seed trace with the given shard count; `shards`
/// must not change a single bit of it (the sharded-learner invariance).
pub fn serial_trace(shards: usize) -> Result<Vec<String>> {
    let rt = Runtime::sim(sim_manifest());
    let params = init_params(&rt.manifest);
    let opt = OptState::zeros(&rt.manifest);
    let mut tr = Trainer::new(&rt, trace_cfg(shards, 0), params, opt);
    let mut out = Vec::new();
    for _ in 0..3 {
        let s = tr.step()?;
        out.push(stat_line(&s, fnv1a(&tr.params.flat)));
    }
    Ok(out)
}

/// Final parameter hash after the same 3 steps under the pipelined trainer
/// (the pipelined-scheduler invariance: must equal the serial final hash).
pub fn pipelined_final_hash(shards: usize, workers: usize) -> Result<u64> {
    let rt = Runtime::sim(sim_manifest());
    let params = init_params(&rt.manifest);
    let opt = OptState::zeros(&rt.manifest);
    let mut tr = PipelineTrainer::new(&rt, trace_cfg(shards, workers), params, opt);
    tr.train(3, false)?;
    Ok(fnv1a(&tr.params.flat))
}

/// The committed fixture location.
pub fn fixture_path() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/sim_trace_v1.txt"))
}

/// Render the full fixture document (trailing newline included).
pub fn render_trace() -> Result<String> {
    Ok(serial_trace(1)?.join("\n") + "\n")
}

/// `nat golden [--write] [--check]`
///
/// Default prints the freshly computed trace. `--write` saves it as the
/// fixture (then commit the file). `--check` compares against the committed
/// fixture and exits nonzero on drift or when no fixture is committed yet —
/// the CI drift gate.
pub fn cmd_golden(args: &Args) -> Result<()> {
    let rendered = render_trace()?;
    let path = fixture_path();
    if args.has_flag("write") {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, &rendered)?;
        println!("nat golden: fixture written to {} — commit this file", path.display());
        return Ok(());
    }
    if args.has_flag("check") {
        if !path.exists() {
            bail!(
                "nat golden --check: no fixture at {} — run `nat golden --write` \
                 and commit the file",
                path.display()
            );
        }
        let committed = std::fs::read_to_string(&path)?;
        if committed != rendered {
            eprintln!("--- committed\n{committed}--- computed\n{rendered}");
            bail!(
                "nat golden --check: training semantics drifted from {}. If the \
                 change is intentional, rerun with --write and commit the new \
                 fixture with an explanation.",
                path.display()
            );
        }
        println!("nat golden: trace matches {}", path.display());
        return Ok(());
    }
    print!("{rendered}");
    Ok(())
}
