//! Structured tracing + savings accounting for the step path.
//!
//! Three pieces:
//!
//! * [`Tracer`] / [`TraceSink`] — lightweight span/event emission threaded
//!   through rollout scheduling, selection, packing, shard execution, the
//!   tree reduction, the optimizer apply, and the pipeline queue. The
//!   tracer is a cheap-clonable handle around `Option<Arc<..>>`: with
//!   tracing off (the default) every call is a branch on `None` — no clock
//!   reads, no allocation, no RNG, no float work — so golden traces and
//!   param hashes are bit-identical to a build with no obs layer at all
//!   (asserted in `tests/obs.rs`).
//! * Sinks: NDJSON (`--obs.trace path`, one JSON object per line, the
//!   format `nat trace` analyzes) and Chrome trace format (`--obs.chrome
//!   path`, open in `chrome://tracing` or <https://ui.perfetto.dev>).
//! * [`ledger::StepLedger`] — the per-step token/FLOP/memory savings
//!   ledger (generated vs selected vs allocated vs backpropped tokens,
//!   grad FLOPs vs the full-token-GRPO counterfactual, HT-weight
//!   extremes). The ledger is *always* computed — it is deterministic and
//!   cheap — so enabling tracing cannot perturb `StepStats`; `--obs.ledger`
//!   only gates the recorder series.
//!
//! NDJSON line schema (all spans are Chrome-style "X" complete events):
//! `{"name":"learn.grad","ph":"X","step":3,"tid":1,"ts":123,"dur":456,
//!   "args":{"rows":4,"tokens":192}}` — `ts`/`dur` in microseconds since
//! the tracer's epoch; `tid` is 0 for the coordinator thread and
//! `1 + shard_id` for shard workers. The per-step ledger is emitted as a
//! zero-duration `"ledger"` event whose args are the ledger fields.

pub mod analyze;
pub mod ledger;

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ObsCfg;
use crate::util::json::Json;

/// One emitted span or instant event (borrowed; sinks serialize it).
pub struct TraceEvent<'a> {
    pub name: &'a str,
    pub step: u64,
    pub tid: u64,
    /// Microseconds since the tracer epoch.
    pub ts_us: u64,
    /// Span duration in microseconds (0 for instant events).
    pub dur_us: u64,
    pub args: &'a [(&'a str, f64)],
}

impl TraceEvent<'_> {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.to_string()));
        m.insert("ph".to_string(), Json::Str("X".to_string()));
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("tid".to_string(), Json::Num(self.tid as f64));
        m.insert("ts".to_string(), Json::Num(self.ts_us as f64));
        m.insert("dur".to_string(), Json::Num(self.dur_us as f64));
        let args: BTreeMap<String, Json> =
            self.args.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect();
        m.insert("args".to_string(), Json::Obj(args));
        Json::Obj(m)
    }
}

/// Receives every event; implementations must be thread-safe (shard
/// workers emit concurrently with the coordinator).
pub trait TraceSink: Send + Sync {
    fn event(&self, ev: &TraceEvent<'_>);
    fn flush(&self) -> Result<()>;
}

struct Inner {
    epoch: Instant,
    sinks: Vec<Box<dyn TraceSink>>,
}

/// Cheap-clonable tracing handle. `Tracer::off()` (the `Default`) is the
/// zero-cost no-op; `Tracer::from_cfg` builds the configured sinks.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<Inner>>);

impl Tracer {
    /// Tracing disabled: every span/event call is a no-op branch.
    pub fn off() -> Tracer {
        Tracer(None)
    }

    /// Build the sink set from the `--obs.*` config group. Empty paths
    /// mean "no sink"; with no sinks at all the tracer is `off()`.
    pub fn from_cfg(obs: &ObsCfg) -> Result<Tracer> {
        let mut sinks: Vec<Box<dyn TraceSink>> = Vec::new();
        if !obs.trace.is_empty() {
            sinks.push(Box::new(NdjsonSink::create(Path::new(&obs.trace))?));
        }
        if !obs.chrome.is_empty() {
            sinks.push(Box::new(ChromeSink::create(Path::new(&obs.chrome))?));
        }
        if sinks.is_empty() {
            return Ok(Tracer::off());
        }
        Ok(Tracer(Some(Arc::new(Inner { epoch: Instant::now(), sinks }))))
    }

    /// A tracer over an arbitrary sink (tests, custom exporters).
    pub fn with_sink(sink: Box<dyn TraceSink>) -> Tracer {
        Tracer(Some(Arc::new(Inner { epoch: Instant::now(), sinks: vec![sink] })))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Zero-duration instant event (used for the per-step ledger).
    pub fn event(&self, name: &str, step: u64, args: &[(&str, f64)]) {
        if let Some(inner) = &self.0 {
            let ev = TraceEvent {
                name,
                step,
                tid: 0,
                ts_us: inner.epoch.elapsed().as_micros() as u64,
                dur_us: 0,
                args,
            };
            for s in &inner.sinks {
                s.event(&ev);
            }
        }
    }

    /// RAII span guard: the duration is measured and emitted when the
    /// guard drops. Prefer the [`span!`](crate::span) macro for args.
    pub fn span(&self, name: &'static str, step: u64) -> Span<'_> {
        let start = self
            .0
            .as_ref()
            .map(|i| (i.epoch.elapsed().as_micros() as u64, Instant::now()));
        Span { tracer: self, name, step, tid: 0, start, args: Vec::new() }
    }

    pub fn flush(&self) -> Result<()> {
        if let Some(inner) = &self.0 {
            for s in &inner.sinks {
                s.flush()?;
            }
        }
        Ok(())
    }
}

/// RAII span guard returned by [`Tracer::span`]; emits on drop.
pub struct Span<'a> {
    tracer: &'a Tracer,
    name: &'static str,
    step: u64,
    tid: u64,
    start: Option<(u64, Instant)>,
    args: Vec<(&'static str, f64)>,
}

impl Span<'_> {
    /// Attach a numeric argument (no-op when tracing is off).
    pub fn arg(&mut self, key: &'static str, value: f64) {
        if self.start.is_some() {
            self.args.push((key, value));
        }
    }

    /// Chrome-trace lane id (shard workers use `1 + shard_id`).
    pub fn set_tid(&mut self, tid: u64) {
        self.tid = tid;
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let (Some((ts_us, t0)), Some(inner)) = (self.start.take(), self.tracer.0.as_deref()) {
            let ev = TraceEvent {
                name: self.name,
                step: self.step,
                tid: self.tid,
                ts_us,
                dur_us: t0.elapsed().as_micros() as u64,
                args: &self.args,
            };
            for s in &inner.sinks {
                s.event(&ev);
            }
        }
    }
}

/// `span!(tracer, step, "learn.grad", {rows: r, tokens: t})` — an RAII
/// span guard with named numeric args (each value cast `as f64`).
#[macro_export]
macro_rules! span {
    ($tracer:expr, $step:expr, $name:expr) => {
        $tracer.span($name, $step as u64)
    };
    ($tracer:expr, $step:expr, $name:expr, { $($k:ident : $v:expr),* $(,)? }) => {{
        #[allow(unused_mut)]
        let mut sp = $tracer.span($name, $step as u64);
        $(sp.arg(stringify!($k), $v as f64);)*
        sp
    }};
}

// ------------------------------------------------------------------ sinks

/// One JSON object per line, append-only; the format `nat trace` reads.
pub struct NdjsonSink {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl NdjsonSink {
    pub fn create(path: &Path) -> Result<NdjsonSink> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(NdjsonSink { w: Mutex::new(std::io::BufWriter::new(f)) })
    }
}

impl TraceSink for NdjsonSink {
    fn event(&self, ev: &TraceEvent<'_>) {
        let line = ev.to_json().to_string();
        let mut w = self.w.lock().expect("trace sink poisoned");
        // Emission is best-effort: a full disk must not kill training.
        let _ = writeln!(w, "{line}");
    }

    fn flush(&self) -> Result<()> {
        self.w.lock().expect("trace sink poisoned").flush()?;
        Ok(())
    }
}

/// Chrome trace format (catapult JSON object form): buffered in memory,
/// written on flush. Open in `chrome://tracing` or ui.perfetto.dev.
pub struct ChromeSink {
    path: std::path::PathBuf,
    events: Mutex<Vec<Json>>,
}

impl ChromeSink {
    pub fn create(path: &Path) -> Result<ChromeSink> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        Ok(ChromeSink { path: path.to_path_buf(), events: Mutex::new(Vec::new()) })
    }
}

impl TraceSink for ChromeSink {
    fn event(&self, ev: &TraceEvent<'_>) {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(ev.name.to_string()));
        m.insert("ph".to_string(), Json::Str("X".to_string()));
        m.insert("pid".to_string(), Json::Num(0.0));
        m.insert("tid".to_string(), Json::Num(ev.tid as f64));
        m.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
        m.insert("dur".to_string(), Json::Num(ev.dur_us.max(1) as f64));
        let mut args: BTreeMap<String, Json> =
            ev.args.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect();
        args.insert("step".to_string(), Json::Num(ev.step as f64));
        m.insert("args".to_string(), Json::Obj(args));
        self.events.lock().expect("trace sink poisoned").push(Json::Obj(m));
    }

    fn flush(&self) -> Result<()> {
        let events = self.events.lock().expect("trace sink poisoned").clone();
        let mut m = BTreeMap::new();
        m.insert("traceEvents".to_string(), Json::Arr(events));
        std::fs::write(&self.path, Json::Obj(m).to_string())
            .with_context(|| format!("writing chrome trace {}", self.path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Collects rendered NDJSON lines in memory.
    struct MemSink(Mutex<Vec<String>>);

    impl TraceSink for MemSink {
        fn event(&self, ev: &TraceEvent<'_>) {
            self.0.lock().unwrap().push(ev.to_json().to_string());
        }
        fn flush(&self) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn off_tracer_is_inert() {
        let t = Tracer::off();
        assert!(!t.enabled());
        {
            let mut s = span!(t, 3, "learn.grad", { rows: 4, tokens: 128 });
            s.arg("extra", 1.0);
        }
        t.event("ledger", 3, &[("gen_tokens", 10.0)]);
        t.flush().unwrap();
    }

    #[test]
    fn span_emits_on_drop_with_args() {
        let lines = Arc::new(MemSink(Mutex::new(Vec::new())));
        struct Shared(Arc<MemSink>);
        impl TraceSink for Shared {
            fn event(&self, ev: &TraceEvent<'_>) {
                self.0.event(ev)
            }
            fn flush(&self) -> Result<()> {
                self.0.flush()
            }
        }
        let t = Tracer::with_sink(Box::new(Shared(lines.clone())));
        {
            let _sp = span!(t, 7, "learn.pack", { items: 5 });
        }
        t.event("ledger", 7, &[("gen_tokens", 64.0)]);
        let got = lines.0.lock().unwrap().clone();
        assert_eq!(got.len(), 2);
        let sp = Json::parse(&got[0]).unwrap();
        assert_eq!(sp.get("name").unwrap().as_str(), Some("learn.pack"));
        assert_eq!(sp.get("step").unwrap().as_i64(), Some(7));
        assert_eq!(sp.get("args").unwrap().get("items").unwrap().as_i64(), Some(5));
        let ev = Json::parse(&got[1]).unwrap();
        assert_eq!(ev.get("dur").unwrap().as_i64(), Some(0));
        assert_eq!(ev.get("args").unwrap().get("gen_tokens").unwrap().as_i64(), Some(64));
    }

    #[test]
    fn ndjson_and_chrome_sinks_write_parseable_output() {
        let dir = std::env::temp_dir().join(format!("nat_obs_test_{}", std::process::id()));
        let nd = dir.join("t.ndjson");
        let ch = dir.join("t.chrome.json");
        let cfg = ObsCfg {
            trace: nd.to_str().unwrap().to_string(),
            chrome: ch.to_str().unwrap().to_string(),
            ledger: true,
        };
        let t = Tracer::from_cfg(&cfg).unwrap();
        assert!(t.enabled());
        {
            let _sp = span!(t, 0, "rollout", { seqs: 8 });
        }
        t.flush().unwrap();
        let text = std::fs::read_to_string(&nd).unwrap();
        for line in text.lines() {
            Json::parse(line).unwrap();
        }
        let chrome = Json::parse(&std::fs::read_to_string(&ch).unwrap()).unwrap();
        assert!(!chrome.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_obs_cfg_is_off() {
        let t = Tracer::from_cfg(&ObsCfg::default()).unwrap();
        assert!(!t.enabled());
    }
}
