//! `nat trace` — offline analyzer for NDJSON traces written by
//! `--obs.trace`.
//!
//! Reads one trace file, aggregates spans by stage name, and prints:
//!
//! * a per-stage wall-clock/token table (calls, total ms, share of the
//!   `learn.step` parent for learner stages),
//! * the stage *coverage* — how much of `learn.step`'s wall-clock the
//!   child stages account for (the acceptance gate asks ≥ 90%: anything
//!   less means a hot region is untraced),
//! * the savings ledger's headline ratios (fraction of tokens selected /
//!   backpropped, estimated grad-FLOP time saving and peak-memory saving
//!   vs the full-token-GRPO counterfactual, HT-weight extremes).
//!
//! `--check` turns the report into an assertion (used by the CI
//! trace-smoke lane): stage coverage ≥ 90% of `learn.step`, the
//! ledger's expected-selected-token fraction agrees with the trainer's
//! `budget_realized` within 1% of generated tokens, and — whenever a π
//! floor was in force (`--train.pi_floor` under a budget-solved selection
//! mode) — the largest realized HT weight respects the `1/pi_floor` bound
//! the floor guarantees by construction. The budget comparison's two sides
//! are computed by independent code paths (closed-form `expected_sum` vs
//! per-plan probability sums), so the gate is deterministic — no sampling
//! noise.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Aggregate of all spans sharing one stage name.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageAgg {
    pub calls: u64,
    pub wall_us: f64,
    /// Sum of the spans' `tokens` arg where present.
    pub tokens: f64,
}

/// Sums/extremes of the per-step `"ledger"` events.
#[derive(Clone, Copy, Debug, Default)]
pub struct LedgerAgg {
    pub steps: u64,
    pub gen_tokens: f64,
    pub sel_tokens: f64,
    pub sel_tokens_exp: f64,
    pub backprop_tokens: f64,
    pub alloc_tokens: f64,
    pub ideal_tokens: f64,
    pub grad_flops: f64,
    pub grad_flops_full: f64,
    pub peak_bytes: f64,
    pub peak_bytes_full: f64,
    pub ht_w_max: f64,
    pub ht_ess_sum: f64,
    /// Largest per-step π floor seen in the trace (0 = no floor in force).
    pub pi_floor: f64,
    /// Worst per-step `ht_w_max · pi_floor` over steps where a floor was in
    /// force — the floor contract says each step's weights obey
    /// `w_max ≤ 1/pi_floor`, so any value above 1 is a violation (checked
    /// per step, which stays exact even if the floor changed mid-trace).
    pub ht_w_excess: f64,
    pub budget_realized: f64,
    pub alloc_tokens_prefix: f64,
    pub compact_kept: f64,
    pub compact_alloc: f64,
    pub compact_bound: f64,
    /// Prefill token-steps the shared-prefix cache avoided (summed).
    pub prefill_steps_saved: f64,
    /// Prefix-cache hits / lookups (summed); `check()` gates hits ≤ lookups.
    pub prefix_hits: f64,
    pub prefix_lookups: f64,
    /// Largest resident cache size seen in the trace.
    pub cache_bytes: f64,
}

#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Stage name → aggregate, iteration-ordered by name.
    pub stages: BTreeMap<String, StageAgg>,
    pub ledger: LedgerAgg,
}

impl Report {
    fn learn_wall_us(&self) -> f64 {
        self.stages.get("learn.step").map_or(0.0, |s| s.wall_us)
    }

    /// Summed wall-clock of the `learn.*` child stages (everything under
    /// the `learn.step` parent except the parent itself and the per-shard
    /// `shard.grad` spans, which run concurrently inside `learn.grad` and
    /// would double-count).
    fn covered_us(&self) -> f64 {
        self.stages
            .iter()
            .filter(|(name, _)| {
                name.starts_with("learn.") && name.as_str() != "learn.step"
            })
            .map(|(_, s)| s.wall_us)
            .sum()
    }

    /// Fraction of `learn.step` wall-clock the child stages cover; `None`
    /// when the trace has no learner spans.
    pub fn coverage(&self) -> Option<f64> {
        let learn = self.learn_wall_us();
        (learn > 0.0).then(|| self.covered_us() / learn)
    }

    /// |E[selected] − budget_realized| as a fraction of generated tokens.
    pub fn budget_gap(&self) -> f64 {
        if self.ledger.gen_tokens > 0.0 {
            (self.ledger.sel_tokens_exp - self.ledger.budget_realized).abs()
                / self.ledger.gen_tokens
        } else {
            0.0
        }
    }

    /// The CI gate: stage coverage ≥ 90% and budget agreement within 1%.
    pub fn check(&self) -> Result<()> {
        if let Some(cov) = self.coverage() {
            if cov < 0.90 {
                bail!(
                    "stage coverage {:.1}% of learn.step is below the 90% gate \
                     — a hot learner region is untraced",
                    100.0 * cov
                );
            }
        } else {
            bail!("trace has no learn.step spans — was --obs.trace enabled?");
        }
        if self.ledger.steps == 0 {
            bail!("trace has no ledger events");
        }
        let gap = self.budget_gap();
        if gap > 0.01 {
            bail!(
                "ledger E[selected] vs budget_realized disagree by {:.2}% of \
                 generated tokens (gate 1%)",
                100.0 * gap
            );
        }
        // Compaction gate (active only when the compacted layout packed
        // anything): the backpropped (gathered) tokens and the allocation
        // must agree within the row-grid rounding bound — kept ≤ allocated
        // ≤ bound, where the bound is re-derived from the gather contents.
        // An allocation above the bound means the packer inflated compacted
        // micro-batches; kept above the allocation means slots were lost.
        let l = &self.ledger;
        if l.compact_alloc > 0.0 {
            let eps = 1e-6 * l.compact_alloc.max(1.0);
            if l.compact_kept > l.compact_alloc + eps {
                bail!(
                    "compacted ledger: kept tokens {:.1} exceed allocated {:.1} \
                     — gather slots were lost",
                    l.compact_kept,
                    l.compact_alloc
                );
            }
            if l.compact_alloc > l.compact_bound + eps {
                bail!(
                    "compacted ledger: allocated tokens {:.1} exceed the row-grid \
                     rounding bound {:.1} — the packer inflated compacted \
                     micro-batches",
                    l.compact_alloc,
                    l.compact_bound
                );
            }
        }
        // HT-weight-health gate (active whenever a π floor was in force):
        // flooring every budget-solved π at selection time bounds the
        // largest 1/π weight at 1/pi_floor by construction, so a violation
        // means some selector sampled with a probability below the floor it
        // solved with — exactly the runaway-weight bug the floor exists to
        // make impossible.
        if l.ht_w_excess > 1.0 + 1e-6 {
            bail!(
                "HT weight max {:.3} exceeds the 1/pi_floor bound {:.3} — a \
                 budget-solved selector sampled below its π floor",
                l.ht_w_max,
                1.0 / l.pi_floor
            );
        }
        // Prefix-cache hit accounting (active whenever the cache did any
        // lookups): a hit count above the lookup count, or savings reported
        // with zero hits, means the scheduler's accounting drifted from the
        // cache's — the exact bug class that would silently inflate the
        // BENCH_prefix saving claim.
        if l.prefix_hits > l.prefix_lookups {
            bail!(
                "prefix cache: {} hits exceed {} lookups — hit accounting is \
                 broken",
                l.prefix_hits,
                l.prefix_lookups
            );
        }
        if l.prefill_steps_saved > 0.0 && l.prefix_hits == 0.0 {
            bail!(
                "prefix cache: {} prefill steps saved with zero hits — savings \
                 must come from hits",
                l.prefill_steps_saved
            );
        }
        Ok(())
    }

    /// Human-readable per-stage table + savings summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let learn = self.learn_wall_us();
        let _ = writeln!(
            s,
            "{:<16} {:>7} {:>12} {:>8} {:>12}",
            "stage", "calls", "wall_ms", "%learn", "tokens"
        );
        for (name, agg) in &self.stages {
            let pct = if learn > 0.0 && name.starts_with("learn.") && name != "learn.step" {
                format!("{:.1}", 100.0 * agg.wall_us / learn)
            } else {
                "-".to_string()
            };
            let toks =
                if agg.tokens > 0.0 { format!("{:.0}", agg.tokens) } else { "-".to_string() };
            let _ = writeln!(
                s,
                "{:<16} {:>7} {:>12.3} {:>8} {:>12}",
                name,
                agg.calls,
                agg.wall_us / 1e3,
                pct,
                toks
            );
        }
        match self.coverage() {
            Some(cov) => {
                let _ = writeln!(
                    s,
                    "\nstage coverage: {:.1}% of learn.step wall-clock",
                    100.0 * cov
                );
            }
            None => {
                let _ = writeln!(s, "\nstage coverage: no learn.step spans in trace");
            }
        }
        let l = &self.ledger;
        if l.steps == 0 {
            let _ = writeln!(s, "no ledger events in trace");
            return s;
        }
        let n = l.steps as f64;
        let pct = |num: f64, den: f64| if den > 0.0 { 100.0 * num / den } else { 0.0 };
        let _ = writeln!(s, "\nsavings ledger ({} steps, per-step means):", l.steps);
        let _ = writeln!(s, "  generated tokens      {:>12.1}", l.gen_tokens / n);
        let _ = writeln!(
            s,
            "  selected tokens (E)   {:>12.1}   {:.1}% of generated (realized {:.1})",
            l.sel_tokens_exp / n,
            pct(l.sel_tokens_exp, l.gen_tokens),
            l.sel_tokens / n
        );
        let _ = writeln!(
            s,
            "  backprop prefix       {:>12.1}   {:.1}% of generated",
            l.backprop_tokens / n,
            pct(l.backprop_tokens, l.gen_tokens)
        );
        let _ = writeln!(
            s,
            "  allocated (padded)    {:>12.1}   padding waste {:.1}%",
            l.alloc_tokens / n,
            pct(l.alloc_tokens - l.ideal_tokens, l.alloc_tokens)
        );
        if l.compact_alloc > 0.0 {
            let _ = writeln!(
                s,
                "  compacted layout      {:>12.1}   vs prefix-packed {:.1} → realized saving {:.1}% \
                 (kept {:.1}, bound {:.1})",
                l.compact_alloc / n,
                l.alloc_tokens_prefix / n,
                pct(l.alloc_tokens_prefix - l.alloc_tokens, l.alloc_tokens_prefix),
                l.compact_kept / n,
                l.compact_bound / n
            );
        }
        let _ = writeln!(
            s,
            "  grad FLOPs            {:>12.3e}   vs full-GRPO {:.3e} → est. time saving {:.1}%",
            l.grad_flops / n,
            l.grad_flops_full / n,
            pct(l.grad_flops_full - l.grad_flops, l.grad_flops_full)
        );
        let _ = writeln!(
            s,
            "  peak memory           {:>9.4} GB   vs full-GRPO {:.4} GB → est. memory saving {:.1}%",
            l.peak_bytes / 1e9,
            l.peak_bytes_full / 1e9,
            pct(l.peak_bytes_full - l.peak_bytes, l.peak_bytes_full)
        );
        if l.pi_floor > 0.0 {
            let _ = writeln!(
                s,
                "  HT weights            max {:.3}, mean ESS {:.1}   (bound 1/pi_floor = {:.1})",
                l.ht_w_max,
                l.ht_ess_sum / n,
                1.0 / l.pi_floor
            );
        } else {
            let _ = writeln!(
                s,
                "  HT weights            max {:.3}, mean ESS {:.1}",
                l.ht_w_max,
                l.ht_ess_sum / n
            );
        }
        if l.prefix_lookups > 0.0 {
            let _ = writeln!(
                s,
                "  prefix cache          {:>12.1} prefill steps saved/step   hit rate {:.1}% \
                 (peak {:.2} MiB)",
                l.prefill_steps_saved / n,
                pct(l.prefix_hits, l.prefix_lookups),
                l.cache_bytes / (1 << 20) as f64
            );
        }
        let _ = writeln!(
            s,
            "  budget agreement      |E[sel] − realized| = {:.3}% of generated (gate 1%)",
            100.0 * self.budget_gap()
        );
        s
    }
}

/// Parse an NDJSON trace into the aggregate report.
pub fn analyze(text: &str) -> Result<Report> {
    let mut report = Report::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("trace line {}: missing name", i + 1))?;
        let args = ev.get("args");
        let arg = |key: &str| -> f64 {
            args.and_then(|a| a.get(key)).and_then(Json::as_f64).unwrap_or(0.0)
        };
        if name == "ledger" {
            let l = &mut report.ledger;
            l.steps += 1;
            l.gen_tokens += arg("gen_tokens");
            l.sel_tokens += arg("sel_tokens");
            l.sel_tokens_exp += arg("sel_tokens_exp");
            l.backprop_tokens += arg("backprop_tokens");
            l.alloc_tokens += arg("alloc_tokens");
            l.ideal_tokens += arg("ideal_tokens");
            l.grad_flops += arg("grad_flops");
            l.grad_flops_full += arg("grad_flops_full");
            l.peak_bytes = l.peak_bytes.max(arg("peak_bytes"));
            l.peak_bytes_full = l.peak_bytes_full.max(arg("peak_bytes_full"));
            l.ht_w_max = l.ht_w_max.max(arg("ht_w_max"));
            l.ht_ess_sum += arg("ht_ess");
            let pf = arg("pi_floor");
            if pf > 0.0 {
                l.pi_floor = l.pi_floor.max(pf);
                l.ht_w_excess = l.ht_w_excess.max(arg("ht_w_max") * pf);
            }
            l.budget_realized += arg("budget_realized");
            l.alloc_tokens_prefix += arg("alloc_tokens_prefix");
            l.compact_kept += arg("compact_kept");
            l.compact_alloc += arg("compact_alloc");
            l.compact_bound += arg("compact_bound");
            l.prefill_steps_saved += arg("prefill_steps_saved");
            l.prefix_hits += arg("prefix_hits");
            l.prefix_lookups += arg("prefix_lookups");
            l.cache_bytes = l.cache_bytes.max(arg("cache_bytes"));
            continue;
        }
        let dur = ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
        let agg = report.stages.entry(name.to_string()).or_default();
        agg.calls += 1;
        agg.wall_us += dur;
        agg.tokens += arg("tokens");
    }
    Ok(report)
}

/// `nat trace --in path.ndjson [--check]`.
pub fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .get("in")
        .map(str::to_string)
        .or_else(|| args.positional.first().cloned())
        .context("nat trace: pass the NDJSON file as --in <path> (or positionally)")?;
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading trace {path}"))?;
    let report = analyze(&text)?;
    println!("{}", report.render());
    if args.has_flag("check") {
        report.check()?;
        println!("trace check passed (coverage ≥ 90%, budget agreement ≤ 1%)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(name: &str, dur: f64, args: &[(&str, f64)]) -> String {
        let inner: Vec<String> =
            args.iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
        format!(
            "{{\"name\":\"{name}\",\"ph\":\"X\",\"step\":1,\"tid\":0,\"ts\":0,\
             \"dur\":{dur},\"args\":{{{}}}}}",
            inner.join(",")
        )
    }

    fn sample_trace(covered: f64) -> String {
        [
            line("rollout", 500.0, &[("tokens", 128.0)]),
            line("learn.step", 1000.0, &[]),
            line("learn.select", 100.0, &[("tokens", 64.0)]),
            line("learn.grad", covered - 100.0, &[]),
            line(
                "ledger",
                0.0,
                &[
                    ("gen_tokens", 128.0),
                    ("sel_tokens", 66.0),
                    ("sel_tokens_exp", 64.0),
                    ("backprop_tokens", 100.0),
                    ("alloc_tokens", 300.0),
                    ("ideal_tokens", 250.0),
                    ("grad_flops", 5e8),
                    ("grad_flops_full", 1e9),
                    ("peak_bytes", 8e6),
                    ("peak_bytes_full", 1e7),
                    ("ht_w_max", 2.0),
                    ("ht_ess", 50.0),
                    ("pi_floor", 0.02),
                    ("budget_realized", 64.2),
                    ("alloc_tokens_prefix", 360.0),
                    ("compact_kept", 40.0),
                    ("compact_alloc", 60.0),
                    ("compact_bound", 60.0),
                    ("prefill_steps_saved", 48.0),
                    ("prefix_hits", 6.0),
                    ("prefix_lookups", 8.0),
                    ("cache_bytes", 4096.0),
                ],
            ),
        ]
        .join("\n")
    }

    #[test]
    fn aggregates_stages_and_ledger() {
        let r = analyze(&sample_trace(950.0)).unwrap();
        assert_eq!(r.stages["rollout"].calls, 1);
        assert_eq!(r.stages["learn.step"].wall_us, 1000.0);
        assert!((r.coverage().unwrap() - 0.95).abs() < 1e-9);
        assert_eq!(r.ledger.steps, 1);
        assert!((r.budget_gap() - 0.2 / 128.0).abs() < 1e-9);
        assert!((r.ledger.pi_floor - 0.02).abs() < 1e-12);
        assert!((r.ledger.ht_w_excess - 2.0 * 0.02).abs() < 1e-12);
        let rendered = r.render();
        assert!(rendered.contains("learn.grad"), "{rendered}");
        assert!(rendered.contains("savings ledger"), "{rendered}");
        r.check().unwrap();
    }

    #[test]
    fn check_fails_below_coverage_gate() {
        let r = analyze(&sample_trace(500.0)).unwrap();
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("coverage"), "{err}");
    }

    #[test]
    fn check_fails_on_budget_disagreement() {
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.budget_realized = r.ledger.sel_tokens_exp + 0.02 * r.ledger.gen_tokens;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("budget_realized"), "{err}");
    }

    #[test]
    fn compaction_gate_enforces_rounding_bound() {
        // healthy compacted step passes (sample_trace has kept 40 ≤ alloc 60
        // ≤ bound 60) and renders the compacted line
        let r = analyze(&sample_trace(950.0)).unwrap();
        r.check().unwrap();
        let rendered = r.render();
        assert!(rendered.contains("compacted layout"), "{rendered}");
        // allocation above the rounding bound = packer inflation
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.compact_alloc = r.ledger.compact_bound + 8.0;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("rounding bound"), "{err}");
        // kept tokens above the allocation = lost gather slots
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.compact_kept = r.ledger.compact_alloc + 1.0;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("gather slots"), "{err}");
        // inactive compaction (no compacted micro-batches) skips the gate
        // and the render line
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.compact_alloc = 0.0;
        r.ledger.compact_kept = 0.0;
        r.ledger.compact_bound = 0.0;
        r.check().unwrap();
        assert!(!r.render().contains("compacted layout"));
    }

    #[test]
    fn check_gates_ht_weights_against_the_pi_floor() {
        // sample trace: pi_floor 0.02 bounds weights at 50; max 2.0 passes
        // and the render advertises the bound
        let r = analyze(&sample_trace(950.0)).unwrap();
        r.check().unwrap();
        assert!(r.render().contains("1/pi_floor"), "{}", r.render());
        // a weight above the per-step bound is a broken floor contract
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.ht_w_max = 51.0;
        r.ledger.ht_w_excess = 51.0 * 0.02;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("pi_floor"), "{err}");
        // no floor in force (budget_mode none / RPC): gate off, legacy
        // traces with huge weights keep passing
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.pi_floor = 0.0;
        r.ledger.ht_w_excess = 0.0;
        r.ledger.ht_w_max = 1e9;
        r.check().unwrap();
        assert!(!r.render().contains("1/pi_floor"));
    }

    #[test]
    fn check_gates_prefix_cache_hit_accounting() {
        // sample trace: 6 hits of 8 lookups, 48 steps saved — healthy
        let r = analyze(&sample_trace(950.0)).unwrap();
        r.check().unwrap();
        assert!(r.render().contains("prefix cache"), "{}", r.render());
        assert!((r.ledger.prefill_steps_saved - 48.0).abs() < 1e-12);
        // hits above lookups = broken accounting
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.prefix_hits = r.ledger.prefix_lookups + 1.0;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("hit accounting"), "{err}");
        // savings without hits = phantom savings
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.prefix_hits = 0.0;
        let err = r.check().unwrap_err().to_string();
        assert!(err.contains("zero hits"), "{err}");
        // cache off (no lookups, no savings): gate inert, render line absent
        let mut r = analyze(&sample_trace(950.0)).unwrap();
        r.ledger.prefix_hits = 0.0;
        r.ledger.prefix_lookups = 0.0;
        r.ledger.prefill_steps_saved = 0.0;
        r.check().unwrap();
        assert!(!r.render().contains("prefix cache"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(analyze("{not json").is_err());
        assert!(analyze("{\"dur\":1}").is_err()); // missing name
        assert!(analyze("").unwrap().stages.is_empty()); // empty trace is fine
    }
}
