//! Per-step token/compute savings ledger — the paper's efficiency claims
//! ("as few as 50% of tokens, 29% faster, 18% less memory") as measured
//! per-step accounting instead of assumptions.
//!
//! The ledger is computed by `learn_stage` on every step, tracing on or
//! off: all of its inputs (token counts, packed shapes, analytic FLOP and
//! byte models) are deterministic functions of the step plan, so it can
//! live inside `StepStats` without perturbing any replay/parity guarantee.
//! Token fields are per-PPO-epoch means so they compare directly with
//! `budget_target`/`budget_realized` (which are per-epoch by contract).
//!
//! Two token counts deserve care:
//!
//! * `sel_tokens` is the *realized* kept count (sampling noise included);
//!   `sel_tokens_exp` is the closed-form expectation Σ_i E[kept_i] under
//!   the step's actual selector, computed through
//!   `selection::budget::expected_sum` — an independent path from the
//!   plan-probability sums behind `budget_realized`, which is what lets
//!   `nat trace --check` assert the two agree within 1% without sampling
//!   noise in the gate.
//! * `backprop_tokens` is Σ learn_len — the forward-prefix positions the
//!   grad kernels actually compute — which exceeds the kept count for
//!   scattered-mask schemes (URS keeps 50% of tokens but still pays the
//!   prefix up to the last kept one). The gap is exactly the headroom the
//!   ROADMAP's sparse-token-compaction item wants to reclaim.
//!
//! The FLOP/memory counterfactual prices full-token GRPO on the *same*
//! rollout group and packer configuration (`batcher::full_length_items`
//! re-packed at `learn_len = resp_len`), so `flop_saving`/`mem_saving`
//! isolate what selection bought, not what the packer or the length
//! distribution happened to do.

use crate::coordinator::batcher::MicroBatch;
use crate::model::manifest::ModelDims;
use crate::model::memory;

/// Deterministic per-step savings accounting (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepLedger {
    /// Generated response tokens (Σ resp_len over the group).
    pub gen_tokens: f64,
    /// Realized selected (kept) tokens, per-epoch mean.
    pub sel_tokens: f64,
    /// Closed-form expected selected tokens under the step's selector.
    pub sel_tokens_exp: f64,
    /// Forward-prefix tokens the grad kernels compute (Σ learn_len).
    pub backprop_tokens: f64,
    /// Allocated (padded) learner tokens, Σ rows × (P + bucket).
    pub alloc_tokens: f64,
    /// Ideal learner tokens with zero padding, Σ (P + learn_len).
    pub ideal_tokens: f64,
    /// Estimated grad FLOPs of the packed step (analytic model).
    pub grad_flops: f64,
    /// Counterfactual grad FLOPs: full-token GRPO on the same group.
    pub grad_flops_full: f64,
    /// Peak live learner bytes (static state + largest micro-batch).
    pub peak_bytes: f64,
    /// Counterfactual peak bytes under full-token GRPO packing.
    pub peak_bytes_full: f64,
    /// Largest realized HT weight (max 1/π over kept tokens).
    pub ht_w_max: f64,
    /// Effective sample size (Σw)²/Σw² over kept tokens.
    pub ht_ess: f64,
    /// The π floor in force for this step's budget-solved selection
    /// (`--train.pi_floor`; 0 when no floor applies — `budget_mode none`,
    /// or RPC under the batch controller, whose prefix-survival weights are
    /// bounded by construction). When positive, `nat trace --check` gates
    /// `ht_w_max ≤ 1/pi_floor`.
    pub pi_floor: f64,
    /// Copy of `StepStats::budget_realized` so a trace event is
    /// self-contained for `nat trace --check`.
    pub budget_realized: f64,
    /// Counterfactual allocated tokens with the gather-compacted layout
    /// DISABLED — the same items prefix-packed through the same packer.
    /// Equals `alloc_tokens` when nothing was compacted, so
    /// `compact_saving()` reads 0 rather than a fiction.
    pub alloc_tokens_prefix: f64,
    /// Kept (gathered) tokens inside compacted micro-batches, per-epoch
    /// mean; 0 when the compacted layout is inactive.
    pub compact_kept: f64,
    /// Allocated tokens of the compacted micro-batches, Σ rows × (P + K).
    pub compact_alloc: f64,
    /// Row-grid rounding bound on `compact_alloc`: the allocation a healthy
    /// packer cannot exceed, re-derived from the gather contents
    /// (`batcher::compact_stats`). `nat trace --check` gates
    /// `compact_kept ≤ compact_alloc ≤ compact_bound` when compaction is
    /// active.
    pub compact_bound: f64,
    /// Prefill token-steps the shared-prefix cache avoided this step
    /// (Σ prompt_len over cache hits). 0 with the cache off or no
    /// prefill/decode split in the manifest.
    pub prefill_steps_saved: f64,
    /// Prefix-cache hits among this step's rollout rows.
    pub prefix_hits: f64,
    /// Prefix-cache lookups (== rollout rows when the cache is active).
    /// `nat trace --check` gates `prefix_hits ≤ prefix_lookups`.
    pub prefix_lookups: f64,
    /// Resident KV bytes in the prefix cache after the step's rollouts.
    pub cache_bytes: f64,
}

impl StepLedger {
    /// Fraction of generated tokens selected for the update (expected).
    pub fn sel_frac(&self) -> f64 {
        frac(self.sel_tokens_exp, self.gen_tokens)
    }

    /// Fraction of generated tokens the backward pass computes over.
    pub fn backprop_frac(&self) -> f64 {
        frac(self.backprop_tokens, self.gen_tokens)
    }

    /// Estimated grad-FLOP saving vs full-token GRPO (the paper's "29%
    /// faster" analogue; time ∝ FLOPs in this analytic model).
    pub fn flop_saving(&self) -> f64 {
        saving(self.grad_flops, self.grad_flops_full)
    }

    /// Estimated peak-memory saving vs full-token GRPO ("18% less memory").
    pub fn mem_saving(&self) -> f64 {
        saving(self.peak_bytes, self.peak_bytes_full)
    }

    /// Realized allocated-token saving of the gather-compacted layout vs
    /// prefix-packing the same step (0 when compaction is inactive).
    pub fn compact_saving(&self) -> f64 {
        saving(self.alloc_tokens, self.alloc_tokens_prefix)
    }

    /// Fraction of rollout rows served from the shared-prefix cache
    /// (0 when the cache is off).
    pub fn prefix_hit_rate(&self) -> f64 {
        frac(self.prefix_hits, self.prefix_lookups)
    }

    /// Estimated grad FLOPs of a packed micro-batch set (Σ over batches of
    /// the fwd+bwd cost at the allocated [rows, P + bucket] shape).
    pub fn flops_of(d: &ModelDims, mbs: &[MicroBatch]) -> f64 {
        mbs.iter()
            .map(|mb| memory::train_flops(d, mb.rows, d.prompt_len + mb.bucket) as f64)
            .sum()
    }

    /// All fields as named args — the per-step `"ledger"` trace event and
    /// the bench stage-breakdown records share this one flattening.
    pub fn trace_args(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("gen_tokens", self.gen_tokens),
            ("sel_tokens", self.sel_tokens),
            ("sel_tokens_exp", self.sel_tokens_exp),
            ("backprop_tokens", self.backprop_tokens),
            ("alloc_tokens", self.alloc_tokens),
            ("ideal_tokens", self.ideal_tokens),
            ("grad_flops", self.grad_flops),
            ("grad_flops_full", self.grad_flops_full),
            ("peak_bytes", self.peak_bytes),
            ("peak_bytes_full", self.peak_bytes_full),
            ("ht_w_max", self.ht_w_max),
            ("ht_ess", self.ht_ess),
            ("pi_floor", self.pi_floor),
            ("budget_realized", self.budget_realized),
            ("alloc_tokens_prefix", self.alloc_tokens_prefix),
            ("compact_kept", self.compact_kept),
            ("compact_alloc", self.compact_alloc),
            ("compact_bound", self.compact_bound),
            ("prefill_steps_saved", self.prefill_steps_saved),
            ("prefix_hits", self.prefix_hits),
            ("prefix_lookups", self.prefix_lookups),
            ("cache_bytes", self.cache_bytes),
        ]
    }

    /// Recorder series (`--obs.ledger`): the raw token/FLOP trajectory plus
    /// the derived headline ratios.
    pub fn series(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("gen_tokens", self.gen_tokens),
            ("sel_tokens_exp", self.sel_tokens_exp),
            ("backprop_tokens", self.backprop_tokens),
            ("alloc_tokens", self.alloc_tokens),
            ("grad_flops", self.grad_flops),
            ("grad_flops_full", self.grad_flops_full),
            ("flop_saving", self.flop_saving()),
            ("mem_saving", self.mem_saving()),
            ("ht_w_max", self.ht_w_max),
            ("ht_ess", self.ht_ess),
            ("pi_floor", self.pi_floor),
            ("alloc_tokens_prefix", self.alloc_tokens_prefix),
            ("compact_saving", self.compact_saving()),
            ("prefill_steps_saved", self.prefill_steps_saved),
            ("prefix_hit_rate", self.prefix_hit_rate()),
            ("cache_bytes", self.cache_bytes),
        ]
    }
}

fn frac(num: f64, den: f64) -> f64 {
    if den > 0.0 { num / den } else { 0.0 }
}

fn saving(actual: f64, full: f64) -> f64 {
    if full > 0.0 { 1.0 - actual / full } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_ratios_guard_zero_denominators() {
        let l = StepLedger::default();
        assert_eq!(l.sel_frac(), 0.0);
        assert_eq!(l.backprop_frac(), 0.0);
        assert_eq!(l.flop_saving(), 0.0);
        assert_eq!(l.mem_saving(), 0.0);
    }

    #[test]
    fn derived_ratios_match_fields() {
        let l = StepLedger {
            gen_tokens: 200.0,
            sel_tokens: 101.0,
            sel_tokens_exp: 100.0,
            backprop_tokens: 150.0,
            grad_flops: 70.0,
            grad_flops_full: 100.0,
            peak_bytes: 82.0,
            peak_bytes_full: 100.0,
            ..StepLedger::default()
        };
        assert!((l.sel_frac() - 0.5).abs() < 1e-12);
        assert!((l.backprop_frac() - 0.75).abs() < 1e-12);
        assert!((l.flop_saving() - 0.3).abs() < 1e-12);
        assert!((l.mem_saving() - 0.18).abs() < 1e-12);
    }

    #[test]
    fn trace_args_cover_every_field() {
        let l = StepLedger { gen_tokens: 1.0, ..StepLedger::default() };
        let args = l.trace_args();
        assert_eq!(args.len(), 22);
        assert_eq!(args[0], ("gen_tokens", 1.0));
        // series is a subset plus the derived ratios
        assert_eq!(l.series().len(), 16);
    }

    #[test]
    fn prefix_hit_rate_guards_zero_and_matches_counts() {
        assert_eq!(StepLedger::default().prefix_hit_rate(), 0.0);
        let l = StepLedger {
            prefix_hits: 21.0,
            prefix_lookups: 28.0,
            prefill_steps_saved: 21.0 * 16.0,
            ..StepLedger::default()
        };
        assert!((l.prefix_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn compact_saving_reads_zero_when_inactive_and_real_when_on() {
        // inactive: prefix counterfactual equals the realized allocation
        let l = StepLedger {
            alloc_tokens: 300.0,
            alloc_tokens_prefix: 300.0,
            ..StepLedger::default()
        };
        assert_eq!(l.compact_saving(), 0.0);
        // active: 210 allocated vs 300 prefix-packed → 30% saving
        let l = StepLedger {
            alloc_tokens: 210.0,
            alloc_tokens_prefix: 300.0,
            compact_kept: 90.0,
            compact_alloc: 120.0,
            compact_bound: 120.0,
            ..StepLedger::default()
        };
        assert!((l.compact_saving() - 0.3).abs() < 1e-12);
        assert_eq!(StepLedger::default().compact_saving(), 0.0);
    }
}
