//! `nat lint` — in-repo static analysis for the determinism and
//! HT-unbiasedness contracts.
//!
//! NAT's correctness rests on two source-level invariants that no type
//! checker sees: the Horvitz-Thompson estimator stays unbiased only while
//! RNG draws are a pure function of `(seed, step, stream/flat id)`, and
//! `shards=K ≡ workers=N ≡ serial` bit-identity holds only while no
//! packing/selection/reduction path iterates unordered containers, reads
//! wall clocks outside the Tracer gate, or accumulates floats outside the
//! blessed tree reduction. This module machine-checks those contracts:
//!
//! * [`lexer`]  — a small Rust lexer (raw strings, nested block comments,
//!   char-vs-lifetime disambiguation, `#[cfg(test)]` region marking);
//! * [`pragma`] — `// natlint: allow(<rule>, reason = "…")` waivers that
//!   must name the rule and carry a written reason;
//! * [`rules`]  — the R1–R6 rule set with module-path scoping;
//! * [`report`] — findings, counts, human and `--json` renderings.
//!
//! The pass runs over the whole `rust/src` tree in tier-1
//! (`tests/analysis.rs`) and as a CI lane (`nat lint --check`), so every
//! future subsystem — elastic sharding, `nat serve` — lands contract-clean
//! instead of hoping a proptest seed hits the regression.

pub mod lexer;
pub mod pragma;
pub mod report;
pub mod rules;

pub use report::{Finding, Report};

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::bench;
use crate::util::cli::Args;

use rules::{registry, FileCtx, PRAGMA_RULE};

/// Lint one file's source text. `rel_path` is the path under the lint root
/// (it determines the module scope, e.g. `coordinator/selection/urs.rs` →
/// `coordinator::selection::urs`).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let ctx = FileCtx { module: module_of(rel_path), toks: &lexed.toks };

    // Pragmas: well-formed ones suppress; malformed or unknown-rule ones
    // are findings themselves (outside test regions).
    let known: Vec<&str> = registry().iter().map(|r| r.slug).collect();
    let mut pragmas: Vec<pragma::Pragma> = Vec::new();
    let mut findings: Vec<Finding> = Vec::new();
    for c in &lexed.comments {
        let Some(parsed) = pragma::parse(c.line, &c.text) else { continue };
        if lexed.line_in_test(c.line) {
            continue;
        }
        match parsed {
            Ok(p) => {
                let unknown: Vec<&String> =
                    p.rules.iter().filter(|r| !known.contains(&r.as_str())).collect();
                if unknown.is_empty() {
                    pragmas.push(p);
                } else {
                    findings.push(pragma_finding(
                        rel_path,
                        c.line,
                        format!(
                            "pragma names unknown rule(s) {:?} — a waiver only ever \
                             silences rules it names correctly",
                            unknown
                        ),
                    ));
                }
            }
            Err(msg) => {
                findings.push(pragma_finding(rel_path, c.line, format!("malformed pragma: {msg}")));
            }
        }
    }
    // Resolve each pragma to the code line it covers: its own line if code
    // shares it, otherwise the next line carrying a code token.
    let covered: Vec<(u32, Vec<String>)> = pragmas
        .iter()
        .map(|p| {
            let same_line = lexed.toks.iter().any(|t| t.line == p.line);
            let target = if same_line {
                p.line
            } else {
                lexed
                    .toks
                    .iter()
                    .map(|t| t.line)
                    .filter(|&l| l > p.line)
                    .min()
                    .unwrap_or(p.line)
            };
            (target, p.rules.clone())
        })
        .collect();

    for rule in registry() {
        for (line, message) in (rule.check)(&ctx) {
            let waived = covered
                .iter()
                .any(|(l, slugs)| *l == line && slugs.iter().any(|s| s == rule.slug));
            if !waived {
                findings.push(Finding {
                    rule_id: rule.id.to_string(),
                    slug: rule.slug.to_string(),
                    file: rel_path.to_string(),
                    line,
                    message,
                });
            }
        }
    }
    findings.sort_by_key(|f| f.line);
    findings
}

fn pragma_finding(rel_path: &str, line: u32, message: String) -> Finding {
    Finding {
        rule_id: PRAGMA_RULE.0.to_string(),
        slug: PRAGMA_RULE.1.to_string(),
        file: rel_path.to_string(),
        line,
        message,
    }
}

/// Module path of a file relative to the lint root: strip `.rs`, split on
/// separators, drop a trailing `mod` (and crate roots `lib`/`main`).
fn module_of(rel_path: &str) -> Vec<String> {
    let mut segs: Vec<String> = rel_path
        .trim_end_matches(".rs")
        .split(['/', '\\'])
        .filter(|s| !s.is_empty())
        .map(|s| s.to_string())
        .collect();
    if matches!(segs.last().map(String::as_str), Some("mod" | "lib" | "main")) {
        segs.pop();
    }
    segs
}

/// Recursively collect `.rs` files under `root`, sorted by path — the walk
/// order must be deterministic (the pass dogfoods its own contract).
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("nat lint: cannot read {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rs_files(&path, out)?;
        } else if path.extension().map_or(false, |x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the full pass over every `.rs` file under `root`.
pub fn run_lint(root: &Path) -> Result<Report> {
    // natlint: allow(wallclock, reason = "lints its own wall time for BENCH_lint.json; no training-path output depends on it")
    let t0 = Instant::now();
    let mut files = Vec::new();
    rs_files(root, &mut files)?;
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("nat lint: cannot read {}", path.display()))?;
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        findings,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// `nat lint [--root DIR] [--json] [--check]`
///
/// Human-readable findings by default; `--json` prints the machine record
/// to stdout AND writes it as `BENCH_lint.json` through the shared bench
/// recorder (rule counts, files scanned, wall time — the perf-trajectory
/// tooling watches the pass stay fast). `--check` exits nonzero on any
/// finding — the CI gate.
pub fn cmd_lint(args: &Args) -> Result<()> {
    let default_root = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
    let root = args.get_or("root", default_root);
    let report = run_lint(Path::new(root))?;
    if args.has_flag("json") {
        println!("{}", report.to_json().to_string());
        let path = bench::write_record("lint", &report.to_json())?;
        eprintln!("nat lint: record written to {path}");
    } else {
        print!("{}", report.render_human());
    }
    if args.has_flag("check") && !report.findings.is_empty() {
        bail!(
            "nat lint --check: {} finding(s) in {} file(s) under {root}",
            report.findings.len(),
            report.files_scanned
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_on_own_line_covers_next_code_line() {
        let src = "// natlint: allow(wallclock, reason = \"queue metric\")\n\
                   let t = Instant::now();\n";
        assert!(lint_source("coordinator/pipeline/engine.rs", src).is_empty());
    }

    #[test]
    fn trailing_pragma_covers_its_own_line() {
        let src = "let t = Instant::now(); // natlint: allow(wallclock, reason = \"metric\")\n";
        assert!(lint_source("coordinator/trainer.rs", src).is_empty());
    }

    #[test]
    fn pragma_does_not_silence_unnamed_rules() {
        // wallclock waived, hot-panic on the same line still fires
        let src = "// natlint: allow(wallclock, reason = \"metric\")\n\
                   let t = Instant::now().elapsed().unwrap();\n";
        let f = lint_source("coordinator/trainer.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].slug, "hot-panic");
    }

    #[test]
    fn malformed_and_unknown_rule_pragmas_are_findings() {
        let f = lint_source("a.rs", "// natlint: allow(wallclock)\nfn x() {}\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].slug, "pragma");
        let f = lint_source("a.rs", "// natlint: allow(wallclok, reason = \"typo\")\nfn x() {}\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].slug, "pragma");
        assert!(f[0].message.contains("unknown rule"));
    }

    #[test]
    fn module_paths_resolve_mod_rs_and_crate_roots() {
        assert_eq!(module_of("coordinator/selection/mod.rs"), vec!["coordinator", "selection"]);
        assert_eq!(module_of("coordinator/trainer.rs"), vec!["coordinator", "trainer"]);
        assert_eq!(module_of("lib.rs"), Vec::<String>::new());
        assert_eq!(module_of("main.rs"), Vec::<String>::new());
    }

    #[test]
    fn findings_carry_rule_metadata_and_sort_by_line() {
        let src = "fn a() { let x = v.iter().sum::<f32>(); }\n\
                   fn b() { let y = w.iter().sum::<f64>(); }\n";
        let f = lint_source("runtime/shard.rs", src);
        assert_eq!(f.len(), 2);
        assert_eq!((f[0].line, f[1].line), (1, 2));
        assert_eq!(f[0].rule_id, "R4");
        assert_eq!(f[0].slug, "float-accum");
    }
}
