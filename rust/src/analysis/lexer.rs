//! Minimal Rust lexer for the `nat lint` pass.
//!
//! This is not a general Rust parser — the rules only need a faithful token
//! stream (identifiers, punctuation, literals) with everything that can
//! *hide* code from a naive scan handled correctly: line comments, nested
//! block comments, plain and raw strings (`r"…"`, `r#"…"#`, byte variants),
//! char literals vs. lifetimes/labels (`'a'` vs. `'a` vs. `'outer:`), and
//! numeric literals with exponents/suffixes. Comments are captured
//! separately (the pragma system reads them), and a post-pass marks every
//! token inside a `#[cfg(test)]` / `#[test]` item so rules can skip test
//! code without a second parser.

/// Token classes the rule engine distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Num,
    Str,
    Char,
    Punct,
}

/// One lexed token. `line` is 1-based and refers to the token's first line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    /// True when the token sits inside a `#[cfg(test)]` / `#[test]` item.
    pub in_test: bool,
}

/// One comment (line or block), verbatim including its `//` / `/*` markers.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lex output: code tokens, comments, and the 1-based inclusive line spans
/// of test items (used to ignore pragma errors inside test code).
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    pub test_spans: Vec<(u32, u32)>,
}

impl Lexed {
    /// True when `line` falls inside a `#[cfg(test)]` / `#[test]` item.
    pub fn line_in_test(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| line >= a && line <= b)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` and mark test regions.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i;
            while i < cs.len() && cs[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: cs[start..i].iter().collect() });
            continue;
        }
        // Block comment — Rust block comments NEST.
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let (start, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < cs.len() && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: cs[start..i].iter().collect() });
            continue;
        }
        // Raw strings: r"…", r#"…"#, br#"…"# — closed only by a quote
        // followed by the same number of hashes.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if cs[j] == 'b' {
                j += 1;
            }
            if cs.get(j) == Some(&'r') {
                j += 1;
                let mut hashes = 0usize;
                while cs.get(j) == Some(&'#') {
                    hashes += 1;
                    j += 1;
                }
                if cs.get(j) == Some(&'"') {
                    let (start, start_line) = (i, line);
                    j += 1;
                    while j < cs.len() {
                        if cs[j] == '\n' {
                            line += 1;
                            j += 1;
                        } else if cs[j] == '"' {
                            let mut k = j + 1;
                            let mut h = 0usize;
                            while h < hashes && cs.get(k) == Some(&'#') {
                                h += 1;
                                k += 1;
                            }
                            j = k;
                            if h == hashes {
                                break;
                            }
                        } else {
                            j += 1;
                        }
                    }
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: cs[start..j].iter().collect(),
                        line: start_line,
                        in_test: false,
                    });
                    i = j;
                    continue;
                }
            }
        }
        // Byte char b'x' — route to the char-literal scanner below.
        if c == 'b' && cs.get(i + 1) == Some(&'\'') {
            let (start, start_line) = (i, line);
            let j = scan_char_literal(&cs, i + 1, &mut line);
            toks.push(Tok {
                kind: TokKind::Char,
                text: cs[start..j].iter().collect(),
                line: start_line,
                in_test: false,
            });
            i = j;
            continue;
        }
        // Plain or byte string.
        if c == '"' || (c == 'b' && cs.get(i + 1) == Some(&'"')) {
            let (start, start_line) = (i, line);
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < cs.len() {
                if cs[j] == '\\' {
                    j += 2;
                } else if cs[j] == '"' {
                    j += 1;
                    break;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::Str,
                text: cs[start..j].iter().collect(),
                line: start_line,
                in_test: false,
            });
            i = j;
            continue;
        }
        // Char literal vs. lifetime/label. After a quote: a backslash means
        // a char escape; ident chars followed by a closing quote mean a char
        // ('a'), without one a lifetime ('a, 'outer); any other single char
        // followed by a quote is a char (' ', '(').
        if c == '\'' {
            let start_line = line;
            let next = cs.get(i + 1).copied();
            let is_char = match next {
                Some('\\') => true,
                Some(n) if is_ident_start(n) || n.is_ascii_digit() => {
                    let mut k = i + 1;
                    while k < cs.len() && is_ident_continue(cs[k]) {
                        k += 1;
                    }
                    cs.get(k) == Some(&'\'')
                }
                Some(_) => cs.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                let start = i;
                let j = scan_char_literal(&cs, i, &mut line);
                toks.push(Tok {
                    kind: TokKind::Char,
                    text: cs[start..j].iter().collect(),
                    line: start_line,
                    in_test: false,
                });
                i = j;
            } else {
                let start = i;
                let mut j = i + 1;
                while j < cs.len() && is_ident_continue(cs[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: cs[start..j].iter().collect(),
                    line: start_line,
                    in_test: false,
                });
                i = j;
            }
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let start = i;
            let mut j = i + 1;
            while j < cs.len() && is_ident_continue(cs[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: cs[start..j].iter().collect(),
                line,
                in_test: false,
            });
            i = j;
            continue;
        }
        // Numeric literal (ints, floats, hex, exponents, suffixes).
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i + 1;
            while j < cs.len() {
                let d = cs[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.'
                    && cs.get(j + 1).is_some_and(|n| n.is_ascii_digit())
                {
                    // a dot starts a fraction only before a digit — `0..n`
                    // and `1.max(2)` stay punct/method tokens
                    j += 1;
                } else if (d == '+' || d == '-')
                    && matches!(cs[j - 1], 'e' | 'E')
                {
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: cs[start..j].iter().collect(),
                line,
                in_test: false,
            });
            i = j;
            continue;
        }
        // Single-char punctuation (rules match multi-char operators as
        // adjacent punct tokens, e.g. `::` = ':' ':').
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
            in_test: false,
        });
        i += 1;
    }
    let test_spans = mark_test_regions(&mut toks);
    Lexed { toks, comments, test_spans }
}

/// Scan a char literal starting at the opening quote; returns the index
/// just past the closing quote.
fn scan_char_literal(cs: &[char], open: usize, line: &mut u32) -> usize {
    let mut j = open + 1;
    while j < cs.len() {
        match cs[j] {
            '\\' => j += 2,
            '\'' => return j + 1,
            c => {
                if c == '\n' {
                    *line += 1;
                }
                j += 1;
            }
        }
    }
    j
}

/// Mark every token belonging to a `#[cfg(test)]` / `#[test]` item (the
/// attribute, any stacked attributes after it, and the item through its
/// closing brace or semicolon). Returns the inclusive line spans marked.
fn mark_test_regions(toks: &mut [Tok]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].text == "#" && toks.get(i + 1).map_or(false, |t| t.text == "[")) {
            i += 1;
            continue;
        }
        // Scan the attribute body for an ident `test` (covers `#[test]`,
        // `#[cfg(test)]`, `#[cfg(all(test, …))]`).
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_test = false;
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                "test" if toks[j].kind == TokKind::Ident => has_test = true,
                _ => {}
            }
            j += 1;
        }
        if !has_test {
            i = j;
            continue;
        }
        // Skip any further stacked attributes.
        while j < toks.len()
            && toks[j].text == "#"
            && toks.get(j + 1).map_or(false, |t| t.text == "[")
        {
            let mut d = 1usize;
            j += 2;
            while j < toks.len() && d > 0 {
                match toks[j].text.as_str() {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                j += 1;
            }
        }
        // Find the item's body: first `{` at bracket/paren depth 0 (then its
        // matching `}`), or a `;` for brace-less items.
        let mut d = 0isize;
        let mut end = toks.len();
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" | "[" => d += 1,
                ")" | "]" => d -= 1,
                ";" if d == 0 => {
                    end = j + 1;
                    break;
                }
                "{" if d == 0 => {
                    let mut bd = 1usize;
                    j += 1;
                    while j < toks.len() && bd > 0 {
                        match toks[j].text.as_str() {
                            "{" => bd += 1,
                            "}" => bd -= 1,
                            _ => {}
                        }
                        j += 1;
                    }
                    end = j;
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        let lo = toks[attr_start].line;
        let hi = toks[end.saturating_sub(1).max(attr_start)].line;
        for t in toks[attr_start..end].iter_mut() {
            t.in_test = true;
        }
        spans.push((lo, hi));
        i = end;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn raw_strings_hide_their_contents() {
        // Idents inside raw strings (any hash depth) must not leak into the
        // token stream — `Instant` here is data, not code.
        let src = r##"let a = r"Instant::now()"; let b = r#"HashMap "quoted" inner"#; use x;"##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "a", "let", "b", "use", "x"]);
        let l = lex(src);
        assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let src = "a /* outer /* inner */ still comment */ b";
        assert_eq!(idents(src), vec!["a", "b"]);
        let l = lex("x /* line1\n/* line2 */\n*/ y");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.toks[1].text, "y");
        assert_eq!(l.toks[1].line, 3);
    }

    #[test]
    fn lifetimes_labels_and_char_literals_disambiguate() {
        let src = "fn f<'a>(x: &'a str) { 'outer: loop { break 'outer; } let c = 'x'; \
                   let n = '\\n'; let q = '\\''; let sp = ' '; }";
        let l = lex(src);
        let lifetimes: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a", "'outer", "'outer"]);
        let chars = l.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 4, "{:?}", l.toks);
    }

    #[test]
    fn byte_literals_and_numbers() {
        let l = lex("let x = b'q'; let s = b\"bytes\"; let f = 1.0e-3f64; let h = 0xCBF2_9CE4; \
                     let r = 0..n; let m = 1.max(2);");
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Char && t.text == "b'q'"));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Str && t.text == "b\"bytes\""));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Num && t.text == "1.0e-3f64"));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Num && t.text == "0xCBF2_9CE4"));
        // `0..n` keeps the range as punctuation; `1.max` keeps the method.
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Num && t.text == "0"));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "max"));
    }

    #[test]
    fn comments_are_captured_with_lines() {
        let l = lex("// first\nlet x = 1; // trailing\n/* block */\n");
        assert_eq!(l.comments.len(), 3);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        assert_eq!(l.comments[2].line, 3);
        assert!(l.comments[0].text.starts_with("// first"));
    }

    #[test]
    fn cfg_test_mod_is_marked_and_spanned() {
        let src = "fn live() { hot(); }\n#[cfg(test)]\nmod tests {\n    use super::*;\n    \
                   #[test]\n    fn t() { cold(); }\n}\nfn live2() {}\n";
        let l = lex(src);
        let hot = l.toks.iter().find(|t| t.text == "hot").unwrap();
        let cold = l.toks.iter().find(|t| t.text == "cold").unwrap();
        let live2 = l.toks.iter().find(|t| t.text == "live2").unwrap();
        assert!(!hot.in_test);
        assert!(cold.in_test);
        assert!(!live2.in_test);
        assert!(l.line_in_test(6));
        assert!(!l.line_in_test(1));
        assert!(!l.line_in_test(8));
    }

    #[test]
    fn cfg_test_on_braceless_items_stops_at_semicolon() {
        let l = lex("#[cfg(test)]\nuse std::collections::HashMap;\nfn live() {}\n");
        let hm = l.toks.iter().find(|t| t.text == "HashMap").unwrap();
        assert!(hm.in_test);
        let live = l.toks.iter().find(|t| t.text == "live").unwrap();
        assert!(!live.in_test);
    }
}
