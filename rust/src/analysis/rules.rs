//! The natlint rule set: lexical checks for the determinism and
//! HT-unbiasedness contracts the NAT trainer's correctness rests on.
//!
//! Every rule is scoped by module path (derived from the file's position
//! under the lint root, so `coordinator/selection/urs.rs` is
//! `coordinator::selection::urs`) and skips `#[cfg(test)]` / `#[test]`
//! regions — the contracts bind production code, not assertions about it.
//!
//! | id | slug           | contract                                        |
//! |----|----------------|-------------------------------------------------|
//! | R1 | unordered-iter | no `HashMap`/`HashSet` where iteration order    |
//! |    |                | feeds packing, selection, reduction, or ledger  |
//! | R2 | wallclock      | no `Instant::now`/`SystemTime::now` outside the |
//! |    |                | `obs/` Tracer gate and `util::bench`            |
//! | R3 | rng-discipline | `Rng::new` only via `util::rng` mixing helpers  |
//! |    |                | (`stream_seed`/`xor_stream`), `slot_seed`,      |
//! |    |                | `fork`, or constant seeds                       |
//! | R4 | float-accum    | no `sum::<f32/f64>()` / `.fold(` float chains   |
//! |    |                | in shard/reduce/apply paths — merges go through |
//! |    |                | `tree_reduce_into`                              |
//! | R5 | hot-panic      | no `unwrap`/`expect`/`panic!` (trainer+runtime) |
//! |    |                | or bare slice indexing (trainer+shard)          |
//! | R6 | lossy-cast     | no ad-hoc `as f32` where HT weights and         |
//! |    |                | inclusion probabilities are computed            |

use super::lexer::{Tok, TokKind};

/// Per-file context handed to every rule.
pub struct FileCtx<'a> {
    /// Module path segments relative to the lint root.
    pub module: Vec<String>,
    pub toks: &'a [Tok],
}

/// One rule: metadata (shared by the report, README table, and pragma
/// validation) plus its token-stream check.
pub struct Rule {
    pub id: &'static str,
    pub slug: &'static str,
    pub summary: &'static str,
    pub check: fn(&FileCtx) -> Vec<(u32, String)>,
}

/// The id/slug of the always-on pragma meta-rule (malformed or unknown-rule
/// pragmas — reported by the engine, not suppressible).
pub const PRAGMA_RULE: (&str, &str) = ("P0", "pragma");

/// The full rule registry, in report order.
pub fn registry() -> &'static [Rule] {
    &[
        Rule {
            id: "R1",
            slug: "unordered-iter",
            summary: "HashMap/HashSet in a bit-identity-scoped module \
                      (batcher, selection, prefix_cache, shard, ledger)",
            check: r1_unordered_iter,
        },
        Rule {
            id: "R2",
            slug: "wallclock",
            summary: "Instant::now/SystemTime::now outside obs/ and util::bench",
            check: r2_wallclock,
        },
        Rule {
            id: "R3",
            slug: "rng-discipline",
            summary: "Rng::new outside the util::rng seed-mixing helpers",
            check: r3_rng_discipline,
        },
        Rule {
            id: "R4",
            slug: "float-accum",
            summary: "float accumulation in runtime reduce/apply paths \
                      outside tree_reduce_into",
            check: r4_float_accum,
        },
        Rule {
            id: "R5",
            slug: "hot-panic",
            summary: "unwrap/expect/panic!/bare indexing in the trainer/runtime hot path",
            check: r5_hot_panic,
        },
        Rule {
            id: "R6",
            slug: "lossy-cast",
            summary: "ad-hoc `as f32` in HT-weight / inclusion-probability code",
            check: r6_lossy_cast,
        },
    ]
}

/// Modules where unordered-container iteration breaks `shards=K ≡ serial`
/// bit-identity (packing order, selection order, reduction order, ledger
/// aggregation order all feed golden traces).
const R1_SCOPE: &[&str] = &[
    "coordinator::batcher",
    "coordinator::selection",
    "coordinator::rollout::prefix_cache",
    "runtime::shard",
    "obs::ledger",
];

/// Modules allowed to read wall clocks: the Tracer gate lives in `obs` and
/// the bench harness exists to time things.
const R2_EXEMPT: &[&str] = &["obs", "util::bench"];

/// `Rng::new` is the mixing primitive itself inside `util::rng`.
const R3_EXEMPT: &[&str] = &["util::rng"];

/// Seed-mixing helpers whose output is a pure function of
/// `(seed, step, stream/flat id)` — calls through these keep HT draws
/// independent of batch composition and chunk order.
const R3_BLESSED: &[&str] = &["stream_seed", "xor_stream", "slot_seed", "fork"];

/// Shard/reduce/apply float paths.
const R4_SCOPE: &[&str] = &["runtime"];

/// The hot path for panics: one poisoned step must surface as `Result`,
/// not tear down workers mid-reduction.
const R5_PANIC_SCOPE: &[&str] = &["coordinator::trainer", "runtime"];

/// Bare indexing scope: the shard executor and trainer, where an
/// out-of-bounds id would abort a scoped-thread worker.
const R5_INDEX_SCOPE: &[&str] = &["coordinator::trainer", "runtime::shard"];

/// Where HT weights and inclusion probabilities are produced.
const R6_SCOPE: &[&str] = &["coordinator::selection", "coordinator::masking"];

fn in_scope(module: &[String], prefixes: &[&str]) -> bool {
    let m = module.join("::");
    prefixes.iter().any(|p| m == *p || m.starts_with(&format!("{p}::")))
}

fn ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// `toks[i..]` starts with `::<name>` (a path segment).
fn path_seg(toks: &[Tok], i: usize, name: &str) -> bool {
    i + 2 < toks.len()
        && punct(&toks[i], ":")
        && punct(&toks[i + 1], ":")
        && ident(&toks[i + 2], name)
}

fn live<'a>(ctx: &'a FileCtx) -> impl Iterator<Item = (usize, &'a Tok)> {
    ctx.toks.iter().enumerate().filter(|(_, t)| !t.in_test)
}

fn r1_unordered_iter(ctx: &FileCtx) -> Vec<(u32, String)> {
    if !in_scope(&ctx.module, R1_SCOPE) {
        return Vec::new();
    }
    live(ctx)
        .filter(|(_, t)| ident(t, "HashMap") || ident(t, "HashSet"))
        .map(|(_, t)| {
            (
                t.line,
                format!(
                    "{} in a module under the shards=K bit-identity contract — iteration \
                     order is nondeterministic; use BTreeMap/BTreeSet or a sorted collect",
                    t.text
                ),
            )
        })
        .collect()
}

fn r2_wallclock(ctx: &FileCtx) -> Vec<(u32, String)> {
    if in_scope(&ctx.module, R2_EXEMPT) {
        return Vec::new();
    }
    live(ctx)
        .filter(|&(i, t)| {
            (ident(t, "Instant") || ident(t, "SystemTime")) && path_seg(ctx.toks, i + 1, "now")
        })
        .map(|(_, t)| {
            (
                t.line,
                format!(
                    "{}::now outside obs/ — clock reads must sit behind the zero-cost \
                     Tracer gate so tracing off stays bit-identical",
                    t.text
                ),
            )
        })
        .collect()
}

fn r3_rng_discipline(ctx: &FileCtx) -> Vec<(u32, String)> {
    if in_scope(&ctx.module, R3_EXEMPT) {
        return Vec::new();
    }
    let toks = ctx.toks;
    let mut out = Vec::new();
    for (i, t) in live(ctx) {
        if !(ident(t, "Rng") && path_seg(toks, i + 1, "new")) {
            continue;
        }
        if !toks.get(i + 4).map_or(false, |n| punct(n, "(")) {
            continue;
        }
        // Collect the argument tokens up to the matching ')'.
        let mut depth = 1usize;
        let mut j = i + 5;
        let mut args: Vec<&Tok> = Vec::new();
        while j < toks.len() && depth > 0 {
            if punct(&toks[j], "(") {
                depth += 1;
            } else if punct(&toks[j], ")") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            args.push(&toks[j]);
            j += 1;
        }
        // Blessed: the seed flows through a util::rng mixing helper.
        if args.iter().any(|a| R3_BLESSED.contains(&a.text.as_str())) {
            continue;
        }
        // Blessed: a pure constant seed (literals and SCREAMING_CASE consts;
        // lowercase idents that are path qualifiers, i.e. followed by `::`,
        // don't count against it).
        let const_seed = args.iter().enumerate().all(|(k, a)| {
            if a.kind != TokKind::Ident {
                return true;
            }
            if k + 2 < args.len() && punct(args[k + 1], ":") && punct(args[k + 2], ":") {
                return true; // path qualifier (e.g. `w::SEED`)
            }
            !a.text.chars().any(|c| c.is_ascii_lowercase())
        });
        if const_seed {
            continue;
        }
        out.push((
            t.line,
            "ad-hoc Rng::new seed — mix seeds through util::rng::stream_seed / xor_stream \
             (or a blessed per-slot helper) so draws stay a pure function of \
             (seed, step, stream id), never of batch composition or chunk order"
                .to_string(),
        ));
    }
    out
}

fn r4_float_accum(ctx: &FileCtx) -> Vec<(u32, String)> {
    if !in_scope(&ctx.module, R4_SCOPE) {
        return Vec::new();
    }
    let toks = ctx.toks;
    let mut out = Vec::new();
    for (i, t) in live(ctx) {
        let sum_turbofish = ident(t, "sum")
            && i + 4 < toks.len()
            && punct(&toks[i + 1], ":")
            && punct(&toks[i + 2], ":")
            && punct(&toks[i + 3], "<")
            && (ident(&toks[i + 4], "f32") || ident(&toks[i + 4], "f64"));
        let fold_call = ident(t, "fold")
            && i > 0
            && punct(&toks[i - 1], ".")
            && toks.get(i + 1).map_or(false, |n| punct(n, "("));
        if sum_turbofish || fold_call {
            out.push((
                t.line,
                "float accumulation in a shard/reduce/apply path — summation order must \
                 be a pure function of the step plan; merge through tree_reduce_into \
                 (or pragma a provably fixed-order reduction)"
                    .to_string(),
            ));
        }
    }
    out
}

/// Keywords that legitimately precede `[` without being an indexing base
/// (slice patterns, array types/literals).
const NON_INDEX_PREV: &[&str] =
    &["let", "mut", "ref", "in", "as", "return", "match", "if", "else", "box", "dyn"];

fn r5_hot_panic(ctx: &FileCtx) -> Vec<(u32, String)> {
    let panics = in_scope(&ctx.module, R5_PANIC_SCOPE);
    let indexing = in_scope(&ctx.module, R5_INDEX_SCOPE);
    if !panics && !indexing {
        return Vec::new();
    }
    let toks = ctx.toks;
    let mut out = Vec::new();
    for (i, t) in live(ctx) {
        if panics {
            let method_call = |name: &str| {
                ident(t, name)
                    && i > 0
                    && punct(&toks[i - 1], ".")
                    && toks.get(i + 1).map_or(false, |n| punct(n, "("))
            };
            let macro_call = |name: &str| {
                ident(t, name) && toks.get(i + 1).map_or(false, |n| punct(n, "!"))
            };
            if method_call("unwrap") || method_call("expect") {
                out.push((
                    t.line,
                    format!(
                        ".{}() in the hot path — a recoverable condition must surface as \
                         Result, not tear down a shard worker mid-step",
                        t.text
                    ),
                ));
                continue;
            }
            if macro_call("panic") || macro_call("unreachable") || macro_call("todo")
                || macro_call("unimplemented")
            {
                out.push((
                    t.line,
                    format!("{}! in the hot path — return an error instead", t.text),
                ));
                continue;
            }
        }
        if indexing && punct(t, "[") && i > 0 {
            let prev = &toks[i - 1];
            let base = (prev.kind == TokKind::Ident
                && !NON_INDEX_PREV.contains(&prev.text.as_str()))
                || punct(prev, ")")
                || punct(prev, "]");
            if base && !bracket_is_range(toks, i) {
                out.push((
                    t.line,
                    "bare slice indexing in the hot path — a bad id aborts the worker \
                     thread; use get()/iterators or pragma the proven-in-bounds access"
                        .to_string(),
                ));
            }
        }
    }
    out
}

/// True when the bracket group opening at `toks[open]` contains a `..` at
/// top level (range slicing, not element indexing).
fn bracket_is_range(toks: &[Tok], open: usize) -> bool {
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < toks.len() && depth > 0 {
        match toks[j].text.as_str() {
            "[" | "(" | "{" => depth += 1,
            "]" | ")" | "}" => depth -= 1,
            "." if depth == 1 && toks.get(j + 1).map_or(false, |n| punct(n, ".")) => {
                return true;
            }
            _ => {}
        }
        j += 1;
    }
    false
}

fn r6_lossy_cast(ctx: &FileCtx) -> Vec<(u32, String)> {
    if !in_scope(&ctx.module, R6_SCOPE) {
        return Vec::new();
    }
    let toks = ctx.toks;
    live(ctx)
        .filter(|&(i, t)| {
            ident(t, "as") && toks.get(i + 1).map_or(false, |n| ident(n, "f32"))
        })
        .map(|(_, t)| {
            (
                t.line,
                "`as f32` where HT weights / inclusion probabilities are computed — \
                 quantize through selection::pi_w32 so π and w = 1/π round at ONE \
                 blessed point, or pragma with the precision argument"
                    .to_string(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run(rule_slug: &str, module: &[&str], src: &str) -> Vec<u32> {
        let lexed = lex(src);
        let ctx = FileCtx {
            module: module.iter().map(|s| s.to_string()).collect(),
            toks: &lexed.toks,
        };
        let rule = registry().iter().find(|r| r.slug == rule_slug).unwrap();
        (rule.check)(&ctx).into_iter().map(|(l, _)| l).collect()
    }

    #[test]
    fn rules_respect_module_scope() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("unordered-iter", &["coordinator", "batcher"], src), vec![1]);
        assert_eq!(run("unordered-iter", &["coordinator", "rollout"], src), Vec::<u32>::new());
        let clock = "let t = Instant::now();\n";
        assert_eq!(run("wallclock", &["coordinator", "trainer"], clock), vec![1]);
        assert!(run("wallclock", &["obs", "ledger"], clock).is_empty());
        assert!(run("wallclock", &["util", "bench"], clock).is_empty());
    }

    #[test]
    fn rng_rule_blesses_mixers_and_constants() {
        let m = &["tasks", "dataset"];
        assert!(run("rng-discipline", m, "let r = Rng::new(stream_seed(s, step, TAG));").is_empty());
        assert!(run("rng-discipline", m, "let r = xor_stream(seed, 0x5EED);").is_empty());
        assert!(run("rng-discipline", m, "let r = Rng::new(SEED ^ 0x5EED);").is_empty());
        assert!(run("rng-discipline", m, "let r = Rng::new(w::SEED);").is_empty());
        assert_eq!(run("rng-discipline", m, "let r = Rng::new(seed ^ 0xEAA1);"), vec![1]);
        assert_eq!(run("rng-discipline", m, "let r = Rng::new(seed + idx as u64);"), vec![1]);
    }

    #[test]
    fn float_accum_catches_sum_and_fold_in_runtime() {
        let m = &["runtime", "params"];
        assert_eq!(run("float-accum", m, "let n = v.iter().sum::<f64>();"), vec![1]);
        assert_eq!(run("float-accum", m, "let n = v.iter().fold(0.0, |a, b| a + b);"), vec![1]);
        assert!(run("float-accum", m, "let n: usize = v.iter().sum::<usize>();").is_empty());
        assert!(run("float-accum", &["coordinator", "rollout"], "x.sum::<f32>();").is_empty());
    }

    #[test]
    fn hot_panic_distinguishes_indexing_from_ranges_and_macros() {
        let m = &["runtime", "shard"];
        assert_eq!(run("hot-panic", m, "let x = slots[i];"), vec![1]);
        assert!(run("hot-panic", m, "let x = &flat[a..b];").is_empty());
        assert!(run("hot-panic", m, "let v = vec![0.0; n];").is_empty());
        assert!(run("hot-panic", m, "#[derive(Clone)] struct S;").is_empty());
        assert_eq!(run("hot-panic", m, "h.join().unwrap();"), vec![1]);
        assert_eq!(run("hot-panic", m, "x.expect(\"poisoned\");"), vec![1]);
        assert_eq!(run("hot-panic", m, "panic!(\"boom\");"), vec![1]);
        // expect/indexing in non-scoped modules stay silent
        assert!(run("hot-panic", &["exp", "tables"], "xs[0].unwrap();").is_empty());
    }

    #[test]
    fn lossy_cast_only_fires_in_selection_scope() {
        let src = "let p = x as f32;\n";
        assert_eq!(run("lossy-cast", &["coordinator", "selection", "urs"], src), vec![1]);
        assert!(run("lossy-cast", &["coordinator", "selection", "urs"], "y as f64;").is_empty());
        assert!(run("lossy-cast", &["runtime", "sim"], src).is_empty());
    }

    #[test]
    fn test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); }\n}\n";
        assert!(run("wallclock", &["coordinator", "trainer"], src).is_empty());
    }
}
