//! The `natlint` pragma: a per-line, per-rule escape hatch that must carry
//! a written reason.
//!
//! Syntax (inside any `//` comment):
//!
//! ```text
//! // natlint: allow(<rule>[, <rule>…], reason = "why this is sound")
//! ```
//!
//! A pragma on its own line covers the next code line; a trailing pragma
//! covers its own line. A pragma only ever silences the rules it names —
//! unknown rule names and missing reasons are themselves findings (the
//! `P0 pragma` meta-rule), so a typo can never turn into a silent blanket
//! waiver.

/// One parsed pragma. `line` is where the comment sits; the engine resolves
/// the code line it covers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pragma {
    pub line: u32,
    /// Rule slugs named by `allow(…)`.
    pub rules: Vec<String>,
    /// The mandatory justification.
    pub reason: String,
}

/// Parse one comment. Returns `None` for comments that are not natlint
/// pragmas, `Some(Err(msg))` for malformed pragmas (the engine reports
/// those), `Some(Ok(p))` for well-formed ones.
pub fn parse(line: u32, comment: &str) -> Option<Result<Pragma, String>> {
    let body = comment.trim_start_matches('/').trim();
    let rest = body.strip_prefix("natlint:")?.trim();
    Some(parse_body(line, rest))
}

fn parse_body(line: u32, rest: &str) -> Result<Pragma, String> {
    let inner = rest
        .strip_prefix("allow")
        .map(str::trim_start)
        .and_then(|s| s.strip_prefix('('))
        .ok_or_else(|| "expected `allow(<rule>, reason = \"…\")`".to_string())?;
    let inner = inner
        .strip_suffix(')')
        .ok_or_else(|| "unclosed `allow(`".to_string())?;
    let mut rules = Vec::new();
    let mut reason: Option<String> = None;
    for part in split_top_level(inner) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some(value) = part.strip_prefix("reason") {
            let value = value.trim_start();
            let value = value
                .strip_prefix('=')
                .map(str::trim_start)
                .ok_or_else(|| "expected `reason = \"…\"`".to_string())?;
            let quoted = value
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| "reason must be a double-quoted string".to_string())?;
            if quoted.trim().is_empty() {
                return Err("reason must not be empty".to_string());
            }
            reason = Some(quoted.to_string());
        } else if part.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
        {
            rules.push(part.to_string());
        } else {
            return Err(format!("bad rule name '{part}' (slugs are kebab-case)"));
        }
    }
    if rules.is_empty() {
        return Err("allow(…) must name at least one rule".to_string());
    }
    let reason =
        reason.ok_or_else(|| "missing `reason = \"…\"` — every waiver needs one".to_string())?;
    Ok(Pragma { line, rules, reason })
}

/// Split on commas that are not inside the reason's double quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Render a pragma back to canonical comment form (the round-trip target
/// of the pragma proptest in `tests/analysis.rs`).
pub fn render(rules: &[&str], reason: &str) -> String {
    format!("// natlint: allow({}, reason = \"{}\")", rules.join(", "), reason)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_and_multi_rule_pragmas() {
        let p = parse(3, "// natlint: allow(wallclock, reason = \"timing series only\")")
            .unwrap()
            .unwrap();
        assert_eq!(p.rules, vec!["wallclock"]);
        assert_eq!(p.reason, "timing series only");
        assert_eq!(p.line, 3);
        let p = parse(1, "// natlint: allow(hot-panic, lossy-cast, reason = \"a, b, c\")")
            .unwrap()
            .unwrap();
        assert_eq!(p.rules, vec!["hot-panic", "lossy-cast"]);
        assert_eq!(p.reason, "a, b, c");
    }

    #[test]
    fn non_pragma_comments_are_ignored() {
        assert!(parse(1, "// plain comment").is_none());
        assert!(parse(1, "/// doc comment about natlint rules").is_none());
    }

    #[test]
    fn malformed_pragmas_are_errors_not_waivers() {
        for bad in [
            "// natlint: allow(wallclock)",
            "// natlint: allow(, reason = \"x\")",
            "// natlint: allow(reason = \"x\")",
            "// natlint: allow(wallclock, reason = )",
            "// natlint: allow(wallclock, reason = \"\")",
            "// natlint: deny(wallclock)",
            "// natlint: allow(WallClock, reason = \"x\")",
            "// natlint: allow(wallclock, reason = \"x\"",
        ] {
            assert!(parse(1, bad).unwrap().is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn render_round_trips() {
        let text = render(&["rng-discipline", "float-accum"], "pre-mixed seed");
        let p = parse(9, &text).unwrap().unwrap();
        assert_eq!(p.rules, vec!["rng-discipline", "float-accum"]);
        assert_eq!(p.reason, "pre-mixed seed");
    }
}
