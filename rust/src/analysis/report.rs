//! Findings, counts, and the human/JSON renderings of a lint run.

use std::collections::BTreeMap;

use crate::util::json::{obj, Json};

use super::rules::{registry, PRAGMA_RULE};

/// One lint finding at a source line.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule id (`R1`…`R6`, or `P0` for pragma errors).
    pub rule_id: String,
    /// Rule slug (the name pragmas use).
    pub slug: String,
    /// Path relative to the lint root.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    pub message: String,
}

/// A whole-tree lint run.
#[derive(Clone, Debug)]
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    /// Findings in (file, line) order.
    pub findings: Vec<Finding>,
    pub wall_ms: f64,
}

impl Report {
    /// Finding count per rule slug — every registered rule appears, rules
    /// with zero findings included (the BENCH record's schema stability).
    pub fn counts(&self) -> BTreeMap<String, usize> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for r in registry() {
            counts.insert(r.slug.to_string(), 0);
        }
        counts.insert(PRAGMA_RULE.1.to_string(), 0);
        for f in &self.findings {
            *counts.entry(f.slug.clone()).or_insert(0) += 1;
        }
        counts
    }

    /// Terminal rendering: one `path:line: [id slug] message` per finding
    /// plus a one-line summary.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{} {}] {}\n",
                f.file, f.line, f.rule_id, f.slug, f.message
            ));
        }
        let nonzero: Vec<String> = self
            .counts()
            .iter()
            .filter(|(_, &n)| n > 0)
            .map(|(slug, n)| format!("{slug}={n}"))
            .collect();
        let breakdown = if nonzero.is_empty() {
            "clean".to_string()
        } else {
            nonzero.join(", ")
        };
        out.push_str(&format!(
            "nat lint: {} file(s), {} finding(s) ({breakdown}) in {:.1}ms\n",
            self.files_scanned,
            self.findings.len(),
            self.wall_ms
        ));
        out
    }

    /// Machine-readable record — the `--json` stdout document and the
    /// `BENCH_lint.json` artifact share this schema.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("rule", Json::Str(f.rule_id.clone())),
                    ("slug", Json::Str(f.slug.clone())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        let mut counts_map: BTreeMap<String, Json> = BTreeMap::new();
        for (slug, n) in self.counts() {
            counts_map.insert(slug, Json::Num(n as f64));
        }
        obj(vec![
            ("bench", Json::Str("lint".to_string())),
            ("root", Json::Str(self.root.clone())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("findings", Json::Arr(findings)),
            ("counts", Json::Obj(counts_map)),
            ("wall_ms", Json::Num(self.wall_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(findings: Vec<Finding>) -> Report {
        Report { root: "src".into(), files_scanned: 2, findings, wall_ms: 1.5 }
    }

    #[test]
    fn clean_report_renders_and_counts_all_rules() {
        let r = report_with(Vec::new());
        let text = r.render_human();
        assert!(text.contains("2 file(s), 0 finding(s) (clean)"), "{text}");
        let counts = r.counts();
        for slug in
            ["unordered-iter", "wallclock", "rng-discipline", "float-accum", "hot-panic",
             "lossy-cast", "pragma"]
        {
            assert_eq!(counts.get(slug), Some(&0), "{slug} missing from counts");
        }
    }

    #[test]
    fn json_record_carries_findings_and_counts() {
        let r = report_with(vec![Finding {
            rule_id: "R2".into(),
            slug: "wallclock".into(),
            file: "coordinator/trainer.rs".into(),
            line: 42,
            message: "clock read".into(),
        }]);
        let j = r.to_json();
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("lint"));
        assert_eq!(j.get("files_scanned").and_then(|v| v.as_usize()), Some(2));
        let f0 = j.get("findings").and_then(|v| v.idx(0)).unwrap();
        assert_eq!(f0.get("line").and_then(|v| v.as_usize()), Some(42));
        assert_eq!(
            j.get("counts").and_then(|c| c.get("wallclock")).and_then(|v| v.as_usize()),
            Some(1)
        );
        // round-trips through the JSON substrate
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }
}
