//! TOML-subset parser for run configuration files (`configs/*.toml`).
//!
//! Supported grammar (all the project's configs need, nothing more):
//!   * `[section]` headers (one level)
//!   * `key = value` with value ∈ {string "..."/'...', integer, float, bool,
//!     flat array of scalars}
//!   * `#` comments and blank lines
//!
//! Values are surfaced as [`crate::util::json::Json`] so config and manifest
//! plumbing share one value type.

use std::collections::BTreeMap;

use super::json::Json;

pub type Table = BTreeMap<String, BTreeMap<String, Json>>;

pub fn parse(text: &str) -> Result<Table, String> {
    let mut out: Table = BTreeMap::new();
    let mut section = String::new();
    out.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must not start a comment
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match in_str {
            Some(q) if c == q => in_str = None,
            None if c == '"' || c == '\'' => in_str = Some(c),
            None if c == '#' => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Json, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = quoted(s) {
        return Ok(Json::Str(inner));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Json::Arr(items));
    }
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("cannot parse value: {s}"))
}

fn quoted(s: &str) -> Option<String> {
    for q in ['"', '\''] {
        if s.len() >= 2 && s.starts_with(q) && s.ends_with(q) {
            return Some(s[1..s.len() - 1].to_string());
        }
    }
    None
}

fn split_top_level(s: &str) -> Vec<&str> {
    // arrays are flat (no nesting) — split on commas outside quotes
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str: Option<char> = None;
    for (i, c) in s.char_indices() {
        match in_str {
            Some(q) if c == q => in_str = None,
            None if c == '"' || c == '\'' => in_str = Some(c),
            None if c == ',' => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let t = parse(
            "top = 1\n[run]\nmethod = \"rpc\" # comment\nsteps = 200\nlr = 2.5e-4\nflag = true\n",
        )
        .unwrap();
        assert_eq!(t[""]["top"].as_i64(), Some(1));
        assert_eq!(t["run"]["method"].as_str(), Some("rpc"));
        assert_eq!(t["run"]["steps"].as_i64(), Some(200));
        assert_eq!(t["run"]["lr"].as_f64(), Some(2.5e-4));
        assert_eq!(t["run"]["flag"], Json::Bool(true));
    }

    #[test]
    fn parses_arrays() {
        let t = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nempty = []\n").unwrap();
        assert_eq!(t[""]["xs"].as_arr().unwrap().len(), 3);
        assert_eq!(t[""]["ys"].idx(1).unwrap().as_str(), Some("b"));
        assert!(t[""]["empty"].as_arr().unwrap().is_empty());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = parse("marker = \"#\"\n").unwrap();
        assert_eq!(t[""]["marker"].as_str(), Some("#"));
    }

    #[test]
    fn errors_are_positioned() {
        let err = parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("k = \n").is_err());
    }

    #[test]
    fn single_quotes() {
        let t = parse("s = 'hello world'\n").unwrap();
        assert_eq!(t[""]["s"].as_str(), Some("hello world"));
    }
}
