//! Deterministic PRNG substrate: xoshiro256++ seeded via SplitMix64.
//!
//! The offline environment vendors no `rand` crate, and the coordinator needs
//! reproducible per-run, per-stream randomness (mask sampling, task
//! generation, rollout seeds). Streams are derived with [`Rng::fork`] so that
//! e.g. the RPC mask stream is independent of the task-sampling stream and
//! results are stable under reordering of unrelated draws.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix `(run seed, step, stream tag)` into an independent stream seed —
/// SplitMix64-style avalanche on each component so that nearby steps and
/// tags land in uncorrelated streams. THE blessed way to derive a per-step
/// RNG: draws become a pure function of `(seed, step, tag)`, which is what
/// keeps Horvitz-Thompson inclusion probabilities honest under pipelined /
/// sharded execution (`nat lint` rule R3 enforces that every `Rng::new`
/// outside this module goes through a helper here or a documented waiver).
pub fn stream_seed(seed: u64, step: u64, tag: u64) -> u64 {
    seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ step.wrapping_add(1).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ tag.wrapping_mul(0x94D0_49BB_1331_11EB)
}

/// Step-free variant for streams that live for a whole run (task sampling,
/// eval, SFT): one stream per `(run seed, stream tag)`. Bit-identical to
/// the historical `Rng::new(seed ^ TAG)` call sites it replaced.
pub fn xor_stream(seed: u64, tag: u64) -> Rng {
    Rng::new(seed ^ tag)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut st);
        }
        Rng { s }
    }

    /// Derive an independent child stream. The label keeps forks of the same
    /// parent at different call sites decorrelated.
    pub fn fork(&mut self, label: u64) -> Rng {
        let a = self.next_u64();
        Rng::new(a ^ label.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (uncached; simplicity over speed).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fresh i32 seed for a PJRT generate call.
    pub fn next_i32_seed(&mut self) -> i32 {
        (self.next_u64() & 0x7FFF_FFFF) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive_covers_endpoints() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            match r.range_inclusive(5, 9) {
                5 => seen_lo = true,
                9 => seen_hi = true,
                x => assert!((5..=9).contains(&x)),
            }
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "{mean}");
        assert!((var - 1.0).abs() < 0.02, "{var}");
    }

    #[test]
    fn forked_streams_are_decorrelated() {
        let mut parent = Rng::new(5);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn stream_seed_is_sensitive_to_every_component() {
        let base = stream_seed(1, 2, 3);
        assert_ne!(base, stream_seed(2, 2, 3));
        assert_ne!(base, stream_seed(1, 3, 3));
        assert_ne!(base, stream_seed(1, 2, 4));
        // pure function: same inputs, same stream
        assert_eq!(
            Rng::new(stream_seed(1, 2, 3)).next_u64(),
            Rng::new(stream_seed(1, 2, 3)).next_u64()
        );
    }

    #[test]
    fn xor_stream_matches_the_legacy_spelling() {
        assert_eq!(
            xor_stream(42, 0xEAA1).next_u64(),
            Rng::new(42 ^ 0xEAA1).next_u64()
        );
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(7);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
