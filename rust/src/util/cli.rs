//! Tiny CLI substrate: subcommand + `--key value` / `--flag` parsing
//! (clap is not in the offline vendor set).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options not supported: {arg}");
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{s}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("train --model small --steps 10 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.parse_or::<usize>("steps", 0).unwrap(), 10);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("repro --fig=2 --out=x.csv");
        assert_eq!(a.get("fig"), Some("2"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn trailing_flag_and_positional() {
        let a = parse("eval ckpt.bin --fast");
        assert_eq!(a.positional, vec!["ckpt.bin"]);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn parse_or_errors_on_bad_value() {
        let a = parse("x --steps ten");
        assert!(a.parse_or::<usize>("steps", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.parse_or::<u64>("seed", 7).unwrap(), 7);
    }
}
