//! In-tree substrates for the offline environment (DESIGN.md §4): PRNG,
//! JSON, TOML-subset config parsing, and a mini benchmark harness.
pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod tomlite;
