//! Mini-benchmark harness (criterion is not in the offline vendor set).
//!
//! Usage in a `harness = false` bench target:
//! ```ignore
//! let mut b = Bench::new("masking");
//! b.iter("rpc/T=192", || masking::sample(&strategy, 192, &mut rng));
//! b.report();
//! ```
//! Each case runs a warmup phase, then timed batches until both a minimum
//! duration and a minimum iteration count are reached; reports mean / std /
//! median / p95 ns per op. `BENCH_JSON=path` additionally dumps the raw
//! numbers so the experiment harness can consume them.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
}

pub struct Bench {
    pub group: String,
    pub min_time: Duration,
    pub min_iters: u64,
    pub warmup: Duration,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Bench {
            group: group.to_string(),
            min_time: Duration::from_millis(
                std::env::var("BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(600),
            ),
            min_iters: 10,
            warmup: Duration::from_millis(150),
            results: Vec::new(),
        }
    }

    /// Fast-path setting for expensive cases (e.g. whole train steps).
    pub fn slow(mut self) -> Self {
        self.min_iters = 3;
        self.warmup = Duration::from_millis(0);
        self
    }

    pub fn iter<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        // Warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed samples: one sample per call (ops here are >= microseconds).
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_time || (samples.len() as u64) < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n.max(1.0);
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let p95_idx = (((sorted.len() as f64) * 0.95) as usize).min(sorted.len() - 1);
        let p95 = sorted[p95_idx];
        self.results.push(CaseResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_ns: mean,
            std_ns: var.sqrt(),
            median_ns: median,
            p95_ns: p95,
        });
    }

    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<40} {:>10} {:>14} {:>12} {:>14} {:>14}",
            "case", "iters", "mean", "std", "median", "p95"
        );
        for r in &self.results {
            println!(
                "{:<40} {:>10} {:>14} {:>12} {:>14} {:>14}",
                r.name,
                r.iters,
                fmt_ns(r.mean_ns),
                fmt_ns(r.std_ns),
                fmt_ns(r.median_ns),
                fmt_ns(r.p95_ns)
            );
        }
        if let Ok(path) = std::env::var("BENCH_JSON") {
            let mut items = Vec::new();
            for r in &self.results {
                items.push(crate::util::json::obj(vec![
                    ("group", crate::util::json::Json::Str(self.group.clone())),
                    ("name", crate::util::json::Json::Str(r.name.clone())),
                    ("iters", crate::util::json::Json::Num(r.iters as f64)),
                    ("mean_ns", crate::util::json::Json::Num(r.mean_ns)),
                    ("std_ns", crate::util::json::Json::Num(r.std_ns)),
                    ("median_ns", crate::util::json::Json::Num(r.median_ns)),
                    ("p95_ns", crate::util::json::Json::Num(r.p95_ns)),
                ]));
            }
            let _ = std::fs::write(
                format!("{path}.{}.json", self.group),
                crate::util::json::Json::Arr(items).to_string(),
            );
        }
    }
}

/// Write one `BENCH_<name>.json` record at the repository root (the parent
/// of the `rust/` crate), so every bench target lands its artifact in the
/// same place no matter which directory cargo was invoked from.
///
/// Record schema (all benches share it):
/// ```json
/// {
///   "bench": "<name>",              // target name, matches BENCH_<name>.json
///   "cases": [ { ... } ],           // per-case results (bench-specific keys)
///   ...                             // optional bench-specific sections, e.g.
///                                   // "ledger": {...} stage/savings breakdown
/// }
/// ```
/// The top-level object always carries "bench"; callers add their sections
/// before handing the record over. Returns the path written.
pub fn write_record(name: &str, record: &crate::util::json::Json) -> std::io::Result<String> {
    // CARGO_MANIFEST_DIR = <repo>/rust at compile time for this crate.
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, record.to_string())?;
    Ok(path.display().to_string())
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench::new("test");
        b.min_time = Duration::from_millis(5);
        b.warmup = Duration::from_millis(1);
        let mut x = 0u64;
        b.iter("noop", || {
            x = x.wrapping_add(1);
            x
        });
        assert_eq!(b.results.len(), 1);
        let r = &b.results[0];
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).ends_with("µs"));
        assert!(fmt_ns(2_500_000.0).ends_with("ms"));
        assert!(fmt_ns(2_500_000_000.0).ends_with("s"));
    }
}
