//! Minimal JSON substrate: recursive-descent parser + writer.
//!
//! Used for artifacts/<cfg>/manifest.json (written by python/compile/aot.py)
//! and for all experiment/metric output files. No serde in the offline
//! vendor set, so this is a from-scratch implementation covering the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) with precise error positions.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialisation (round-trips through `parse`).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// Convenience builders used by metric/experiment writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_i64(), Some(1));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\n")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,"x"],"b":{"c":true},"d":null}"#,
            r#"[[],{},"\"quoted\"","line\nbreak"]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(v.to_string(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest() {
        // the actual contract file, if artifacts are built
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("param_count").unwrap().as_i64().unwrap() > 0);
            assert!(!m.get("params").unwrap().as_arr().unwrap().is_empty());
        }
    }
}
