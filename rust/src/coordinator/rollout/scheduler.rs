//! Length-bucketed continuous-batching rollout scheduler.
//!
//! The legacy rollout path runs every generate call over the full
//! `batch_rollout × (P + max_resp)` window: tail chunks are padded with
//! duplicate rows and short responses keep a slot allocated until the
//! slowest straggler finishes. This module mirrors the learner-side
//! bucketing (PR 2) on the inference side:
//!
//! * Each **slot** (one pending completion) carries its own RNG seed,
//!   derived as a pure function of `(run seed, step, flat_id)` via
//!   [`slot_seed`] — never from chunk-order draws. Combined with per-row
//!   sampling streams in the `generate_T<b>` artifacts, a slot's output is
//!   **scheduling-invariant**: bit-identical for any device batch size,
//!   bucket routing, refill interleaving, or worker count.
//! * Slots are routed into the shortest viable response bucket by an EMA
//!   response-length predictor ([`LenPredictor`], reusing the
//!   [`EmaHist`](crate::coordinator::bucket_tuner::EmaHist) machinery of
//!   the learner's `BucketTuner`), and batches are drained smallest bucket
//!   first.
//! * A tail batch is never padded with duplicate rows while real work is
//!   pending: a partial remainder is **promoted** into the next non-empty
//!   larger bucket whenever the extra decode steps cost less than the
//!   padding rows it replaces (the continuous-batching "refill" — the
//!   monolithic artifact call is the refill granularity).
//! * A row that exhausts its bucket without emitting EOS **escalates** to
//!   the next bucket and re-decodes there; per-row seeding makes the re-run
//!   prefix bit-identical, so escalation changes cost, never output.
//!
//! The scheduler core ([`schedule`]) is generic over a [`RolloutBackend`]
//! so its routing/refill/escalation logic — and the scheduling-invariance
//! contract — are testable host-side against simulated policies
//! ([`SimBackend`]) without PJRT. The legacy engine is preserved as
//! [`run_slots_fixed`] (`--rollout.engine fixed`): the single place that
//! implements the chunk/pad-with-duplicates/scatter loop that
//! `run_group_rollouts` and the evaluator both used to hand-roll.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::config::RolloutCfg;
use crate::coordinator::bucket_tuner::EmaHist;
use crate::coordinator::rollout::prefix_cache::{prompt_key, CacheStats, PrefixCache};
use crate::coordinator::rollout::{plan_chunks, trim_at_eos};
use crate::runtime::{GenerateOut, KvBlock, ParamStore, Runtime};
use crate::tokenizer::{EOS, PAD};
use crate::util::rng::Rng;

/// Per-slot RNG seed: a pure one-way mix of `(run seed, step, flat_id)`.
///
/// This is the invariance keystone — the seed belongs to the *slot*, not to
/// the generate call it happens to land in, so rollout output is a pure
/// function of the plan.
pub fn slot_seed(seed: u64, step: u64, flat_id: u64) -> i32 {
    let mut x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ step.wrapping_add(1).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ flat_id.wrapping_add(1).wrapping_mul(0x94D0_49BB_1331_11EB)
        ^ 0x524F_4C4C_534C_4F54; // "ROLLSLOT" tag
    // SplitMix64 finalizer: full avalanche so nearby (step, flat_id) pairs
    // land on decorrelated seeds.
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x & 0x7FFF_FFFF) as i32
}

/// One pending completion: which prompt to decode and with which seed.
#[derive(Clone, Copy, Debug)]
pub struct SlotSpec {
    /// The caller's flat rollout index (e.g. `task_idx * G + j`).
    pub flat_id: usize,
    /// Index into the caller's encoded-prompt table.
    pub prompt_idx: usize,
    /// Per-slot sampling seed (see [`slot_seed`]).
    pub seed: i32,
}

/// One completed slot, in the legacy full-window layout.
#[derive(Clone, Debug)]
pub struct SlotOut {
    pub flat_id: usize,
    /// Full `[P + top_bucket]` row; positions past the stop point are PAD.
    pub tokens: Vec<i32>,
    /// Response length after EOS trim (1..=top bucket, EOS included).
    pub resp_len: usize,
    /// Behaviour logprobs over `0..resp_len`.
    pub lp: Vec<f32>,
}

/// Device abstraction the bucketed scheduler drives. `Runtime` implements
/// it over the manifest's `generate_T<b>` artifacts ([`RuntimeBackend`]);
/// tests and benches implement simulated policies ([`SimBackend`]).
///
/// Contract required for scheduling invariance: each row's sampled stream
/// must be a pure function of its own `(prompt, seed)` — independent of its
/// batch position, of the other rows, and of the bucket cap (a longer
/// bucket extends the stream, bit-identical prefix).
pub trait RolloutBackend {
    /// Ascending response buckets with compiled generate artifacts; the
    /// last is the full response window (`max_resp`).
    fn gen_buckets(&self) -> Vec<usize>;
    /// Rows per generate call (the device batch).
    fn batch_rollout(&self) -> usize;
    fn prompt_len(&self) -> usize;
    /// One bucketed call: prompts `[B, P]`, pads/seeds `[B]`; returns
    /// tokens `[B, P + bucket]` and behaviour logprobs `[B, bucket]`.
    fn generate_bucket(
        &self,
        bucket: usize,
        prompts: &[i32],
        pads: &[i32],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut>;

    /// True when the backend carries the prefill/decode split, i.e.
    /// [`RolloutBackend::prefill`] + [`RolloutBackend::generate_bucket_kv`]
    /// can execute. Default false: legacy backends keep fused generate and
    /// the scheduler never routes them through the prefix cache.
    fn supports_prefill(&self) -> bool {
        false
    }

    /// Prefill one prompt into its KV block. Must be a pure function of
    /// `(params, prompt)` — the block is shared across slots with
    /// different seeds.
    fn prefill(&self, _prompt: &[i32], _pad: i32) -> Result<KvBlock> {
        bail!("backend has no prefill artifact")
    }

    /// Bucketed decode from per-row KV blocks. Contract: bit-identical to
    /// [`RolloutBackend::generate_bucket`] over the blocks' prompts for the
    /// same seeds — the split changes cost, never output.
    fn generate_bucket_kv(
        &self,
        bucket: usize,
        _kvs: &[&KvBlock],
        _seeds: &[i32],
        _temp: f32,
    ) -> Result<GenerateOut> {
        bail!("backend has no decode_T{bucket} artifact")
    }
}

/// [`RolloutBackend`] over the runtime's per-bucket generate artifacts.
pub struct RuntimeBackend<'a> {
    pub rt: &'a Runtime,
    pub params: &'a ParamStore,
}

impl RolloutBackend for RuntimeBackend<'_> {
    fn gen_buckets(&self) -> Vec<usize> {
        self.rt.manifest.generate_files.iter().map(|&(b, _)| b).collect()
    }

    fn batch_rollout(&self) -> usize {
        self.rt.manifest.dims.batch_rollout
    }

    fn prompt_len(&self) -> usize {
        self.rt.manifest.dims.prompt_len
    }

    fn generate_bucket(
        &self,
        bucket: usize,
        prompts: &[i32],
        pads: &[i32],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut> {
        self.rt.generate_bucketed(self.params, bucket, prompts, pads, seeds, temp)
    }

    fn supports_prefill(&self) -> bool {
        self.rt.manifest.has_prefill_split()
    }

    fn prefill(&self, prompt: &[i32], pad: i32) -> Result<KvBlock> {
        self.rt.prefill(self.params, prompt, pad)
    }

    fn generate_bucket_kv(
        &self,
        bucket: usize,
        kvs: &[&KvBlock],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut> {
        self.rt.generate_bucketed_kv(self.params, bucket, kvs, seeds, temp)
    }
}

/// Cost accounting for one scheduled run (benches + perf tracking).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    /// Generate calls issued.
    pub calls: usize,
    /// Σ allocated_rows × bucket over all calls — the decode-step budget
    /// the device pays regardless of early exits.
    pub decode_token_steps: usize,
    /// Rows re-decoded in a larger bucket after overflowing their first.
    pub escalations: usize,
    /// Allocated rows that carried no real slot (tail padding).
    pub padded_rows: usize,
    /// Σ prompt-window token-steps prefill actually paid: allocated_rows × P
    /// per fused generate call, or P per prefill-cache miss. The quantity
    /// `bench_prefix` gates the ≥60% reduction on.
    pub prefill_token_steps: usize,
    /// Prefix-cache lookups that found a ready KV block.
    pub prefill_hits: usize,
    /// Prefix-cache lookups issued (one per allocated row).
    pub prefill_lookups: usize,
    /// Σ prefill token-steps hits avoided re-paying (= hits × P).
    pub prefill_steps_saved: usize,
    /// Resident prefix-cache bytes after the run (gauge, not a counter).
    pub cache_bytes: usize,
}

impl SchedStats {
    /// The stats as named span args for the trainer's `rollout` trace span.
    pub fn trace_args(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("calls", self.calls as f64),
            ("decode_token_steps", self.decode_token_steps as f64),
            ("escalations", self.escalations as f64),
            ("padded_rows", self.padded_rows as f64),
            ("prefill_token_steps", self.prefill_token_steps as f64),
            ("prefill_hits", self.prefill_hits as f64),
            ("prefill_lookups", self.prefill_lookups as f64),
            ("prefill_steps_saved", self.prefill_steps_saved as f64),
            ("cache_bytes", self.cache_bytes as f64),
        ]
    }
}

/// Run every slot to completion through bucketed generate calls.
///
/// `routes[i]` is slot i's initial routing hint (any length; snapped to the
/// smallest bucket that covers it). Because escalation re-decodes the
/// bit-identical prefix and continues, the *output* is independent of the
/// routing — only the cost ([`SchedStats`]) changes. Returned slots are in
/// input order.
pub fn schedule<B: RolloutBackend + ?Sized>(
    backend: &B,
    encoded: &[(Vec<i32>, usize)],
    slots: &[SlotSpec],
    routes: &[usize],
    temp: f32,
) -> Result<(Vec<SlotOut>, SchedStats)> {
    schedule_cached(backend, encoded, slots, routes, temp, None)
}

/// [`schedule`] with an optional shared-prefix prefill cache.
///
/// With `cache = Some((cache, param_version))` each allocated row resolves
/// its prompt through the cache (single-flight prefill on a miss) and the
/// batch decodes via `generate_bucket_kv`; without it every call is a fused
/// `generate_bucket` that re-prefills its prompt window. The two paths are
/// **bit-identical** — decode-from-KV reproduces fused generate for the
/// same `(prompt, seed)` rows — so the cache shapes `SchedStats` only.
pub fn schedule_cached<B: RolloutBackend + ?Sized>(
    backend: &B,
    encoded: &[(Vec<i32>, usize)],
    slots: &[SlotSpec],
    routes: &[usize],
    temp: f32,
    cache: Option<(&PrefixCache, u64)>,
) -> Result<(Vec<SlotOut>, SchedStats)> {
    let buckets = backend.gen_buckets();
    if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
        bail!("generate buckets must be non-empty ascending: {buckets:?}");
    }
    if slots.len() != routes.len() {
        bail!("schedule: {} slots vs {} routes", slots.len(), routes.len());
    }
    let top = *buckets.last().unwrap();
    let b_roll = backend.batch_rollout();
    let p = backend.prompt_len();
    if b_roll == 0 {
        bail!("rollout batch must be positive");
    }

    // Per-bucket FIFO queues of slot indices; arbitrary initial routing is
    // snapped into the compiled grid (over-long hints clamp to top).
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); buckets.len()];
    for (i, &route) in routes.iter().enumerate() {
        let bi = buckets
            .iter()
            .position(|&b| b >= route)
            .unwrap_or(buckets.len() - 1);
        queues[bi].push_back(i);
    }

    let mut out: Vec<Option<SlotOut>> = slots.iter().map(|_| None).collect();
    let mut stats = SchedStats::default();
    // Per-call staging buffers, hoisted out of the refill loop and cleared
    // per batch instead of reallocated per generate call.
    let mut batch: Vec<usize> = Vec::with_capacity(b_roll);
    let mut prompts: Vec<i32> = Vec::with_capacity(b_roll * p);
    let mut pads: Vec<i32> = Vec::with_capacity(b_roll);
    let mut seeds: Vec<i32> = Vec::with_capacity(b_roll);
    let mut kvs: Vec<Arc<KvBlock>> = Vec::with_capacity(if cache.is_some() { b_roll } else { 0 });
    // Drain smallest bucket first so escalations cascade upward into
    // batches that have not formed yet.
    while let Some(bi) = (0..buckets.len()).find(|&i| !queues[i].is_empty()) {
        let b = buckets[bi];
        let pending = queues[bi].len();
        if pending < b_roll {
            // Refill-over-padding: a partial tail is promoted into the next
            // non-empty larger bucket when the extra decode steps cost less
            // than the duplicate-padding rows they replace.
            if let Some(bj) = (bi + 1..buckets.len()).find(|&j| !queues[j].is_empty()) {
                let extra = pending * (buckets[bj] - b);
                let padding = (b_roll - pending) * b;
                if extra <= padding {
                    while let Some(s) = queues[bi].pop_back() {
                        queues[bj].push_front(s);
                    }
                    continue;
                }
            }
        }
        batch.clear();
        while batch.len() < b_roll {
            match queues[bi].pop_front() {
                Some(s) => batch.push(s),
                None => break,
            }
        }

        seeds.clear();
        let gen = if let Some((cache, version)) = cache {
            // Cached path: resolve each row's prompt to its shared KV block
            // (group siblings, refill rounds, escalation re-decodes, and
            // tail-padding rows all hit after the first build), then decode
            // from KV — the prompt window is paid once per distinct prompt.
            kvs.clear();
            for row in 0..b_roll {
                // Padding rows repeat the first slot; their output is never
                // scattered back (the loop below iterates real slots only).
                let si = batch.get(row).copied().unwrap_or(batch[0]);
                let (ref ids, pad) = encoded[slots[si].prompt_idx];
                stats.prefill_lookups += 1;
                let (block, hit) = cache.get_or_prefill(
                    version,
                    prompt_key(ids, pad as i32),
                    || backend.prefill(ids, pad as i32),
                )?;
                if hit {
                    stats.prefill_hits += 1;
                    stats.prefill_steps_saved += block.prefill_steps;
                } else {
                    stats.prefill_token_steps += block.prefill_steps;
                }
                kvs.push(block);
                seeds.push(slots[si].seed);
            }
            let refs: Vec<&KvBlock> = kvs.iter().map(Arc::as_ref).collect();
            backend.generate_bucket_kv(b, &refs, &seeds, temp)?
        } else {
            // Fused path: every generate call re-prefills its whole prompt
            // window (allocated rows × P token-steps), padding included.
            prompts.clear();
            pads.clear();
            for row in 0..b_roll {
                let si = batch.get(row).copied().unwrap_or(batch[0]);
                let (ref ids, pad) = encoded[slots[si].prompt_idx];
                prompts.extend_from_slice(ids);
                pads.push(pad as i32);
                seeds.push(slots[si].seed);
            }
            stats.prefill_token_steps += b_roll * p;
            backend.generate_bucket(b, &prompts, &pads, &seeds, temp)?
        };
        let s_len = p + b;
        if gen.tokens.len() != b_roll * s_len || gen.lp.len() != b_roll * b {
            bail!(
                "generate_T{b}: bad output shapes ({} tokens, {} lp)",
                gen.tokens.len(),
                gen.lp.len()
            );
        }
        stats.calls += 1;
        stats.decode_token_steps += b_roll * b;
        stats.padded_rows += b_roll - batch.len();
        for (row, &si) in batch.iter().enumerate() {
            let row_toks = &gen.tokens[row * s_len..(row + 1) * s_len];
            let resp = &row_toks[p..];
            if !resp.contains(&EOS) && b < top {
                // No EOS within this bucket: re-decode in the next one (the
                // per-row stream makes the longer run's prefix identical).
                stats.escalations += 1;
                queues[bi + 1].push_back(si);
                continue;
            }
            let resp_len = trim_at_eos(resp);
            let mut tokens = row_toks.to_vec();
            // Canonicalize: the decode loop keeps sampling into rows that
            // finished early until the whole batch stops, so positions past
            // the stop point hold batch-dependent garbage — blank them to
            // PAD so the row is a pure function of its slot.
            for t in &mut tokens[p + resp_len..] {
                *t = PAD;
            }
            tokens.resize(p + top, PAD);
            debug_assert!(out[si].is_none(), "slot {si} scheduled twice");
            out[si] = Some(SlotOut {
                flat_id: slots[si].flat_id,
                tokens,
                resp_len,
                lp: gen.lp[row * b..row * b + resp_len].to_vec(),
            });
        }
    }
    let outs = out.into_iter().map(|o| o.expect("rollout slot unfilled")).collect();
    Ok((outs, stats))
}

/// Observations before the predictor trusts its histogram (cold start
/// routes everything to the top bucket — always correct, never cheaper).
const PREDICTOR_WARMUP: u64 = 2;

/// EMA blend factor for the response-length predictor.
const PREDICTOR_ALPHA: f64 = 0.2;

/// EMA response-length predictor: picks the initial routing bucket that
/// minimises expected decode steps per slot under the observed length
/// distribution, accounting for the escalation chain (`b_i` is always paid;
/// each `b_{j+1}` is paid with probability `P(len > b_j)`).
#[derive(Clone, Debug)]
pub struct LenPredictor {
    hist: EmaHist,
}

impl LenPredictor {
    pub fn new(max_len: usize) -> LenPredictor {
        LenPredictor { hist: EmaHist::new(max_len, PREDICTOR_ALPHA) }
    }

    /// Fold one run's realised response lengths into the EMA.
    pub fn observe(&mut self, lens: &[usize]) {
        self.hist.observe(lens);
    }

    /// The routing bucket minimising expected decode steps per slot.
    pub fn route(&self, buckets: &[usize]) -> usize {
        let top = *buckets.last().expect("non-empty buckets");
        if self.hist.steps() < PREDICTOR_WARMUP {
            return top;
        }
        let mut best = (f64::INFINITY, top);
        for i in 0..buckets.len() {
            let mut cost = buckets[i] as f64;
            for j in i..buckets.len() - 1 {
                cost += self.hist.tail(buckets[j]) * buckets[j + 1] as f64;
            }
            if cost < best.0 {
                best = (cost, buckets[i]);
            }
        }
        best.1
    }
}

/// The production scheduler: routing state (EMA predictor) and the
/// shared-prefix prefill cache behind locks so pipelined rollout workers
/// share one instance. Neither shapes output — routing and cache state
/// only shape cost — so cross-thread observation order is benign.
#[derive(Debug)]
pub struct RolloutScheduler {
    predictor: Mutex<LenPredictor>,
    /// `--rollout.prefix_cache`: None when disabled; the scheduler then
    /// always takes the fused-generate path.
    cache: Option<PrefixCache>,
}

impl RolloutScheduler {
    /// A scheduler with the prefix cache disabled (fused generate only).
    pub fn new(max_resp: usize) -> RolloutScheduler {
        RolloutScheduler { predictor: Mutex::new(LenPredictor::new(max_resp)), cache: None }
    }

    /// A scheduler with a shared-prefix prefill cache of `capacity_bytes`.
    /// The cache only engages against backends with the prefill/decode
    /// split (`supports_prefill`); legacy backends run fused regardless.
    pub fn with_cache(max_resp: usize, capacity_bytes: usize) -> RolloutScheduler {
        RolloutScheduler {
            predictor: Mutex::new(LenPredictor::new(max_resp)),
            cache: Some(PrefixCache::new(capacity_bytes)),
        }
    }

    /// Construct from `--rollout.*` config: cache on/off and its byte
    /// budget (`cache_mb`).
    pub fn from_cfg(max_resp: usize, cfg: &RolloutCfg) -> RolloutScheduler {
        if cfg.prefix_cache {
            RolloutScheduler::with_cache(max_resp, cfg.cache_mb << 20)
        } else {
            RolloutScheduler::new(max_resp)
        }
    }

    /// Prefix-cache counters (None when the cache is disabled).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(PrefixCache::stats)
    }

    /// Route, schedule, and fold the realised lengths back into the
    /// predictor. `param_version` keys prefix-cache entries to the
    /// parameter snapshot the rollout runs against; entries more than one
    /// version stale are evicted up front (lookups never match them anyway
    /// — eviction only frees budget). Returned slots are in input order.
    pub fn run<B: RolloutBackend + ?Sized>(
        &self,
        backend: &B,
        encoded: &[(Vec<i32>, usize)],
        slots: &[SlotSpec],
        temp: f32,
        param_version: u64,
    ) -> Result<(Vec<SlotOut>, SchedStats)> {
        let buckets = backend.gen_buckets();
        if buckets.is_empty() {
            bail!("bucketed scheduling needs generate_T<b> artifacts (rebuild artifacts)");
        }
        let cache = self.cache.as_ref().filter(|_| backend.supports_prefill());
        if let Some(c) = cache {
            // The pipeline's staleness bound keeps at most the previous
            // snapshot in flight alongside the current one.
            c.evict_before(param_version.saturating_sub(1));
        }
        let route = self.predictor.lock().expect("predictor poisoned").route(&buckets);
        let routes = vec![route; slots.len()];
        let (outs, mut stats) =
            schedule_cached(backend, encoded, slots, &routes, temp, cache.map(|c| (c, param_version)))?;
        if let Some(c) = cache {
            stats.cache_bytes = c.bytes();
        }
        let lens: Vec<usize> = outs.iter().map(|o| o.resp_len).collect();
        self.predictor.lock().expect("predictor poisoned").observe(&lens);
        Ok((outs, stats))
    }
}

/// The legacy fixed engine, shared by training rollouts and evaluation:
/// flat slots are chunked into full-window generate calls with ONE scalar
/// seed drawn per chunk in chunk order, the tail chunk is padded with
/// duplicates of its first slot, and padding rows are discarded by the
/// scatter (which iterates real slots only). `prompt_idx[flat_id]` indexes
/// `encoded`; `gen_call(prompts, pads, seed)` is one device call.
pub fn run_slots_fixed<F>(
    batch: usize,
    prompt_len: usize,
    max_resp: usize,
    encoded: &[(Vec<i32>, usize)],
    prompt_idx: &[usize],
    rng: &mut Rng,
    mut gen_call: F,
) -> Result<Vec<SlotOut>>
where
    F: FnMut(&[i32], &[i32], i32) -> Result<GenerateOut>,
{
    let (p, t_max) = (prompt_len, max_resp);
    let total = prompt_idx.len();
    let mut out: Vec<Option<SlotOut>> = (0..total).map(|_| None).collect();
    // Per-call staging, hoisted out of the chunk loop and cleared per call.
    let mut prompts: Vec<i32> = Vec::with_capacity(batch * p);
    let mut pads: Vec<i32> = Vec::with_capacity(batch);
    for chunk in plan_chunks(total, batch) {
        prompts.clear();
        pads.clear();
        for row in 0..batch {
            let flat_id = chunk.get(row).copied().unwrap_or(chunk[0]);
            let (ref ids, pad) = encoded[prompt_idx[flat_id]];
            prompts.extend_from_slice(ids);
            pads.push(pad as i32);
        }
        let gen = gen_call(&prompts, &pads, rng.next_i32_seed())?;
        let s = p + t_max;
        if gen.tokens.len() != batch * s || gen.lp.len() != batch * t_max {
            bail!(
                "generate: bad output shapes ({} tokens, {} lp)",
                gen.tokens.len(),
                gen.lp.len()
            );
        }
        for (row, &flat_id) in chunk.iter().enumerate() {
            let tokens = gen.tokens[row * s..(row + 1) * s].to_vec();
            let resp_len = trim_at_eos(&tokens[p..]);
            out[flat_id] = Some(SlotOut {
                flat_id,
                resp_len,
                lp: gen.lp[row * t_max..row * t_max + resp_len].to_vec(),
                tokens,
            });
        }
    }
    Ok(out.into_iter().map(|o| o.expect("rollout slot unfilled")).collect())
}

/// Deterministic host-side policy simulation (benches + the
/// scheduling-invariance tests; no PJRT). Each row's token/logprob stream
/// is a pure hash of its `(prompt, seed)` — the exact contract the per-row
/// `generate_T<b>` artifacts provide — so the same slot produces the same
/// stream in any batch position and under any bucket cap.
pub struct SimBackend {
    pub batch: usize,
    pub prompt_len: usize,
    pub buckets: Vec<usize>,
    /// Mean of the simulated (geometric-ish) response-length distribution.
    pub mean_len: usize,
}

impl SimBackend {
    fn mix(mut x: u64) -> u64 {
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }

    fn row_key(&self, prompt: &[i32], seed: i32) -> u64 {
        let mut h = 0xCBF2_9CE4_8422_2325u64 ^ seed as u64;
        for &t in prompt {
            h = Self::mix(h ^ t as u64);
        }
        h
    }

    /// Simulated response length for a row stream (may exceed the top
    /// bucket, in which case the row never emits EOS — the no-EOS path).
    fn row_len(&self, key: u64) -> usize {
        let u = (Self::mix(key ^ 0x4C45_4E) >> 11) as f64 / (1u64 << 53) as f64;
        1 + (-(self.mean_len as f64) * (1.0 - u).max(1e-12).ln()) as usize
    }
}

impl RolloutBackend for SimBackend {
    fn gen_buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn batch_rollout(&self) -> usize {
        self.batch
    }

    fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    fn generate_bucket(
        &self,
        bucket: usize,
        prompts: &[i32],
        pads: &[i32],
        seeds: &[i32],
        _temp: f32,
    ) -> Result<GenerateOut> {
        let (b_roll, p) = (self.batch, self.prompt_len);
        if prompts.len() != b_roll * p || pads.len() != b_roll || seeds.len() != b_roll {
            bail!("sim generate_T{bucket}: bad input shapes");
        }
        let s = p + bucket;
        let mut tokens = vec![PAD; b_roll * s];
        let mut lp = vec![0.0f32; b_roll * bucket];
        for row in 0..b_roll {
            let prompt = &prompts[row * p..(row + 1) * p];
            tokens[row * s..row * s + p].copy_from_slice(prompt);
            let key = self.row_key(prompt, seeds[row]);
            let len = self.row_len(key);
            for t in 0..bucket.min(len) {
                let draw = Self::mix(key ^ (t as u64).wrapping_mul(0x9E37_79B9));
                tokens[row * s + p + t] =
                    if t == len - 1 { EOS } else { 3 + (draw % 61) as i32 };
                lp[row * bucket + t] = -0.01 - (draw >> 32) as f32 / u32::MAX as f32;
            }
        }
        Ok(GenerateOut { tokens, lp })
    }

    fn supports_prefill(&self) -> bool {
        true
    }

    fn prefill(&self, prompt: &[i32], pad: i32) -> Result<KvBlock> {
        if prompt.len() != self.prompt_len {
            bail!("sim prefill: prompt of {} tokens, window {}", prompt.len(), self.prompt_len);
        }
        Ok(KvBlock {
            prompt: prompt.to_vec(),
            pad,
            kv: Vec::new(),
            // modeled footprint: 4 bytes per prompt position plus the pad
            bytes: 4 * (prompt.len() + 1),
            prefill_steps: self.prompt_len,
        })
    }

    fn generate_bucket_kv(
        &self,
        bucket: usize,
        kvs: &[&KvBlock],
        seeds: &[i32],
        temp: f32,
    ) -> Result<GenerateOut> {
        // Materialize the prompt matrix from the blocks and delegate —
        // decode-from-KV is bit-identical to fused generate by construction.
        let (b_roll, p) = (self.batch, self.prompt_len);
        if kvs.len() != b_roll {
            bail!("sim decode_T{bucket}: {} kv blocks, batch {b_roll}", kvs.len());
        }
        let mut prompts = Vec::with_capacity(b_roll * p);
        let mut pads = Vec::with_capacity(b_roll);
        for block in kvs {
            prompts.extend_from_slice(&block.prompt);
            pads.push(block.pad);
        }
        self.generate_bucket(bucket, &prompts, &pads, seeds, temp)
    }
}

/// The default simulated rollout workload: the paper's post-RL regime
/// (mostly short responses with a long tail) over the learner's bucket
/// grid at bulk scale. ONE definition shared by `benches/bench_rollout.rs`
/// (which writes `BENCH_rollout.json`) and the tier-1 decode-step
/// acceptance test, so the perf record and the CI gate always measure the
/// same workload.
pub mod sim_workload {
    use super::{slot_seed, SimBackend, SlotSpec};

    pub const BATCH: usize = 8;
    pub const PROMPT_LEN: usize = 48;
    pub const BUCKETS: [usize; 4] = [32, 64, 96, 128];
    pub const MEAN_RESP_LEN: usize = 24;
    /// prompts_per_step × G at bulk scale.
    pub const SLOTS_PER_STEP: usize = 64;
    pub const STEPS: u64 = 12;
    pub const RUN_SEED: u64 = 17;
    const N_PROMPTS: usize = 16;

    pub fn backend() -> SimBackend {
        SimBackend {
            batch: BATCH,
            prompt_len: PROMPT_LEN,
            buckets: BUCKETS.to_vec(),
            mean_len: MEAN_RESP_LEN,
        }
    }

    pub fn prompts() -> Vec<(Vec<i32>, usize)> {
        (0..N_PROMPTS)
            .map(|i| {
                let mut row = vec![0i32; PROMPT_LEN];
                for (t, slot) in row.iter_mut().enumerate().skip(4) {
                    *slot = 3 + ((i * 13 + t * 7) % 50) as i32;
                }
                (row, 4)
            })
            .collect()
    }

    pub fn slots(step: u64) -> Vec<SlotSpec> {
        (0..SLOTS_PER_STEP)
            .map(|f| SlotSpec {
                flat_id: f,
                prompt_idx: f % N_PROMPTS,
                seed: slot_seed(RUN_SEED, step, f as u64),
            })
            .collect()
    }

    /// GRPO-shaped slot plan: `SLOTS_PER_STEP` slots as groups of G
    /// siblings per prompt (`flat_id / g` picks the prompt), the workload
    /// `bench_prefix` and the tier-1 prefill-saving gate measure on.
    pub fn grouped_slots(step: u64, g: usize) -> Vec<SlotSpec> {
        (0..SLOTS_PER_STEP)
            .map(|f| SlotSpec {
                flat_id: f,
                prompt_idx: (f / g) % N_PROMPTS,
                seed: slot_seed(RUN_SEED, step, f as u64),
            })
            .collect()
    }

    /// Prefill token-steps the FUSED engine pays for one scheduled run:
    /// every generate call re-prefills its whole `BATCH × PROMPT_LEN`
    /// window. (`SchedStats::prefill_token_steps` reports exactly this on
    /// the uncached path; the helper exists for bench-record context.)
    pub fn fused_prefill_steps(calls: usize) -> usize {
        calls * BATCH * PROMPT_LEN
    }

    /// The fixed engine's allocation for the same workload: every chunk
    /// decodes the full top-bucket window over the whole device batch.
    pub fn fixed_decode_steps() -> usize {
        let top = *BUCKETS.last().unwrap();
        STEPS as usize * SLOTS_PER_STEP.div_ceil(BATCH) * BATCH * top
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(batch: usize, buckets: &[usize], mean_len: usize) -> SimBackend {
        SimBackend { batch, prompt_len: 6, buckets: buckets.to_vec(), mean_len }
    }

    fn encoded_prompts(n: usize, p: usize) -> Vec<(Vec<i32>, usize)> {
        (0..n)
            .map(|i| {
                let mut row = vec![PAD; p];
                for (t, slot) in row.iter_mut().enumerate().skip(1) {
                    *slot = 3 + ((i * 7 + t * 3) % 50) as i32;
                }
                (row, 1)
            })
            .collect()
    }

    fn slots_for(n_prompts: usize, g: usize, seed: u64, step: u64) -> Vec<SlotSpec> {
        (0..n_prompts * g)
            .map(|f| SlotSpec {
                flat_id: f,
                prompt_idx: f / g,
                seed: slot_seed(seed, step, f as u64),
            })
            .collect()
    }

    /// Bit-comparable fingerprint of a scheduled run, sorted by flat id.
    fn canon(outs: &[SlotOut]) -> Vec<(usize, usize, Vec<i32>, Vec<u32>)> {
        let mut v: Vec<_> = outs
            .iter()
            .map(|o| {
                (
                    o.flat_id,
                    o.resp_len,
                    o.tokens.clone(),
                    o.lp.iter().map(|x| x.to_bits()).collect(),
                )
            })
            .collect();
        v.sort();
        v
    }

    #[test]
    fn slot_seed_is_pure_and_decorrelated() {
        assert_eq!(slot_seed(7, 3, 11), slot_seed(7, 3, 11));
        let mut seen = std::collections::HashSet::new();
        for step in 0..8u64 {
            for flat in 0..64u64 {
                let s = slot_seed(42, step, flat);
                assert!(s >= 0);
                seen.insert(s);
            }
        }
        // full avalanche: essentially no collisions across nearby inputs
        assert!(seen.len() >= 8 * 64 - 1, "{}", seen.len());
        assert_ne!(slot_seed(1, 0, 0), slot_seed(2, 0, 0));
        assert_ne!(slot_seed(1, 0, 0), slot_seed(1, 1, 0));
        assert_ne!(slot_seed(1, 0, 0), slot_seed(1, 0, 1));
    }

    #[test]
    fn schedule_fills_every_slot_once_and_trims_eos() {
        let backend = sim(4, &[8, 16, 32], 6);
        let encoded = encoded_prompts(3, 6);
        let slots = slots_for(3, 3, 1, 0);
        let routes = vec![8; slots.len()];
        let (outs, stats) = schedule(&backend, &encoded, &slots, &routes, 1.0).unwrap();
        assert_eq!(outs.len(), 9);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.flat_id, i);
            assert_eq!(o.tokens.len(), 6 + 32);
            assert!(o.resp_len >= 1 && o.resp_len <= 32);
            assert_eq!(o.lp.len(), o.resp_len);
            // prompt region preserved verbatim
            assert_eq!(&o.tokens[..6], &encoded[i / 3].0[..]);
            // past the stop point the row is PAD
            assert!(o.tokens[6 + o.resp_len..].iter().all(|&t| t == PAD));
        }
        assert!(stats.calls > 0);
        assert_eq!(stats.decode_token_steps % 4, 0);
    }

    #[test]
    fn overflow_rows_escalate_to_the_next_bucket() {
        // mean_len 40 over buckets [8, 64]: most rows overflow bucket 8
        // when routed there and must re-decode at 64.
        let backend = sim(2, &[8, 64], 40);
        let encoded = encoded_prompts(2, 6);
        let slots = slots_for(2, 2, 9, 1);
        let routes = [8usize; 4];
        let (outs, stats) = schedule(&backend, &encoded, &slots, &routes, 1.0).unwrap();
        assert_eq!(outs.len(), 4);
        assert!(stats.escalations > 0, "{stats:?}");
        // ...and the no-EOS path: rows longer than the top bucket report
        // the full window.
        assert!(outs.iter().all(|o| o.resp_len <= 64));
    }

    /// The tentpole invariance contract: the same slot plan yields
    /// byte-identical outputs for ANY batch size, bucket grid (same top),
    /// and initial routing — scheduling shapes cost only.
    #[test]
    fn outputs_are_invariant_to_batch_buckets_and_routing() {
        let top = 48usize;
        let encoded = encoded_prompts(5, 6);
        for case in 0..40u64 {
            let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ case);
            let g = 1 + rng.below(4) as usize;
            let slots = slots_for(5, g, case, rng.below(100));
            let mean = 4 + rng.below(40) as usize;
            // reference: single-bucket grid (everything decodes at top)
            let reference = {
                let backend = sim(4, &[top], mean);
                let routes = vec![top; slots.len()];
                canon(&schedule(&backend, &encoded, &slots, &routes, 1.0).unwrap().0)
            };
            let grids: [&[usize]; 4] =
                [&[top], &[12, top], &[8, 16, 24, top], &[6, 12, 18, 24, 30, 36, 42, top]];
            for _ in 0..3 {
                let batch = 1 + rng.below(9) as usize;
                let grid = grids[rng.below(grids.len() as u64) as usize];
                let backend = sim(batch, grid, mean);
                // adversarial routing: arbitrary initial buckets per slot
                let routes: Vec<usize> =
                    slots.iter().map(|_| 1 + rng.below(top as u64) as usize).collect();
                let (outs, _) = schedule(&backend, &encoded, &slots, &routes, 1.0).unwrap();
                assert_eq!(canon(&outs), reference, "case {case} batch {batch} {grid:?}");
            }
        }
    }

    #[test]
    fn predictor_cold_start_routes_top_then_adapts() {
        let buckets = [16usize, 32, 64];
        let mut p = LenPredictor::new(64);
        assert_eq!(p.route(&buckets), 64);
        p.observe(&[4, 5, 6, 7]);
        assert_eq!(p.route(&buckets), 64, "one observation is still warm-up");
        p.observe(&[4, 5, 6, 7]);
        // all mass <= 16: expected cost 16 beats 32/64
        assert_eq!(p.route(&buckets), 16);
        // shift the distribution long: routing follows
        let mut p = LenPredictor::new(64);
        for _ in 0..8 {
            p.observe(&[60, 61, 62, 63]);
        }
        assert_eq!(p.route(&buckets), 64);
    }

    #[test]
    fn predictor_accounts_for_escalation_cost() {
        // Half the mass at <=16, half at <=64: routing at 16 costs
        // 16 + 0.5*32 + 0.5*64 = 64, routing at 32 costs 32 + 0.5*64 = 64,
        // routing at 64 costs 64 — all tied here; make the long half
        // dominant so low routing is strictly worse and top wins.
        let buckets = [16usize, 32, 64];
        let mut p = LenPredictor::new(64);
        for _ in 0..8 {
            p.observe(&[10, 60, 60, 60]);
        }
        assert_eq!(p.route(&buckets), 64);
    }

    #[test]
    fn partial_tails_promote_instead_of_padding_when_cheaper() {
        // 1 slot pending at bucket 8 + work pending at 16, batch 4: padding
        // would burn 3×8 = 24 steps, promotion costs 1×(16-8) = 8 → the
        // scheduler must merge the tail upward (no padded rows at all when
        // the merged bucket fills exactly).
        let backend = sim(4, &[8, 16], 3);
        let encoded = encoded_prompts(4, 6);
        let slots = slots_for(4, 1, 3, 0);
        let routes = [8usize, 16, 16, 16];
        let (_, stats) = schedule(&backend, &encoded, &slots, &routes, 1.0).unwrap();
        assert_eq!(stats.calls, 1, "{stats:?}");
        assert_eq!(stats.padded_rows, 0, "{stats:?}");
        assert_eq!(stats.decode_token_steps, 4 * 16);
    }

    #[test]
    fn scheduler_run_warms_predictor_and_cuts_cost() {
        // Short-response policy (mean 6) over buckets up to 64: after the
        // predictor warms up, scheduled decode steps must undercut the
        // fixed engine's total-slots × top allocation by well over 25%.
        let backend = sim(8, &[8, 16, 32, 64], 6);
        let encoded = encoded_prompts(8, 6);
        let sched = RolloutScheduler::new(64);
        let mut warm_steps = 0usize;
        for step in 0..6u64 {
            let slots = slots_for(8, 2, 11, step);
            let (outs, stats) = sched.run(&backend, &encoded, &slots, 1.0, step).unwrap();
            assert_eq!(outs.len(), 16);
            if step >= 2 {
                warm_steps += stats.decode_token_steps;
            }
        }
        let fixed_steps = 4 * (16usize.div_ceil(8) * 8 * 64); // 4 warm runs
        // Loose bound here (a tiny 16-slot workload has lumpy escalation
        // counts); the ≥25% acceptance runs in bench_rollout at bulk scale.
        assert!(
            (warm_steps as f64) < 0.85 * fixed_steps as f64,
            "bucketed {warm_steps} vs fixed {fixed_steps}"
        );
    }

    #[test]
    fn fixed_engine_matches_the_legacy_loop_bit_for_bit() {
        // The refactored shared fixed path must reproduce the pre-scheduler
        // implementation exactly: same chunking, same one-seed-per-chunk rng
        // consumption, same duplicate-padded tail, same scatter.
        let (batch, p, t_max) = (4usize, 6usize, 16usize);
        let encoded = encoded_prompts(3, p);
        let prompt_idx: Vec<usize> = (0..7).map(|f| f / 3).collect();
        let sim_gen = |prompts: &[i32], _pads: &[i32], seed: i32| -> Result<GenerateOut> {
            // scalar-seed mock: each row's stream hashes (call seed, row)
            let s = p + t_max;
            let mut tokens = vec![PAD; batch * s];
            let mut lp = vec![0.0f32; batch * t_max];
            for row in 0..batch {
                tokens[row * s..row * s + p].copy_from_slice(&prompts[row * p..(row + 1) * p]);
                let key = SimBackend::mix(seed as u64 ^ ((row as u64) << 32));
                let len = 1 + (key % t_max as u64) as usize;
                for t in 0..len {
                    let draw = SimBackend::mix(key ^ t as u64);
                    tokens[row * s + p + t] =
                        if t == len - 1 { EOS } else { 3 + (draw % 61) as i32 };
                    lp[row * t_max + t] = -(draw % 97) as f32 / 97.0 - 0.01;
                }
            }
            Ok(GenerateOut { tokens, lp })
        };
        // legacy reference, transcribed from the pre-PR run_group_rollouts
        let mut rng = crate::util::rng::Rng::new(55);
        let mut legacy: Vec<Option<(Vec<i32>, usize, Vec<f32>)>> = vec![None; 7];
        for chunk in plan_chunks(7, batch) {
            let mut prompts = Vec::new();
            let mut pads = Vec::new();
            for row in 0..batch {
                let flat_id = chunk.get(row).copied().unwrap_or(chunk[0]);
                let (ref ids, pad) = encoded[prompt_idx[flat_id]];
                prompts.extend_from_slice(ids);
                pads.push(pad as i32);
            }
            let gen = sim_gen(&prompts, &pads, rng.next_i32_seed()).unwrap();
            for (row, &flat_id) in chunk.iter().enumerate() {
                let s = p + t_max;
                let tokens = gen.tokens[row * s..(row + 1) * s].to_vec();
                let resp_len = trim_at_eos(&tokens[p..]);
                let lp = gen.lp[row * t_max..row * t_max + resp_len].to_vec();
                legacy[flat_id] = Some((tokens, resp_len, lp));
            }
        }
        let mut rng2 = crate::util::rng::Rng::new(55);
        let outs =
            run_slots_fixed(batch, p, t_max, &encoded, &prompt_idx, &mut rng2, sim_gen).unwrap();
        for (o, l) in outs.iter().zip(&legacy) {
            let (tokens, resp_len, lp) = l.as_ref().unwrap();
            assert_eq!(&o.tokens, tokens);
            assert_eq!(o.resp_len, *resp_len);
            assert_eq!(&o.lp, lp);
        }
        // identical rng consumption: both streams are at the same point
        assert_eq!(rng.next_u64(), rng2.next_u64());
    }

    #[test]
    fn prefix_cache_on_off_is_bit_identical() {
        // The acceptance contract: --rollout.prefix_cache on|off produce
        // identical rollouts. Exercised across group sizes and steps so
        // hits survive siblings, refill promotion, and escalation rounds.
        let backend = sim(4, &[8, 16, 32], 10);
        let encoded = encoded_prompts(5, 6);
        for g in [1usize, 2, 4] {
            let off = RolloutScheduler::new(32);
            let on = RolloutScheduler::with_cache(32, 1 << 20);
            for step in 0..4u64 {
                let slots = slots_for(5, g, 21, step);
                let (a, sa) = off.run(&backend, &encoded, &slots, 1.0, step).unwrap();
                let (b, sb) = on.run(&backend, &encoded, &slots, 1.0, step).unwrap();
                assert_eq!(canon(&a), canon(&b), "g={g} step={step}");
                // identical decode cost, identical call structure
                assert_eq!(sa.decode_token_steps, sb.decode_token_steps);
                assert_eq!(sa.calls, sb.calls);
                assert_eq!(sa.escalations, sb.escalations);
                // accounting invariants
                assert_eq!(sb.prefill_lookups, sa.calls * 4, "one lookup per allocated row");
                assert!(sb.prefill_hits <= sb.prefill_lookups);
                assert!(sa.prefill_token_steps >= sb.prefill_token_steps);
                assert_eq!(sa.prefill_hits, 0);
                assert_eq!(sa.cache_bytes, 0);
            }
            // the cache saw every lookup and only 5 prompts × steps missed
            let cs = on.cache_stats().unwrap();
            assert!(cs.hits > 0 && cs.misses > 0);
        }
    }

    #[test]
    fn cached_run_cuts_prefill_steps_over_60pct_at_g8() {
        // Tier-1 mirror of the BENCH_prefix gate, on the same shared
        // workload: at G=8 the cache must cut prefill token-steps by ≥60%.
        let backend = sim_workload::backend();
        let encoded = sim_workload::prompts();
        let uncached = RolloutScheduler::new(*sim_workload::BUCKETS.last().unwrap());
        let cached =
            RolloutScheduler::with_cache(*sim_workload::BUCKETS.last().unwrap(), 64 << 20);
        let (mut base, mut opt) = (0usize, 0usize);
        for step in 0..sim_workload::STEPS {
            let slots = sim_workload::grouped_slots(step, 8);
            let (a, sa) = uncached.run(&backend, &encoded, &slots, 1.0, step).unwrap();
            let (b, sb) = cached.run(&backend, &encoded, &slots, 1.0, step).unwrap();
            assert_eq!(canon(&a), canon(&b), "step {step}");
            base += sa.prefill_token_steps;
            opt += sb.prefill_token_steps;
        }
        assert!(base > 0);
        let saving = 1.0 - opt as f64 / base as f64;
        assert!(
            saving >= 0.60,
            "prefill saving {saving:.3} below the 60% gate ({opt} vs {base} steps)"
        );
    }

    #[test]
    fn full_cache_degrades_to_uncached_prefill() {
        // Regression (satellite): capacity 0 means every insert is
        // oversized — the scheduler must keep working, every lookup a
        // miss, outputs unchanged.
        let backend = sim(4, &[8, 16], 6);
        let encoded = encoded_prompts(3, 6);
        let slots = slots_for(3, 4, 5, 2);
        let off = RolloutScheduler::new(16);
        let zero = RolloutScheduler::with_cache(16, 0);
        let (a, _) = off.run(&backend, &encoded, &slots, 1.0, 0).unwrap();
        let (b, sb) = zero.run(&backend, &encoded, &slots, 1.0, 0).unwrap();
        assert_eq!(canon(&a), canon(&b));
        assert_eq!(sb.prefill_hits, 0, "nothing can hit a zero-budget cache");
        assert!(sb.prefill_lookups > 0);
        assert_eq!(sb.cache_bytes, 0);
        let cs = zero.cache_stats().unwrap();
        assert_eq!((cs.entries, cs.bytes), (0, 0));
    }

    #[test]
    fn stale_param_versions_evict_but_current_survive() {
        let backend = sim(4, &[8, 16], 6);
        let encoded = encoded_prompts(4, 6);
        let sched = RolloutScheduler::with_cache(16, 1 << 20);
        let slots = slots_for(4, 2, 13, 0);
        sched.run(&backend, &encoded, &slots, 1.0, 5).unwrap();
        let after_v5 = sched.cache_stats().unwrap();
        assert!(after_v5.entries > 0);
        // v6 keeps v5 entries resident (staleness bound of one)...
        sched.run(&backend, &encoded, &slots, 1.0, 6).unwrap();
        let after_v6 = sched.cache_stats().unwrap();
        assert!(after_v6.entries >= after_v5.entries);
        // ...but v8 evicts both v5 and v6 up front.
        sched.run(&backend, &encoded, &slots, 1.0, 8).unwrap();
        let after_v8 = sched.cache_stats().unwrap();
        assert!(after_v8.evictions > after_v6.evictions, "{after_v8:?}");
    }

    #[test]
    fn schedule_rejects_bad_inputs() {
        let backend = sim(2, &[8, 16], 4);
        let encoded = encoded_prompts(1, 6);
        let slots = slots_for(1, 1, 0, 0);
        assert!(schedule(&backend, &encoded, &slots, &[], 1.0).is_err());
        let empty = SimBackend { batch: 2, prompt_len: 6, buckets: vec![], mean_len: 4 };
        assert!(schedule(&empty, &encoded, &slots, &[8], 1.0).is_err());
        let unsorted = SimBackend { batch: 2, prompt_len: 6, buckets: vec![16, 8], mean_len: 4 };
        assert!(schedule(&unsorted, &encoded, &slots, &[8], 1.0).is_err());
    }

    #[test]
    fn empty_slot_list_is_a_noop() {
        let backend = sim(2, &[8], 4);
        let (outs, stats) = schedule(&backend, &[], &[], &[], 1.0).unwrap();
        assert!(outs.is_empty());
        assert_eq!(stats.calls, 0);
    }
}
