//! Shared-prefix prefill cache: prefill each distinct `(param version,
//! prompt)` once, decode every group sibling from the cached KV block.
//!
//! In GRPO every prompt is decoded G times — the group — and again on every
//! refill round, escalation re-decode, tail-padding row, and eval pass. The
//! prompt forward pass (prefill) is pure per-prompt work being paid per-row.
//! This cache sits under the bucketed rollout scheduler and turns prefill
//! into per-prompt work again: the first row to need a prompt under a given
//! parameter snapshot builds its [`KvBlock`]; everyone else decodes from the
//! shared, ref-counted (`Arc`) block.
//!
//! Contracts:
//!
//! * **Determinism.** The cache can change *cost*, never *output*: a
//!   [`KvBlock`] is a pure function of `(params, prompt)` and decode-from-KV
//!   is bit-identical to fused generate by construction, so cache on/off —
//!   and any eviction schedule — produce byte-identical rollouts. All
//!   internal state lives in `BTreeMap`s: iteration and eviction follow the
//!   insertion-epoch order, never a hasher's (lint R1 covers this module).
//! * **Keying.** Entries are keyed `(param_version, prompt_hash)`. A new
//!   parameter snapshot changes the version half, so stale blocks can never
//!   serve a fresh lookup; they are dropped by [`PrefixCache::evict_before`]
//!   at snapshot turnover and by LRU pressure otherwise.
//! * **Byte-budget LRU.** Ready entries are indexed by a monotonically
//!   increasing touch epoch; when the resident bytes exceed the budget the
//!   smallest epoch (least recently used) is evicted first. A block larger
//!   than the whole budget — including the degenerate capacity-0 cache — is
//!   served to the caller but never stored: graceful degrade to per-call
//!   prefill, not an error.
//! * **Single-flight.** Concurrent pipeline workers asking for the same key
//!   never duplicate the prefill: the first caller installs a `Pending`
//!   marker and builds outside the lock; everyone else blocks on a condvar
//!   until the block is published (the check → lock → re-check → build →
//!   publish idiom).

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::runtime::KvBlock;

/// FNV-1a over a left-padded prompt row plus its pad length — the prompt
/// half of the cache key. Pure integer mixing: stable across runs and
/// platforms, like every other key in the determinism contract.
pub fn prompt_key(tokens: &[i32], pad: i32) -> u64 {
    const PRIME: u64 = 0x100_0000_01B3;
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &t in tokens {
        h = (h ^ t as u32 as u64).wrapping_mul(PRIME);
    }
    (h ^ pad as u32 as u64).wrapping_mul(PRIME)
}

/// Aggregate cache counters. `hits`/`misses`/`evictions` are monotonic over
/// the cache's lifetime; `bytes`/`entries` are point-in-time gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: usize,
    pub entries: usize,
}

/// One cache slot: a block being built by some caller, or the published
/// result (with its current recency epoch, mirrored in the LRU index).
enum Slot {
    Pending,
    Ready { block: Arc<KvBlock>, epoch: u64 },
}

struct Inner {
    /// `(param_version, prompt_hash)` → slot.
    slots: BTreeMap<(u64, u64), Slot>,
    /// Recency index: touch epoch → key. The smallest epoch is the LRU
    /// victim; a hit re-inserts its entry under a fresh epoch. Only Ready
    /// entries appear here (Pending holds no bytes and is never evicted).
    lru: BTreeMap<u64, (u64, u64)>,
    epoch: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// The cache. One instance lives inside each `RolloutScheduler`, shared by
/// every pipeline worker that scheduler serves.
pub struct PrefixCache {
    inner: Mutex<Inner>,
    ready: Condvar,
    capacity: usize,
}

impl std::fmt::Debug for PrefixCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PrefixCache")
            .field("capacity", &self.capacity)
            .field("stats", &s)
            .finish()
    }
}

impl PrefixCache {
    pub fn new(capacity_bytes: usize) -> PrefixCache {
        PrefixCache {
            inner: Mutex::new(Inner {
                slots: BTreeMap::new(),
                lru: BTreeMap::new(),
                epoch: 0,
                bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            ready: Condvar::new(),
            capacity: capacity_bytes,
        }
    }

    /// Byte budget this cache evicts down to.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up `(version, key)`; on a miss run `build` exactly once across
    /// all concurrent callers (single-flight) and publish the result.
    /// Returns the block and whether this call hit.
    ///
    /// A build error is returned to the caller that ran the build; waiters
    /// wake, find the slot vacated, and retry the build themselves — an
    /// error never wedges the key.
    pub fn get_or_prefill<F>(
        &self,
        version: u64,
        key: u64,
        build: F,
    ) -> Result<(Arc<KvBlock>, bool)>
    where
        F: FnOnce() -> Result<KvBlock>,
    {
        let k = (version, key);
        let mut inner = self.inner.lock().expect("prefix cache poisoned");
        loop {
            match inner.slots.get(&k) {
                Some(Slot::Ready { block, epoch }) => {
                    let (block, old) = (block.clone(), *epoch);
                    inner.epoch += 1;
                    let e = inner.epoch;
                    if let Some(Slot::Ready { epoch, .. }) = inner.slots.get_mut(&k) {
                        *epoch = e;
                    }
                    inner.lru.remove(&old);
                    inner.lru.insert(e, k);
                    inner.hits += 1;
                    return Ok((block, true));
                }
                Some(Slot::Pending) => {
                    inner = self.ready.wait(inner).expect("prefix cache poisoned");
                }
                None => break,
            }
        }
        inner.slots.insert(k, Slot::Pending);
        inner.misses += 1;
        drop(inner);

        let built = build();

        let mut inner = self.inner.lock().expect("prefix cache poisoned");
        let block = match built {
            Ok(b) => Arc::new(b),
            Err(e) => {
                inner.slots.remove(&k);
                self.ready.notify_all();
                return Err(e);
            }
        };
        if block.bytes <= self.capacity {
            inner.epoch += 1;
            let e = inner.epoch;
            inner.slots.insert(k, Slot::Ready { block: block.clone(), epoch: e });
            inner.lru.insert(e, k);
            inner.bytes += block.bytes;
            // Byte-budget LRU: evict smallest-epoch entries until the budget
            // holds. The fresh entry carries the largest epoch, so it is
            // considered last and survives (it fits the budget on its own).
            while inner.bytes > self.capacity {
                let Some((&old, &victim)) = inner.lru.iter().next() else {
                    break;
                };
                inner.lru.remove(&old);
                if let Some(Slot::Ready { block, .. }) = inner.slots.remove(&victim) {
                    inner.bytes -= block.bytes;
                    inner.evictions += 1;
                }
            }
        } else {
            // Oversized for the whole budget (including capacity 0): serve
            // the block uncached — graceful degrade to per-call prefill.
            inner.slots.remove(&k);
        }
        self.ready.notify_all();
        Ok((block, false))
    }

    /// Drop every Ready entry whose param version is below `min_version`.
    /// Lookups always carry the caller's current version, so blocks from
    /// retired snapshots can never hit again — they only occupy budget.
    /// Pending markers are left alone (their builder owns their lifecycle).
    pub fn evict_before(&self, min_version: u64) {
        let mut inner = self.inner.lock().expect("prefix cache poisoned");
        let stale: Vec<(u64, u64)> = inner
            .slots
            .range(..(min_version, 0))
            .filter(|(_, s)| matches!(s, Slot::Ready { .. }))
            .map(|(&k, _)| k)
            .collect();
        for k in stale {
            if let Some(Slot::Ready { block, epoch }) = inner.slots.remove(&k) {
                inner.lru.remove(&epoch);
                inner.bytes -= block.bytes;
                inner.evictions += 1;
            }
        }
    }

    /// Resident bytes (Ready entries only).
    pub fn bytes(&self) -> usize {
        self.inner.lock().expect("prefix cache poisoned").bytes
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().expect("prefix cache poisoned");
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            bytes: inner.bytes,
            entries: inner.slots.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn block(tag: i32, bytes: usize) -> KvBlock {
        KvBlock {
            prompt: vec![tag; 4],
            pad: 0,
            kv: Vec::new(),
            bytes,
            prefill_steps: 4,
        }
    }

    #[test]
    fn prompt_key_is_stable_and_sensitive() {
        let a = prompt_key(&[1, 2, 3], 0);
        assert_eq!(a, prompt_key(&[1, 2, 3], 0));
        assert_ne!(a, prompt_key(&[1, 2, 4], 0));
        assert_ne!(a, prompt_key(&[1, 2, 3], 1));
        assert_ne!(a, prompt_key(&[1, 2], 0));
    }

    #[test]
    fn hit_returns_the_same_block_and_counts() {
        let cache = PrefixCache::new(1 << 20);
        let builds = AtomicUsize::new(0);
        let mk = || {
            builds.fetch_add(1, Ordering::SeqCst);
            Ok(block(7, 100))
        };
        let (a, hit_a) = cache.get_or_prefill(1, 42, mk).unwrap();
        let (b, hit_b) = cache.get_or_prefill(1, 42, mk).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!((s.bytes, s.entries), (100, 1));
    }

    #[test]
    fn versions_partition_the_key_space() {
        let cache = PrefixCache::new(1 << 20);
        let (_, h1) = cache.get_or_prefill(1, 42, || Ok(block(1, 10))).unwrap();
        let (_, h2) = cache.get_or_prefill(2, 42, || Ok(block(2, 10))).unwrap();
        assert!(!h1 && !h2, "a new param version must never hit stale KV");
        cache.evict_before(2);
        let s = cache.stats();
        assert_eq!((s.entries, s.bytes, s.evictions), (1, 10, 1));
        // the surviving entry still hits
        let (_, h3) = cache.get_or_prefill(2, 42, || Ok(block(2, 10))).unwrap();
        assert!(h3);
    }

    #[test]
    fn lru_evicts_in_touch_epoch_order() {
        // Budget fits two 100-byte blocks. Insert a, b; touch a; insert c —
        // b (smallest touch epoch) must be the victim, not a.
        let cache = PrefixCache::new(200);
        cache.get_or_prefill(1, 1, || Ok(block(1, 100))).unwrap();
        cache.get_or_prefill(1, 2, || Ok(block(2, 100))).unwrap();
        let (_, hit) = cache.get_or_prefill(1, 1, || Ok(block(1, 100))).unwrap();
        assert!(hit);
        cache.get_or_prefill(1, 3, || Ok(block(3, 100))).unwrap();
        let (_, a_alive) = cache.get_or_prefill(1, 1, || Ok(block(1, 100))).unwrap();
        let (_, b_alive) = cache.get_or_prefill(1, 2, || Ok(block(2, 100))).unwrap();
        assert!(a_alive, "recently touched entry was evicted");
        assert!(!b_alive, "LRU entry survived past the byte budget");
    }

    #[test]
    fn capacity_zero_degrades_to_uncached_prefill() {
        // Regression (satellite): a full cache must degrade gracefully —
        // every call builds, nothing is stored, nothing errors.
        let cache = PrefixCache::new(0);
        let builds = AtomicUsize::new(0);
        for _ in 0..3 {
            let (b, hit) = cache
                .get_or_prefill(1, 42, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    Ok(block(9, 64))
                })
                .unwrap();
            assert!(!hit);
            assert_eq!(b.prompt, vec![9; 4]);
        }
        assert_eq!(builds.load(Ordering::SeqCst), 3);
        let s = cache.stats();
        assert_eq!((s.bytes, s.entries), (0, 0));
        assert_eq!(s.misses, 3);
    }

    #[test]
    fn build_error_vacates_the_slot_instead_of_wedging_it() {
        let cache = PrefixCache::new(1 << 20);
        let err = cache.get_or_prefill(1, 5, || anyhow::bail!("device fell over"));
        assert!(err.is_err());
        // the key is free again: the next caller builds successfully
        let (_, hit) = cache.get_or_prefill(1, 5, || Ok(block(5, 10))).unwrap();
        assert!(!hit);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn single_flight_builds_once_across_threads() {
        let cache = Arc::new(PrefixCache::new(1 << 20));
        let builds = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (cache, builds) = (cache.clone(), builds.clone());
            handles.push(std::thread::spawn(move || {
                let (b, _) = cache
                    .get_or_prefill(3, 99, || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        // widen the race window so waiters actually wait
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(block(3, 50))
                    })
                    .unwrap();
                assert_eq!(b.prompt, vec![3; 4]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "single-flight violated");
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 7);
    }

    /// Satellite proptest: for random key sequences, the eviction schedule
    /// is a pure replay-deterministic function of the access order, and the
    /// *returned blocks* are identical across every capacity (the cache can
    /// change cost, never content) and across workers ∈ {1, 2}.
    #[test]
    fn prop_eviction_and_outputs_replay_identically_across_capacities() {
        use crate::util::rng::Rng;
        for case in 0..40u64 {
            let mut rng = Rng::new(0x5EED_CAFE ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let accesses: Vec<(u64, u64)> = (0..60)
                .map(|_| (1 + rng.below(3), rng.below(12)))
                .collect();
            let run = |capacity: usize| -> (Vec<Vec<i32>>, CacheStats) {
                let cache = PrefixCache::new(capacity);
                let mut outs = Vec::new();
                for &(v, key) in &accesses {
                    let (b, _) = cache
                        .get_or_prefill(v, key, || {
                            Ok(block((v * 100 + key) as i32, 40 + (key as usize % 3) * 20))
                        })
                        .unwrap();
                    outs.push(b.prompt.clone());
                }
                (outs, cache.stats())
            };
            let capacities = [0usize, 50, 130, 1 << 20];
            let reference = run(capacities[0]).0;
            for &cap in &capacities {
                let (outs, stats_a) = run(cap);
                assert_eq!(outs, reference, "case {case}: capacity {cap} changed content");
                // replay: the same access order reproduces the same stats
                // (hits, misses, evictions, residency) bit-for-bit
                let (_, stats_b) = run(cap);
                assert_eq!(stats_a, stats_b, "case {case}: eviction not deterministic");
            }
            // two workers splitting the same sequence still return the same
            // blocks (single-flight + pure builds); counters may interleave
            let cache = Arc::new(PrefixCache::new(130));
            let acc = Arc::new(accesses.clone());
            let mut handles = Vec::new();
            for w in 0..2usize {
                let (cache, acc) = (cache.clone(), acc.clone());
                handles.push(std::thread::spawn(move || {
                    let mut outs = Vec::new();
                    for &(v, key) in acc.iter().skip(w).step_by(2) {
                        let (b, _) = cache
                            .get_or_prefill(v, key, || {
                                Ok(block(
                                    (v * 100 + key) as i32,
                                    40 + (key as usize % 3) * 20,
                                ))
                            })
                            .unwrap();
                        outs.push(b.prompt.clone());
                    }
                    outs
                }));
            }
            let joined: Vec<Vec<Vec<i32>>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for (w, outs) in joined.iter().enumerate() {
                let expect: Vec<Vec<i32>> = accesses
                    .iter()
                    .skip(w)
                    .step_by(2)
                    .map(|&(v, key)| vec![(v * 100 + key) as i32; 4])
                    .collect();
                assert_eq!(outs, &expect, "case {case}: worker {w} got wrong content");
            }
            let s = cache.stats();
            assert_eq!(s.hits + s.misses, accesses.len() as u64, "case {case}");
        }
    }
}
