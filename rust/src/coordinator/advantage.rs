//! Group-relative advantages (GRPO, paper Eq. 2).

/// epsilon in the normalised advantage denominator.
pub const ADV_EPS: f64 = 1e-6;

/// \hat A_i = (R_i - mean) / (std + eps), computed per group.
/// Population std (1/G), matching the paper's Eq. 2.
pub fn group_advantages(rewards: &[f32]) -> Vec<f32> {
    let g = rewards.len();
    if g == 0 {
        return vec![];
    }
    let mean = rewards.iter().map(|&r| r as f64).sum::<f64>() / g as f64;
    let var = rewards.iter().map(|&r| (r as f64 - mean).powi(2)).sum::<f64>() / g as f64;
    let std = var.sqrt();
    rewards.iter().map(|&r| ((r as f64 - mean) / (std + ADV_EPS)) as f32).collect()
}

/// Advantages for a flat reward slice organised as consecutive groups of
/// size `group_size` (the rollout scheduler's layout).
pub fn grouped_advantages(rewards: &[f32], group_size: usize) -> Vec<f32> {
    assert!(group_size > 0 && rewards.len() % group_size == 0,
        "rewards {} not divisible into groups of {group_size}", rewards.len());
    rewards.chunks(group_size).flat_map(|g| group_advantages(g)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_variance_group_gets_zero_advantages() {
        // all-correct or all-wrong groups provide no signal (std=0)
        for r in [0.0f32, 1.0] {
            let a = group_advantages(&[r; 8]);
            assert!(a.iter().all(|&x| x.abs() < 1e-3), "{a:?}");
        }
    }

    #[test]
    fn advantages_are_standardised() {
        let a = group_advantages(&[1.0, 0.0, 1.0, 0.0]);
        let mean: f32 = a.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        // half correct: (1 - .5)/.5 = 1, (0 - .5)/.5 = -1
        assert!((a[0] - 1.0).abs() < 1e-4);
        assert!((a[1] + 1.0).abs() < 1e-4);
    }

    #[test]
    fn single_winner_gets_large_advantage() {
        let a = group_advantages(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert!(a[0] > 2.0);
        assert!(a[1] < 0.0);
        // winner's advantage balances the 7 losers
        let sum: f32 = a.iter().sum();
        assert!(sum.abs() < 1e-4);
    }

    #[test]
    fn grouped_layout() {
        let r = [1.0, 0.0, /* group 2 */ 1.0, 1.0];
        let a = grouped_advantages(&r, 2);
        assert_eq!(a.len(), 4);
        assert!(a[0] > 0.0 && a[1] < 0.0);
        assert!(a[2].abs() < 1e-3 && a[3].abs() < 1e-3); // no-signal group
    }

    #[test]
    #[should_panic]
    fn indivisible_groups_panic() {
        grouped_advantages(&[1.0, 0.0, 1.0], 2);
    }

    #[test]
    fn empty_input() {
        assert!(group_advantages(&[]).is_empty());
    }
}
