//! Length-bucketed micro-batching.
//!
//! Each learner item carries a `learn_len` from the NAT masker; the batcher
//! routes it to the smallest compiled grad-artifact bucket that fits and
//! packs fixed-size micro-batches (padding short rows with inert entries:
//! zero HT weights and zero advantage contribute exactly nothing to the
//! accumulated gradient). This is where RPC's forward savings materialise:
//! GRPO/URS items always land in the top bucket, RPC items spread across
//! buckets roughly uniformly.

use crate::tokenizer::PAD;

/// One response ready for the learner.
#[derive(Clone, Debug)]
pub struct LearnItem {
    /// Full [P + max_resp] token row from the rollout (left-padded prompt).
    pub tokens: Vec<i32>,
    /// Left-pad length of the prompt window.
    pub pad_len: usize,
    /// True response length t_i (1..=max_resp), before any cutting.
    pub resp_len: usize,
    /// HT weights over 0..resp_len (from the masker).
    pub ht_w: Vec<f32>,
    /// Forward prefix the learner needs.
    pub learn_len: usize,
    /// Group-relative advantage.
    pub adv: f32,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
}

/// A packed micro-batch for one grad-artifact bucket.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    pub bucket: usize,
    /// Number of real (non-padding) rows.
    pub real_rows: usize,
    pub tokens: Vec<i32>,   // [B, P + bucket]
    pub ht_w: Vec<f32>,     // [B, bucket]
    pub adv: Vec<f32>,      // [B]
    pub old_lp: Vec<f32>,   // [B, bucket]
    pub inv_len: Vec<f32>,  // [B] = 1 / t_i (FULL response length)
    pub pad_len: Vec<i32>,  // [B]
}

/// Route items to buckets and pack micro-batches of `batch` rows.
pub fn pack(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    batch: usize,
) -> Vec<MicroBatch> {
    let mut by_bucket: Vec<Vec<&LearnItem>> = vec![Vec::new(); buckets.len()];
    for item in items {
        debug_assert!(item.learn_len >= 1 && item.learn_len <= item.resp_len);
        debug_assert_eq!(item.ht_w.len(), item.resp_len);
        let bi = buckets
            .iter()
            .position(|&b| b >= item.learn_len)
            .unwrap_or(buckets.len() - 1);
        by_bucket[bi].push(item);
    }
    let mut out = Vec::new();
    for (bi, group) in by_bucket.iter().enumerate() {
        let bucket = buckets[bi];
        for chunk in group.chunks(batch) {
            out.push(pack_one(chunk, bucket, prompt_len, batch));
        }
    }
    out
}

fn pack_one(rows: &[&LearnItem], bucket: usize, prompt_len: usize, batch: usize) -> MicroBatch {
    let s = prompt_len + bucket;
    let mut mb = MicroBatch {
        bucket,
        real_rows: rows.len(),
        tokens: vec![PAD; batch * s],
        ht_w: vec![0.0; batch * bucket],
        adv: vec![0.0; batch],
        old_lp: vec![0.0; batch * bucket],
        inv_len: vec![0.0; batch],
        pad_len: vec![prompt_len as i32; batch],
    };
    for (r, item) in rows.iter().enumerate() {
        // token prefix: prompt window + first `bucket` response tokens
        mb.tokens[r * s..(r + 1) * s].copy_from_slice(&item.tokens[..s]);
        let take = item.learn_len.min(bucket);
        for t in 0..take {
            mb.ht_w[r * bucket + t] = item.ht_w[t];
            mb.old_lp[r * bucket + t] = item.old_lp[t];
        }
        mb.adv[r] = item.adv;
        mb.inv_len[r] = 1.0 / item.resp_len as f32;
        mb.pad_len[r] = item.pad_len as i32;
    }
    mb
}

/// Micro-batch (batch, seq) shapes for the analytic memory model.
pub fn micro_shapes(mbs: &[MicroBatch], prompt_len: usize) -> Vec<(usize, usize)> {
    mbs.iter().map(|m| (m.adv.len(), prompt_len + m.bucket)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: usize = 8;
    const BUCKETS: [usize; 3] = [4, 8, 16];

    fn item(resp_len: usize, learn_len: usize, adv: f32) -> LearnItem {
        LearnItem {
            tokens: (0..(P + 16) as i32).collect(),
            pad_len: 2,
            resp_len,
            ht_w: (0..resp_len).map(|t| if t < learn_len { 1.5 } else { 0.0 }).collect(),
            learn_len,
            adv,
            old_lp: (0..resp_len).map(|t| -(t as f32)).collect(),
        }
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let items = vec![item(16, 3, 1.0), item(16, 4, 1.0), item(16, 5, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4);
        let buckets: Vec<usize> = mbs.iter().map(|m| m.bucket).collect();
        assert!(buckets.contains(&4));
        assert!(buckets.contains(&8));
        assert!(buckets.contains(&16));
        let total_rows: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(total_rows, 4);
    }

    #[test]
    fn splits_into_fixed_micro_batches() {
        let items: Vec<LearnItem> = (0..10).map(|_| item(16, 16, 0.5)).collect();
        let mbs = pack(&items, &BUCKETS, P, 4);
        assert_eq!(mbs.len(), 3); // 4 + 4 + 2
        assert_eq!(mbs[2].real_rows, 2);
        for m in &mbs {
            assert_eq!(m.adv.len(), 4); // padded to full batch
            assert_eq!(m.tokens.len(), 4 * (P + m.bucket));
        }
    }

    #[test]
    fn padding_rows_are_inert() {
        let items = vec![item(16, 16, 2.0)];
        let mbs = pack(&items, &BUCKETS, P, 4);
        let m = &mbs[0];
        for r in 1..4 {
            assert_eq!(m.adv[r], 0.0);
            assert_eq!(m.inv_len[r], 0.0);
            assert!(m.ht_w[r * m.bucket..(r + 1) * m.bucket].iter().all(|&w| w == 0.0));
        }
    }

    #[test]
    fn weights_beyond_learn_len_are_zero_and_truncated_to_bucket() {
        let items = vec![item(16, 6, 1.0)]; // routes to bucket 8
        let mbs = pack(&items, &BUCKETS, P, 1);
        let m = &mbs[0];
        assert_eq!(m.bucket, 8);
        assert!(m.ht_w[..6].iter().all(|&w| w == 1.5));
        assert!(m.ht_w[6..8].iter().all(|&w| w == 0.0));
        // inv_len reflects the FULL response length, not the cut
        assert!((m.inv_len[0] - 1.0 / 16.0).abs() < 1e-7);
    }

    #[test]
    fn token_rows_are_sliced_to_bucket_window() {
        let items = vec![item(16, 3, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 1);
        let m = &mbs[0];
        assert_eq!(m.bucket, 4);
        assert_eq!(m.tokens.len(), P + 4);
        assert_eq!(m.tokens[..P + 4], (0..(P + 4) as i32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn learn_len_over_top_bucket_clamps() {
        let items = vec![item(16, 16, 1.0)];
        let mbs = pack(&items, &[4, 8], P, 1); // top bucket smaller than learn_len
        assert_eq!(mbs[0].bucket, 8);
        assert!(mbs[0].ht_w.iter().take(8).all(|&w| w > 0.0));
    }

    #[test]
    fn micro_shapes_for_memory_model() {
        let items = vec![item(16, 3, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4);
        let shapes = micro_shapes(&mbs, P);
        assert!(shapes.contains(&(4, P + 4)));
        assert!(shapes.contains(&(4, P + 16)));
    }
}
