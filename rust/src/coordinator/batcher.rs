//! Micro-batching: turning NAT `learn_len` prefixes into real workloads.
//!
//! Each learner item carries a `learn_len` from the NAT masker; the batcher
//! routes it to a compiled grad-artifact shape and packs micro-batches
//! (padding short rows with inert entries: zero HT weights and zero
//! advantage contribute exactly nothing to the accumulated gradient). This
//! is where RPC's forward savings materialise: GRPO items always need the
//! top bucket, RPC items spread across buckets roughly uniformly.
//!
//! Two packers share the [`MicroBatch`] layout:
//!
//! * [`pack`] — the legacy **fixed** packer: every micro-batch allocates
//!   exactly `batch_train` rows in the smallest sequence bucket that fits
//!   its items. Kept selectable (`--train.packer fixed`) for parity
//!   testing: bit-identical to the pre-budget-packer trainer for the
//!   prefix methods (GRPO/DetTrunc/RPC; URS/Saliency route into smaller
//!   buckets since the `learn_len = last kept + 1` fix, so only their
//!   estimator — not the float schedule — is unchanged).
//! * [`pack_budget`] — the cost-based **token-budget** packer: items are
//!   sorted by `learn_len` and partitioned into a 2-D artifact grid of
//!   (sequence bucket × row-count bucket), minimising padded-token waste
//!   under `rows × (P + bucket) <= token_budget`. Row counts are drawn from
//!   the manifest's compiled row grid (e.g. {1, 2, 4, ..., batch_train}),
//!   so a 3-item tail decomposes into exact 2+1 rows instead of a full
//!   `batch_train`; on a coarse (legacy) grid the partition instead merges
//!   stragglers into the next bucket's batch when that wastes less. The
//!   model counts tokens only — per-micro-batch launch overhead is noise
//!   next to a fwd+bwd in this stack, and artifact shapes come from a
//!   small fixed grid so the compile cache stays warm.
//!
//! Both packers reject items whose `learn_len` exceeds the top sequence
//! bucket: silently zero-weighting the overflow (the old behaviour) drops
//! selected tokens with no HT reweighting, biasing the gradient exactly
//! like deterministic truncation.

use anyhow::{bail, Result};

use crate::coordinator::rollout::RolloutSeq;
use crate::coordinator::selection::SelectionPlan;
use crate::tokenizer::PAD;

/// One response ready for the learner.
#[derive(Clone, Debug)]
pub struct LearnItem {
    /// Full [P + max_resp] token row from the rollout (left-padded prompt).
    pub tokens: Vec<i32>,
    /// Left-pad length of the prompt window.
    pub pad_len: usize,
    /// True response length t_i (1..=max_resp), before any cutting.
    pub resp_len: usize,
    /// HT weights over 0..resp_len (from the masker).
    pub ht_w: Vec<f32>,
    /// Forward prefix the learner needs.
    pub learn_len: usize,
    /// Group-relative advantage.
    pub adv: f32,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
}

impl LearnItem {
    /// Build a learner item from a rollout row and its drawn
    /// [`SelectionPlan`] — this is the seam between the selection subsystem
    /// and the batcher: packing routes on `SelectionPlan::learn_len`, and
    /// the plan's HT weights are the only selection state the learner
    /// tensors carry.
    pub fn from_plan(seq: &RolloutSeq, plan: SelectionPlan, adv: f32) -> LearnItem {
        debug_assert_eq!(plan.ht_w.len(), seq.resp_len);
        LearnItem {
            tokens: seq.tokens.clone(),
            pad_len: seq.pad_len,
            resp_len: seq.resp_len,
            ht_w: plan.ht_w,
            learn_len: plan.learn_len,
            adv,
            old_lp: seq.old_lp.clone(),
        }
    }

    /// True if the row contributes nothing to the accumulated gradient:
    /// no kept token (all-Bernoulli-miss URS/Saliency draws) or zero
    /// advantage (zero-variance reward groups). Such rows still burn a
    /// full forward/backward if packed.
    pub fn is_zero_contribution(&self) -> bool {
        self.adv == 0.0 || self.ht_w.iter().all(|&w| w == 0.0)
    }
}

/// The full-token-GRPO counterfactual of a rollout group: every response at
/// `learn_len = resp_len`, unit HT weights, unit advantage. The savings
/// ledger (`obs::ledger`) packs these through the *same* packer config as
/// the real step to price what the step would have cost without selection —
/// advantages and weights are irrelevant to that cost, only the shape
/// routing matters. Zero-length responses are skipped, mirroring the learn
/// loop's `empty_rows` guard.
pub fn full_length_items(seqs: &[RolloutSeq]) -> Vec<LearnItem> {
    seqs.iter()
        .filter(|s| s.resp_len > 0)
        .map(|s| LearnItem {
            tokens: s.tokens.clone(),
            pad_len: s.pad_len,
            resp_len: s.resp_len,
            ht_w: vec![1.0; s.resp_len],
            learn_len: s.resp_len,
            adv: 1.0,
            old_lp: s.old_lp.clone(),
        })
        .collect()
}

/// A packed micro-batch for one (sequence bucket, row bucket) grad artifact.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Sequence bucket: response window length of the grad artifact.
    pub bucket: usize,
    /// Allocated rows (the artifact's batch dimension). Always `batch_train`
    /// under the fixed packer; a row-grid bucket under the budget packer.
    pub rows: usize,
    /// Number of real (non-padding) rows.
    pub real_rows: usize,
    pub tokens: Vec<i32>,   // [rows, P + bucket]
    pub ht_w: Vec<f32>,     // [rows, bucket]
    pub adv: Vec<f32>,      // [rows]
    pub old_lp: Vec<f32>,   // [rows, bucket]
    pub inv_len: Vec<f32>,  // [rows] = 1 / t_i (FULL response length)
    pub pad_len: Vec<i32>,  // [rows]
}

/// Smallest bucket >= learn_len; hard error past the top bucket (silent
/// clamping would zero-weight selected tokens with no HT reweighting —
/// DetTrunc-style bias smuggled in by the batcher).
fn bucket_for(buckets: &[usize], learn_len: usize) -> Result<usize> {
    match buckets.iter().copied().find(|&b| b >= learn_len) {
        Some(b) => Ok(b),
        None => bail!(
            "learn_len {learn_len} exceeds top bucket {} — packing it would \
             silently truncate selected tokens and bias the gradient",
            buckets.last().copied().unwrap_or(0)
        ),
    }
}

fn validate(items: &[LearnItem], buckets: &[usize]) -> Result<()> {
    if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
        bail!("buckets must be non-empty ascending: {buckets:?}");
    }
    for item in items {
        debug_assert!(item.learn_len >= 1 && item.learn_len <= item.resp_len);
        debug_assert_eq!(item.ht_w.len(), item.resp_len);
        bucket_for(buckets, item.learn_len)?;
    }
    Ok(())
}

/// Fixed packer: route items to sequence buckets and pack micro-batches of
/// exactly `batch` allocated rows (the pre-budget-packer layout, bit-for-bit).
pub fn pack(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    batch: usize,
) -> Result<Vec<MicroBatch>> {
    validate(items, buckets)?;
    let mut by_bucket: Vec<Vec<&LearnItem>> = vec![Vec::new(); buckets.len()];
    for item in items {
        let bi = buckets.iter().position(|&b| b >= item.learn_len).expect("validated");
        by_bucket[bi].push(item);
    }
    let mut out = Vec::new();
    for (bi, group) in by_bucket.iter().enumerate() {
        let bucket = buckets[bi];
        for chunk in group.chunks(batch) {
            out.push(pack_one(chunk, bucket, prompt_len, batch));
        }
    }
    Ok(out)
}

/// Smallest row-grid entry >= `n`. The grid is the set of batch dimensions
/// compiled grad artifacts exist for (ascending, max = batch_train).
pub fn alloc_rows(row_grid: &[usize], n: usize) -> usize {
    row_grid
        .iter()
        .copied()
        .find(|&r| r >= n)
        .unwrap_or_else(|| row_grid.last().copied().unwrap_or(n))
}

/// Token-budget packer: sort by `learn_len`, then fill micro-batches in the
/// (sequence bucket × row bucket) grid so that total allocated tokens are
/// minimal subject to `rows × (P + bucket) <= token_budget` per micro-batch.
///
/// Because items are sorted, every micro-batch is a contiguous run of the
/// sorted list and its sequence bucket is decided by its longest (= last)
/// item, so the minimal-waste grouping is an exact O(n × batch_train)
/// partition DP rather than a heuristic: the cost of a run is
/// `alloc_rows(len) × (P + bucket(last))`, and the DP decides where runs
/// split — automatically merging a short-bucket straggler into the next
/// bucket's batch when that allocates fewer tokens than an under-filled
/// micro-batch of its own.
///
/// `token_budget == 0` means "no extra limit": the budget defaults to the
/// fixed packer's per-batch allocation, `batch_train × (P + top bucket)`.
pub fn pack_budget(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    row_grid: &[usize],
    token_budget: usize,
) -> Result<Vec<MicroBatch>> {
    validate(items, buckets)?;
    if row_grid.is_empty() || row_grid.windows(2).any(|w| w[0] >= w[1]) {
        bail!("row grid must be non-empty ascending: {row_grid:?}");
    }
    let max_rows = *row_grid.last().unwrap();
    let top = *buckets.last().unwrap();
    let budget = if token_budget == 0 { max_rows * (prompt_len + top) } else { token_budget };
    let cost = |n: usize, bucket: usize| alloc_rows(row_grid, n) * (prompt_len + bucket);
    for item in items {
        let b = bucket_for(buckets, item.learn_len)?;
        if cost(1, b) > budget {
            bail!(
                "train.token_budget {budget} is below one row of bucket {b} \
                 ({} tokens); raise the budget or use --train.packer fixed",
                cost(1, b)
            );
        }
    }

    // Sort by learn_len (stable: ties keep arrival order) so every group of
    // consecutive items shares the smallest viable bucket of its last item.
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| items[i].learn_len);

    // dp[i] = minimal allocated tokens packing the first i sorted items;
    // cut[i] = start of the last micro-batch in that optimum. Ties prefer
    // the longest run (fewest micro-batches).
    let n = order.len();
    let mut dp = vec![usize::MAX; n + 1];
    let mut cut = vec![0usize; n + 1];
    dp[0] = 0;
    for i in 1..=n {
        let b_i = bucket_for(buckets, items[order[i - 1]].learn_len)?;
        for j in i.saturating_sub(max_rows)..i {
            let c = cost(i - j, b_i);
            if c > budget || dp[j] == usize::MAX {
                continue;
            }
            if dp[j] + c < dp[i] {
                dp[i] = dp[j] + c;
                cut[i] = j;
            }
        }
        debug_assert_ne!(dp[i], usize::MAX, "single rows were pre-validated against the budget");
    }

    let mut bounds = Vec::new();
    let mut i = n;
    while i > 0 {
        bounds.push((cut[i], i));
        i = cut[i];
    }
    bounds.reverse();
    let mut out = Vec::new();
    for (lo, hi) in bounds {
        let group: Vec<&LearnItem> = order[lo..hi].iter().map(|&k| &items[k]).collect();
        let bucket = bucket_for(buckets, items[order[hi - 1]].learn_len)?;
        let rows = alloc_rows(row_grid, group.len());
        out.push(pack_one(&group, bucket, prompt_len, rows));
    }
    Ok(out)
}

fn pack_one(rows: &[&LearnItem], bucket: usize, prompt_len: usize, alloc: usize) -> MicroBatch {
    debug_assert!(rows.len() <= alloc);
    let s = prompt_len + bucket;
    let mut mb = MicroBatch {
        bucket,
        rows: alloc,
        real_rows: rows.len(),
        tokens: vec![PAD; alloc * s],
        ht_w: vec![0.0; alloc * bucket],
        adv: vec![0.0; alloc],
        old_lp: vec![0.0; alloc * bucket],
        inv_len: vec![0.0; alloc],
        pad_len: vec![prompt_len as i32; alloc],
    };
    for (r, item) in rows.iter().enumerate() {
        // token prefix: prompt window + first `bucket` response tokens
        mb.tokens[r * s..(r + 1) * s].copy_from_slice(&item.tokens[..s]);
        for t in 0..item.learn_len {
            mb.ht_w[r * bucket + t] = item.ht_w[t];
            mb.old_lp[r * bucket + t] = item.old_lp[t];
        }
        mb.adv[r] = item.adv;
        mb.inv_len[r] = 1.0 / item.resp_len as f32;
        mb.pad_len[r] = item.pad_len as i32;
    }
    mb
}

/// The per-micro-batch token cap the budget packer should run with. Under
/// `--train.budget_mode batch` the `token_budget` flag is repurposed as the
/// selection controller's expected-selected-token target, NOT a packing
/// cap — the packer then falls back to its auto budget (0); under
/// `budget_mode none` the flag means what it always did.
pub fn packer_token_budget(train: &crate::config::TrainCfg) -> usize {
    if train.budget_mode == crate::config::BudgetMode::Batch {
        0
    } else {
        train.token_budget
    }
}

/// Allocated token cost of one micro-batch: what the device pays for it
/// regardless of padding rows (`rows × (P + bucket)`).
pub fn micro_batch_cost(mb: &MicroBatch, prompt_len: usize) -> usize {
    mb.rows * (prompt_len + mb.bucket)
}

/// Shard-aware assignment of packed micro-batches to `shards` data-parallel
/// learner workers, balancing **allocated token cost** — not micro-batch or
/// row counts, which would let one shard hoard the long-bucket batches and
/// cap the step on the slowest worker (LPT greedy: heaviest first onto the
/// least-loaded shard, every tie broken by index).
///
/// The plan is a pure function of the micro-batch list: it never looks at
/// timing, thread ids, or the shard count's interaction with completion
/// order. Combined with the id-keyed tree reduction (`runtime::shard`),
/// that is what makes `shards = K` bit-identical to `shards = 1`.
///
/// Returns `min(shards, #micro-batches)` non-empty shards (padded with
/// empty ones up to `shards` so callers can index by worker), each listing
/// its micro-batch ids in ascending order.
pub fn plan_shards(mbs: &[MicroBatch], prompt_len: usize, shards: usize) -> Vec<Vec<usize>> {
    let k = shards.max(1);
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut load = vec![0usize; k];
    let mut order: Vec<usize> = (0..mbs.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(micro_batch_cost(&mbs[i], prompt_len)), i));
    for &i in &order {
        let j = (0..k).min_by_key(|&j| (load[j], j)).expect("k >= 1");
        load[j] += micro_batch_cost(&mbs[i], prompt_len);
        plan[j].push(i);
    }
    for ids in &mut plan {
        ids.sort_unstable();
    }
    plan
}

/// The default sharded-learner workload: 32 RPC-shaped responses over the
/// sim runtime's bucket grid. ONE definition shared by
/// `benches/bench_train_step.rs` (which measures wall-clock and writes
/// `BENCH_train_step.json`) and the tier-1 cost-balance gate in
/// `tests/sharding.rs`, so the perf record and the CI assertion always
/// describe the same workload — the `sim_workload` pattern from the
/// rollout scheduler, learner-side.
pub mod shard_workload {
    use super::{pack_budget, LearnItem, MicroBatch};
    use crate::util::rng::Rng;

    pub const SEED: u64 = 0x5EED;
    pub const ITEMS: usize = 32;
    pub const PROMPT_LEN: usize = 32;
    pub const MAX_RESP: usize = 16;
    pub const BUCKETS: [usize; 3] = [4, 8, 16];
    pub const ROW_GRID: [usize; 3] = [1, 2, 4];

    /// 32 responses with RPC-shaped `learn_len` spread (at this seed the
    /// budget packer yields 10 micro-batches across all three buckets).
    pub fn items() -> Vec<LearnItem> {
        let mut rng = Rng::new(SEED);
        (0..ITEMS)
            .map(|_| {
                let t = 1 + rng.below(MAX_RESP as u64) as usize;
                let ll = 1 + rng.below(t as u64) as usize;
                LearnItem {
                    tokens: (0..(PROMPT_LEN + MAX_RESP) as i32).map(|x| 3 + x % 50).collect(),
                    pad_len: 4,
                    resp_len: t,
                    ht_w: (0..t).map(|i| if i < ll { 1.25 } else { 0.0 }).collect(),
                    learn_len: ll,
                    adv: 0.75,
                    old_lp: (0..t).map(|i| -0.1 - 0.05 * (i % 7) as f32).collect(),
                }
            })
            .collect()
    }

    /// The workload packed by the token-budget packer (auto budget).
    pub fn micro_batches() -> Vec<MicroBatch> {
        pack_budget(&items(), &BUCKETS, PROMPT_LEN, &ROW_GRID, 0)
            .expect("shard workload packs within the top bucket")
    }
}

/// Split items into (contributing, dropped-count): rows with no kept token
/// or zero advantage contribute exactly nothing to the accumulated gradient
/// but burn a full forward/backward if packed. The caller must keep the
/// dropped count in the apply scale (`GradAccum::sequences`) so the applied
/// gradient is bit-for-bit what packing the inert rows would have produced.
pub fn split_zero_contribution(items: Vec<LearnItem>) -> (Vec<LearnItem>, usize) {
    let n = items.len();
    let kept: Vec<LearnItem> = items.into_iter().filter(|i| !i.is_zero_contribution()).collect();
    let dropped = n - kept.len();
    (kept, dropped)
}

/// Micro-batch (rows, seq) shapes for the analytic memory model.
pub fn micro_shapes(mbs: &[MicroBatch], prompt_len: usize) -> Vec<(usize, usize)> {
    mbs.iter().map(|m| (m.rows, prompt_len + m.bucket)).collect()
}

/// Learner tokens actually allocated by a packed step: Σ rows × (P + bucket).
pub fn allocated_tokens(mbs: &[MicroBatch], prompt_len: usize) -> usize {
    mbs.iter().map(|m| m.rows * (prompt_len + m.bucket)).sum()
}

/// Zero-padding lower bound for an item list: Σ (P + learn_len).
pub fn ideal_tokens(items: &[LearnItem], prompt_len: usize) -> usize {
    items.iter().map(|i| prompt_len + i.learn_len).sum()
}

/// Fraction of allocated learner tokens that are padding (the
/// `padding_waste` metric series).
pub fn padding_waste(mbs: &[MicroBatch], items: &[LearnItem], prompt_len: usize) -> f64 {
    let alloc = allocated_tokens(mbs, prompt_len);
    if alloc == 0 {
        return 0.0;
    }
    1.0 - ideal_tokens(items, prompt_len) as f64 / alloc as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::masking::sample;
    use crate::util::rng::Rng;

    const P: usize = 8;
    const BUCKETS: [usize; 3] = [4, 8, 16];
    const GRID: [usize; 3] = [1, 2, 4];

    fn item(resp_len: usize, learn_len: usize, adv: f32) -> LearnItem {
        LearnItem {
            tokens: (0..(P + 16) as i32).collect(),
            pad_len: 2,
            resp_len,
            ht_w: (0..resp_len).map(|t| if t < learn_len { 1.5 } else { 0.0 }).collect(),
            learn_len,
            adv,
            old_lp: (0..resp_len).map(|t| -(t as f32)).collect(),
        }
    }

    #[test]
    fn full_length_items_build_the_grpo_counterfactual() {
        let seqs = crate::coordinator::selection::bench_workload::seqs(P, 16);
        let items = full_length_items(&seqs);
        assert_eq!(items.len(), seqs.len()); // workload has no empty responses
        for (it, s) in items.iter().zip(&seqs) {
            assert_eq!(it.learn_len, s.resp_len);
            assert_eq!(it.ht_w.len(), s.resp_len);
            assert!(it.ht_w.iter().all(|&w| w == 1.0));
            assert_eq!(it.adv, 1.0);
            assert!(!it.is_zero_contribution());
        }
        // counterfactual cost dominates any selected-prefix packing
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(allocated_tokens(&mbs, P) >= ideal_tokens(&items, P));
        // a zero-length response is skipped, matching the learn loop
        let mut with_empty = seqs;
        with_empty[0].resp_len = 0;
        with_empty[0].old_lp.clear();
        assert_eq!(full_length_items(&with_empty).len(), items.len() - 1);
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let items = vec![item(16, 3, 1.0), item(16, 4, 1.0), item(16, 5, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let buckets: Vec<usize> = mbs.iter().map(|m| m.bucket).collect();
        assert!(buckets.contains(&4));
        assert!(buckets.contains(&8));
        assert!(buckets.contains(&16));
        let total_rows: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(total_rows, 4);
    }

    #[test]
    fn splits_into_fixed_micro_batches() {
        let items: Vec<LearnItem> = (0..10).map(|_| item(16, 16, 0.5)).collect();
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        assert_eq!(mbs.len(), 3); // 4 + 4 + 2
        assert_eq!(mbs[2].real_rows, 2);
        for m in &mbs {
            assert_eq!(m.rows, 4); // fixed packer: padded to full batch
            assert_eq!(m.adv.len(), 4);
            assert_eq!(m.tokens.len(), 4 * (P + m.bucket));
        }
    }

    #[test]
    fn padding_rows_are_inert() {
        let items = vec![item(16, 16, 2.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let m = &mbs[0];
        for r in 1..4 {
            assert_eq!(m.adv[r], 0.0);
            assert_eq!(m.inv_len[r], 0.0);
            assert!(m.ht_w[r * m.bucket..(r + 1) * m.bucket].iter().all(|&w| w == 0.0));
        }
    }

    #[test]
    fn weights_beyond_learn_len_are_zero() {
        let items = vec![item(16, 6, 1.0)]; // routes to bucket 8
        let mbs = pack(&items, &BUCKETS, P, 1).unwrap();
        let m = &mbs[0];
        assert_eq!(m.bucket, 8);
        assert!(m.ht_w[..6].iter().all(|&w| w == 1.5));
        assert!(m.ht_w[6..8].iter().all(|&w| w == 0.0));
        // inv_len reflects the FULL response length, not the cut
        assert!((m.inv_len[0] - 1.0 / 16.0).abs() < 1e-7);
    }

    #[test]
    fn token_rows_are_sliced_to_bucket_window() {
        let items = vec![item(16, 3, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 1).unwrap();
        let m = &mbs[0];
        assert_eq!(m.bucket, 4);
        assert_eq!(m.tokens.len(), P + 4);
        assert_eq!(m.tokens[..P + 4], (0..(P + 4) as i32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn learn_len_over_top_bucket_is_rejected() {
        // Clamping (the old behaviour) would zero-weight tokens 8..16 with
        // no HT reweighting — DetTrunc-style bias. Both packers refuse.
        let items = vec![item(16, 16, 1.0)];
        let err = pack(&items, &[4, 8], P, 1).unwrap_err();
        assert!(err.to_string().contains("exceeds top bucket"), "{err}");
        let err = pack_budget(&items, &[4, 8], P, &GRID, 0).unwrap_err();
        assert!(err.to_string().contains("exceeds top bucket"), "{err}");
    }

    #[test]
    fn micro_shapes_for_memory_model() {
        let items = vec![item(16, 3, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let shapes = micro_shapes(&mbs, P);
        assert!(shapes.contains(&(4, P + 4)));
        assert!(shapes.contains(&(4, P + 16)));
    }

    #[test]
    fn alloc_rows_rounds_up_in_grid() {
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 1), 1);
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 3), 4);
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 8), 8);
        // legacy manifests compile only the full batch dimension
        assert_eq!(alloc_rows(&[8], 2), 8);
    }

    #[test]
    fn budget_rows_follow_the_row_grid() {
        // 3 short items: the fixed packer burns 4 allocated rows in one
        // micro-batch; the budget packer decomposes 3 = 2 + 1 exactly in
        // the power-of-two grid — zero row padding.
        let items = vec![item(16, 2, 1.0), item(16, 3, 1.0), item(16, 3, 1.0)];
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        let alloc: usize = mbs.iter().map(|m| m.rows).sum();
        let real: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(real, 3);
        assert_eq!(alloc, 3, "{mbs:?}");
        assert!(mbs.iter().all(|m| m.bucket == 4 && GRID.contains(&m.rows)));
        assert_eq!(allocated_tokens(&mbs, P), 3 * (P + 4));
        let fixed = pack(&items, &BUCKETS, P, 4).unwrap();
        assert_eq!(allocated_tokens(&fixed, P), 4 * (P + 4));
        let one = pack_budget(&items[..1], &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(one[0].rows, 1);
    }

    #[test]
    fn budget_limit_splits_micro_batches() {
        let items: Vec<LearnItem> = (0..4).map(|_| item(16, 4, 1.0)).collect();
        // 2 rows × (8 + 4) = 24 tokens fits; 4 rows = 48 does not.
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 24).unwrap();
        assert_eq!(mbs.len(), 2);
        for m in &mbs {
            assert_eq!(m.rows, 2);
            assert!(m.rows * (P + m.bucket) <= 24);
        }
        // A budget below one minimal row is a config error.
        let err = pack_budget(&items, &BUCKETS, P, &GRID, 8).unwrap_err();
        assert!(err.to_string().contains("token_budget"), "{err}");
    }

    #[test]
    fn budget_merges_small_buckets_when_cheaper() {
        // Coarse row grid (a legacy manifest compiles only rows=4): the
        // straggler at learn_len 4 would need its own 4-row batch (4×12=48)
        // next to the bucket-8 batch (4×16=64); merging everything into one
        // bucket-8 batch costs 64 total → the DP merges.
        let items =
            vec![item(16, 4, 1.0), item(16, 8, 1.0), item(16, 8, 1.0), item(16, 8, 1.0)];
        let coarse = [4usize];
        let mbs = pack_budget(&items, &BUCKETS, P, &coarse, 0).unwrap();
        assert_eq!(mbs.len(), 1);
        assert_eq!(mbs[0].bucket, 8);
        assert_eq!(mbs[0].rows, 4);
        assert_eq!(mbs[0].real_rows, 4);
        // With a fine grid, exact row sums beat cross-bucket merging: the
        // straggler gets its own 1-row bucket-4 batch instead.
        let fine = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(fine.iter().any(|m| m.bucket == 4 && m.rows == 1));
        assert!(allocated_tokens(&fine, P) < allocated_tokens(&mbs, P));
    }

    #[test]
    fn budget_splits_buckets_when_upgrade_is_wasteful() {
        // 2 items at learn_len 4 + 1 at learn_len 16: one merged batch at
        // bucket 16 costs alloc(3)=4 rows × (8+16) = 96; splitting costs
        // 2×12 + 1×24 = 48 → the DP splits.
        let items = vec![item(16, 4, 1.0), item(16, 4, 1.0), item(16, 16, 1.0)];
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(mbs.len(), 2);
        assert_eq!(mbs[0].bucket, 4);
        assert_eq!(mbs[0].real_rows, 2);
        assert_eq!(mbs[1].bucket, 16);
        assert_eq!(mbs[1].rows, 1);
    }

    #[test]
    fn budget_conserves_rows_and_weights() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = 1 + rng.below(24) as usize;
            let items: Vec<LearnItem> = (0..n)
                .map(|_| {
                    let t = 1 + rng.below(16) as usize;
                    let ll = 1 + rng.below(t as u64) as usize;
                    item(t, ll, rng.normal() as f32)
                })
                .collect();
            let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
            let total: usize = mbs.iter().map(|m| m.real_rows).sum();
            assert_eq!(total, n);
            let w = |mbs: &[MicroBatch]| -> f64 {
                mbs.iter().flat_map(|m| m.ht_w.iter()).map(|&x| x as f64).sum()
            };
            let fixed = pack(&items, &BUCKETS, P, 4).unwrap();
            assert!((w(&mbs) - w(&fixed)).abs() < 1e-9);
            for m in &mbs {
                assert!(GRID.contains(&m.rows));
                assert!(m.real_rows <= m.rows);
            }
        }
    }

    #[test]
    fn budget_packer_cuts_rpc_padded_waste_by_30pct() {
        // Acceptance: ≥ 30% lower padded-token waste for RPC (min_cut
        // default 8) at equal batch config. Realistic per-step scale:
        // prompts_per_step × G = 16 items, buckets [32,64,96,128], B=8.
        let (p, buckets, grid) = (48usize, [32usize, 64, 96, 128], [1usize, 2, 4, 8]);
        let mut rng = Rng::new(7);
        let mut waste_fixed = 0.0;
        let mut waste_budget = 0.0;
        for _ in 0..50 {
            let items: Vec<LearnItem> = (0..16)
                .map(|_| {
                    let t = 1 + rng.below(128) as usize;
                    let m = sample(&Method::Rpc { min_cut: 8 }, t, &mut rng);
                    LearnItem {
                        tokens: vec![7; p + 128],
                        pad_len: 5,
                        resp_len: t,
                        ht_w: m.ht_w,
                        learn_len: m.learn_len,
                        adv: 1.0,
                        old_lp: vec![-1.0; t],
                    }
                })
                .collect();
            let fixed = pack(&items, &buckets, p, 8).unwrap();
            let budget = pack_budget(&items, &buckets, p, &grid, 0).unwrap();
            waste_fixed += padding_waste(&fixed, &items, p);
            waste_budget += padding_waste(&budget, &items, p);
        }
        assert!(
            waste_budget < 0.7 * waste_fixed,
            "budget packer waste {waste_budget:.3} not ≥30% below fixed {waste_fixed:.3}"
        );
    }

    #[test]
    fn zero_contribution_split_preserves_population_accounting() {
        let items = vec![
            item(16, 4, 1.0),                // contributes
            item(16, 4, 0.0),                // zero advantage
            LearnItem { ht_w: vec![0.0; 16], ..item(16, 4, 1.0) }, // no kept token
            item(16, 8, -0.5),               // contributes
        ];
        let n = items.len();
        let (kept, dropped) = split_zero_contribution(items);
        assert_eq!(kept.len(), 2);
        assert_eq!(dropped, 2);
        assert_eq!(kept.len() + dropped, n);
        assert!(kept.iter().all(|i| !i.is_zero_contribution()));
    }

    #[test]
    fn plan_shards_partitions_all_ids_and_balances_token_cost() {
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let n = 1 + rng.below(20) as usize;
            let items: Vec<LearnItem> = (0..n)
                .map(|_| {
                    let t = 1 + rng.below(16) as usize;
                    let ll = 1 + rng.below(t as u64) as usize;
                    item(t, ll, 1.0)
                })
                .collect();
            let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
            for k in [1usize, 2, 3, 4, 7] {
                let plan = plan_shards(&mbs, P, k);
                assert_eq!(plan.len(), k);
                // exact partition of 0..mbs.len()
                let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..mbs.len()).collect::<Vec<_>>());
                // ids ascend within each shard (execution = id order)
                for ids in &plan {
                    assert!(ids.windows(2).all(|w| w[0] < w[1]));
                }
                // LPT guarantee: max load <= min load + max single cost
                let cost = |ids: &[usize]| -> usize {
                    ids.iter().map(|&i| micro_batch_cost(&mbs[i], P)).sum()
                };
                let loads: Vec<usize> = plan.iter().map(|ids| cost(ids)).collect();
                let biggest =
                    mbs.iter().map(|m| micro_batch_cost(m, P)).max().unwrap_or(0);
                let (lo, hi) =
                    (loads.iter().min().unwrap(), loads.iter().max().unwrap());
                assert!(hi - lo <= biggest, "k={k}: loads {loads:?}, biggest {biggest}");
            }
        }
    }

    #[test]
    fn plan_shards_is_deterministic() {
        let items: Vec<LearnItem> =
            (0..9).map(|i| item(16, 1 + (i * 5) % 16, 1.0)).collect();
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(plan_shards(&mbs, P, 3), plan_shards(&mbs, P, 3));
        // k beyond the micro-batch count leaves the tail shards empty
        let plan = plan_shards(&mbs, P, mbs.len() + 2);
        assert_eq!(plan.iter().filter(|ids| !ids.is_empty()).count(), mbs.len());
    }

    #[test]
    fn learn_item_from_plan_packs_off_the_plan_learn_len() {
        use crate::coordinator::rollout::RolloutSeq;
        use crate::coordinator::selection::{Selector, Urs};

        let seq = RolloutSeq {
            task_idx: 0,
            tokens: (0..(P + 16) as i32).collect(),
            pad_len: 2,
            resp_len: 12,
            old_lp: (0..12).map(|t| -(t as f32)).collect(),
            reward: 1.0,
        };
        let mut rng = Rng::new(5);
        let plan = Urs { p: 0.5 }.sample(seq.resp_len, None, &mut rng);
        let (ll, w) = (plan.learn_len, plan.ht_w.clone());
        let it = LearnItem::from_plan(&seq, plan, 0.7);
        assert_eq!(it.learn_len, ll);
        assert_eq!(it.ht_w, w);
        assert_eq!(it.resp_len, 12);
        assert_eq!(it.adv, 0.7);
        assert_eq!(it.old_lp, seq.old_lp);
        let mbs = pack_budget(&[it], &BUCKETS, P, &GRID, 0).unwrap();
        assert!(mbs[0].bucket >= ll);
    }

    #[test]
    fn packer_budget_is_auto_under_batch_budget_mode() {
        use crate::config::{BudgetMode, TrainCfg};
        let mut train = TrainCfg::default();
        train.token_budget = 512;
        assert_eq!(packer_token_budget(&train), 512);
        train.budget_mode = BudgetMode::Batch;
        assert_eq!(packer_token_budget(&train), 0);
    }

    #[test]
    fn waste_metric_is_zero_for_perfect_fit() {
        let items: Vec<LearnItem> = (0..4).map(|_| item(16, 16, 1.0)).collect();
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(padding_waste(&mbs, &items, P) < 1e-9);
        assert_eq!(allocated_tokens(&mbs, P), ideal_tokens(&items, P));
        assert_eq!(padding_waste(&[], &[], P), 0.0);
    }
}
