//! Micro-batching: turning NAT `learn_len` prefixes into real workloads.
//!
//! Each learner item carries a `learn_len` from the NAT masker; the batcher
//! routes it to a compiled grad-artifact shape and packs micro-batches
//! (padding short rows with inert entries: zero HT weights and zero
//! advantage contribute exactly nothing to the accumulated gradient). This
//! is where RPC's forward savings materialise: GRPO items always need the
//! top bucket, RPC items spread across buckets roughly uniformly.
//!
//! Two packers share the [`MicroBatch`] layout:
//!
//! * [`pack`] — the legacy **fixed** packer: every micro-batch allocates
//!   exactly `batch_train` rows in the smallest sequence bucket that fits
//!   its items. Kept selectable (`--train.packer fixed`) for parity
//!   testing: bit-identical to the pre-budget-packer trainer for the
//!   prefix methods (GRPO/DetTrunc/RPC; URS/Saliency route into smaller
//!   buckets since the `learn_len = last kept + 1` fix, so only their
//!   estimator — not the float schedule — is unchanged).
//! * [`pack_budget`] — the cost-based **token-budget** packer: items are
//!   sorted by `learn_len` and partitioned into a 2-D artifact grid of
//!   (sequence bucket × row-count bucket), minimising padded-token waste
//!   under `rows × (P + bucket) <= token_budget`. Row counts are drawn from
//!   the manifest's compiled row grid (e.g. {1, 2, 4, ..., batch_train}),
//!   so a 3-item tail decomposes into exact 2+1 rows instead of a full
//!   `batch_train`; on a coarse (legacy) grid the partition instead merges
//!   stragglers into the next bucket's batch when that wastes less. The
//!   model counts tokens only — per-micro-batch launch overhead is noise
//!   next to a fwd+bwd in this stack, and artifact shapes come from a
//!   small fixed grid so the compile cache stays warm.
//!
//! Both packers reject items whose `learn_len` exceeds the top sequence
//! bucket: silently zero-weighting the overflow (the old behaviour) drops
//! selected tokens with no HT reweighting, biasing the gradient exactly
//! like deterministic truncation.

use anyhow::{bail, Result};

use crate::coordinator::rollout::RolloutSeq;
use crate::coordinator::selection::SelectionPlan;
use crate::tokenizer::PAD;

/// One response ready for the learner.
#[derive(Clone, Debug)]
pub struct LearnItem {
    /// Full [P + max_resp] token row from the rollout (left-padded prompt).
    pub tokens: Vec<i32>,
    /// Left-pad length of the prompt window.
    pub pad_len: usize,
    /// True response length t_i (1..=max_resp), before any cutting.
    pub resp_len: usize,
    /// HT weights over 0..resp_len (from the masker).
    pub ht_w: Vec<f32>,
    /// Forward prefix the learner needs.
    pub learn_len: usize,
    /// Group-relative advantage.
    pub adv: f32,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
}

impl LearnItem {
    /// Build a learner item from a rollout row and its drawn
    /// [`SelectionPlan`] — this is the seam between the selection subsystem
    /// and the batcher: packing routes on `SelectionPlan::learn_len`, and
    /// the plan's HT weights are the only selection state the learner
    /// tensors carry.
    pub fn from_plan(seq: &RolloutSeq, plan: SelectionPlan, adv: f32) -> LearnItem {
        debug_assert_eq!(plan.ht_w.len(), seq.resp_len);
        LearnItem {
            tokens: seq.tokens.clone(),
            pad_len: seq.pad_len,
            resp_len: seq.resp_len,
            ht_w: plan.ht_w,
            learn_len: plan.learn_len,
            adv,
            old_lp: seq.old_lp.clone(),
        }
    }

    /// True if the row contributes nothing to the accumulated gradient:
    /// no kept token (all-Bernoulli-miss URS/Saliency draws) or zero
    /// advantage (zero-variance reward groups). Such rows still burn a
    /// full forward/backward if packed.
    pub fn is_zero_contribution(&self) -> bool {
        self.adv == 0.0 || self.ht_w.iter().all(|&w| w == 0.0)
    }

    /// Number of kept (non-zero-weight) response tokens — the quantity the
    /// compacted grad grid buckets on instead of `learn_len`.
    pub fn kept(&self) -> usize {
        self.ht_w.iter().filter(|&&w| w != 0.0).count()
    }

    /// Original response positions of the kept tokens, ascending — the dense
    /// gather index list the compacted layout packs by (and that gradients
    /// scatter back through).
    pub fn kept_indices(&self) -> Vec<usize> {
        (0..self.resp_len).filter(|&t| self.ht_w[t] != 0.0).collect()
    }

    /// True when the kept set is a contiguous prefix `0..kept()` of the
    /// response (GRPO/DetTrunc/RPC plans). Prefix-shaped items gain nothing
    /// from gather compaction — the prefix layout already pays exactly
    /// `learn_len` — so the packer keeps routing them to the legacy
    /// `grad_T<b>` grid.
    pub fn is_prefix_shaped(&self) -> bool {
        let k = self.kept();
        self.ht_w[..k].iter().all(|&w| w != 0.0)
    }
}

/// The full-token-GRPO counterfactual of a rollout group: every response at
/// `learn_len = resp_len`, unit HT weights, unit advantage. The savings
/// ledger (`obs::ledger`) packs these through the *same* packer config as
/// the real step to price what the step would have cost without selection —
/// advantages and weights are irrelevant to that cost, only the shape
/// routing matters. Zero-length responses are skipped, mirroring the learn
/// loop's `empty_rows` guard.
pub fn full_length_items(seqs: &[RolloutSeq]) -> Vec<LearnItem> {
    seqs.iter()
        .filter(|s| s.resp_len > 0)
        .map(|s| LearnItem {
            tokens: s.tokens.clone(),
            pad_len: s.pad_len,
            resp_len: s.resp_len,
            ht_w: vec![1.0; s.resp_len],
            learn_len: s.resp_len,
            adv: 1.0,
            old_lp: s.old_lp.clone(),
        })
        .collect()
}

/// A packed micro-batch for one (sequence bucket, row bucket) grad artifact.
///
/// Two layouts share this struct, discriminated by `gather`:
///
/// * `gather == None` — the legacy **prefix** layout: response slot `t`
///   holds the response token at position `t`, and `bucket` is a
///   `learn_len` bucket (`grad_T<b>_B<r>` artifacts).
/// * `gather == Some(..)` — the **compacted** layout: response slot `j`
///   holds the `j`-th *kept* token of its row, `bucket` is a
///   **kept-count** bucket (`grad_K<k>_B<r>` artifacts), and
///   `gather[r * bucket + j]` records the token's original response
///   position (−1 for empty slots) so gradients scatter back by position.
///   `ht_w`/`old_lp` are gathered into the same slot order.
#[derive(Clone, Debug)]
pub struct MicroBatch {
    /// Sequence bucket: response window length of the grad artifact
    /// (a `learn_len` bucket in the prefix layout, a kept-count bucket in
    /// the compacted layout).
    pub bucket: usize,
    /// Allocated rows (the artifact's batch dimension). Always `batch_train`
    /// under the fixed packer; a row-grid bucket under the budget packer.
    pub rows: usize,
    /// Number of real (non-padding) rows.
    pub real_rows: usize,
    pub tokens: Vec<i32>,   // [rows, P + bucket]
    pub ht_w: Vec<f32>,     // [rows, bucket]
    pub adv: Vec<f32>,      // [rows]
    pub old_lp: Vec<f32>,   // [rows, bucket]
    pub inv_len: Vec<f32>,  // [rows] = 1 / t_i (FULL response length)
    pub pad_len: Vec<i32>,  // [rows]
    /// Original response position per slot ([rows, bucket], −1 = empty);
    /// `Some` selects the compacted `grad_K<k>_B<r>` artifact family.
    pub gather: Option<Vec<i32>>,
}

/// Smallest bucket >= learn_len; hard error past the top bucket (silent
/// clamping would zero-weight selected tokens with no HT reweighting —
/// DetTrunc-style bias smuggled in by the batcher).
fn bucket_for(buckets: &[usize], learn_len: usize) -> Result<usize> {
    match buckets.iter().copied().find(|&b| b >= learn_len) {
        Some(b) => Ok(b),
        None => bail!(
            "learn_len {learn_len} exceeds top bucket {} — packing it would \
             silently truncate selected tokens and bias the gradient",
            buckets.last().copied().unwrap_or(0)
        ),
    }
}

fn validate(items: &[LearnItem], buckets: &[usize]) -> Result<()> {
    if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
        bail!("buckets must be non-empty ascending: {buckets:?}");
    }
    for item in items {
        debug_assert!(item.learn_len >= 1 && item.learn_len <= item.resp_len);
        debug_assert_eq!(item.ht_w.len(), item.resp_len);
        bucket_for(buckets, item.learn_len)?;
    }
    Ok(())
}

/// Fixed packer: route items to sequence buckets and pack micro-batches of
/// exactly `batch` allocated rows (the pre-budget-packer layout, bit-for-bit).
pub fn pack(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    batch: usize,
) -> Result<Vec<MicroBatch>> {
    validate(items, buckets)?;
    let mut by_bucket: Vec<Vec<&LearnItem>> = vec![Vec::new(); buckets.len()];
    for item in items {
        let bi = buckets.iter().position(|&b| b >= item.learn_len).expect("validated");
        by_bucket[bi].push(item);
    }
    let mut out = Vec::new();
    for (bi, group) in by_bucket.iter().enumerate() {
        let bucket = buckets[bi];
        for chunk in group.chunks(batch) {
            out.push(pack_one(chunk, bucket, prompt_len, batch));
        }
    }
    Ok(out)
}

/// Smallest row-grid entry >= `n`. The grid is the set of batch dimensions
/// compiled grad artifacts exist for (ascending, max = batch_train).
///
/// Panics when `n` exceeds the top of a non-empty grid: silently clamping
/// to `row_grid.last()` (the old behaviour) would under-allocate rows and
/// truncate the group — the row-axis twin of the over-top-bucket bias both
/// packers hard-error on. The budget packer's partition DP never forms a
/// group larger than the top grid entry, so a panic here means a caller
/// bug, not a data-dependent condition.
pub fn alloc_rows(row_grid: &[usize], n: usize) -> usize {
    match row_grid.iter().copied().find(|&r| r >= n) {
        Some(r) => r,
        None if row_grid.is_empty() => n,
        None => panic!(
            "alloc_rows: group of {n} rows exceeds the top of the row grid \
             {row_grid:?} — packing it would silently truncate rows"
        ),
    }
}

/// Token-budget packer: sort by `learn_len`, then fill micro-batches in the
/// (sequence bucket × row bucket) grid so that total allocated tokens are
/// minimal subject to `rows × (P + bucket) <= token_budget` per micro-batch.
///
/// Because items are sorted, every micro-batch is a contiguous run of the
/// sorted list and its sequence bucket is decided by its longest (= last)
/// item, so the minimal-waste grouping is an exact O(n × batch_train)
/// partition DP rather than a heuristic: the cost of a run is
/// `alloc_rows(len) × (P + bucket(last))`, and the DP decides where runs
/// split — automatically merging a short-bucket straggler into the next
/// bucket's batch when that allocates fewer tokens than an under-filled
/// micro-batch of its own.
///
/// `token_budget == 0` means "no extra limit": the budget defaults to the
/// fixed packer's per-batch allocation, `batch_train × (P + top bucket)`.
pub fn pack_budget(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    row_grid: &[usize],
    token_budget: usize,
) -> Result<Vec<MicroBatch>> {
    pack_budget_with(items, buckets, prompt_len, row_grid, token_budget, false)
}

/// True when gather compaction is the cheaper layout for this item: its
/// kept set is scattered (non-prefix) AND its kept count routes to a
/// strictly smaller bucket than its `learn_len` would. Prefix-shaped plans
/// (GRPO/DetTrunc/RPC) and scattered plans whose kept count lands in the
/// same bucket keep the legacy layout — never pay the gather for nothing.
fn routes_compact(item: &LearnItem, buckets: &[usize]) -> Result<bool> {
    let k = item.kept();
    if k == 0 || item.is_prefix_shaped() {
        return Ok(false);
    }
    Ok(bucket_for(buckets, k)? < bucket_for(buckets, item.learn_len)?)
}

/// [`pack_budget`] with an explicit layout switch. `compact = false` is the
/// legacy prefix-only packer, bit-for-bit. `compact = true` routes each item
/// through [`routes_compact`] and packs the two pools separately — the
/// prefix pool keyed (and bucketed) on `learn_len` into `grad_T<b>_B<r>`
/// shapes, the compacted pool keyed on kept-token count into
/// `grad_K<k>_B<r>` shapes — each with the same exact partition DP.
/// Prefix-shaped plans therefore produce *identical* micro-batches under
/// both switches.
pub fn pack_budget_with(
    items: &[LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    row_grid: &[usize],
    token_budget: usize,
    compact: bool,
) -> Result<Vec<MicroBatch>> {
    validate(items, buckets)?;
    if row_grid.is_empty() || row_grid.windows(2).any(|w| w[0] >= w[1]) {
        bail!("row grid must be non-empty ascending: {row_grid:?}");
    }
    let max_rows = *row_grid.last().unwrap();
    let top = *buckets.last().unwrap();
    let budget = if token_budget == 0 { max_rows * (prompt_len + top) } else { token_budget };
    let mut prefix_pool: Vec<&LearnItem> = Vec::new();
    let mut compact_pool: Vec<&LearnItem> = Vec::new();
    for item in items {
        if compact && routes_compact(item, buckets)? {
            compact_pool.push(item);
        } else {
            prefix_pool.push(item);
        }
    }
    let mut out = pack_pool(&prefix_pool, buckets, prompt_len, row_grid, budget, false)?;
    out.extend(pack_pool(&compact_pool, buckets, prompt_len, row_grid, budget, true)?);
    Ok(out)
}

/// The exact partition DP over one layout pool. `compact` selects the
/// grouping key (kept count vs `learn_len`) and the emitted layout.
fn pack_pool(
    pool: &[&LearnItem],
    buckets: &[usize],
    prompt_len: usize,
    row_grid: &[usize],
    budget: usize,
    compact: bool,
) -> Result<Vec<MicroBatch>> {
    let max_rows = *row_grid.last().unwrap();
    let key = |it: &LearnItem| if compact { it.kept() } else { it.learn_len };
    let cost = |n: usize, bucket: usize| alloc_rows(row_grid, n) * (prompt_len + bucket);
    for &item in pool {
        let b = bucket_for(buckets, key(item))?;
        if cost(1, b) > budget {
            bail!(
                "train.token_budget {budget} is below one row of bucket {b} \
                 ({} tokens); raise the budget or use --train.packer fixed",
                cost(1, b)
            );
        }
    }

    // Sort by the pool key (stable: ties keep arrival order) so every group
    // of consecutive items shares the smallest viable bucket of its last
    // item.
    let mut order: Vec<usize> = (0..pool.len()).collect();
    order.sort_by_key(|&i| key(pool[i]));

    // dp[i] = minimal allocated tokens packing the first i sorted items;
    // cut[i] = start of the last micro-batch in that optimum. Ties prefer
    // the longest run (fewest micro-batches).
    let n = order.len();
    let mut dp = vec![usize::MAX; n + 1];
    let mut cut = vec![0usize; n + 1];
    dp[0] = 0;
    for i in 1..=n {
        let b_i = bucket_for(buckets, key(pool[order[i - 1]]))?;
        for j in i.saturating_sub(max_rows)..i {
            let c = cost(i - j, b_i);
            if c > budget || dp[j] == usize::MAX {
                continue;
            }
            if dp[j] + c < dp[i] {
                dp[i] = dp[j] + c;
                cut[i] = j;
            }
        }
        debug_assert_ne!(dp[i], usize::MAX, "single rows were pre-validated against the budget");
    }

    let mut bounds = Vec::new();
    let mut i = n;
    while i > 0 {
        bounds.push((cut[i], i));
        i = cut[i];
    }
    bounds.reverse();
    let mut out = Vec::new();
    for (lo, hi) in bounds {
        let group: Vec<&LearnItem> = order[lo..hi].iter().map(|&k| pool[k]).collect();
        let bucket = bucket_for(buckets, key(pool[order[hi - 1]]))?;
        let rows = alloc_rows(row_grid, group.len());
        out.push(if compact {
            pack_one_compact(&group, bucket, prompt_len, rows)
        } else {
            pack_one(&group, bucket, prompt_len, rows)
        });
    }
    Ok(out)
}

fn pack_one(rows: &[&LearnItem], bucket: usize, prompt_len: usize, alloc: usize) -> MicroBatch {
    debug_assert!(rows.len() <= alloc);
    let s = prompt_len + bucket;
    let mut mb = MicroBatch {
        bucket,
        rows: alloc,
        real_rows: rows.len(),
        tokens: vec![PAD; alloc * s],
        ht_w: vec![0.0; alloc * bucket],
        adv: vec![0.0; alloc],
        old_lp: vec![0.0; alloc * bucket],
        inv_len: vec![0.0; alloc],
        pad_len: vec![prompt_len as i32; alloc],
        gather: None,
    };
    for (r, item) in rows.iter().enumerate() {
        // token prefix: prompt window + first `bucket` response tokens
        mb.tokens[r * s..(r + 1) * s].copy_from_slice(&item.tokens[..s]);
        for t in 0..item.learn_len {
            mb.ht_w[r * bucket + t] = item.ht_w[t];
            mb.old_lp[r * bucket + t] = item.old_lp[t];
        }
        mb.adv[r] = item.adv;
        mb.inv_len[r] = 1.0 / item.resp_len as f32;
        mb.pad_len[r] = item.pad_len as i32;
    }
    mb
}

/// Compacted layout: response slot `j` of a row holds the row's `j`-th kept
/// token (gathered from original position `kept_indices()[j]`); `bucket` is
/// a **kept-count** bucket. The gather list records each slot's original
/// response position (−1 for empty slots) so the grad kernel can scatter
/// per-token gradients back by position; `ht_w`/`old_lp` gather into the
/// same slot order, and `inv_len` still reflects the FULL response length —
/// the HT estimator is untouched, only the layout is dense.
fn pack_one_compact(
    rows: &[&LearnItem],
    bucket: usize,
    prompt_len: usize,
    alloc: usize,
) -> MicroBatch {
    debug_assert!(rows.len() <= alloc);
    let s = prompt_len + bucket;
    let mut mb = MicroBatch {
        bucket,
        rows: alloc,
        real_rows: rows.len(),
        tokens: vec![PAD; alloc * s],
        ht_w: vec![0.0; alloc * bucket],
        adv: vec![0.0; alloc],
        old_lp: vec![0.0; alloc * bucket],
        inv_len: vec![0.0; alloc],
        pad_len: vec![prompt_len as i32; alloc],
        gather: None,
    };
    let mut gather = vec![-1i32; alloc * bucket];
    for (r, item) in rows.iter().enumerate() {
        mb.tokens[r * s..r * s + prompt_len].copy_from_slice(&item.tokens[..prompt_len]);
        for (j, pos) in item.kept_indices().into_iter().enumerate() {
            debug_assert!(j < bucket, "kept count exceeds the kept-count bucket");
            mb.tokens[r * s + prompt_len + j] = item.tokens[prompt_len + pos];
            mb.ht_w[r * bucket + j] = item.ht_w[pos];
            mb.old_lp[r * bucket + j] = item.old_lp[pos];
            gather[r * bucket + j] = pos as i32;
        }
        mb.adv[r] = item.adv;
        mb.inv_len[r] = 1.0 / item.resp_len as f32;
        mb.pad_len[r] = item.pad_len as i32;
    }
    mb.gather = Some(gather);
    mb
}

/// Ledger accounting for the compacted layout: `(kept, alloc, bound)`
/// summed over the COMPACTED micro-batches only — backpropped kept tokens,
/// allocated tokens, and the minimal grid-legal allocation re-derived from
/// each micro-batch's own contents (real rows rounded up on the row grid ×
/// the max per-row kept count rounded up on the bucket grid, plus the
/// prompt window). A healthy packer satisfies `kept ≤ alloc ≤ bound`:
/// `bound − kept` is exactly the row-grid + bucket rounding slack, and an
/// `alloc` above `bound` means some micro-batch allocated more than the
/// minimal cover of its rows. `nat trace --check` enforces the invariant.
pub fn compact_stats(
    mbs: &[MicroBatch],
    buckets: &[usize],
    row_grid: &[usize],
    prompt_len: usize,
) -> (usize, usize, usize) {
    let (mut kept, mut alloc, mut bound) = (0usize, 0usize, 0usize);
    for mb in mbs {
        let Some(g) = &mb.gather else { continue };
        alloc += mb.rows * (prompt_len + mb.bucket);
        let mut max_k = 0usize;
        for r in 0..mb.real_rows {
            let k = g[r * mb.bucket..(r + 1) * mb.bucket].iter().filter(|&&p| p >= 0).count();
            kept += k;
            max_k = max_k.max(k);
        }
        let b = buckets.iter().copied().find(|&b| b >= max_k).unwrap_or(mb.bucket);
        bound += alloc_rows(row_grid, mb.real_rows) * (prompt_len + b);
    }
    (kept, alloc, bound)
}

/// The per-micro-batch token cap the budget packer should run with. Under
/// `--train.budget_mode batch|neyman` the `token_budget` flag is repurposed
/// as the selection controller's expected-selected-token target, NOT a
/// packing cap — the packer then falls back to its auto budget (0); under
/// `budget_mode none` the flag means what it always did.
pub fn packer_token_budget(train: &crate::config::TrainCfg) -> usize {
    use crate::config::BudgetMode;
    if matches!(train.budget_mode, BudgetMode::Batch | BudgetMode::Neyman) {
        0
    } else {
        train.token_budget
    }
}

/// Allocated token cost of one micro-batch: what the device pays for it
/// regardless of padding rows (`rows × (P + bucket)`).
pub fn micro_batch_cost(mb: &MicroBatch, prompt_len: usize) -> usize {
    mb.rows * (prompt_len + mb.bucket)
}

/// Shard-aware assignment of packed micro-batches to `shards` data-parallel
/// learner workers, balancing **allocated token cost** — not micro-batch or
/// row counts, which would let one shard hoard the long-bucket batches and
/// cap the step on the slowest worker (LPT greedy: heaviest first onto the
/// least-loaded shard, every tie broken by index).
///
/// The plan is a pure function of the micro-batch list: it never looks at
/// timing, thread ids, or the shard count's interaction with completion
/// order. Combined with the id-keyed tree reduction (`runtime::shard`),
/// that is what makes `shards = K` bit-identical to `shards = 1`.
///
/// Returns `min(shards, #micro-batches)` non-empty shards (padded with
/// empty ones up to `shards` so callers can index by worker), each listing
/// its micro-batch ids in ascending order.
pub fn plan_shards(mbs: &[MicroBatch], prompt_len: usize, shards: usize) -> Vec<Vec<usize>> {
    let k = shards.max(1);
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut load = vec![0usize; k];
    let mut order: Vec<usize> = (0..mbs.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(micro_batch_cost(&mbs[i], prompt_len)), i));
    for &i in &order {
        let j = (0..k).min_by_key(|&j| (load[j], j)).expect("k >= 1");
        load[j] += micro_batch_cost(&mbs[i], prompt_len);
        plan[j].push(i);
    }
    for ids in &mut plan {
        ids.sort_unstable();
    }
    plan
}

/// The default sharded-learner workload: 32 RPC-shaped responses over the
/// sim runtime's bucket grid. ONE definition shared by
/// `benches/bench_train_step.rs` (which measures wall-clock and writes
/// `BENCH_train_step.json`) and the tier-1 cost-balance gate in
/// `tests/sharding.rs`, so the perf record and the CI assertion always
/// describe the same workload — the `sim_workload` pattern from the
/// rollout scheduler, learner-side.
pub mod shard_workload {
    use super::{pack_budget, LearnItem, MicroBatch};
    use crate::util::rng::Rng;

    pub const SEED: u64 = 0x5EED;
    pub const ITEMS: usize = 32;
    pub const PROMPT_LEN: usize = 32;
    pub const MAX_RESP: usize = 16;
    pub const BUCKETS: [usize; 3] = [4, 8, 16];
    pub const ROW_GRID: [usize; 3] = [1, 2, 4];

    /// 32 responses with RPC-shaped `learn_len` spread (at this seed the
    /// budget packer yields 10 micro-batches across all three buckets).
    pub fn items() -> Vec<LearnItem> {
        let mut rng = Rng::new(SEED);
        (0..ITEMS)
            .map(|_| {
                let t = 1 + rng.below(MAX_RESP as u64) as usize;
                let ll = 1 + rng.below(t as u64) as usize;
                LearnItem {
                    tokens: (0..(PROMPT_LEN + MAX_RESP) as i32).map(|x| 3 + x % 50).collect(),
                    pad_len: 4,
                    resp_len: t,
                    ht_w: (0..t).map(|i| if i < ll { 1.25 } else { 0.0 }).collect(),
                    learn_len: ll,
                    adv: 0.75,
                    old_lp: (0..t).map(|i| -0.1 - 0.05 * (i % 7) as f32).collect(),
                }
            })
            .collect()
    }

    /// The workload packed by the token-budget packer (auto budget).
    pub fn micro_batches() -> Vec<MicroBatch> {
        pack_budget(&items(), &BUCKETS, PROMPT_LEN, &ROW_GRID, 0)
            .expect("shard workload packs within the top bucket")
    }
}

/// The compaction acceptance workload: long responses (64..=128 tokens)
/// under scattered ~50%-keep selection — the case where prefix packing pays
/// for nearly the full response while only half its tokens carry gradient.
/// ONE definition shared by `benches/bench_compaction.rs` (which writes
/// `BENCH_compaction.json`) and the tier-1 ≥30%-fewer-allocated-tokens gate
/// in this module's tests, mirroring the `shard_workload` pattern.
pub mod compaction_workload {
    use super::{pack_budget, pack_budget_with, LearnItem, MicroBatch};
    use crate::config::Method;
    use crate::coordinator::masking::sample;
    use crate::util::rng::Rng;

    pub const SEED: u64 = 0xC0_4F_AC_7;
    pub const ITEMS: usize = 24;
    pub const PROMPT_LEN: usize = 16;
    pub const MAX_RESP: usize = 128;
    pub const BUCKETS: [usize; 8] = [16, 32, 48, 64, 80, 96, 112, 128];
    pub const ROW_GRID: [usize; 4] = [1, 2, 4, 8];

    /// The scattered ~50%-keep methods the acceptance gate covers. Poisson's
    /// per-sequence rate targets ~48 of the 64..=128-token responses — about
    /// half, like the two p = 0.5 schemes.
    pub fn methods() -> Vec<(&'static str, Method)> {
        vec![
            ("urs", Method::Urs { p: 0.5 }),
            ("stratified", Method::Stratified { p: 0.5 }),
            ("poisson", Method::Poisson { k: 48 }),
        ]
    }

    /// One draw of the workload: `ITEMS` responses of 64..=128 tokens with
    /// the given method's selection applied.
    pub fn items(method: &Method, rng: &mut Rng) -> Vec<LearnItem> {
        (0..ITEMS)
            .map(|_| {
                let t = 64 + rng.below(65) as usize;
                let m = sample(method, t, rng);
                LearnItem {
                    tokens: (0..(PROMPT_LEN + MAX_RESP) as i32).map(|x| 3 + x % 50).collect(),
                    pad_len: 4,
                    resp_len: t,
                    ht_w: m.ht_w,
                    learn_len: m.learn_len,
                    adv: 0.75,
                    old_lp: (0..t).map(|i| -0.1 - 0.05 * (i % 7) as f32).collect(),
                }
            })
            .collect()
    }

    /// The same items packed prefix-only vs with gather compaction.
    pub fn both_layouts(items: &[LearnItem]) -> (Vec<MicroBatch>, Vec<MicroBatch>) {
        let prefix = pack_budget(items, &BUCKETS, PROMPT_LEN, &ROW_GRID, 0)
            .expect("compaction workload packs within the top bucket");
        let compact = pack_budget_with(items, &BUCKETS, PROMPT_LEN, &ROW_GRID, 0, true)
            .expect("compaction workload packs within the top bucket");
        (prefix, compact)
    }
}

/// Split items into (contributing, dropped-count): rows with no kept token
/// or zero advantage contribute exactly nothing to the accumulated gradient
/// but burn a full forward/backward if packed. The caller must keep the
/// dropped count in the apply scale (`GradAccum::sequences`) so the applied
/// gradient is bit-for-bit what packing the inert rows would have produced.
pub fn split_zero_contribution(items: Vec<LearnItem>) -> (Vec<LearnItem>, usize) {
    let n = items.len();
    let kept: Vec<LearnItem> = items.into_iter().filter(|i| !i.is_zero_contribution()).collect();
    let dropped = n - kept.len();
    (kept, dropped)
}

/// Micro-batch (rows, seq) shapes for the analytic memory model.
pub fn micro_shapes(mbs: &[MicroBatch], prompt_len: usize) -> Vec<(usize, usize)> {
    mbs.iter().map(|m| (m.rows, prompt_len + m.bucket)).collect()
}

/// Learner tokens actually allocated by a packed step: Σ rows × (P + bucket).
pub fn allocated_tokens(mbs: &[MicroBatch], prompt_len: usize) -> usize {
    mbs.iter().map(|m| m.rows * (prompt_len + m.bucket)).sum()
}

/// Zero-padding lower bound for an item list: Σ (P + learn_len).
pub fn ideal_tokens(items: &[LearnItem], prompt_len: usize) -> usize {
    items.iter().map(|i| prompt_len + i.learn_len).sum()
}

/// Fraction of allocated learner tokens that are padding (the
/// `padding_waste` metric series).
pub fn padding_waste(mbs: &[MicroBatch], items: &[LearnItem], prompt_len: usize) -> f64 {
    let alloc = allocated_tokens(mbs, prompt_len);
    if alloc == 0 {
        return 0.0;
    }
    1.0 - ideal_tokens(items, prompt_len) as f64 / alloc as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::coordinator::masking::sample;
    use crate::util::rng::Rng;

    const P: usize = 8;
    const BUCKETS: [usize; 3] = [4, 8, 16];
    const GRID: [usize; 3] = [1, 2, 4];

    fn item(resp_len: usize, learn_len: usize, adv: f32) -> LearnItem {
        LearnItem {
            tokens: (0..(P + 16) as i32).collect(),
            pad_len: 2,
            resp_len,
            ht_w: (0..resp_len).map(|t| if t < learn_len { 1.5 } else { 0.0 }).collect(),
            learn_len,
            adv,
            old_lp: (0..resp_len).map(|t| -(t as f32)).collect(),
        }
    }

    #[test]
    fn full_length_items_build_the_grpo_counterfactual() {
        let seqs = crate::coordinator::selection::bench_workload::seqs(P, 16);
        let items = full_length_items(&seqs);
        assert_eq!(items.len(), seqs.len()); // workload has no empty responses
        for (it, s) in items.iter().zip(&seqs) {
            assert_eq!(it.learn_len, s.resp_len);
            assert_eq!(it.ht_w.len(), s.resp_len);
            assert!(it.ht_w.iter().all(|&w| w == 1.0));
            assert_eq!(it.adv, 1.0);
            assert!(!it.is_zero_contribution());
        }
        // counterfactual cost dominates any selected-prefix packing
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(allocated_tokens(&mbs, P) >= ideal_tokens(&items, P));
        // a zero-length response is skipped, matching the learn loop
        let mut with_empty = seqs;
        with_empty[0].resp_len = 0;
        with_empty[0].old_lp.clear();
        assert_eq!(full_length_items(&with_empty).len(), items.len() - 1);
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let items = vec![item(16, 3, 1.0), item(16, 4, 1.0), item(16, 5, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let buckets: Vec<usize> = mbs.iter().map(|m| m.bucket).collect();
        assert!(buckets.contains(&4));
        assert!(buckets.contains(&8));
        assert!(buckets.contains(&16));
        let total_rows: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(total_rows, 4);
    }

    #[test]
    fn splits_into_fixed_micro_batches() {
        let items: Vec<LearnItem> = (0..10).map(|_| item(16, 16, 0.5)).collect();
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        assert_eq!(mbs.len(), 3); // 4 + 4 + 2
        assert_eq!(mbs[2].real_rows, 2);
        for m in &mbs {
            assert_eq!(m.rows, 4); // fixed packer: padded to full batch
            assert_eq!(m.adv.len(), 4);
            assert_eq!(m.tokens.len(), 4 * (P + m.bucket));
        }
    }

    #[test]
    fn padding_rows_are_inert() {
        let items = vec![item(16, 16, 2.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let m = &mbs[0];
        for r in 1..4 {
            assert_eq!(m.adv[r], 0.0);
            assert_eq!(m.inv_len[r], 0.0);
            assert!(m.ht_w[r * m.bucket..(r + 1) * m.bucket].iter().all(|&w| w == 0.0));
        }
    }

    #[test]
    fn weights_beyond_learn_len_are_zero() {
        let items = vec![item(16, 6, 1.0)]; // routes to bucket 8
        let mbs = pack(&items, &BUCKETS, P, 1).unwrap();
        let m = &mbs[0];
        assert_eq!(m.bucket, 8);
        assert!(m.ht_w[..6].iter().all(|&w| w == 1.5));
        assert!(m.ht_w[6..8].iter().all(|&w| w == 0.0));
        // inv_len reflects the FULL response length, not the cut
        assert!((m.inv_len[0] - 1.0 / 16.0).abs() < 1e-7);
    }

    #[test]
    fn token_rows_are_sliced_to_bucket_window() {
        let items = vec![item(16, 3, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 1).unwrap();
        let m = &mbs[0];
        assert_eq!(m.bucket, 4);
        assert_eq!(m.tokens.len(), P + 4);
        assert_eq!(m.tokens[..P + 4], (0..(P + 4) as i32).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn learn_len_over_top_bucket_is_rejected() {
        // Clamping (the old behaviour) would zero-weight tokens 8..16 with
        // no HT reweighting — DetTrunc-style bias. Both packers refuse.
        let items = vec![item(16, 16, 1.0)];
        let err = pack(&items, &[4, 8], P, 1).unwrap_err();
        assert!(err.to_string().contains("exceeds top bucket"), "{err}");
        let err = pack_budget(&items, &[4, 8], P, &GRID, 0).unwrap_err();
        assert!(err.to_string().contains("exceeds top bucket"), "{err}");
    }

    #[test]
    fn micro_shapes_for_memory_model() {
        let items = vec![item(16, 3, 1.0), item(16, 16, 1.0)];
        let mbs = pack(&items, &BUCKETS, P, 4).unwrap();
        let shapes = micro_shapes(&mbs, P);
        assert!(shapes.contains(&(4, P + 4)));
        assert!(shapes.contains(&(4, P + 16)));
    }

    #[test]
    fn alloc_rows_rounds_up_in_grid() {
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 1), 1);
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 3), 4);
        assert_eq!(alloc_rows(&[1, 2, 4, 8], 8), 8);
        // legacy manifests compile only the full batch dimension
        assert_eq!(alloc_rows(&[8], 2), 8);
    }

    #[test]
    fn budget_rows_follow_the_row_grid() {
        // 3 short items: the fixed packer burns 4 allocated rows in one
        // micro-batch; the budget packer decomposes 3 = 2 + 1 exactly in
        // the power-of-two grid — zero row padding.
        let items = vec![item(16, 2, 1.0), item(16, 3, 1.0), item(16, 3, 1.0)];
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        let alloc: usize = mbs.iter().map(|m| m.rows).sum();
        let real: usize = mbs.iter().map(|m| m.real_rows).sum();
        assert_eq!(real, 3);
        assert_eq!(alloc, 3, "{mbs:?}");
        assert!(mbs.iter().all(|m| m.bucket == 4 && GRID.contains(&m.rows)));
        assert_eq!(allocated_tokens(&mbs, P), 3 * (P + 4));
        let fixed = pack(&items, &BUCKETS, P, 4).unwrap();
        assert_eq!(allocated_tokens(&fixed, P), 4 * (P + 4));
        let one = pack_budget(&items[..1], &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(one[0].rows, 1);
    }

    #[test]
    fn budget_limit_splits_micro_batches() {
        let items: Vec<LearnItem> = (0..4).map(|_| item(16, 4, 1.0)).collect();
        // 2 rows × (8 + 4) = 24 tokens fits; 4 rows = 48 does not.
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 24).unwrap();
        assert_eq!(mbs.len(), 2);
        for m in &mbs {
            assert_eq!(m.rows, 2);
            assert!(m.rows * (P + m.bucket) <= 24);
        }
        // A budget below one minimal row is a config error.
        let err = pack_budget(&items, &BUCKETS, P, &GRID, 8).unwrap_err();
        assert!(err.to_string().contains("token_budget"), "{err}");
    }

    #[test]
    fn budget_merges_small_buckets_when_cheaper() {
        // Coarse row grid (a legacy manifest compiles only rows=4): the
        // straggler at learn_len 4 would need its own 4-row batch (4×12=48)
        // next to the bucket-8 batch (4×16=64); merging everything into one
        // bucket-8 batch costs 64 total → the DP merges.
        let items =
            vec![item(16, 4, 1.0), item(16, 8, 1.0), item(16, 8, 1.0), item(16, 8, 1.0)];
        let coarse = [4usize];
        let mbs = pack_budget(&items, &BUCKETS, P, &coarse, 0).unwrap();
        assert_eq!(mbs.len(), 1);
        assert_eq!(mbs[0].bucket, 8);
        assert_eq!(mbs[0].rows, 4);
        assert_eq!(mbs[0].real_rows, 4);
        // With a fine grid, exact row sums beat cross-bucket merging: the
        // straggler gets its own 1-row bucket-4 batch instead.
        let fine = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(fine.iter().any(|m| m.bucket == 4 && m.rows == 1));
        assert!(allocated_tokens(&fine, P) < allocated_tokens(&mbs, P));
    }

    #[test]
    fn budget_splits_buckets_when_upgrade_is_wasteful() {
        // 2 items at learn_len 4 + 1 at learn_len 16: one merged batch at
        // bucket 16 costs alloc(3)=4 rows × (8+16) = 96; splitting costs
        // 2×12 + 1×24 = 48 → the DP splits.
        let items = vec![item(16, 4, 1.0), item(16, 4, 1.0), item(16, 16, 1.0)];
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(mbs.len(), 2);
        assert_eq!(mbs[0].bucket, 4);
        assert_eq!(mbs[0].real_rows, 2);
        assert_eq!(mbs[1].bucket, 16);
        assert_eq!(mbs[1].rows, 1);
    }

    #[test]
    fn budget_conserves_rows_and_weights() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = 1 + rng.below(24) as usize;
            let items: Vec<LearnItem> = (0..n)
                .map(|_| {
                    let t = 1 + rng.below(16) as usize;
                    let ll = 1 + rng.below(t as u64) as usize;
                    item(t, ll, rng.normal() as f32)
                })
                .collect();
            let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
            let total: usize = mbs.iter().map(|m| m.real_rows).sum();
            assert_eq!(total, n);
            let w = |mbs: &[MicroBatch]| -> f64 {
                mbs.iter().flat_map(|m| m.ht_w.iter()).map(|&x| x as f64).sum()
            };
            let fixed = pack(&items, &BUCKETS, P, 4).unwrap();
            assert!((w(&mbs) - w(&fixed)).abs() < 1e-9);
            for m in &mbs {
                assert!(GRID.contains(&m.rows));
                assert!(m.real_rows <= m.rows);
            }
        }
    }

    #[test]
    fn budget_packer_cuts_rpc_padded_waste_by_30pct() {
        // Acceptance: ≥ 30% lower padded-token waste for RPC (min_cut
        // default 8) at equal batch config. Realistic per-step scale:
        // prompts_per_step × G = 16 items, buckets [32,64,96,128], B=8.
        let (p, buckets, grid) = (48usize, [32usize, 64, 96, 128], [1usize, 2, 4, 8]);
        let mut rng = Rng::new(7);
        let mut waste_fixed = 0.0;
        let mut waste_budget = 0.0;
        for _ in 0..50 {
            let items: Vec<LearnItem> = (0..16)
                .map(|_| {
                    let t = 1 + rng.below(128) as usize;
                    let m = sample(&Method::Rpc { min_cut: 8 }, t, &mut rng);
                    LearnItem {
                        tokens: vec![7; p + 128],
                        pad_len: 5,
                        resp_len: t,
                        ht_w: m.ht_w,
                        learn_len: m.learn_len,
                        adv: 1.0,
                        old_lp: vec![-1.0; t],
                    }
                })
                .collect();
            let fixed = pack(&items, &buckets, p, 8).unwrap();
            let budget = pack_budget(&items, &buckets, p, &grid, 0).unwrap();
            waste_fixed += padding_waste(&fixed, &items, p);
            waste_budget += padding_waste(&budget, &items, p);
        }
        assert!(
            waste_budget < 0.7 * waste_fixed,
            "budget packer waste {waste_budget:.3} not ≥30% below fixed {waste_fixed:.3}"
        );
    }

    #[test]
    fn zero_contribution_split_preserves_population_accounting() {
        let items = vec![
            item(16, 4, 1.0),                // contributes
            item(16, 4, 0.0),                // zero advantage
            LearnItem { ht_w: vec![0.0; 16], ..item(16, 4, 1.0) }, // no kept token
            item(16, 8, -0.5),               // contributes
        ];
        let n = items.len();
        let (kept, dropped) = split_zero_contribution(items);
        assert_eq!(kept.len(), 2);
        assert_eq!(dropped, 2);
        assert_eq!(kept.len() + dropped, n);
        assert!(kept.iter().all(|i| !i.is_zero_contribution()));
    }

    #[test]
    fn plan_shards_partitions_all_ids_and_balances_token_cost() {
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let n = 1 + rng.below(20) as usize;
            let items: Vec<LearnItem> = (0..n)
                .map(|_| {
                    let t = 1 + rng.below(16) as usize;
                    let ll = 1 + rng.below(t as u64) as usize;
                    item(t, ll, 1.0)
                })
                .collect();
            let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
            for k in [1usize, 2, 3, 4, 7] {
                let plan = plan_shards(&mbs, P, k);
                assert_eq!(plan.len(), k);
                // exact partition of 0..mbs.len()
                let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..mbs.len()).collect::<Vec<_>>());
                // ids ascend within each shard (execution = id order)
                for ids in &plan {
                    assert!(ids.windows(2).all(|w| w[0] < w[1]));
                }
                // LPT guarantee: max load <= min load + max single cost
                let cost = |ids: &[usize]| -> usize {
                    ids.iter().map(|&i| micro_batch_cost(&mbs[i], P)).sum()
                };
                let loads: Vec<usize> = plan.iter().map(|ids| cost(ids)).collect();
                let biggest =
                    mbs.iter().map(|m| micro_batch_cost(m, P)).max().unwrap_or(0);
                let (lo, hi) =
                    (loads.iter().min().unwrap(), loads.iter().max().unwrap());
                assert!(hi - lo <= biggest, "k={k}: loads {loads:?}, biggest {biggest}");
            }
        }
    }

    #[test]
    fn plan_shards_is_deterministic() {
        let items: Vec<LearnItem> =
            (0..9).map(|i| item(16, 1 + (i * 5) % 16, 1.0)).collect();
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(plan_shards(&mbs, P, 3), plan_shards(&mbs, P, 3));
        // k beyond the micro-batch count leaves the tail shards empty
        let plan = plan_shards(&mbs, P, mbs.len() + 2);
        assert_eq!(plan.iter().filter(|ids| !ids.is_empty()).count(), mbs.len());
    }

    #[test]
    fn learn_item_from_plan_packs_off_the_plan_learn_len() {
        use crate::coordinator::rollout::RolloutSeq;
        use crate::coordinator::selection::{Selector, Urs};

        let seq = RolloutSeq {
            task_idx: 0,
            tokens: (0..(P + 16) as i32).collect(),
            pad_len: 2,
            resp_len: 12,
            old_lp: (0..12).map(|t| -(t as f32)).collect(),
            reward: 1.0,
        };
        let mut rng = Rng::new(5);
        let plan = Urs { p: 0.5 }.sample(seq.resp_len, None, &mut rng);
        let (ll, w) = (plan.learn_len, plan.ht_w.clone());
        let it = LearnItem::from_plan(&seq, plan, 0.7);
        assert_eq!(it.learn_len, ll);
        assert_eq!(it.ht_w, w);
        assert_eq!(it.resp_len, 12);
        assert_eq!(it.adv, 0.7);
        assert_eq!(it.old_lp, seq.old_lp);
        let mbs = pack_budget(&[it], &BUCKETS, P, &GRID, 0).unwrap();
        assert!(mbs[0].bucket >= ll);
    }

    #[test]
    fn packer_budget_is_auto_under_batch_budget_mode() {
        use crate::config::{BudgetMode, TrainCfg};
        let mut train = TrainCfg::default();
        train.token_budget = 512;
        assert_eq!(packer_token_budget(&train), 512);
        train.budget_mode = BudgetMode::Batch;
        assert_eq!(packer_token_budget(&train), 0);
        train.budget_mode = BudgetMode::Neyman;
        assert_eq!(packer_token_budget(&train), 0);
    }

    #[test]
    fn waste_metric_is_zero_for_perfect_fit() {
        let items: Vec<LearnItem> = (0..4).map(|_| item(16, 16, 1.0)).collect();
        let mbs = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert!(padding_waste(&mbs, &items, P) < 1e-9);
        assert_eq!(allocated_tokens(&mbs, P), ideal_tokens(&items, P));
        assert_eq!(padding_waste(&[], &[], P), 0.0);
    }

    /// A row with nonzero weights only at the given response positions.
    fn scattered_item(resp_len: usize, kept: &[usize], adv: f32) -> LearnItem {
        let mut ht_w = vec![0.0f32; resp_len];
        for &pos in kept {
            ht_w[pos] = 2.0 + pos as f32;
        }
        let learn_len = kept.iter().max().map_or(1, |&m| m + 1);
        LearnItem { ht_w, learn_len, ..item(resp_len, learn_len, adv) }
    }

    #[test]
    #[should_panic(expected = "exceeds the top of the row grid")]
    fn alloc_rows_errors_past_grid_top() {
        // Regression: the old fallback clamped to row_grid.last(), silently
        // under-allocating rows for an oversized group.
        alloc_rows(&[1, 2, 4], 5);
    }

    #[test]
    fn kept_helpers_classify_prefix_and_scattered_shapes() {
        let prefix = item(16, 6, 1.0);
        assert_eq!(prefix.kept(), 6);
        assert_eq!(prefix.kept_indices(), vec![0, 1, 2, 3, 4, 5]);
        assert!(prefix.is_prefix_shaped());

        let scattered = scattered_item(16, &[1, 7, 12], 1.0);
        assert_eq!(scattered.kept(), 3);
        assert_eq!(scattered.kept_indices(), vec![1, 7, 12]);
        assert_eq!(scattered.learn_len, 13);
        assert!(!scattered.is_prefix_shaped());

        // empty kept set is (vacuously) prefix-shaped and never compacts
        let empty = LearnItem { ht_w: vec![0.0; 16], ..item(16, 4, 1.0) };
        assert_eq!(empty.kept(), 0);
        assert!(empty.is_prefix_shaped());
        assert!(!routes_compact(&empty, &BUCKETS).unwrap());
    }

    #[test]
    fn compact_pack_gathers_kept_tokens_and_records_positions() {
        let it = scattered_item(16, &[1, 7, 12], 0.5);
        let mbs = pack_budget_with(&[it.clone()], &BUCKETS, P, &GRID, 0, true).unwrap();
        assert_eq!(mbs.len(), 1);
        let m = &mbs[0];
        // 3 kept tokens bucket to 4, not learn_len 13's bucket 16
        assert_eq!(m.bucket, 4);
        assert_eq!(m.rows, 1);
        let g = m.gather.as_ref().expect("compacted micro-batch carries gather");
        assert_eq!(g, &vec![1, 7, 12, -1]);
        // slot j holds the token/weight/logprob from original position g[j]
        for (j, &pos) in [1usize, 7, 12].iter().enumerate() {
            assert_eq!(m.tokens[P + j], it.tokens[P + pos]);
            assert_eq!(m.ht_w[j], it.ht_w[pos]);
            assert_eq!(m.old_lp[j], it.old_lp[pos]);
        }
        // prompt window and per-row scalars are layout-independent
        assert_eq!(m.tokens[..P], it.tokens[..P]);
        assert!((m.inv_len[0] - 1.0 / 16.0).abs() < 1e-7);
        assert_eq!(m.pad_len[0], it.pad_len as i32);
    }

    #[test]
    fn pack_budget_with_routes_only_cheaper_scattered_items() {
        let items = vec![
            item(16, 6, 1.0),                    // prefix-shaped -> legacy
            scattered_item(16, &[1, 7, 12], 1.0), // kept 3 < learn_len 13 -> compact
            scattered_item(16, &[0, 1, 3], 1.0),  // kept 3, learn_len 4: same bucket -> legacy
        ];
        let mbs = pack_budget_with(&items, &BUCKETS, P, &GRID, 0, true).unwrap();
        let compacted: Vec<&MicroBatch> = mbs.iter().filter(|m| m.gather.is_some()).collect();
        let legacy_rows: usize =
            mbs.iter().filter(|m| m.gather.is_none()).map(|m| m.real_rows).sum();
        assert_eq!(compacted.len(), 1);
        assert_eq!(compacted[0].real_rows, 1);
        assert_eq!(legacy_rows, 2);
    }

    #[test]
    fn prefix_shaped_plans_identical_under_compact_switch() {
        // RPC/DetTrunc/GRPO-shaped pools: compact=true must be bit-for-bit
        // the legacy packer (every item routes to the prefix pool).
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let items: Vec<LearnItem> = (0..12)
                .map(|_| {
                    let t = 1 + rng.below(16) as usize;
                    let m = sample(&Method::Rpc { min_cut: 4 }, t, &mut rng);
                    LearnItem {
                        ht_w: m.ht_w,
                        learn_len: m.learn_len,
                        ..item(t, 1, 1.0)
                    }
                })
                .collect();
            let legacy = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
            let with = pack_budget_with(&items, &BUCKETS, P, &GRID, 0, true).unwrap();
            assert_eq!(format!("{legacy:?}"), format!("{with:?}"));
        }
    }

    #[test]
    fn compact_stats_invariants_hold() {
        let items = vec![
            scattered_item(16, &[1, 7, 12], 1.0),
            scattered_item(16, &[0, 5, 9, 14], 1.0),
            item(16, 6, 1.0),
        ];
        let mbs = pack_budget_with(&items, &BUCKETS, P, &GRID, 0, true).unwrap();
        let (kept, alloc, bound) = compact_stats(&mbs, &BUCKETS, &GRID, P);
        assert_eq!(kept, 7); // 3 + 4 kept tokens; the prefix row is excluded
        assert!(kept <= alloc, "kept {kept} > alloc {alloc}");
        assert!(alloc <= bound, "alloc {alloc} > bound {bound}");
        // prefix-only packings report zeros
        let legacy = pack_budget(&items, &BUCKETS, P, &GRID, 0).unwrap();
        assert_eq!(compact_stats(&legacy, &BUCKETS, &GRID, P), (0, 0, 0));
    }

    #[test]
    fn compaction_cuts_scattered_keep_allocation_by_30pct() {
        // THE acceptance gate (tier-1 twin of benches/bench_compaction.rs):
        // on the shared scattered ~50%-keep workload, gather compaction must
        // allocate >= 30% fewer grad tokens than prefix packing for each of
        // URS / stratified / Poisson.
        use super::compaction_workload as w;
        for (name, method) in w::methods() {
            let mut rng = Rng::new(w::SEED);
            let (mut prefix_alloc, mut compact_alloc) = (0usize, 0usize);
            for _ in 0..20 {
                let items = w::items(&method, &mut rng);
                let (items, _) = split_zero_contribution(items);
                let (prefix, compact) = w::both_layouts(&items);
                prefix_alloc += allocated_tokens(&prefix, w::PROMPT_LEN);
                compact_alloc += allocated_tokens(&compact, w::PROMPT_LEN);
                // every allocation still covers all backpropped tokens
                let (kept, alloc, bound) =
                    compact_stats(&compact, &w::BUCKETS, &w::ROW_GRID, w::PROMPT_LEN);
                assert!(kept <= alloc && alloc <= bound, "{name}: {kept}/{alloc}/{bound}");
            }
            assert!(
                10 * compact_alloc <= 7 * prefix_alloc,
                "{name}: compacted {compact_alloc} tokens not >=30% below \
                 prefix-packed {prefix_alloc}"
            );
        }
    }
}
