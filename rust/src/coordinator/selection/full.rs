//! Full selection — the vanilla GRPO baseline: every response token
//! backpropagates with weight 1 (inclusion probability 1 everywhere, so the
//! "HT estimator" is the plain sum). Consumes no RNG draws.

use super::{SelectionPlan, Selector};
use crate::util::rng::Rng;

pub struct Full;

impl Selector for Full {
    fn label(&self) -> String {
        "full".into()
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        vec![1.0; t_i]
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        t_i as f64
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, _rng: &mut Rng) -> SelectionPlan {
        SelectionPlan {
            probs: vec![1.0; t_i],
            ht_w: vec![1.0; t_i],
            kept: t_i,
            learn_len: t_i,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_everything_without_touching_the_rng() {
        let mut rng = Rng::new(0);
        let before = rng.clone();
        let plan = Full.sample(37, None, &mut rng);
        assert_eq!(plan.kept, 37);
        assert_eq!(plan.learn_len, 37);
        assert!(plan.ht_w.iter().all(|&w| w == 1.0));
        assert!(plan.probs.iter().all(|&p| p == 1.0));
        let mut a = before;
        assert_eq!(a.next_u64(), rng.next_u64());
    }
}
