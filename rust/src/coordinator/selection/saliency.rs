//! Information-aware selection (paper §7 future work, implemented):
//! behaviour surprisal u_t = -log pi_old(o_t) normalised to [0, 1] per
//! sequence, then p_t = floor + (1 - floor) * u_t. High-surprisal
//! ("high-entropy minority") tokens are (almost) always kept; boilerplate
//! tokens are kept with probability ~floor and up-weighted by 1/p_t when
//! they are — the same HT framework as URS/RPC.
//!
//! The budget controller's hook is `scale`: inclusion probabilities become
//! min(1, scale · p_t), and because the HT weights always divide by the
//! probability actually sampled with, any scale keeps the estimator exactly
//! unbiased. `scale == 1` takes the verbatim legacy path (bit-identical
//! probabilities and draws).

use super::{pi_w32, tail_learn_len, SelectionPlan, Selector};
use crate::util::rng::Rng;

/// Base inclusion probabilities (the legacy `masking::saliency_probs`).
pub fn probs(old_lp: &[f32], floor: f64) -> Vec<f32> {
    let max_u = old_lp.iter().map(|&lp| -lp).fold(1e-6f32, f32::max);
    old_lp
        .iter()
        .map(|&lp| {
            let u = (-lp / max_u).clamp(0.0, 1.0);
            // natlint: allow(lossy-cast, reason = "legacy saliency_probs arithmetic kept bit-identical; the whole blend is f32 by design and floor is a config literal far above f32 epsilon")
            (floor as f32 + (1.0 - floor as f32) * u).clamp(floor as f32, 1.0)
        })
        .collect()
}

pub struct Saliency {
    pub floor: f64,
    /// Batch-budget multiplier on the base probabilities (1.0 = off).
    pub scale: f64,
    /// Shared solve-clamp π floor (`--train.pi_floor`; 0 = guard off).
    /// Applied to the *scaled* probabilities, mirroring the budget solve's
    /// clamp, so sampling and 1/π reweighting agree and `w_max ≤ 1/pi_floor`
    /// by construction.
    pub pi_floor: f64,
}

impl Saliency {
    pub fn new(floor: f64) -> Saliency {
        Saliency { floor, scale: 1.0, pi_floor: 0.0 }
    }

    fn inclusion(&self, old_lp: &[f32]) -> Vec<f32> {
        let base = probs(old_lp, self.floor);
        if self.scale == 1.0 && self.pi_floor <= 0.0 {
            base
        } else {
            let pf = self.pi_floor.max(0.0);
            base.iter()
                // clamp in f64, quantize once through the blessed point
                .map(|&p| pi_w32((self.scale * p as f64).min(1.0).max(pf)).0.max(f32::MIN_POSITIVE))
                .collect()
        }
    }
}

impl Selector for Saliency {
    fn label(&self) -> String {
        format!("saliency(floor={}, scale={})", self.floor, self.scale)
    }

    fn probs(&self, t_i: usize, ctx: Option<&[f32]>) -> Vec<f32> {
        let lp = ctx.expect("Saliency selection needs behaviour logprobs");
        debug_assert_eq!(lp.len(), t_i);
        self.inclusion(lp)
    }

    fn expected_kept(&self, t_i: usize, ctx: Option<&[f32]>) -> f64 {
        match ctx {
            Some(lp) => self.inclusion(lp).iter().map(|&p| p as f64).sum(),
            // without the surprisal profile the floor is the lower bound
            None => self.floor * t_i as f64,
        }
    }

    fn draw(&self, t_i: usize, ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        let p = self.probs(t_i, ctx);
        let mut ht_w = vec![0.0f32; t_i];
        let mut kept = 0;
        let mut last_kept = 0usize;
        for (t, (slot, &pt)) in ht_w.iter_mut().zip(&p).enumerate() {
            if rng.bernoulli(pt as f64) {
                *slot = 1.0 / pt;
                kept += 1;
                last_kept = t + 1;
            }
        }
        // independent masking: forward only up to the last scored token
        SelectionPlan { probs: p, ht_w, kept, learn_len: tail_learn_len(last_kept) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probs_are_floored_and_monotone_in_surprisal() {
        let old_lp = [-0.1f32, -1.0, -5.0, -0.01];
        let p = probs(&old_lp, 0.25);
        assert!(p.iter().all(|&x| (0.25..=1.0).contains(&x)));
        assert!((p[2] - 1.0).abs() < 1e-6);
        assert!(p[3] < p[0] && p[0] < p[1] && p[1] < p[2]);
    }

    #[test]
    fn scale_one_is_the_identity_and_scaling_clamps_at_one() {
        let old_lp: Vec<f32> = (0..40).map(|t| -0.2 - 0.1 * (t % 7) as f32).collect();
        let base = Saliency::new(0.3).probs(40, Some(&old_lp));
        assert_eq!(base, probs(&old_lp, 0.3));
        let scaled = Saliency { floor: 0.3, scale: 0.5, pi_floor: 0.0 }.probs(40, Some(&old_lp));
        for (&s, &b) in scaled.iter().zip(&base) {
            assert!(s > 0.0 && s <= 1.0);
            assert!(s <= b + 1e-7);
        }
        let up = Saliency { floor: 0.3, scale: 10.0, pi_floor: 0.0 }.probs(40, Some(&old_lp));
        assert!(up.iter().all(|&p| (p - 1.0).abs() < 1e-6));
    }

    #[test]
    fn scaled_draws_stay_ht_unbiased() {
        // Σ w_t must average to t_i under ANY scale — the controller's
        // correctness hinges on this.
        let old_lp: Vec<f32> = (0..40).map(|t| -0.2 - 0.1 * (t % 7) as f32).collect();
        let mut rng = Rng::new(10);
        for scale in [0.5, 1.0, 1.7] {
            let sel = Saliency { floor: 0.3, scale, pi_floor: 0.0 };
            let n = 30_000;
            let mut acc = 0.0f64;
            for _ in 0..n {
                let plan = sel.sample(40, Some(&old_lp), &mut rng);
                acc += plan.ht_w.iter().map(|&w| w as f64).sum::<f64>();
                assert!(plan.learn_len >= 1 && plan.learn_len <= 40);
            }
            let mean = acc / n as f64;
            assert!((mean - 40.0).abs() < 0.5, "scale {scale}: {mean}");
        }
    }

    #[test]
    fn pi_floor_bounds_scaled_probabilities_and_weights() {
        let old_lp: Vec<f32> = (0..48).map(|t| -0.1 - 0.15 * (t % 5) as f32).collect();
        // a crushing down-scale would send probabilities toward 0; the
        // guard pins them at pi_floor so 1/π stays ≤ 1/pi_floor
        let sel = Saliency { floor: 0.25, scale: 1e-9, pi_floor: 1e-3 };
        let p = sel.probs(48, Some(&old_lp));
        assert!(p.iter().all(|&x| x >= 1e-3 - 1e-9 && x <= 1.0), "{p:?}");
        let mut rng = Rng::new(12);
        for _ in 0..200 {
            let plan = sel.sample(48, Some(&old_lp), &mut rng);
            for &w in &plan.ht_w {
                assert!(w as f64 <= 1.0 / 1e-3 * (1.0 + 1e-6), "runaway weight {w}");
            }
        }
        // guard off reproduces the legacy (tiny-but-positive) behaviour
        let legacy = Saliency { floor: 0.25, scale: 1e-9, pi_floor: 0.0 };
        assert!(legacy.probs(48, Some(&old_lp)).iter().all(|&x| x > 0.0 && x < 1e-3));
    }

    #[test]
    fn keeps_surprising_tokens_more_often() {
        let mut old_lp = vec![-0.05f32; 30];
        old_lp[7] = -6.0; // one very surprising token
        let sel = Saliency::new(0.2);
        let mut rng = Rng::new(11);
        let (mut kept7, mut kept0) = (0, 0);
        for _ in 0..2000 {
            let plan = sel.sample(30, Some(&old_lp), &mut rng);
            if plan.ht_w[7] > 0.0 {
                kept7 += 1;
            }
            if plan.ht_w[0] > 0.0 {
                kept0 += 1;
            }
        }
        assert!(kept7 > 1950, "{kept7}");
        assert!(kept0 < 600, "{kept0}");
    }
}
