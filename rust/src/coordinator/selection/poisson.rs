//! Length-aware Poisson sampling: independent Bernoulli with per-token
//! rate min(1, k / T), so every sequence contributes ~k selected tokens
//! *regardless of its length* (HT weight T/k on long sequences). Where URS
//! thins every response by the same factor — long chains of thought still
//! dominate the step's selected-token mass — the length-aware rate
//! equalises per-sequence contribution, which is also what makes it the
//! natural scheme for the batch budget controller: the expected step cost
//! is just k × (number of non-empty sequences), independent of the length
//! distribution.
//!
//! `k` is f64 so the controller can solve it exactly (a fractional rate is
//! perfectly valid Poisson sampling); the `--method poisson --method.k N`
//! literal is an integer.

use super::{pi_w32, tail_learn_len, SelectionPlan, Selector};
use crate::util::rng::Rng;

pub struct Poisson {
    pub k: f64,
}

impl Poisson {
    fn rate(&self, t_i: usize) -> f64 {
        (self.k / t_i as f64).min(1.0)
    }
}

impl Selector for Poisson {
    fn label(&self) -> String {
        format!("poisson(k={})", self.k)
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        vec![pi_w32(self.rate(t_i)).0; t_i]
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        self.rate(t_i) * t_i as f64
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        let rate = self.rate(t_i);
        let (pi, w) = pi_w32(rate);
        let mut ht_w = vec![0.0f32; t_i];
        let mut kept = 0;
        let mut last_kept = 0usize;
        for (t, slot) in ht_w.iter_mut().enumerate() {
            if rng.bernoulli(rate) {
                *slot = w;
                kept += 1;
                last_kept = t + 1;
            }
        }
        SelectionPlan {
            probs: vec![pi; t_i],
            ht_w,
            kept,
            learn_len: tail_learn_len(last_kept),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_sequences_keep_everything_long_ones_thin_to_k() {
        let sel = Poisson { k: 8.0 };
        let mut rng = Rng::new(30);
        // t <= k: rate 1, every token kept
        let plan = sel.sample(5, None, &mut rng);
        assert_eq!(plan.kept, 5);
        assert!(plan.ht_w.iter().all(|&w| w == 1.0));
        // t >> k: expected kept ≈ k with weight t/k
        assert!((sel.expected_kept(64, None) - 8.0).abs() < 1e-9);
        let n = 20_000;
        let mut acc = 0usize;
        for _ in 0..n {
            let p = sel.sample(64, None, &mut rng);
            acc += p.kept;
            for &w in &p.ht_w {
                assert!(w == 0.0 || (w - 8.0).abs() < 1e-6); // 64/8
            }
        }
        let mean = acc as f64 / n as f64;
        assert!((mean - 8.0).abs() < 0.1, "{mean}");
    }

    #[test]
    fn ht_weight_sums_are_unbiased_across_lengths() {
        let sel = Poisson { k: 6.0 };
        let mut rng = Rng::new(31);
        for t_i in [3usize, 10, 40, 120] {
            let n = 20_000;
            let mut acc = 0.0f64;
            for _ in 0..n {
                acc += sel
                    .sample(t_i, None, &mut rng)
                    .ht_w
                    .iter()
                    .map(|&w| w as f64)
                    .sum::<f64>();
            }
            let mean = acc / n as f64;
            let tol = (t_i as f64 * 0.02).max(0.2);
            assert!((mean - t_i as f64).abs() < tol, "t={t_i}: {mean}");
        }
    }

    #[test]
    fn fractional_k_is_valid() {
        let sel = Poisson { k: 2.5 };
        assert!((sel.expected_kept(10, None) - 2.5).abs() < 1e-12);
        let mut rng = Rng::new(32);
        let plan = sel.sample(10, None, &mut rng);
        assert_eq!(plan.probs.len(), 10);
        assert!(plan.probs.iter().all(|&p| (p - 0.25).abs() < 1e-6));
    }
}
