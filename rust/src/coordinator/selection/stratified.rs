//! Systematic (stratified) sampling at rate p — variance reduction over
//! URS at equal estimator cost.
//!
//! One uniform offset u ~ U[0, 1) per sequence places an equally-spaced
//! grid over the cumulative rate: token t (0-based) is selected iff
//! ⌊p·(t+1) + u⌋ > ⌊p·t + u⌋. Every token's marginal inclusion probability
//! is exactly p (so the HT weight is the same 1/p as URS and the estimator
//! is identically unbiased), but the realized sample size is pinned to
//! ⌊p·T⌋ or ⌈p·T⌉ — the Bernoulli sampling noise of URS's kept-count
//! (variance T·p·(1-p)) collapses to at most 1/4. Host cost is *lower*
//! than URS: one RNG draw per sequence instead of T.

use super::{pi_w32, tail_learn_len, SelectionPlan, Selector};
use crate::util::rng::Rng;

/// One systematic-grid draw at rate `p` over `t_i` tokens: a single uniform
/// offset places the equally-spaced grid, marginal inclusion is exactly `p`
/// and the kept count is pinned to ⌊p·t_i⌋ or ⌈p·t_i⌉. Shared by
/// [`Stratified`] (one rate per scheme) and the per-sequence Neyman
/// allocation ([`super::neyman`], one rate per row), so their draw streams
/// are bit-identical at equal rates.
pub(crate) fn systematic_plan(p: f64, t_i: usize, rng: &mut Rng) -> SelectionPlan {
    let u = rng.uniform();
    let (pi, w) = pi_w32(p);
    let mut ht_w = vec![0.0f32; t_i];
    let mut kept = 0;
    let mut last_kept = 0usize;
    // ⌊p·0 + u⌋ = 0 because u ∈ [0, 1).
    let mut prev = 0.0f64;
    for (t, slot) in ht_w.iter_mut().enumerate() {
        let cum = (p * (t + 1) as f64 + u).floor();
        if cum > prev {
            *slot = w;
            kept += 1;
            last_kept = t + 1;
        }
        prev = cum;
    }
    SelectionPlan { probs: vec![pi; t_i], ht_w, kept, learn_len: tail_learn_len(last_kept) }
}

pub struct Stratified {
    pub p: f64,
}

impl Selector for Stratified {
    fn label(&self) -> String {
        format!("stratified(p={})", self.p)
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        vec![pi_w32(self.p).0; t_i]
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        self.p * t_i as f64
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        systematic_plan(self.p, t_i, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_size_is_pinned_to_floor_or_ceil() {
        let mut rng = Rng::new(20);
        for &(t_i, p) in &[(100usize, 0.35f64), (64, 0.5), (200, 0.13), (7, 0.9)] {
            let lo = (p * t_i as f64).floor() as usize;
            let hi = (p * t_i as f64).ceil() as usize;
            for _ in 0..200 {
                let plan = Stratified { p }.sample(t_i, None, &mut rng);
                assert!(
                    plan.kept == lo || plan.kept == hi,
                    "t={t_i} p={p}: kept {} not in {{{lo},{hi}}}",
                    plan.kept
                );
            }
        }
    }

    #[test]
    fn marginal_inclusion_is_exactly_p() {
        // Monte-Carlo check of the HT premise E[m_t] = p for every position.
        let (t_i, p, n) = (30usize, 0.4f64, 40_000);
        let mut rng = Rng::new(21);
        let mut counts = vec![0u32; t_i];
        for _ in 0..n {
            let plan = Stratified { p }.sample(t_i, None, &mut rng);
            for (t, &w) in plan.ht_w.iter().enumerate() {
                if w > 0.0 {
                    counts[t] += 1;
                }
            }
        }
        for (t, &c) in counts.iter().enumerate() {
            let hat = c as f64 / n as f64;
            assert!((hat - p).abs() < 0.02, "t={t}: {hat} vs {p}");
        }
    }

    #[test]
    fn ht_weight_sums_are_unbiased() {
        let (t_i, p) = (50usize, 0.3f64);
        let mut rng = Rng::new(22);
        let n = 30_000;
        let mut acc = 0.0f64;
        for _ in 0..n {
            acc += Stratified { p }
                .sample(t_i, None, &mut rng)
                .ht_w
                .iter()
                .map(|&w| w as f64)
                .sum::<f64>();
        }
        let mean = acc / n as f64;
        assert!((mean - t_i as f64).abs() < 0.2, "{mean}");
    }

    #[test]
    fn p_one_keeps_every_token_and_one_draw_is_consumed() {
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        let plan = Stratified { p: 1.0 }.sample(40, None, &mut a);
        assert_eq!(plan.kept, 40);
        assert_eq!(plan.learn_len, 40);
        b.uniform();
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
