//! Deterministic prefix truncation — the paper's *biased* baseline: keep
//! the first ⌊frac·T⌋ tokens with weight 1 and drop the suffix outright.
//! No HT correction exists (inclusion probability 0 on the suffix), which
//! is exactly the bias the unbiased schemes are measured against. Consumes
//! no RNG draws.

use super::{SelectionPlan, Selector};
use crate::util::rng::Rng;

pub struct DetTrunc {
    pub frac: f64,
}

impl DetTrunc {
    fn cut(&self, t_i: usize) -> usize {
        ((self.frac * t_i as f64).floor() as usize).clamp(1, t_i)
    }
}

impl Selector for DetTrunc {
    fn label(&self) -> String {
        format!("det_trunc(frac={})", self.frac)
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        let k = self.cut(t_i);
        let mut p = vec![0.0f32; t_i];
        for slot in p.iter_mut().take(k) {
            *slot = 1.0;
        }
        p
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        self.cut(t_i) as f64
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, _rng: &mut Rng) -> SelectionPlan {
        let k = self.cut(t_i);
        let mut ht_w = vec![0.0f32; t_i];
        for slot in ht_w.iter_mut().take(k) {
            *slot = 1.0; // no HT correction exists: p = 0 on the suffix
        }
        SelectionPlan { probs: self.probs(t_i, None), ht_w, kept: k, learn_len: k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_deterministic_prefix() {
        let mut rng = Rng::new(3);
        let a = DetTrunc { frac: 0.5 }.sample(101, None, &mut rng);
        let b = DetTrunc { frac: 0.5 }.sample(101, None, &mut rng);
        assert_eq!(a.kept, 50);
        assert_eq!(a.learn_len, 50);
        assert_eq!(a.ht_w, b.ht_w);
        assert!(a.ht_w[..50].iter().all(|&w| w == 1.0));
        assert!(a.ht_w[50..].iter().all(|&w| w == 0.0));
        // the suffix has zero inclusion probability — the documented bias
        assert!(a.probs[50..].iter().all(|&p| p == 0.0));
        assert_eq!(DetTrunc { frac: 0.5 }.expected_kept(101, None), 50.0);
    }
}
