//! Uniform Random Sampling: independent Bernoulli(p) per token, HT weight
//! 1/p (paper §3). The draw loop is byte-for-byte the legacy
//! `masking::sample_ctx` URS arm — exactly one `bernoulli(p)` draw per
//! token, with `p` kept in f64 — so mask streams are bit-identical across
//! the refactor (proptested in `tests/selection.rs`).

use super::{pi_w32, tail_learn_len, SelectionPlan, Selector};
use crate::util::rng::Rng;

pub struct Urs {
    pub p: f64,
}

impl Selector for Urs {
    fn label(&self) -> String {
        format!("urs(p={})", self.p)
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        vec![pi_w32(self.p).0; t_i]
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        self.p * t_i as f64
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        let (pi, w) = pi_w32(self.p);
        let mut ht_w = vec![0.0f32; t_i];
        let mut kept = 0;
        let mut last_kept = 0usize;
        for (t, slot) in ht_w.iter_mut().enumerate() {
            if rng.bernoulli(self.p) {
                *slot = w;
                kept += 1;
                last_kept = t + 1;
            }
        }
        // Causal attention only needs the prefix up to the last *scored*
        // token. In expectation this is close to t_i for moderate p — URS
        // keeps near-full forward cost, as the paper notes — but the
        // realised tail savings are real and let short draws land in
        // smaller buckets.
        SelectionPlan {
            probs: vec![pi; t_i],
            ht_w,
            kept,
            learn_len: tail_learn_len(last_kept),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_is_inverse_p_and_learn_len_stops_at_last_kept() {
        let mut rng = Rng::new(1);
        let plan = Urs { p: 0.25 }.sample(200, None, &mut rng);
        let last = plan.ht_w.iter().rposition(|&w| w > 0.0).map(|t| t + 1).unwrap_or(0);
        assert_eq!(plan.learn_len, last.max(1));
        for &w in &plan.ht_w {
            assert!(w == 0.0 || (w - 4.0).abs() < 1e-6);
        }
        assert_eq!(plan.kept, plan.ht_w.iter().filter(|&&w| w > 0.0).count());
        assert!((Urs { p: 0.25 }.expected_kept(200, None) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn consumes_exactly_t_draws() {
        let sel = Urs { p: 0.3 };
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        sel.sample(64, None, &mut a);
        for _ in 0..64 {
            b.bernoulli(0.3);
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
