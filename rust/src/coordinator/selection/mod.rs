//! First-class token-selection subsystem — NAT's core primitive, promoted
//! from a single enum-matched file into a pluggable architecture.
//!
//! Every scheme implements [`Selector`]: given a response length (and, for
//! information-aware schemes, the behaviour logprobs) it can report its
//! per-token **inclusion probabilities** and draw a [`SelectionPlan`] — the
//! realized Horvitz-Thompson weights `w_t = m_t / p_t`, the kept count, and
//! the `learn_len` forward prefix the batcher packs on. Keeping the
//! probabilities in the plan (not just the realized weights) is what makes
//! the subsystem composable: the batch-level budget controller
//! ([`budget`]) can *re-solve* a scheme's keep parameter against the
//! batch's actual length distribution and the estimator stays exactly
//! unbiased, because the weights are always `1 / (probability actually
//! sampled with)`.
//!
//! Scheme modules (one per file):
//!
//! * [`full`]       — GRPO baseline: every token, weight 1.
//! * [`urs`]        — uniform Bernoulli(p), weight 1/p.
//! * [`det_trunc`]  — deterministic prefix truncation (biased baseline).
//! * [`rpc`]        — random prefix cutting with survival-probability HT
//!                    weights (the paper's headline scheme).
//! * [`saliency`]   — behaviour-surprisal-proportional inclusion (§7).
//! * [`stratified`] — systematic sampling: URS's marginals with a fixed
//!                    realized sample size (variance reduction at equal —
//!                    actually lower — host cost).
//! * [`poisson`]    — length-aware Poisson rates: ~k selected tokens per
//!                    sequence regardless of length.
//! * [`budget`]     — the batch-level adaptive token-budget controller
//!                    (`--train.budget_mode batch`).
//! * [`neyman`]     — variance-optimal per-sequence budget allocation
//!                    (`--train.budget_mode neyman`, selection v2): rates
//!                    `∝ |advantage| × surprisal`, floored at
//!                    `--train.pi_floor`, drawn by within-sequence
//!                    systematic sampling.
//!
//! The legacy `coordinator::masking` API (`sample_ctx` et al.) is a thin
//! shim over this module; its RNG streams are bit-identical to the
//! pre-refactor implementation (proptested against a frozen copy in
//! `tests/selection.rs`).

pub mod budget;
pub mod det_trunc;
pub mod full;
pub mod neyman;
pub mod poisson;
pub mod rpc;
pub mod saliency;
pub mod stratified;
pub mod urs;

pub use budget::{solve_batch, BudgetOutcome};
pub use det_trunc::DetTrunc;
pub use full::Full;
pub use neyman::{solve_neyman, NeymanAllocation};
pub use poisson::Poisson;
pub use rpc::Rpc;
pub use saliency::Saliency;
pub use stratified::Stratified;
pub use urs::Urs;

use crate::config::Method;
use crate::util::rng::Rng;

/// Quantize an inclusion probability for the f32 artifact boundary: returns
/// `(π, w) = (p as f32, (1/p) as f32)` — THE one blessed rounding point for
/// rate-style schemes (`nat lint` rule R6 flags any other `as f32` in
/// selection code). Both values round from the same f64 `p`, so a plan's
/// `probs` and `ht_w` can never disagree about which probability was
/// sampled with; bit-identical to the historical per-site casts it
/// replaced.
pub fn pi_w32(p: f64) -> (f32, f32) {
    // natlint: allow(lossy-cast, reason = "the single blessed quantization point: f64->f32 rounding happens once here, HT math upstream stays in f64")
    (p as f32, (1.0 / p) as f32)
}

/// The shared solve-clamp floor (`--train.pi_floor`): every budget-solved
/// inclusion probability is clamped to at least this value *before*
/// quantization through [`pi_w32`], and sampling uses the floored
/// probability — so the estimator stays exactly HT-unbiased while every
/// realized weight is `≤ 1/pi_floor` by construction. With the guard off
/// (`pi_floor == 0`) the historical per-solve tiny clamp applies instead:
/// enough to keep 1/π finite, not enough to stop an unattainably low
/// `--train.token_budget` from minting ~1e6+ f32 HT weights.
pub fn solve_floor(pi_floor: f64, legacy_tiny: f64) -> f64 {
    if pi_floor > 0.0 {
        pi_floor
    } else {
        legacy_tiny
    }
}

/// One sampled selection for one response: the per-token inclusion
/// probabilities that were *actually used* to draw the mask, the realized
/// HT weights, and the forward prefix the learner must process.
#[derive(Clone, Debug)]
pub struct SelectionPlan {
    /// Inclusion probability per token over 0..t_i (the HT denominators).
    /// For the biased DetTrunc baseline the suffix is 0.0 — no unbiased
    /// weight exists there, which is exactly its documented bias.
    pub probs: Vec<f32>,
    /// HT weights over 0..t_i (0.0 = excluded from the update).
    pub ht_w: Vec<f32>,
    /// Number of selected tokens.
    pub kept: usize,
    /// Forward prefix length the learner must process (<= t_i).
    pub learn_len: usize,
}

impl SelectionPlan {
    /// The degenerate plan for an empty response.
    pub fn empty() -> SelectionPlan {
        SelectionPlan { probs: Vec::new(), ht_w: Vec::new(), kept: 0, learn_len: 0 }
    }

    /// Expected selected-token count under this plan's probabilities.
    pub fn expected_kept(&self) -> f64 {
        self.probs.iter().map(|&p| p as f64).sum()
    }

    pub fn selected_ratio(&self) -> f64 {
        if self.ht_w.is_empty() {
            0.0
        } else {
            self.kept as f64 / self.ht_w.len() as f64
        }
    }

    /// Dense gather index list: the original response positions of the kept
    /// tokens, ascending. This is the compacted grad layout's packing key —
    /// `grad_K<k>_B<r>` micro-batches gather token/logprob/weight rows
    /// through these indices and scatter gradients back by position.
    pub fn gather_indices(&self) -> Vec<usize> {
        (0..self.ht_w.len()).filter(|&t| self.ht_w[t] != 0.0).collect()
    }

    /// True when the kept set is a contiguous prefix `0..kept` (GRPO /
    /// DetTrunc / RPC shapes) — such plans stay on the legacy prefix grid
    /// because compaction cannot shrink them.
    pub fn is_prefix_shaped(&self) -> bool {
        self.ht_w[..self.kept.min(self.ht_w.len())].iter().all(|&w| w != 0.0)
    }
}

/// A pluggable token-selection scheme.
///
/// Implementations must keep `draw` a deterministic function of
/// `(self, t_i, ctx, rng)` with a *fixed RNG draw pattern* per `(scheme,
/// t_i)` — the trainer derives mask streams from `(seed, step)` and every
/// replay/resume/parity guarantee rides on the draw count never depending
/// on the realized mask.
pub trait Selector: Send + Sync {
    /// Human-readable label (diagnostics only).
    fn label(&self) -> String;

    /// Per-token inclusion probabilities for a length-`t_i` response.
    /// `ctx` carries the behaviour logprobs over 0..t_i where available
    /// (required by information-aware schemes).
    fn probs(&self, t_i: usize, ctx: Option<&[f32]>) -> Vec<f32>;

    /// Closed-form expected selected-token count (exact, f64 — the budget
    /// controller's solve target).
    fn expected_kept(&self, t_i: usize, ctx: Option<&[f32]>) -> f64;

    /// Draw one selection for `t_i >= 1` (implementations may assume a
    /// non-empty response; use [`Selector::sample`] from call sites).
    fn draw(&self, t_i: usize, ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan;

    /// Guarded entry point: a degenerate empty response (`trim_at_eos`
    /// floors real rollouts at 1, but a zero-width response window can
    /// produce 0) yields the empty plan WITHOUT consuming any RNG draws, so
    /// the mask stream stays aligned with the non-degenerate case.
    fn sample(&self, t_i: usize, ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        if t_i == 0 {
            SelectionPlan::empty()
        } else {
            self.draw(t_i, ctx, rng)
        }
    }
}

/// The selector configured by a [`Method`] literal (no budget adaptation —
/// see [`budget::solve_batch`] for the batch-controlled variant).
pub fn selector_for(method: &Method) -> Box<dyn Selector> {
    match *method {
        Method::Grpo => Box::new(Full),
        Method::Urs { p } => Box::new(Urs { p }),
        Method::DetTrunc { frac } => Box::new(DetTrunc { frac }),
        Method::Rpc { min_cut } => Box::new(Rpc { min_cut }),
        Method::Saliency { floor } => Box::new(Saliency::new(floor)),
        Method::Stratified { p } => Box::new(Stratified { p }),
        Method::Poisson { k } => Box::new(Poisson { k: k as f64 }),
    }
}

/// Expected selected-token ratio (paper Fig. 3 prediction), in the exact
/// closed forms the legacy `masking::expected_ratio` promised (RPC with
/// minimum cutoff keeps E[L]/T = 1/2 + C/(2T)).
///
/// **Saliency caveat:** its true expectation depends on the realised
/// surprisal profile, which this ctx-less form does not have — the `floor`
/// returned here is a *lower bound*, not the inclusion probability. Callers
/// holding the behaviour logprobs should use [`expected_ratio_ctx`], which
/// is exact for every scheme.
pub fn expected_ratio(method: &Method, t_i: usize) -> f64 {
    match *method {
        Method::Grpo => 1.0,
        Method::Urs { p } | Method::Stratified { p } => p,
        Method::DetTrunc { frac } => ((frac * t_i as f64).floor().max(1.0)) / t_i as f64,
        Method::Rpc { min_cut } => {
            let c = min_cut.clamp(1, t_i) as f64;
            let t = t_i as f64;
            (c + t) / (2.0 * t)
        }
        // lower bound only — see the doc caveat / expected_ratio_ctx
        Method::Saliency { floor } => floor,
        Method::Poisson { k } => (k as f64 / t_i as f64).min(1.0),
    }
}

/// Honest expected selected-token ratio: identical to [`expected_ratio`]
/// for the closed-form schemes, but uses the realised surprisal profile for
/// Saliency when `ctx` carries the behaviour logprobs — matching what the
/// `budget_realized` accounting actually sums (`Selector::expected_kept`).
pub fn expected_ratio_ctx(method: &Method, t_i: usize, ctx: Option<&[f32]>) -> f64 {
    if t_i == 0 {
        return 0.0;
    }
    match (method, ctx) {
        (&Method::Saliency { floor }, Some(lp)) => {
            Saliency::new(floor).expected_kept(t_i, Some(lp)) / t_i as f64
        }
        _ => expected_ratio(method, t_i),
    }
}

/// Running Horvitz-Thompson weight diagnostics over a step's realized
/// selection plans — the ledger's `ht_w_max` / `ht_ess` inputs and the
/// raw material for the ROADMAP's variance-optimal-allocation item.
///
/// `ess()` is the standard importance-sampling effective sample size
/// (Σw)²/Σw²: it equals the kept count when all weights agree (GRPO,
/// stratified at fixed p) and collapses toward 1 when a few tokens carry
/// extreme 1/π weights — exactly the degeneracy the budget controller must
/// not be allowed to hide.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HtMoments {
    /// Largest realized HT weight (max 1/π over kept tokens).
    pub w_max: f64,
    /// Σ w over kept tokens.
    pub w_sum: f64,
    /// Σ w² over kept tokens.
    pub w2_sum: f64,
    /// Kept-token count observed.
    pub kept: u64,
}

impl HtMoments {
    /// Fold one realized plan's kept-token weights into the moments.
    pub fn observe(&mut self, plan: &SelectionPlan) {
        for &w in &plan.ht_w {
            if w > 0.0 {
                let w = w as f64;
                self.w_max = self.w_max.max(w);
                self.w_sum += w;
                self.w2_sum += w * w;
                self.kept += 1;
            }
        }
    }

    /// Effective sample size (Σw)²/Σw²; 0 when nothing was kept.
    pub fn ess(&self) -> f64 {
        if self.w2_sum > 0.0 {
            self.w_sum * self.w_sum / self.w2_sum
        } else {
            0.0
        }
    }
}

/// Shared tail bookkeeping for independent-masking schemes (URS, Saliency,
/// Poisson, Stratified): causal attention only needs the prefix up to the
/// last *scored* token, floored at 1 so empty draws still produce a valid
/// artifact shape.
pub(crate) fn tail_learn_len(last_kept: usize) -> usize {
    last_kept.max(1)
}

/// The selection bench/test workload: one deterministic population shared
/// by `benches/bench_selection.rs` (which writes `BENCH_selection.json`)
/// and the tier-1 budget-controller gate in `tests/selection.rs`, so the
/// perf record and the CI assertion describe the same workload — the
/// `shard_workload` pattern, selection-side.
pub mod bench_workload {
    use crate::coordinator::rollout::RolloutSeq;
    use crate::tokenizer::PAD;
    use crate::util::rng::{xor_stream, Rng};

    pub const SEED: u64 = 0x5E1E_C701;

    /// Controller-level length population: 64 responses, RPC-shaped lengths
    /// in 1..=256 — large enough that RPC's integer-cut granularity
    /// (≤ n/2 tokens per cut step) stays well under the 2% budget gate.
    pub const N_LENS: usize = 64;
    pub const T_MAX: usize = 256;

    pub fn lens() -> Vec<usize> {
        let mut rng = Rng::new(SEED);
        (0..N_LENS).map(|_| 1 + rng.below(T_MAX as u64) as usize).collect()
    }

    /// Synthetic behaviour logprobs for a response of length `t` (the
    /// saliency controller's context), deterministic per (SEED, index).
    pub fn old_lp(idx: usize, t: usize) -> Vec<f32> {
        let mut rng = xor_stream(SEED, (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // natlint: allow(lossy-cast, reason = "synthetic bench logprobs, not HT quantities; precision is irrelevant to the workload shape")
        (0..t).map(|_| -0.02 - rng.uniform() as f32).collect()
    }

    /// End-to-end population for `learn_stage` on the sim runtime: 6 prompt
    /// groups × G=4 rollouts with varied lengths, logprobs, pads and binary
    /// rewards (group variance guaranteed by construction).
    pub const GROUPS: usize = 6;
    pub const GROUP_SIZE: usize = 4;

    pub fn seqs(prompt_len: usize, max_resp: usize) -> Vec<RolloutSeq> {
        let mut rng = Rng::new(SEED ^ 0x5EED);
        (0..GROUPS * GROUP_SIZE)
            .map(|flat| {
                let resp_len = 1 + rng.below(max_resp as u64) as usize;
                let mut tokens = vec![PAD; prompt_len + max_resp];
                for (i, slot) in tokens.iter_mut().enumerate().take(prompt_len) {
                    *slot = 3 + ((flat * 7 + i * 3) % 50) as i32;
                }
                for t in 0..resp_len {
                    tokens[prompt_len + t] = 3 + ((flat * 11 + t * 5) % 50) as i32;
                }
                let old_lp: Vec<f32> =
                    // natlint: allow(lossy-cast, reason = "synthetic bench logprobs, not HT quantities; precision is irrelevant to the workload shape")
                    (0..resp_len).map(|_| -0.02 - rng.uniform() as f32).collect();
                RolloutSeq {
                    task_idx: flat / GROUP_SIZE,
                    tokens,
                    pad_len: rng.below(8) as usize,
                    resp_len,
                    old_lp,
                    // alternate within each group so every group has reward
                    // variance (nonzero advantages)
                    reward: if flat % 2 == 0 { 1.0 } else { 0.0 },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selector_for_dispatches_every_method() {
        let methods = [
            Method::Grpo,
            Method::Urs { p: 0.5 },
            Method::DetTrunc { frac: 0.5 },
            Method::Rpc { min_cut: 8 },
            Method::Saliency { floor: 0.25 },
            Method::Stratified { p: 0.5 },
            Method::Poisson { k: 8 },
        ];
        let old_lp: Vec<f32> = (0..32).map(|t| -0.1 - 0.05 * (t % 9) as f32).collect();
        let mut rng = Rng::new(1);
        for m in methods {
            let sel = selector_for(&m);
            let plan = sel.sample(32, Some(&old_lp), &mut rng);
            assert_eq!(plan.probs.len(), 32, "{m:?}");
            assert_eq!(plan.ht_w.len(), 32, "{m:?}");
            assert!(plan.learn_len >= 1 && plan.learn_len <= 32, "{m:?}");
            assert_eq!(
                plan.kept,
                plan.ht_w.iter().filter(|&&w| w > 0.0).count(),
                "{m:?}"
            );
            // weights and probabilities are consistent: w_t = m_t / p_t
            for (t, (&w, &p)) in plan.ht_w.iter().zip(&plan.probs).enumerate() {
                if w > 0.0 {
                    assert!(p > 0.0, "{m:?} t={t}");
                    assert!((w - 1.0 / p).abs() < 1e-5, "{m:?} t={t}: {w} vs 1/{p}");
                }
            }
            assert!(!sel.label().is_empty());
            // guarded empty sample consumes no draws
            let before = rng.clone();
            let empty = sel.sample(0, Some(&[]), &mut rng);
            assert_eq!(empty.learn_len, 0);
            assert_eq!(empty.kept, 0);
            let mut a = before;
            assert_eq!(a.next_u64(), rng.clone().next_u64(), "{m:?} consumed draws at t=0");
        }
    }

    #[test]
    fn plan_expected_kept_sums_probs() {
        let plan = SelectionPlan {
            probs: vec![1.0, 0.5, 0.25],
            ht_w: vec![1.0, 2.0, 0.0],
            kept: 2,
            learn_len: 2,
        };
        assert!((plan.expected_kept() - 1.75).abs() < 1e-12);
        assert!((plan.selected_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(SelectionPlan::empty().expected_kept(), 0.0);
        assert_eq!(plan.gather_indices(), vec![0, 1]);
        assert!(plan.is_prefix_shaped());
        let scattered = SelectionPlan {
            probs: vec![0.5; 4],
            ht_w: vec![2.0, 0.0, 2.0, 0.0],
            kept: 2,
            learn_len: 3,
        };
        assert_eq!(scattered.gather_indices(), vec![0, 2]);
        assert!(!scattered.is_prefix_shaped());
        assert!(SelectionPlan::empty().is_prefix_shaped());
    }

    #[test]
    fn ht_moments_track_max_and_ess() {
        let mut m = HtMoments::default();
        assert_eq!(m.ess(), 0.0);
        // uniform weights: ESS == kept count
        m.observe(&SelectionPlan {
            probs: vec![0.5; 4],
            ht_w: vec![2.0, 2.0, 0.0, 2.0],
            kept: 3,
            learn_len: 4,
        });
        assert_eq!(m.kept, 3);
        assert_eq!(m.w_max, 2.0);
        assert!((m.ess() - 3.0).abs() < 1e-12);
        // one extreme weight drags ESS toward 1 and raises the max
        m.observe(&SelectionPlan {
            probs: vec![0.01],
            ht_w: vec![100.0],
            kept: 1,
            learn_len: 1,
        });
        assert_eq!(m.w_max, 100.0);
        assert_eq!(m.kept, 4);
        let ess = m.ess();
        assert!(ess > 1.0 && ess < 2.0, "ESS should collapse toward 1, got {ess}");
    }

    #[test]
    fn bench_workload_is_deterministic_and_nontrivial() {
        assert_eq!(bench_workload::lens(), bench_workload::lens());
        let lens = bench_workload::lens();
        assert_eq!(lens.len(), bench_workload::N_LENS);
        assert!(lens.iter().all(|&t| t >= 1 && t <= bench_workload::T_MAX));
        let total: usize = lens.iter().sum();
        assert!(total > 64, "degenerate workload: {total} tokens");
        let seqs = bench_workload::seqs(32, 16);
        assert_eq!(seqs.len(), bench_workload::GROUPS * bench_workload::GROUP_SIZE);
        for s in &seqs {
            assert!(s.resp_len >= 1 && s.resp_len <= 16);
            assert_eq!(s.old_lp.len(), s.resp_len);
            assert_eq!(s.tokens.len(), 32 + 16);
        }
        // every group mixes rewards → nonzero advantages
        for g in 0..bench_workload::GROUPS {
            let grp = &seqs[g * bench_workload::GROUP_SIZE..(g + 1) * bench_workload::GROUP_SIZE];
            assert!(grp.iter().any(|s| s.reward > 0.5) && grp.iter().any(|s| s.reward < 0.5));
        }
    }
}
