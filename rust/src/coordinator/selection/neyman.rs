//! Variance-optimal (Neyman) budget allocation across sequences
//! (`--train.budget_mode neyman`) — ROADMAP's "selection v2".
//!
//! The batch controller ([`super::budget`]) hits an *expected token count*
//! but says nothing about where the budget buys signal: every sequence gets
//! the same solved keep parameter. This module spends the budget where it
//! reduces estimator variance. Treat each sequence as a stratum sampled at
//! its own rate `p_i` with systematic (stratified-grid) sampling inside the
//! sequence; the HT gradient estimator's variance then decomposes as
//!
//! ```text
//!   Var ≈ Σ_i t_i · σ_i² · (1/p_i − 1)
//! ```
//!
//! where `σ_i` is the per-token contribution scale of sequence `i`.
//! Minimizing over the budget constraint `Σ_i t_i·p_i = B` (Lagrange /
//! Cauchy–Schwarz) gives the classic Neyman solution `p_i ∝ σ_i`, clamped
//! into `[π_floor, 1]`, with the multiplier `λ` re-solved by bisection so
//! the expected kept count still hits the budget wherever it is attainable.
//!
//! `σ_i` is estimated from data the rollout already produced: |advantage_i|
//! (every token's policy-gradient term carries the sequence advantage as a
//! factor) times the RMS behaviour surprisal `−log π_old` of the response —
//! the token-significance signal of PAPERS.md "Not All Tokens Matter" at
//! sequence granularity. Zero-advantage sequences carry no gradient; they
//! sit at the floor rate so every token keeps a positive inclusion
//! probability and the estimator stays unbiased for *any* integrand, not
//! just the gradient that happens to vanish there.
//!
//! Unbiasedness is inherited from the systematic draw: marginal inclusion
//! is exactly `p_i` and weights divide by the probability actually sampled
//! with, so E[Σ w_t x_t] = Σ x_t for any solved allocation (MC-verified
//! through the full pack → shard → reduce path in `tests/selection.rs`).
//! With the guard on, every solved rate is ≥ `π_floor`, so realized HT
//! weights are bounded by `1/π_floor` by construction.

use super::stratified::systematic_plan;
use super::{solve_floor, SelectionPlan};
use crate::util::rng::Rng;

/// The historical tiny clamp used when the π-floor guard is disabled
/// (`--train.pi_floor 0`): enough to keep 1/π finite, not enough to keep it
/// sane — the failure mode the guard exists to prevent.
const LEGACY_TINY: f64 = 1e-6;

/// Per-sequence contribution scale `σ_i = |adv_i| · rms(−log π_old)`.
/// Without a behaviour-logprob profile the surprisal factor defaults to 1,
/// degrading gracefully to an |advantage|-proportional allocation.
pub fn sigma(abs_adv: f64, old_lp: Option<&[f32]>) -> f64 {
    let rms = match old_lp {
        Some(lp) if !lp.is_empty() => {
            let ss: f64 = lp
                .iter()
                .map(|&l| {
                    let u = -(l as f64);
                    u * u
                })
                .sum();
            (ss / lp.len() as f64).sqrt()
        }
        _ => 1.0,
    };
    abs_adv.abs() * rms
}

/// The solved per-sequence allocation: one inclusion rate per input row,
/// aligned with the `rows` slice passed to [`solve_neyman`].
pub struct NeymanAllocation {
    /// Solved inclusion rate per row (f64 — quantized once through
    /// `pi_w32` at draw time). Zero-length rows carry the floor rate but
    /// never sample.
    rates: Vec<f64>,
    lens: Vec<usize>,
    /// The requested expected-selected-token target.
    pub target: f64,
    /// Achieved expectation `Σ_i t_i·p_i` (== target when attainable).
    pub expected: f64,
    /// The effective floor every rate was clamped to (`--train.pi_floor`,
    /// or the legacy tiny clamp when the guard is off).
    pub floor: f64,
    /// The solved Neyman multiplier (`p_i = clamp(λ·σ_i, floor, 1)`).
    pub lambda: f64,
}

impl NeymanAllocation {
    /// The solved rate for row `i` (0.0 for an out-of-range index — such a
    /// row was never part of the solve and must not be sampled).
    pub fn rate(&self, i: usize) -> f64 {
        self.rates.get(i).copied().unwrap_or(0.0)
    }

    /// Expected kept tokens for row `i`.
    pub fn expected_kept(&self, i: usize) -> f64 {
        self.rate(i) * self.lens.get(i).copied().unwrap_or(0) as f64
    }

    /// Achieved batch expectation (the `budget_realized` input).
    pub fn expected_sum(&self) -> f64 {
        self.expected
    }

    /// Draw row `i`'s selection: one systematic-grid pass at the solved
    /// rate — exactly one uniform RNG draw per non-empty row (bit-identical
    /// to [`super::Stratified`] at an equal rate), zero draws for an empty
    /// row, so mask streams stay aligned across replay/resume/sharding.
    pub fn sample_row(&self, i: usize, t_i: usize, rng: &mut Rng) -> SelectionPlan {
        debug_assert_eq!(Some(&t_i), self.lens.get(i), "allocation/row misalignment");
        if t_i == 0 {
            SelectionPlan::empty()
        } else {
            systematic_plan(self.rate(i), t_i, rng)
        }
    }

    /// Solve bookkeeping as trace args, mirroring
    /// [`super::BudgetOutcome::trace_args`].
    pub fn trace_args(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("budget_target", self.target),
            ("budget_expected", self.expected),
            ("adapted", 1.0),
        ]
    }

    pub fn label(&self) -> String {
        format!("neyman(lambda={}, floor={})", self.lambda, self.floor)
    }
}

/// Solve the variance-optimal allocation: rates `p_i = clamp(λ·σ_i, pf, 1)`
/// with `λ` bisected so `Σ t_i·p_i` hits `budget`. `rows` carries
/// `(resp_len, behaviour logprobs)` and `abs_adv` the per-sequence
/// |advantage|, both in rollout order. Targets below `pf·Σt` or above the
/// reachable maximum clamp to the nearest endpoint (reported in
/// `expected`, like the batch controller's attainability contract).
pub fn solve_neyman(
    rows: &[(usize, Option<&[f32]>)],
    abs_adv: &[f64],
    budget: usize,
    pi_floor: f64,
) -> NeymanAllocation {
    let pf = solve_floor(pi_floor, LEGACY_TINY);
    let target = budget as f64;
    let lens: Vec<usize> = rows.iter().map(|&(t, _)| t).collect();
    let sig: Vec<f64> = rows
        .iter()
        .zip(abs_adv.iter().chain(std::iter::repeat(&0.0)))
        .map(|(&(_, ctx), &a)| sigma(a, ctx))
        .collect();
    // Expected kept count at multiplier λ — monotone non-decreasing, so a
    // doubling search brackets the root and bisection pins it.
    let g = |lambda: f64| -> f64 {
        lens.iter()
            .zip(&sig)
            .filter(|&(&t, _)| t > 0)
            .map(|(&t, &s)| t as f64 * (lambda * s).clamp(pf, 1.0))
            .sum()
    };
    // Reachable band: [g(0), g(∞)] — zero-σ rows never leave the floor.
    let reach_max: f64 = lens
        .iter()
        .zip(&sig)
        .filter(|&(&t, _)| t > 0)
        .map(|(&t, &s)| t as f64 * if s > 0.0 { 1.0 } else { pf })
        .sum();
    let lambda = if target <= g(0.0) {
        0.0
    } else if target >= reach_max {
        f64::MAX
    } else {
        let mut hi = 1.0f64;
        while g(hi) < target && hi < 1e30 {
            hi *= 2.0;
        }
        let mut lo = 0.0f64;
        for _ in 0..128 {
            let mid = 0.5 * (lo + hi);
            if g(mid) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    };
    let rates: Vec<f64> =
        sig.iter().map(|&s| (lambda * s).clamp(pf, 1.0)).collect();
    let expected: f64 = lens
        .iter()
        .zip(&rates)
        .filter(|&(&t, _)| t > 0)
        .map(|(&t, &p)| t as f64 * p)
        .sum();
    NeymanAllocation { rates, lens, target, expected, floor: pf, lambda }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_of(lens: &[usize]) -> Vec<(usize, Option<&'static [f32]>)> {
        lens.iter().map(|&t| (t, None)).collect()
    }

    #[test]
    fn rates_lie_in_floor_one_and_hit_attainable_budgets() {
        let lens = [10usize, 20, 30, 40];
        let advs = [0.2f64, 1.0, 0.5, 1.5];
        let rows = rows_of(&lens);
        for budget in [20usize, 40, 60, 90] {
            let alloc = solve_neyman(&rows, &advs, budget, 1e-3);
            for i in 0..lens.len() {
                let p = alloc.rate(i);
                assert!((1e-3..=1.0).contains(&p), "budget {budget} row {i}: {p}");
            }
            assert!(
                (alloc.expected - budget as f64).abs() < 1e-6 * budget as f64,
                "budget {budget}: expected {}",
                alloc.expected
            );
        }
    }

    #[test]
    fn higher_sigma_rows_get_higher_rates() {
        let lens = [25usize; 4];
        let advs = [0.1f64, 0.4, 0.9, 1.6];
        let alloc = solve_neyman(&rows_of(&lens), &advs, 40, 1e-3);
        for i in 1..4 {
            assert!(
                alloc.rate(i) >= alloc.rate(i - 1) - 1e-12,
                "rates not monotone in sigma: {} vs {}",
                alloc.rate(i),
                alloc.rate(i - 1)
            );
        }
        assert!(alloc.rate(3) > alloc.rate(0));
    }

    #[test]
    fn zero_sigma_rows_sit_at_the_floor_and_unattainable_targets_clamp() {
        let lens = [10usize, 20, 30];
        let advs = [0.0f64, 1.0, 1.0];
        let alloc = solve_neyman(&rows_of(&lens), &advs, 40, 1e-2);
        assert_eq!(alloc.rate(0), 1e-2);
        assert!(alloc.rate(1) > 1e-2 && alloc.rate(2) > 1e-2);
        // above the reachable maximum (σ>0 rows saturate at 1, σ=0 stays
        // at the floor): clamp and report
        let alloc = solve_neyman(&rows_of(&lens), &advs, 1000, 1e-2);
        assert_eq!(alloc.rate(0), 1e-2);
        assert_eq!(alloc.rate(1), 1.0);
        assert_eq!(alloc.rate(2), 1.0);
        assert!((alloc.expected - (0.1 + 50.0)).abs() < 1e-9);
        // below the floor cost: every rate pinned at the floor
        let alloc = solve_neyman(&rows_of(&lens), &advs, 0, 1e-2);
        assert!((0..3).all(|i| alloc.rate(i) == 1e-2));
        assert!(alloc.expected > 0.0);
    }

    #[test]
    fn allocation_is_variance_optimal_vs_uniform_at_equal_cost() {
        // The Neyman objective Σ t·σ²·(1/p − 1) must not exceed the
        // uniform allocation's at the same expected cost (uniform is
        // feasible for the same constraint set, so optimality is testable
        // as a deterministic inequality).
        let lens = [12usize, 48, 31, 80, 5, 64];
        let advs = [1.4f64, 0.3, 0.0, 0.9, 2.0, 0.6];
        let total: usize = lens.iter().sum();
        let budget = total * 2 / 5;
        let alloc = solve_neyman(&rows_of(&lens), &advs, budget, 1e-3);
        let u = budget as f64 / total as f64;
        assert!(u >= 1e-3, "uniform rate must be feasible for the comparison");
        let var = |rates: &dyn Fn(usize) -> f64| -> f64 {
            lens.iter()
                .enumerate()
                .map(|(i, &t)| {
                    let s = advs[i];
                    t as f64 * s * s * (1.0 / rates(i) - 1.0)
                })
                .sum()
        };
        let v_neyman = var(&|i| alloc.rate(i));
        let v_uniform = var(&|_| u);
        assert!(
            v_neyman <= v_uniform + 1e-9,
            "neyman {v_neyman} worse than uniform {v_uniform}"
        );
    }

    #[test]
    fn surprisal_profile_scales_sigma() {
        let flat = [-0.1f32; 16];
        let spiky = [-2.0f32; 16];
        assert!(sigma(1.0, Some(&spiky)) > sigma(1.0, Some(&flat)));
        assert_eq!(sigma(1.0, None), 1.0);
        assert_eq!(sigma(-2.0, None), 2.0);
        assert_eq!(sigma(0.0, Some(&spiky)), 0.0);
    }

    #[test]
    fn sample_row_is_systematic_with_one_draw_and_pinned_kept() {
        let lens = [40usize, 0, 17];
        let advs = [1.0f64, 1.0, 0.5];
        let alloc = solve_neyman(&rows_of(&lens), &advs, 30, 1e-3);
        let mut rng = Rng::new(30);
        for (i, &t) in lens.iter().enumerate() {
            let before = rng.clone();
            let plan = alloc.sample_row(i, t, &mut rng);
            assert_eq!(plan.ht_w.len(), t);
            let e = alloc.expected_kept(i);
            assert!(
                plan.kept == e.floor() as usize || plan.kept == e.ceil() as usize,
                "row {i}: kept {} vs expected {e}",
                plan.kept
            );
            // draw-pattern contract: 1 uniform for t>0, none for t=0
            let mut replay = before;
            if t > 0 {
                replay.uniform();
            }
            assert_eq!(replay.next_u64(), rng.clone().next_u64(), "row {i} draw count");
        }
        // out-of-range rate is 0 (never sampled)
        assert_eq!(alloc.rate(99), 0.0);
    }

    #[test]
    fn ht_weight_sums_stay_unbiased_per_row() {
        let lens = [33usize, 50];
        let advs = [0.7f64, 1.3];
        let alloc = solve_neyman(&rows_of(&lens), &advs, 35, 1e-3);
        let mut rng = Rng::new(31);
        let n = 30_000;
        for (i, &t) in lens.iter().enumerate() {
            let mut acc = 0.0f64;
            for _ in 0..n {
                acc += alloc
                    .sample_row(i, t, &mut rng)
                    .ht_w
                    .iter()
                    .map(|&w| w as f64)
                    .sum::<f64>();
            }
            let mean = acc / n as f64;
            assert!((mean - t as f64).abs() < 0.25, "row {i}: {mean} vs {t}");
        }
    }

    #[test]
    fn trace_args_mirror_the_batch_controller() {
        let alloc = solve_neyman(&rows_of(&[10, 20]), &[1.0, 1.0], 12, 1e-3);
        let args = alloc.trace_args();
        assert_eq!(args[0], ("budget_target", 12.0));
        assert_eq!(args[1].0, "budget_expected");
        assert!((args[1].1 - 12.0).abs() < 1e-6);
        assert_eq!(args[2], ("adapted", 1.0));
        assert!(!alloc.label().is_empty());
    }
}
