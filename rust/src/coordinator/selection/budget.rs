//! Batch-level adaptive token-budget controller (`--train.budget_mode
//! batch`).
//!
//! NAT's framing makes the token budget a first-class optimization
//! primitive, yet fixed per-sequence keep parameters (URS `p`, RPC
//! `min_cut`, ...) spend a *length-distribution-dependent* amount of
//! compute: the same `p = 0.5` selects twice the tokens when responses run
//! twice as long. This controller inverts that: given the batch's actual
//! response lengths (and, for saliency, its surprisal profiles), it
//! re-solves the scheme's keep parameter each optimizer step so the
//! **expected** selected-token count hits the global target
//! `--train.token_budget`.
//!
//! Unbiasedness is free by construction: every scheme samples with the
//! *adjusted* inclusion probabilities and HT-weights by their inverse, so
//! E[Σ w_t x_t] = Σ x_t for any solved parameter — the estimator never
//! learns that the controller exists (Monte-Carlo-verified through the full
//! pack → shard → reduce path in `tests/selection.rs`).
//!
//! Per-scheme solves (all deterministic, all O(n log n) or better):
//!
//! * URS / Stratified — expected kept is p·Σt, linear in p: p* = B / Σt.
//! * Poisson — expected kept is Σ min(t_i, k), piecewise-linear and
//!   monotone in k: exact waterfill over the sorted lengths.
//! * RPC — expected kept is Σ (clamp(C, 1, t_i) + t_i)/2, monotone in the
//!   integer cutoff: binary search, then the closer of the two neighbours.
//!   Granularity is at most n/2 tokens per cutoff step.
//! * Saliency — expected kept is Σ min(1, s·p_t), monotone in the scale s:
//!   bisection to machine precision.
//! * GRPO / DetTrunc — fixed-cost baselines: no free parameter to solve;
//!   returned unadapted (`adapted = false`). The config layer rejects
//!   `budget_mode batch` for them up front (`RunConfig::validate`); direct
//!   API callers get the unadapted selector and can inspect `adapted`.
//!
//! Attainability: a solve can only promise targets inside the scheme's
//! reachable range (RPC cannot select fewer than Σ(1 + t_i)/2 tokens, no
//! unbiased scheme can select more than Σ t_i). Outside it the controller
//! clamps to the nearest endpoint and reports the achieved expectation in
//! `BudgetOutcome::expected` — which also feeds the `budget_realized`
//! metric series, so a clamped run is visible in the step stats.
//!
//! π floor: every rate-style solve clamps its solved probabilities through
//! the shared [`super::solve_floor`] (`--train.pi_floor`), so an
//! unattainably low target floors the probabilities instead of letting the
//! 1/π HT weights run away — `w_max ≤ 1/pi_floor` by construction, still
//! exactly unbiased because sampling uses the floored probabilities. RPC is
//! the exception by design: its prefix-survival law keeps every weight
//! ≤ t_i − C + 1 without any probability clamp, and flooring survival
//! probabilities independently would change the sampling law.

use anyhow::{bail, Result};

use crate::config::Method;

use super::{selector_for, solve_floor, Poisson, Rpc, Saliency, Selector, Stratified, Urs};

/// The solved batch plan: an adjusted selector shared by every sequence in
/// the step, plus the solve's bookkeeping.
pub struct BudgetOutcome {
    pub selector: Box<dyn Selector>,
    /// The requested expected-selected-token target (`--train.token_budget`).
    pub target: f64,
    /// The achieved expectation Σ_i E[kept_i] under the adjusted
    /// probabilities (== target whenever the target is attainable).
    pub expected: f64,
    /// False for the fixed-cost baselines (GRPO, DetTrunc) the controller
    /// cannot adapt.
    pub adapted: bool,
}

impl BudgetOutcome {
    /// The solve's bookkeeping as span args for the `learn.select` trace
    /// (target, achieved expectation, and whether anything was solved).
    pub fn trace_args(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("budget_target", self.target),
            ("budget_expected", self.expected),
            ("adapted", if self.adapted { 1.0 } else { 0.0 }),
        ]
    }
}

/// Solve the batch's keep parameter. `rows` carries `(resp_len, behaviour
/// logprobs)` per sequence — zero-length rows contribute nothing and are
/// ignored by every solve. `pi_floor` is the shared solve-clamp floor
/// (`--train.pi_floor`; 0 disables the guard and falls back to the legacy
/// per-solve tiny clamps). Errors are configuration-shaped — e.g. a
/// saliency solve over rows missing behaviour logprobs — and surface
/// before any step math runs.
pub fn solve_batch(
    method: &Method,
    rows: &[(usize, Option<&[f32]>)],
    budget: usize,
    pi_floor: f64,
) -> Result<BudgetOutcome> {
    let target = budget as f64;
    let total: f64 = rows.iter().map(|&(t, _)| t as f64).sum();
    Ok(match *method {
        Method::Grpo | Method::DetTrunc { .. } => {
            let selector = selector_for(method);
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: false }
        }
        Method::Urs { .. } => {
            let p = rate_for(target, total, pi_floor);
            let selector: Box<dyn Selector> = Box::new(Urs { p });
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: true }
        }
        Method::Stratified { .. } => {
            let p = rate_for(target, total, pi_floor);
            let selector: Box<dyn Selector> = Box::new(Stratified { p });
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: true }
        }
        Method::Poisson { .. } => {
            let k = solve_poisson_k(rows, target, pi_floor);
            let selector: Box<dyn Selector> = Box::new(Poisson { k });
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: true }
        }
        Method::Rpc { .. } => {
            let min_cut = solve_rpc_cut(rows, target);
            let selector: Box<dyn Selector> = Box::new(Rpc { min_cut });
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: true }
        }
        Method::Saliency { floor } => {
            let scale = solve_saliency_scale(rows, floor, target, pi_floor)?;
            let selector: Box<dyn Selector> =
                Box::new(Saliency { floor, scale, pi_floor });
            let expected = expected_sum(&*selector, rows);
            BudgetOutcome { selector, target, expected, adapted: true }
        }
    })
}

/// Σ_i E[kept_i] for a selector over the batch (zero-length rows are 0).
pub fn expected_sum(sel: &dyn Selector, rows: &[(usize, Option<&[f32]>)]) -> f64 {
    rows.iter()
        .filter(|&&(t, _)| t > 0)
        .map(|&(t, ctx)| sel.expected_kept(t, ctx))
        .sum()
}

/// Shared URS/Stratified solve: expected kept = p · Σt ⇒ p* = B / Σt,
/// clamped into [π floor, 1].
fn rate_for(target: f64, total: f64, pi_floor: f64) -> f64 {
    if total <= 0.0 {
        return 1.0; // empty batch: nothing to select, any rate is vacuous
    }
    (target / total).clamp(solve_floor(pi_floor, 1e-6), 1.0)
}

/// Waterfill: the k with Σ min(t_i, k) = target (piecewise linear, knots at
/// the sorted lengths), clamped to [π floor · max t, max t] — the longest
/// sequence has the smallest rate k/t, so flooring k at `pi_floor · max_t`
/// keeps every per-token rate ≥ `pi_floor`.
fn solve_poisson_k(rows: &[(usize, Option<&[f32]>)], target: f64, pi_floor: f64) -> f64 {
    let mut lens: Vec<usize> = rows.iter().map(|&(t, _)| t).filter(|&t| t > 0).collect();
    if lens.is_empty() {
        return 1.0;
    }
    lens.sort_unstable();
    let n = lens.len();
    let max_t = *lens.last().unwrap() as f64;
    let total: f64 = lens.iter().map(|&t| t as f64).sum();
    let k_min = solve_floor(pi_floor * max_t, 1e-9);
    if target >= total {
        return max_t; // saturated: every token of every sequence
    }
    // Below the smallest knot the sum is n·k; between knots i-1 and i it is
    // prefix(i) + k·(n - i).
    let mut prefix = 0.0f64; // Σ of lens[..i]
    for (i, &t) in lens.iter().enumerate() {
        let hi = t as f64;
        let remaining = (n - i) as f64;
        // sum at k = hi with this segment's slope:
        let at_hi = prefix + hi * remaining;
        if target <= at_hi {
            // k lands in (lo, hi] by construction; clamp through the shared
            // floor so min rate k/max_t stays ≥ pi_floor (legacy: > 0).
            let k = (target - prefix) / remaining;
            return k.max(k_min);
        }
        prefix += hi;
    }
    max_t
}

/// Monotone integer solve: the cutoff whose expectation is closest to the
/// target (ties prefer the smaller cutoff).
fn solve_rpc_cut(rows: &[(usize, Option<&[f32]>)], target: f64) -> usize {
    let lens: Vec<usize> = rows.iter().map(|&(t, _)| t).filter(|&t| t > 0).collect();
    let max_t = lens.iter().copied().max().unwrap_or(1);
    let expect = |c: usize| -> f64 {
        lens.iter().map(|&t| (c.clamp(1, t) as f64 + t as f64) / 2.0).sum()
    };
    // first c in [1, max_t] with expect(c) >= target (expect is monotone
    // non-decreasing in c)
    let (mut lo, mut hi) = (1usize, max_t);
    if expect(lo) >= target {
        return lo;
    }
    if expect(hi) < target {
        return hi;
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if expect(mid) >= target {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // hi is the first cutoff at/above target; lo = hi - 1 undershoots.
    if (expect(hi) - target).abs() < (target - expect(lo)).abs() {
        hi
    } else {
        lo
    }
}

/// Bisection on the probability scale s: f(s) = Σ clamp(s·p_t, π floor, 1)
/// is continuous and monotone, so 64 halvings reach machine precision. A
/// row missing its behaviour logprobs is a configuration error (a rollout
/// path that never recorded them), surfaced here as a hard `Err` before
/// any step math runs rather than a hot-path panic.
fn solve_saliency_scale(
    rows: &[(usize, Option<&[f32]>)],
    floor: f64,
    target: f64,
    pi_floor: f64,
) -> Result<f64> {
    let mut base: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
    for &(t, ctx) in rows.iter().filter(|&&(t, _)| t > 0) {
        let Some(lp) = ctx else {
            bail!(
                "budget controller: saliency selection needs behaviour logprobs for \
                 every sequence, but a length-{t} row has none — the rollout path \
                 feeding budget_mode batch/neyman must record old_lp"
            );
        };
        debug_assert_eq!(lp.len(), t);
        base.push(super::saliency::probs(lp, floor));
    }
    // The inclusion clamp (mirrored by `Saliency::inclusion`) keeps every
    // probability ≥ pf, so targets below pf·N floor out instead of driving
    // the scale (and the 1/π weights) through the tiny legacy clamp.
    let pf = solve_floor(pi_floor, 0.0);
    let f = |s: f64| -> f64 {
        base.iter()
            .flat_map(|p| p.iter())
            .map(|&p| (s * p as f64).min(1.0).max(pf))
            .sum()
    };
    // s_hi = 1/floor saturates every probability at 1 (p_t >= floor).
    let s_hi = 1.0 / floor.max(1e-6);
    if f(s_hi) <= target {
        return Ok(s_hi);
    }
    let (mut lo, mut hi) = (0.0f64, s_hi);
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if f(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // hi's expectation >= target by loop invariant; the interval is ~1 ulp
    // wide. Never return exactly 0 (probabilities must stay positive even
    // with the guard off).
    Ok(hi.max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn plain_rows(lens: &[usize]) -> Vec<(usize, Option<&'static [f32]>)> {
        lens.iter().map(|&t| (t, None)).collect()
    }

    /// Legacy-floor solve (guard off) — the pre-π-floor behaviour every
    /// historical assertion in this module was written against.
    fn solve(method: &Method, rows: &[(usize, Option<&[f32]>)], budget: usize) -> BudgetOutcome {
        solve_batch(method, rows, budget, 0.0).unwrap()
    }

    #[test]
    fn urs_and_stratified_hit_the_target_exactly() {
        let rows = plain_rows(&[10, 20, 30, 40]);
        for method in [Method::Urs { p: 0.9 }, Method::Stratified { p: 0.9 }] {
            let out = solve(&method, &rows, 50);
            assert!(out.adapted);
            assert_eq!(out.target, 50.0);
            // f32 probability rounding keeps this to ~1e-5 relative
            assert!((out.expected - 50.0).abs() < 0.01, "{}", out.expected);
        }
    }

    #[test]
    fn poisson_waterfill_equalises_long_sequences() {
        // lens 10/20/30/40, target 60 ⇒ k=15: 10 + 15·3 = 55 ≠ 60... solve:
        // k ≤ 10: 4k; k=10→40. 10..20: 10+3k; k=50/3≈16.67 → sum 60. ✔
        let rows = plain_rows(&[10, 20, 30, 40]);
        let out = solve(&Method::Poisson { k: 8 }, &rows, 60);
        assert!(out.adapted);
        assert!((out.expected - 60.0).abs() < 0.01, "{}", out.expected);
        // saturated target clamps to the full token count
        let out = solve(&Method::Poisson { k: 8 }, &rows, 1000);
        assert!((out.expected - 100.0).abs() < 0.01, "{}", out.expected);
    }

    #[test]
    fn rpc_integer_cut_lands_within_half_batch_granularity() {
        let mut rng = Rng::new(40);
        let lens: Vec<usize> = (0..64).map(|_| 1 + rng.below(256) as usize).collect();
        let rows = plain_rows(&lens);
        let total: f64 = lens.iter().map(|&t| t as f64).sum();
        let floor_e: f64 = lens.iter().map(|&t| (1.0 + t as f64) / 2.0).sum();
        // attainable band: [Σ(1+t)/2, Σt]
        for frac in [0.55f64, 0.65, 0.8, 0.95] {
            let target = total * frac;
            if target < floor_e {
                continue;
            }
            let out = solve(&Method::Rpc { min_cut: 8 }, &rows, target as usize);
            assert!(out.adapted);
            // worst case: half an integer-cut step = n/4 tokens
            assert!(
                (out.expected - target).abs() <= lens.len() as f64 / 2.0 + 1.0,
                "target {target}: expected {}",
                out.expected
            );
        }
        // unattainably low target clamps to the C=1 floor
        let out = solve(&Method::Rpc { min_cut: 8 }, &rows, 1);
        assert!((out.expected - floor_e).abs() < 1e-6);
        // unattainably high target clamps to full length
        let out = solve(&Method::Rpc { min_cut: 8 }, &rows, total as usize * 2);
        assert!((out.expected - total).abs() < 1e-6);
    }

    #[test]
    fn saliency_scale_bisection_hits_target() {
        let mut rng = Rng::new(41);
        let lens: Vec<usize> = (0..16).map(|_| 4 + rng.below(60) as usize).collect();
        let lps: Vec<Vec<f32>> = lens
            .iter()
            .map(|&t| (0..t).map(|_| -0.02 - rng.uniform() as f32).collect())
            .collect();
        let rows: Vec<(usize, Option<&[f32]>)> =
            lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
        let total: f64 = lens.iter().map(|&t| t as f64).sum();
        let target = (0.4 * total) as usize;
        let out = solve(&Method::Saliency { floor: 0.25 }, &rows, target);
        assert!(out.adapted);
        assert!(
            (out.expected - target as f64).abs() < 0.01 * target as f64,
            "target {target}: expected {}",
            out.expected
        );
        // saturated: every probability clamps at 1
        let out = solve(&Method::Saliency { floor: 0.25 }, &rows, total as usize * 2);
        assert!((out.expected - total).abs() < 1e-6);
    }

    #[test]
    fn baselines_are_not_adapted() {
        let rows = plain_rows(&[10, 20, 30]);
        let out = solve(&Method::Grpo, &rows, 10);
        assert!(!out.adapted);
        assert_eq!(out.expected, 60.0);
        let out = solve(&Method::DetTrunc { frac: 0.5 }, &rows, 10);
        assert!(!out.adapted);
        assert_eq!(out.expected, 30.0);
    }

    #[test]
    fn trace_args_report_the_solve() {
        let rows = plain_rows(&[10, 20, 30, 40]);
        let out = solve(&Method::Urs { p: 0.9 }, &rows, 50);
        let args = out.trace_args();
        assert_eq!(args[0], ("budget_target", 50.0));
        assert_eq!(args[1].0, "budget_expected");
        assert!((args[1].1 - 50.0).abs() < 0.01);
        assert_eq!(args[2], ("adapted", 1.0));
        let out = solve(&Method::Grpo, &rows, 50);
        assert_eq!(out.trace_args()[2], ("adapted", 0.0));
    }

    #[test]
    fn pathologically_low_targets_mint_runaway_weights_only_without_the_guard() {
        // The historical failure mode: a budget far below the reachable
        // range drives the solved probabilities into the legacy tiny
        // clamps (1e-6 / 1e-9 / 1e-12) and the 1/π weights explode.
        use crate::coordinator::selection::HtMoments;
        let lens: Vec<usize> = vec![64, 128, 256, 512, 1024];
        let lps: Vec<Vec<f32>> = lens
            .iter()
            .map(|&t| (0..t).map(|i| -0.05 - 0.01 * (i % 13) as f32).collect())
            .collect();
        let rows: Vec<(usize, Option<&[f32]>)> =
            lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
        let methods = [
            Method::Urs { p: 0.5 },
            Method::Stratified { p: 0.5 },
            Method::Poisson { k: 8 },
            Method::Saliency { floor: 0.25 },
        ];
        let mut rng = Rng::new(0x9F10);
        for method in &methods {
            // guard on: every solved probability ≥ pi_floor, so every
            // realized weight ≤ 1/pi_floor — even at budget 1
            let pf = 1e-3;
            let out = solve_batch(method, &rows, 1, pf).unwrap();
            let mut ht = HtMoments::default();
            for &(t, ctx) in &rows {
                for &p in &out.selector.probs(t, ctx) {
                    assert!(p as f64 >= pf - 1e-9, "{method:?}: solved π {p} below floor");
                }
                ht.observe(&out.selector.sample(t, ctx, &mut rng));
            }
            assert!(
                ht.w_max <= 1.0 / pf * (1.0 + 1e-6),
                "{method:?}: w_max {} above 1/pi_floor",
                ht.w_max
            );
            // guard off: probabilities stay positive (legacy clamp), but
            // the weights are allowed to run away — the documented bug
            // this PR caps
            let out = solve_batch(method, &rows, 1, 0.0).unwrap();
            for &(t, ctx) in &rows {
                assert!(out.selector.probs(t, ctx).iter().all(|&p| p > 0.0), "{method:?}");
            }
        }
    }

    #[test]
    fn saliency_rows_without_logprobs_error_instead_of_panicking() {
        let lp: Vec<f32> = vec![-0.5; 10];
        let rows: Vec<(usize, Option<&[f32]>)> =
            vec![(10, Some(lp.as_slice())), (20, None)];
        let err = solve_batch(&Method::Saliency { floor: 0.25 }, &rows, 10, 1e-3)
            .err()
            .expect("missing logprobs must be a hard error");
        assert!(err.to_string().contains("behaviour logprobs"), "{err}");
        // zero-length rows without logprobs are fine (ignored by the solve)
        let rows: Vec<(usize, Option<&[f32]>)> = vec![(0, None), (10, Some(lp.as_slice()))];
        assert!(solve_batch(&Method::Saliency { floor: 0.25 }, &rows, 5, 1e-3).is_ok());
    }

    #[test]
    fn empty_and_zero_length_rows_are_ignored() {
        let out = solve(&Method::Urs { p: 0.5 }, &[], 10);
        assert_eq!(out.expected, 0.0);
        let rows = [(0usize, None), (10usize, None)];
        let out = solve(&Method::Poisson { k: 4 }, &rows, 5);
        assert!((out.expected - 5.0).abs() < 0.01);
        let out = solve(&Method::Rpc { min_cut: 8 }, &rows, 8);
        assert!(out.expected >= 5.5 - 1e-9); // C=1 floor on the single row
    }
}
