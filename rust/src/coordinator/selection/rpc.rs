//! Random Prefix Cutting — the paper's headline scheme: draw a cut
//! L ~ Uniform({C..T}) and keep the prefix, HT-weighting each kept token by
//! the inverse of its survival probability so the estimator stays unbiased
//! while the forward prefix (and with it learner time and memory) shrinks
//! deterministically. Exactly one `range_inclusive` draw per sequence, the
//! same stream as the legacy `masking::sample_ctx` RPC arm.

use super::{SelectionPlan, Selector};
use crate::util::rng::Rng;

/// Survival function of RPC with minimum cutoff C (paper Eq. after (8)):
/// p_t = 1 for t <= C, (T - t + 1) / (T - C + 1) for t > C (1-based t).
pub fn survival(t_i: usize, min_cut: usize) -> Vec<f32> {
    let c = min_cut.clamp(1, t_i);
    (1..=t_i)
        .map(|t| {
            if t <= c {
                1.0
            } else {
                // natlint: allow(lossy-cast, reason = "integer survival counts are < 2^24 (bounded by max_resp), so both casts and the quotient are exact up to one f32 rounding — the same single rounding pi_w32 blesses")
                (t_i - t + 1) as f32 / (t_i - c + 1) as f32
            }
        })
        .collect()
}

pub struct Rpc {
    pub min_cut: usize,
}

impl Selector for Rpc {
    fn label(&self) -> String {
        format!("rpc(C={})", self.min_cut)
    }

    fn probs(&self, t_i: usize, _ctx: Option<&[f32]>) -> Vec<f32> {
        survival(t_i, self.min_cut)
    }

    fn expected_kept(&self, t_i: usize, _ctx: Option<&[f32]>) -> f64 {
        // E[L] for L ~ Uniform({C..T}) is (C + T) / 2.
        let c = self.min_cut.clamp(1, t_i) as f64;
        (c + t_i as f64) / 2.0
    }

    fn draw(&self, t_i: usize, _ctx: Option<&[f32]>, rng: &mut Rng) -> SelectionPlan {
        let c = self.min_cut.clamp(1, t_i);
        let cut = rng.range_inclusive(c as u64, t_i as u64) as usize;
        let p = survival(t_i, self.min_cut);
        let mut ht_w = vec![0.0f32; t_i];
        for t in 0..cut {
            ht_w[t] = 1.0 / p[t];
        }
        SelectionPlan { probs: p, ht_w, kept: cut, learn_len: cut }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_is_prefix_with_ht_weights() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let t_i = 1 + rng.below(150) as usize;
            let c = 1 + rng.below(30) as usize;
            let plan = Rpc { min_cut: c }.sample(t_i, None, &mut rng);
            let p = survival(t_i, c);
            assert!(plan.kept >= c.min(t_i));
            assert_eq!(plan.learn_len, plan.kept);
            for t in 0..t_i {
                if t < plan.kept {
                    assert!((plan.ht_w[t] - 1.0 / p[t]).abs() < 1e-6);
                } else {
                    assert_eq!(plan.ht_w[t], 0.0);
                }
            }
        }
    }

    #[test]
    fn survival_properties() {
        for (t_i, c) in [(1, 1), (10, 3), (100, 100), (64, 1), (200, 50)] {
            let p = survival(t_i, c);
            assert_eq!(p.len(), t_i);
            assert_eq!(p[0], 1.0);
            assert!(p.iter().all(|&x| x > 0.0)); // HT requirement
            assert!(p.windows(2).all(|w| w[1] <= w[0] + 1e-7)); // monotone
            let cc = c.clamp(1, t_i);
            assert!(p[..cc].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn expected_kept_is_half_c_plus_t() {
        assert_eq!(Rpc { min_cut: 10 }.expected_kept(100, None), 55.0);
        assert_eq!(Rpc { min_cut: 200 }.expected_kept(100, None), 100.0);
    }
}
