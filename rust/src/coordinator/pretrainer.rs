//! SFT pretraining phase: produces the "base model" the RL phase starts
//! from (the reproduction's stand-in for Qwen checkpoints, DESIGN.md §2).
//!
//! The corpus is rendered gold CoT with controlled label noise, so the base
//! model emits well-formed solutions with imperfect accuracy — leaving the
//! verifiable-reward headroom RL needs to demonstrate lift.

use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::Recorder;
use crate::runtime::{OptState, ParamStore, Runtime};
use crate::tasks::SftCorpus;
use crate::tokenizer::Tokenizer;
use crate::util::rng::xor_stream;

pub struct PretrainResult {
    pub params: ParamStore,
    pub opt: OptState,
    pub recorder: Recorder,
    pub final_loss: f64,
}

pub fn pretrain(rt: &Runtime, cfg: &RunConfig, verbose: bool) -> Result<PretrainResult> {
    let tok = Tokenizer::new();
    let d = &rt.manifest.dims;
    let mut rng = xor_stream(cfg.seed, 0x5F7A_11CE);
    let corpus = SftCorpus::build(
        &tok,
        cfg.pretrain.corpus_size,
        d.prompt_len,
        d.pretrain_len,
        cfg.pretrain.noise,
        cfg.seed,
        &cfg.task_mix(),
    );
    let mut params = ParamStore::load_init(&rt.manifest)?;
    let mut opt = OptState::zeros(&rt.manifest);
    let mut recorder = Recorder::new();
    let mut step = 0u64;
    // natlint: allow(wallclock, reason = "SFT progress-line throughput only; loss math never reads the clock")
    let t0 = Instant::now();
    'outer: loop {
        let batches = corpus.batches(d.batch_pretrain, &mut rng);
        for (tokens, mask, pads) in &batches {
            if step >= cfg.pretrain.steps as u64 {
                break 'outer;
            }
            let (loss, gnorm) = rt.pretrain_step(&mut params, &mut opt, tokens, mask, pads)?;
            step += 1;
            recorder.push("sft_loss", step, loss);
            recorder.push("sft_grad_norm", step, gnorm);
            if verbose && (step % 25 == 0 || step == 1) {
                println!(
                    "sft step {:>5} | loss {:.4} | gnorm {:.3} | {:.1}s",
                    step,
                    loss,
                    gnorm,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        if batches.is_empty() {
            anyhow::bail!("pretrain corpus produced no full batches");
        }
    }
    let final_loss = recorder.tail_mean("sft_loss", 0.05).unwrap_or(f64::NAN);
    Ok(PretrainResult { params, opt, recorder, final_loss })
}
