//! NAT token selection — the paper's core contribution (§3-4).
//!
//! Given a response of true length `t_i`, each strategy produces a
//! Horvitz-Thompson weight vector `w_t = m_t / p_t` (zero where the token is
//! excluded) plus the *learner length*: the forward prefix the gradient
//! computation actually needs — the causal prefix up to the last scored
//! token. The learner length is what the bucketed batcher routes on: RPC's
//! prefix cuts shorten it deterministically, while URS/Saliency only save
//! whatever tail their Bernoulli draws happen to leave unscored.

use crate::config::Method;
use crate::util::rng::Rng;

/// One sampled selection for one response.
#[derive(Clone, Debug)]
pub struct MaskSample {
    /// HT weights over tokens 0..t_i (0.0 = excluded from the update).
    pub ht_w: Vec<f32>,
    /// Number of selected tokens.
    pub kept: usize,
    /// Forward prefix length the learner must process (<= t_i).
    pub learn_len: usize,
}

impl MaskSample {
    pub fn selected_ratio(&self) -> f64 {
        if self.ht_w.is_empty() {
            0.0
        } else {
            self.kept as f64 / self.ht_w.len() as f64
        }
    }
}

/// Survival function of RPC with minimum cutoff C (paper Eq. after (8)):
/// p_t = 1 for t <= C, (T - t + 1) / (T - C + 1) for t > C (1-based t).
pub fn rpc_survival(t_i: usize, min_cut: usize) -> Vec<f32> {
    let c = min_cut.clamp(1, t_i);
    (1..=t_i)
        .map(|t| {
            if t <= c {
                1.0
            } else {
                (t_i - t + 1) as f32 / (t_i - c + 1) as f32
            }
        })
        .collect()
}

/// Sample a token selection for a response of length `t_i`.
/// For context-dependent strategies (Saliency) use [`sample_ctx`].
pub fn sample(method: &Method, t_i: usize, rng: &mut Rng) -> MaskSample {
    sample_ctx(method, t_i, None, rng)
}

/// Sample with optional per-token context (behaviour logprobs over
/// 0..t_i), required by information-aware strategies.
pub fn sample_ctx(
    method: &Method,
    t_i: usize,
    old_lp: Option<&[f32]>,
    rng: &mut Rng,
) -> MaskSample {
    if t_i == 0 {
        // Degenerate empty response (`trim_at_eos` floors real rollouts at
        // 1, but a zero-width response window can produce 0): nothing to
        // select, nothing to forward, and — crucially — no RNG draws, so
        // the mask stream stays aligned with the non-degenerate case.
        return MaskSample { ht_w: Vec::new(), kept: 0, learn_len: 0 };
    }
    match *method {
        Method::Grpo => MaskSample { ht_w: vec![1.0; t_i], kept: t_i, learn_len: t_i },
        Method::Urs { p } => {
            let w = (1.0 / p) as f32;
            let mut ht_w = vec![0.0f32; t_i];
            let mut kept = 0;
            let mut last_kept = 0usize;
            for (t, slot) in ht_w.iter_mut().enumerate() {
                if rng.bernoulli(p) {
                    *slot = w;
                    kept += 1;
                    last_kept = t + 1;
                }
            }
            // Causal attention only needs the prefix up to the last *scored*
            // token: positions past it contribute nothing to the update, so
            // the forward may stop there (floor 1 so empty draws still
            // produce a valid artifact shape). In expectation this is close
            // to t_i for moderate p — URS keeps near-full forward cost, as
            // the paper notes — but the realised tail savings are real and
            // let short draws land in smaller buckets.
            MaskSample { ht_w, kept, learn_len: last_kept.max(1) }
        }
        Method::DetTrunc { frac } => {
            let k = ((frac * t_i as f64).floor() as usize).clamp(1, t_i);
            let mut ht_w = vec![0.0f32; t_i];
            for slot in ht_w.iter_mut().take(k) {
                *slot = 1.0; // no HT correction exists: p = 0 on the suffix
            }
            MaskSample { ht_w, kept: k, learn_len: k }
        }
        Method::Rpc { min_cut } => {
            let c = min_cut.clamp(1, t_i);
            let cut = rng.range_inclusive(c as u64, t_i as u64) as usize;
            let p = rpc_survival(t_i, min_cut);
            let mut ht_w = vec![0.0f32; t_i];
            for t in 0..cut {
                ht_w[t] = 1.0 / p[t];
            }
            MaskSample { ht_w, kept: cut, learn_len: cut }
        }
        Method::Saliency { floor } => {
            let p = saliency_probs(
                old_lp.expect("Saliency masking needs behaviour logprobs"),
                floor,
            );
            debug_assert_eq!(p.len(), t_i);
            let mut ht_w = vec![0.0f32; t_i];
            let mut kept = 0;
            let mut last_kept = 0usize;
            for (t, (slot, &pt)) in ht_w.iter_mut().zip(&p).enumerate() {
                if rng.bernoulli(pt as f64) {
                    *slot = 1.0 / pt;
                    kept += 1;
                    last_kept = t + 1;
                }
            }
            // independent masking: forward only up to the last scored token
            // (same realised-tail savings as URS; floor 1 for empty draws)
            MaskSample { ht_w, kept, learn_len: last_kept.max(1) }
        }
    }
}

/// Inclusion probabilities for information-aware selection: behaviour
/// surprisal u_t = -log pi_old(o_t) normalised to [0, 1] per sequence, then
/// p_t = floor + (1 - floor) * u_t. High-surprisal ("high-entropy
/// minority") tokens are (almost) always kept; boilerplate tokens are kept
/// with probability ~floor and up-weighted by 1/p_t when they are — the
/// paper's §7 future-work scheme inside the same HT framework.
pub fn saliency_probs(old_lp: &[f32], floor: f64) -> Vec<f32> {
    let max_u = old_lp.iter().map(|&lp| -lp).fold(1e-6f32, f32::max);
    old_lp
        .iter()
        .map(|&lp| {
            let u = (-lp / max_u).clamp(0.0, 1.0);
            (floor as f32 + (1.0 - floor as f32) * u).clamp(floor as f32, 1.0)
        })
        .collect()
}

/// Expected selected-token ratio (paper Fig. 3 prediction): RPC with
/// minimum cutoff keeps E[L]/T = 1/2 + C/(2T).
pub fn expected_ratio(method: &Method, t_i: usize) -> f64 {
    match *method {
        Method::Grpo => 1.0,
        Method::Urs { p } => p,
        Method::DetTrunc { frac } => {
            ((frac * t_i as f64).floor().max(1.0)) / t_i as f64
        }
        Method::Rpc { min_cut } => {
            let c = min_cut.clamp(1, t_i) as f64;
            let t = t_i as f64;
            (c + t) / (2.0 * t)
        }
        // depends on the realised surprisal profile; floor is a lower bound
        Method::Saliency { floor } => floor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grpo_keeps_everything() {
        let mut rng = Rng::new(0);
        let s = sample(&Method::Grpo, 37, &mut rng);
        assert_eq!(s.kept, 37);
        assert_eq!(s.learn_len, 37);
        assert!(s.ht_w.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn urs_weight_is_inverse_p_and_learn_len_stops_at_last_kept() {
        let mut rng = Rng::new(1);
        let s = sample(&Method::Urs { p: 0.25 }, 200, &mut rng);
        // forward prefix ends at the last scored token (floor 1)
        let last_kept = s.ht_w.iter().rposition(|&w| w > 0.0).map(|t| t + 1).unwrap_or(0);
        assert_eq!(s.learn_len, last_kept.max(1));
        assert!(s.learn_len <= 200);
        for &w in &s.ht_w {
            assert!(w == 0.0 || (w - 4.0).abs() < 1e-6);
        }
        assert_eq!(s.kept, s.ht_w.iter().filter(|&&w| w > 0.0).count());
    }

    #[test]
    fn urs_and_saliency_learn_len_covers_every_scored_token() {
        let mut rng = Rng::new(42);
        let old_lp: Vec<f32> = (0..64).map(|t| -0.1 - 0.05 * (t % 9) as f32).collect();
        for _ in 0..500 {
            for method in [Method::Urs { p: 0.3 }, Method::Saliency { floor: 0.25 }] {
                let s = sample_ctx(&method, 64, Some(&old_lp), &mut rng);
                assert!(s.learn_len >= 1 && s.learn_len <= 64);
                // no scored token may lie beyond the forward prefix...
                assert!(s.ht_w[s.learn_len..].iter().all(|&w| w == 0.0));
                // ...and the prefix is tight: its last position is scored
                // (unless the draw kept nothing and the floor kicked in).
                if s.kept > 0 {
                    assert!(s.ht_w[s.learn_len - 1] > 0.0);
                }
            }
        }
    }

    #[test]
    fn urs_keep_rate_concentrates() {
        let mut rng = Rng::new(2);
        let mut total = 0usize;
        let n = 300;
        for _ in 0..n {
            total += sample(&Method::Urs { p: 0.5 }, 100, &mut rng).kept;
        }
        let rate = total as f64 / (n * 100) as f64;
        assert!((rate - 0.5).abs() < 0.02, "{rate}");
    }

    #[test]
    fn det_trunc_is_deterministic_prefix() {
        let mut rng = Rng::new(3);
        let s1 = sample(&Method::DetTrunc { frac: 0.5 }, 101, &mut rng);
        let s2 = sample(&Method::DetTrunc { frac: 0.5 }, 101, &mut rng);
        assert_eq!(s1.kept, 50);
        assert_eq!(s1.learn_len, 50);
        assert_eq!(s1.ht_w, s2.ht_w);
        assert!(s1.ht_w[..50].iter().all(|&w| w == 1.0));
        assert!(s1.ht_w[50..].iter().all(|&w| w == 0.0));
    }

    #[test]
    fn rpc_mask_is_prefix_with_ht_weights() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let t_i = 1 + rng.below(150) as usize;
            let c = 1 + rng.below(30) as usize;
            let s = sample(&Method::Rpc { min_cut: c }, t_i, &mut rng);
            let p = rpc_survival(t_i, c);
            assert!(s.kept >= c.min(t_i));
            assert_eq!(s.learn_len, s.kept);
            for t in 0..t_i {
                if t < s.kept {
                    assert!((s.ht_w[t] - 1.0 / p[t]).abs() < 1e-6);
                } else {
                    assert_eq!(s.ht_w[t], 0.0);
                }
            }
        }
    }

    #[test]
    fn rpc_survival_properties() {
        for (t_i, c) in [(1, 1), (10, 3), (100, 100), (64, 1), (200, 50)] {
            let p = rpc_survival(t_i, c);
            assert_eq!(p.len(), t_i);
            assert_eq!(p[0], 1.0);
            assert!(p.iter().all(|&x| x > 0.0)); // HT requirement
            assert!(p.windows(2).all(|w| w[1] <= w[0] + 1e-7)); // monotone
            let cc = c.clamp(1, t_i);
            assert!(p[..cc].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn rpc_empirical_inclusion_matches_survival() {
        // Monte-Carlo validation of the HT premise E[m_t] = p_t.
        let (t_i, c, n) = (30, 4, 40_000);
        let mut rng = Rng::new(5);
        let method = Method::Rpc { min_cut: c };
        let mut counts = vec![0u32; t_i];
        for _ in 0..n {
            let s = sample(&method, t_i, &mut rng);
            for t in 0..s.kept {
                counts[t] += 1;
            }
        }
        let p = rpc_survival(t_i, c);
        for t in 0..t_i {
            let hat = counts[t] as f64 / n as f64;
            assert!((hat - p[t] as f64).abs() < 0.02, "t={t} {hat} vs {}", p[t]);
        }
    }

    #[test]
    fn ht_weights_are_unbiased_token_counts() {
        // sum_t w_t must average to t_i for unbiased strategies...
        let t_i = 50;
        let mut rng = Rng::new(6);
        for method in [Method::Urs { p: 0.5 }, Method::Rpc { min_cut: 5 }] {
            let n = 30_000;
            let mut acc = 0.0f64;
            for _ in 0..n {
                acc += sample(&method, t_i, &mut rng).ht_w.iter().map(|&w| w as f64).sum::<f64>();
            }
            let mean = acc / n as f64;
            assert!((mean - t_i as f64).abs() < 0.5, "{method:?}: {mean}");
        }
        // ...and to strictly less for the biased baseline.
        let s = sample(&Method::DetTrunc { frac: 0.5 }, t_i, &mut rng);
        assert_eq!(s.ht_w.iter().sum::<f32>(), 25.0);
    }

    #[test]
    fn expected_ratio_formulas() {
        assert_eq!(expected_ratio(&Method::Grpo, 100), 1.0);
        assert_eq!(expected_ratio(&Method::Urs { p: 0.5 }, 100), 0.5);
        assert_eq!(expected_ratio(&Method::DetTrunc { frac: 0.5 }, 100), 0.5);
        // paper Fig. 3: C=100, T~3000 -> ratio slightly above 0.5
        let r = expected_ratio(&Method::Rpc { min_cut: 10 }, 100);
        assert!((r - 0.55).abs() < 1e-9);
    }

    #[test]
    fn rpc_empirical_ratio_matches_paper_prediction() {
        let mut rng = Rng::new(7);
        let method = Method::Rpc { min_cut: 10 };
        let n = 30_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += sample(&method, 100, &mut rng).selected_ratio();
        }
        let mean = acc / n as f64;
        // .abs(): the one-sided form passed even if the selected ratio
        // collapsed to 0 — it only bounded the mean from above.
        assert!((mean - 0.55).abs() < 0.01, "{mean}"); // ~0.55 like Fig. 3
    }

    #[test]
    fn saliency_probs_are_floored_and_monotone_in_surprisal() {
        let old_lp = [-0.1f32, -1.0, -5.0, -0.01];
        let p = saliency_probs(&old_lp, 0.25);
        assert!(p.iter().all(|&x| (0.25..=1.0).contains(&x)));
        // most surprising token gets p == 1
        assert!((p[2] - 1.0).abs() < 1e-6);
        // less surprising => smaller p
        assert!(p[3] < p[0] && p[0] < p[1] && p[1] < p[2]);
    }

    #[test]
    fn saliency_mask_is_ht_unbiased() {
        let old_lp: Vec<f32> = (0..40).map(|t| -0.2 - 0.1 * (t % 7) as f32).collect();
        let method = Method::Saliency { floor: 0.3 };
        let mut rng = Rng::new(10);
        let n = 30_000;
        let mut acc = 0.0f64;
        for _ in 0..n {
            let s = sample_ctx(&method, 40, Some(&old_lp), &mut rng);
            acc += s.ht_w.iter().map(|&w| w as f64).sum::<f64>();
            assert!(s.learn_len >= 1 && s.learn_len <= 40);
        }
        let mean = acc / n as f64;
        assert!((mean - 40.0).abs() < 0.3, "{mean}");
    }

    #[test]
    fn saliency_keeps_surprising_tokens_more_often() {
        let mut old_lp = vec![-0.05f32; 30];
        old_lp[7] = -6.0; // one very surprising token
        let method = Method::Saliency { floor: 0.2 };
        let mut rng = Rng::new(11);
        let mut kept7 = 0;
        let mut kept0 = 0;
        for _ in 0..2000 {
            let s = sample_ctx(&method, 30, Some(&old_lp), &mut rng);
            if s.ht_w[7] > 0.0 {
                kept7 += 1;
            }
            if s.ht_w[0] > 0.0 {
                kept0 += 1;
            }
        }
        assert!(kept7 > 1950, "{kept7}");
        assert!(kept0 < 600, "{kept0}");
    }

    #[test]
    fn zero_length_response_yields_empty_sample() {
        // Regression (issue satellite): an empty response after
        // `trim_at_eos` must produce an empty, zero-ratio sample — not a
        // panic — for every method, without consuming any RNG draws.
        let mut rng = Rng::new(12);
        let before = rng.clone();
        for method in [
            Method::Grpo,
            Method::Urs { p: 0.5 },
            Method::DetTrunc { frac: 0.5 },
            Method::Rpc { min_cut: 8 },
            Method::Saliency { floor: 0.25 },
        ] {
            let s = sample_ctx(&method, 0, Some(&[]), &mut rng);
            assert!(s.ht_w.is_empty(), "{method:?}");
            assert_eq!(s.kept, 0);
            assert_eq!(s.learn_len, 0);
            assert_eq!(s.selected_ratio(), 0.0);
        }
        // the RNG stream is untouched
        let mut a = before;
        assert_eq!(a.next_u64(), rng.next_u64());
    }

    #[test]
    fn degenerate_lengths() {
        let mut rng = Rng::new(8);
        for method in [
            Method::Grpo,
            Method::Urs { p: 0.5 },
            Method::DetTrunc { frac: 0.5 },
            Method::Rpc { min_cut: 8 },
        ] {
            let s = sample(&method, 1, &mut rng);
            assert_eq!(s.ht_w.len(), 1);
            assert!(s.learn_len >= 1);
            assert!(s.kept >= 1 || matches!(method, Method::Urs { .. }));
        }
    }
}
