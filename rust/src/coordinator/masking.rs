//! Legacy NAT token-selection façade — a thin shim over the first-class
//! [`selection`](crate::coordinator::selection) subsystem.
//!
//! The original implementation lived here as one enum-matched function;
//! it now delegates to the per-scheme [`Selector`] modules. The contract
//! is **bit-identical RNG streams and outputs**: for every method, `t_i`
//! and seed, `sample_ctx` consumes exactly the draws the pre-refactor code
//! consumed and returns the same `ht_w` / `kept` / `learn_len` bits
//! (proptested against a frozen copy of the old code in
//! `tests/selection.rs`). New call sites should use the subsystem directly
//! — it additionally exposes the per-token inclusion probabilities
//! ([`SelectionPlan`](crate::coordinator::selection::SelectionPlan)) that
//! the batch budget controller and the selection metrics need.

use crate::config::Method;
use crate::coordinator::selection::{self, rpc, saliency};
use crate::util::rng::Rng;

/// One sampled selection for one response.
#[derive(Clone, Debug)]
pub struct MaskSample {
    /// HT weights over tokens 0..t_i (0.0 = excluded from the update).
    pub ht_w: Vec<f32>,
    /// Number of selected tokens.
    pub kept: usize,
    /// Forward prefix length the learner must process (<= t_i).
    pub learn_len: usize,
}

impl MaskSample {
    pub fn selected_ratio(&self) -> f64 {
        if self.ht_w.is_empty() {
            0.0
        } else {
            self.kept as f64 / self.ht_w.len() as f64
        }
    }
}

/// Survival function of RPC with minimum cutoff C (paper Eq. after (8)):
/// p_t = 1 for t <= C, (T - t + 1) / (T - C + 1) for t > C (1-based t).
pub fn rpc_survival(t_i: usize, min_cut: usize) -> Vec<f32> {
    rpc::survival(t_i, min_cut)
}

/// Sample a token selection for a response of length `t_i`.
/// For context-dependent strategies (Saliency) use [`sample_ctx`].
pub fn sample(method: &Method, t_i: usize, rng: &mut Rng) -> MaskSample {
    sample_ctx(method, t_i, None, rng)
}

/// Sample with optional per-token context (behaviour logprobs over
/// 0..t_i), required by information-aware strategies.
pub fn sample_ctx(
    method: &Method,
    t_i: usize,
    old_lp: Option<&[f32]>,
    rng: &mut Rng,
) -> MaskSample {
    let plan = selection::selector_for(method).sample(t_i, old_lp, rng);
    MaskSample { ht_w: plan.ht_w, kept: plan.kept, learn_len: plan.learn_len }
}

/// Inclusion probabilities for information-aware selection (see
/// [`selection::saliency::probs`]).
pub fn saliency_probs(old_lp: &[f32], floor: f64) -> Vec<f32> {
    saliency::probs(old_lp, floor)
}

/// Expected selected-token ratio (paper Fig. 3 prediction): RPC with
/// minimum cutoff keeps E[L]/T = 1/2 + C/(2T).
///
/// Saliency has no closed form without the surprisal profile: this ctx-less
/// shim returns its `floor` parameter, which is a **lower bound** on the
/// true ratio, not the inclusion probability. Callers holding the
/// behaviour logprobs should use [`expected_ratio_ctx`] — the form the
/// `budget_realized` accounting agrees with.
pub fn expected_ratio(method: &Method, t_i: usize) -> f64 {
    selection::expected_ratio(method, t_i)
}

/// Honest expected ratio: exact for every scheme when `ctx` carries the
/// behaviour logprobs (matches `Selector::expected_kept / t_i`).
pub fn expected_ratio_ctx(method: &Method, t_i: usize, ctx: Option<&[f32]>) -> f64 {
    selection::expected_ratio_ctx(method, t_i, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grpo_keeps_everything() {
        let mut rng = Rng::new(0);
        let s = sample(&Method::Grpo, 37, &mut rng);
        assert_eq!(s.kept, 37);
        assert_eq!(s.learn_len, 37);
        assert!(s.ht_w.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn urs_weight_is_inverse_p_and_learn_len_stops_at_last_kept() {
        let mut rng = Rng::new(1);
        let s = sample(&Method::Urs { p: 0.25 }, 200, &mut rng);
        // forward prefix ends at the last scored token (floor 1)
        let last_kept = s.ht_w.iter().rposition(|&w| w > 0.0).map(|t| t + 1).unwrap_or(0);
        assert_eq!(s.learn_len, last_kept.max(1));
        assert!(s.learn_len <= 200);
        for &w in &s.ht_w {
            assert!(w == 0.0 || (w - 4.0).abs() < 1e-6);
        }
        assert_eq!(s.kept, s.ht_w.iter().filter(|&&w| w > 0.0).count());
    }

    #[test]
    fn urs_and_saliency_learn_len_covers_every_scored_token() {
        let mut rng = Rng::new(42);
        let old_lp: Vec<f32> = (0..64).map(|t| -0.1 - 0.05 * (t % 9) as f32).collect();
        for _ in 0..500 {
            for method in [Method::Urs { p: 0.3 }, Method::Saliency { floor: 0.25 }] {
                let s = sample_ctx(&method, 64, Some(&old_lp), &mut rng);
                assert!(s.learn_len >= 1 && s.learn_len <= 64);
                // no scored token may lie beyond the forward prefix...
                assert!(s.ht_w[s.learn_len..].iter().all(|&w| w == 0.0));
                // ...and the prefix is tight: its last position is scored
                // (unless the draw kept nothing and the floor kicked in).
                if s.kept > 0 {
                    assert!(s.ht_w[s.learn_len - 1] > 0.0);
                }
            }
        }
    }

    #[test]
    fn urs_keep_rate_concentrates() {
        let mut rng = Rng::new(2);
        let mut total = 0usize;
        let n = 300;
        for _ in 0..n {
            total += sample(&Method::Urs { p: 0.5 }, 100, &mut rng).kept;
        }
        let rate = total as f64 / (n * 100) as f64;
        assert!((rate - 0.5).abs() < 0.02, "{rate}");
    }

    #[test]
    fn det_trunc_is_deterministic_prefix() {
        let mut rng = Rng::new(3);
        let s1 = sample(&Method::DetTrunc { frac: 0.5 }, 101, &mut rng);
        let s2 = sample(&Method::DetTrunc { frac: 0.5 }, 101, &mut rng);
        assert_eq!(s1.kept, 50);
        assert_eq!(s1.learn_len, 50);
        assert_eq!(s1.ht_w, s2.ht_w);
        assert!(s1.ht_w[..50].iter().all(|&w| w == 1.0));
        assert!(s1.ht_w[50..].iter().all(|&w| w == 0.0));
    }

    #[test]
    fn rpc_mask_is_prefix_with_ht_weights() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let t_i = 1 + rng.below(150) as usize;
            let c = 1 + rng.below(30) as usize;
            let s = sample(&Method::Rpc { min_cut: c }, t_i, &mut rng);
            let p = rpc_survival(t_i, c);
            assert!(s.kept >= c.min(t_i));
            assert_eq!(s.learn_len, s.kept);
            for t in 0..t_i {
                if t < s.kept {
                    assert!((s.ht_w[t] - 1.0 / p[t]).abs() < 1e-6);
                } else {
                    assert_eq!(s.ht_w[t], 0.0);
                }
            }
        }
    }

    #[test]
    fn rpc_survival_properties() {
        for (t_i, c) in [(1, 1), (10, 3), (100, 100), (64, 1), (200, 50)] {
            let p = rpc_survival(t_i, c);
            assert_eq!(p.len(), t_i);
            assert_eq!(p[0], 1.0);
            assert!(p.iter().all(|&x| x > 0.0)); // HT requirement
            assert!(p.windows(2).all(|w| w[1] <= w[0] + 1e-7)); // monotone
            let cc = c.clamp(1, t_i);
            assert!(p[..cc].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn rpc_empirical_inclusion_matches_survival() {
        // Monte-Carlo validation of the HT premise E[m_t] = p_t.
        let (t_i, c, n) = (30, 4, 40_000);
        let mut rng = Rng::new(5);
        let method = Method::Rpc { min_cut: c };
        let mut counts = vec![0u32; t_i];
        for _ in 0..n {
            let s = sample(&method, t_i, &mut rng);
            for t in 0..s.kept {
                counts[t] += 1;
            }
        }
        let p = rpc_survival(t_i, c);
        for t in 0..t_i {
            let hat = counts[t] as f64 / n as f64;
            assert!((hat - p[t] as f64).abs() < 0.02, "t={t} {hat} vs {}", p[t]);
        }
    }

    #[test]
    fn ht_weights_are_unbiased_token_counts() {
        // sum_t w_t must average to t_i for unbiased strategies...
        let t_i = 50;
        let mut rng = Rng::new(6);
        for method in [Method::Urs { p: 0.5 }, Method::Rpc { min_cut: 5 }] {
            let n = 30_000;
            let mut acc = 0.0f64;
            for _ in 0..n {
                acc += sample(&method, t_i, &mut rng).ht_w.iter().map(|&w| w as f64).sum::<f64>();
            }
            let mean = acc / n as f64;
            assert!((mean - t_i as f64).abs() < 0.5, "{method:?}: {mean}");
        }
        // ...and to strictly less for the biased baseline.
        let s = sample(&Method::DetTrunc { frac: 0.5 }, t_i, &mut rng);
        assert_eq!(s.ht_w.iter().sum::<f32>(), 25.0);
    }

    #[test]
    fn expected_ratio_formulas() {
        assert_eq!(expected_ratio(&Method::Grpo, 100), 1.0);
        assert_eq!(expected_ratio(&Method::Urs { p: 0.5 }, 100), 0.5);
        assert_eq!(expected_ratio(&Method::DetTrunc { frac: 0.5 }, 100), 0.5);
        assert_eq!(expected_ratio(&Method::Stratified { p: 0.5 }, 100), 0.5);
        assert_eq!(expected_ratio(&Method::Poisson { k: 25 }, 100), 0.25);
        assert_eq!(expected_ratio(&Method::Poisson { k: 200 }, 100), 1.0);
        // paper Fig. 3: C=100, T~3000 -> ratio slightly above 0.5
        let r = expected_ratio(&Method::Rpc { min_cut: 10 }, 100);
        assert!((r - 0.55).abs() < 1e-9);
    }

    #[test]
    fn saliency_expected_ratio_is_a_lower_bound_and_ctx_form_is_honest() {
        // Regression for the `budget_realized` accounting: the ctx-less
        // Saliency arm returns the floor (a lower bound, NOT the inclusion
        // probability), while the ctx form must agree exactly with what the
        // selection plan's expected_kept sums — the quantity the budget
        // controller realizes.
        use crate::coordinator::selection::{selector_for, Selector};
        let old_lp: Vec<f32> = (0..50).map(|t| -0.1 - 0.12 * (t % 11) as f32).collect();
        let method = Method::Saliency { floor: 0.25 };
        let lower = expected_ratio(&method, 50);
        assert_eq!(lower, 0.25);
        let honest = expected_ratio_ctx(&method, 50, Some(&old_lp));
        assert!(honest > lower, "surprisal profile must lift the ratio: {honest}");
        let sel = selector_for(&method);
        let from_probs: f64 =
            sel.probs(50, Some(&old_lp)).iter().map(|&p| p as f64).sum::<f64>() / 50.0;
        assert!((honest - from_probs).abs() < 1e-12, "{honest} vs {from_probs}");
        assert!((honest - sel.expected_kept(50, Some(&old_lp)) / 50.0).abs() < 1e-12);
        // ctx-less falls back to the closed forms for every other scheme
        assert_eq!(expected_ratio_ctx(&Method::Urs { p: 0.5 }, 100, None), 0.5);
        assert_eq!(expected_ratio_ctx(&Method::Grpo, 0, None), 0.0);
    }

    #[test]
    fn rpc_empirical_ratio_matches_paper_prediction() {
        let mut rng = Rng::new(7);
        let method = Method::Rpc { min_cut: 10 };
        let n = 30_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += sample(&method, 100, &mut rng).selected_ratio();
        }
        let mean = acc / n as f64;
        // .abs(): the one-sided form passed even if the selected ratio
        // collapsed to 0 — it only bounded the mean from above.
        assert!((mean - 0.55).abs() < 0.01, "{mean}"); // ~0.55 like Fig. 3
    }

    #[test]
    fn saliency_probs_are_floored_and_monotone_in_surprisal() {
        let old_lp = [-0.1f32, -1.0, -5.0, -0.01];
        let p = saliency_probs(&old_lp, 0.25);
        assert!(p.iter().all(|&x| (0.25..=1.0).contains(&x)));
        // most surprising token gets p == 1
        assert!((p[2] - 1.0).abs() < 1e-6);
        // less surprising => smaller p
        assert!(p[3] < p[0] && p[0] < p[1] && p[1] < p[2]);
    }

    #[test]
    fn saliency_mask_is_ht_unbiased() {
        let old_lp: Vec<f32> = (0..40).map(|t| -0.2 - 0.1 * (t % 7) as f32).collect();
        let method = Method::Saliency { floor: 0.3 };
        let mut rng = Rng::new(10);
        let n = 30_000;
        let mut acc = 0.0f64;
        for _ in 0..n {
            let s = sample_ctx(&method, 40, Some(&old_lp), &mut rng);
            acc += s.ht_w.iter().map(|&w| w as f64).sum::<f64>();
            assert!(s.learn_len >= 1 && s.learn_len <= 40);
        }
        let mean = acc / n as f64;
        assert!((mean - 40.0).abs() < 0.3, "{mean}");
    }

    #[test]
    fn saliency_keeps_surprising_tokens_more_often() {
        let mut old_lp = vec![-0.05f32; 30];
        old_lp[7] = -6.0; // one very surprising token
        let method = Method::Saliency { floor: 0.2 };
        let mut rng = Rng::new(11);
        let mut kept7 = 0;
        let mut kept0 = 0;
        for _ in 0..2000 {
            let s = sample_ctx(&method, 30, Some(&old_lp), &mut rng);
            if s.ht_w[7] > 0.0 {
                kept7 += 1;
            }
            if s.ht_w[0] > 0.0 {
                kept0 += 1;
            }
        }
        assert!(kept7 > 1950, "{kept7}");
        assert!(kept0 < 600, "{kept0}");
    }

    #[test]
    fn zero_length_response_yields_empty_sample() {
        // Regression: an empty response after `trim_at_eos` must produce an
        // empty, zero-ratio sample — not a panic — for every method,
        // without consuming any RNG draws.
        let mut rng = Rng::new(12);
        let before = rng.clone();
        for method in [
            Method::Grpo,
            Method::Urs { p: 0.5 },
            Method::DetTrunc { frac: 0.5 },
            Method::Rpc { min_cut: 8 },
            Method::Saliency { floor: 0.25 },
            Method::Stratified { p: 0.5 },
            Method::Poisson { k: 8 },
        ] {
            let s = sample_ctx(&method, 0, Some(&[]), &mut rng);
            assert!(s.ht_w.is_empty(), "{method:?}");
            assert_eq!(s.kept, 0);
            assert_eq!(s.learn_len, 0);
            assert_eq!(s.selected_ratio(), 0.0);
        }
        // the RNG stream is untouched
        let mut a = before;
        assert_eq!(a.next_u64(), rng.next_u64());
    }

    #[test]
    fn degenerate_lengths() {
        let mut rng = Rng::new(8);
        for method in [
            Method::Grpo,
            Method::Urs { p: 0.5 },
            Method::DetTrunc { frac: 0.5 },
            Method::Rpc { min_cut: 8 },
            Method::Stratified { p: 0.5 },
            Method::Poisson { k: 8 },
        ] {
            let s = sample(&method, 1, &mut rng);
            assert_eq!(s.ht_w.len(), 1);
            assert!(s.learn_len >= 1);
            assert!(
                s.kept >= 1
                    || matches!(method, Method::Urs { .. } | Method::Stratified { .. }),
            );
        }
    }
}
