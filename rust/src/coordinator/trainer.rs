//! The NAT×GRPO training loop — the L3 system the paper's learner-side
//! claims are measured on.
//!
//! One optimizer step:
//!   rollout (G completions per prompt) → verify rewards → group-relative
//!   advantages → token selection (`coordinator::selection`: a `Selector`
//!   per method; under `--train.budget_mode batch` the batch controller
//!   first re-solves the keep parameter so expected selected tokens hit
//!   `--train.token_budget`, and under `neyman` a variance-optimal
//!   per-sequence allocation replaces the shared selector, both with every
//!   solved π floored at `--train.pi_floor`) → micro-batching off
//!   `SelectionPlan::learn_len`
//!   (fixed or token-budget packer; see `--train.packer`; under
//!   `--train.compact` the budget packer re-keys scattered plans by
//!   KEPT-token count into gather-compacted `grad_K<k>_B<r>` micro-batches
//!   when that is strictly cheaper) → per-(bucket, rows) grad artifacts
//!   executed across `--train.shards` data-parallel workers → fixed-order
//!   tree reduction keyed by micro-batch id → AdamW apply.
//!   The reduction order is a pure function of the step plan, so any shard
//!   count produces bit-identical parameters and statistics
//!   (`runtime::shard`; proptested in `tests/sharding.rs`).
//!
//! The step is split into two reusable stage functions so the serial
//! [`Trainer`] and the pipelined trainer (`coordinator::pipeline`) share one
//! code path bit-for-bit:
//!
//! * [`rollout_stage`] — inference: tasks → grouped completions + rewards.
//! * [`learn_stage`]   — forward/backward/apply on a completed group.
//!
//! Every per-step random stream (task sampling, rollout seeds, NAT masks) is
//! derived as a pure function of `(cfg.seed, step)` via [`plan_step`] —
//! under the bucketed rollout engine, per-slot sampling seeds go one level
//! deeper, `(cfg.seed, step, flat_id)` — so (a) rollout workers can plan any
//! future step without having consumed the previous ones, and (b) resuming
//! from a checkpointed step reproduces the uninterrupted run exactly (the
//! `--train.auto_buckets` tuner, the one cross-step learner state outside
//! this scheme, is serialized into `TrainMeta`).
//!
//! Timing is split exactly as in the paper's Table 3: `t_learn` is the
//! train-time-per-step *excluding inference*, `t_total` includes rollout.

use std::time::Instant;

use anyhow::Result;

use crate::config::{BudgetMode, Method, Packer, RolloutEngine, RunConfig};
use crate::coordinator::batcher::{
    allocated_tokens, compact_stats, full_length_items, ideal_tokens, micro_shapes, pack,
    pack_budget, pack_budget_with, packer_token_budget, plan_shards, split_zero_contribution,
    LearnItem, MicroBatch,
};
use crate::coordinator::bucket_tuner::{BucketTuner, TunerState};
use crate::coordinator::rollout::scheduler::{RolloutScheduler, SchedStats};
use crate::coordinator::rollout::RolloutSeq;
use crate::coordinator::selection::{self, HtMoments, SelectionPlan, Selector};
use crate::coordinator::{advantage, rollout};
use crate::metrics::Recorder;
use crate::model::memory;
use crate::obs::ledger::StepLedger;
use crate::obs::Tracer;
use crate::runtime::shard::{execute_shards, tree_reduce_into};
use crate::runtime::{Checkpoint, GradAccum, GradMetrics, OptState, ParamStore, Runtime, TrainMeta};
use crate::tasks::{Task, TaskSampler};
use crate::tokenizer::Tokenizer;
use crate::util::rng::{stream_seed, Rng};

/// Per-step scalar statistics (the rows behind Figures 1-6).
#[derive(Clone, Debug)]
pub struct StepStats {
    pub step: u64,
    pub reward_mean: f64,
    pub entropy: f64,
    pub clip_frac: f64,
    pub kl: f64,
    pub grad_norm: f64,
    /// Fraction of response tokens selected for the update (Fig. 3).
    pub selected_ratio: f64,
    /// Batch budget controller target: the expected selected-token count
    /// per epoch the controller solved for (`--train.token_budget` under
    /// `--train.budget_mode batch|neyman`; 0 when the controller is off).
    pub budget_target: f64,
    /// Achieved expectation Σ_i E[kept_i] under the (possibly adjusted)
    /// inclusion probabilities, per epoch — the realized-vs-target series.
    pub budget_realized: f64,
    /// Selection variance: mean squared deviation of each sequence's
    /// realized kept-token count from its expectation. Stratified collapses
    /// this versus URS at the same rate.
    pub sel_var: f64,
    pub resp_len_mean: f64,
    /// Fraction of allocated learner tokens that were padding (bucket slack
    /// + inert rows). The budget packer exists to push this down.
    pub padding_waste: f64,
    /// Analytic mean allocated learner memory (Table 3 / Fig. 6 headline).
    pub mem_gb: f64,
    /// Analytic strict peak (largest single micro-batch).
    pub peak_mem_gb: f64,
    /// Train time per step WITHOUT inference (Table 3 col 2, Fig. 5).
    pub t_learn_s: f64,
    /// Total time per step including rollout (Table 3 col 3). For the
    /// pipelined trainer this is the wall-clock between consecutive applies
    /// (learner throughput), since rollout runs concurrently.
    pub t_total_s: f64,
    pub micro_batches: usize,
    pub sequences: usize,
    /// Per-step token/compute savings accounting (`obs::ledger`). Always
    /// computed — every input is a deterministic function of the step plan —
    /// so tracing on/off cannot perturb it; `--obs.ledger` only gates
    /// whether it is exported as Recorder series.
    pub ledger: StepLedger,
}

/// Stream tags for [`stream_seed`]; distinct per consumer so forked streams
/// at the same step stay decorrelated. The mixer itself lives in
/// `util::rng` (the blessed helper `nat lint` rule R3 checks for).
const TAG_TASKS: u64 = 0x5441_534B;
const TAG_ROLLOUT: u64 = 0x524F_4C4C;
const TAG_MASK: u64 = 0x4D41_534B;

/// Deterministic per-step context: tasks and RNG streams for optimizer step
/// `step` (0-based), independent of any other step's state.
pub struct StepPlan {
    pub step: u64,
    pub tasks: Vec<Task>,
    pub rng_rollout: Rng,
    pub rng_mask: Rng,
}

/// Build the plan for a step as a pure function of `(cfg.seed, step)`.
pub fn plan_step(cfg: &RunConfig, step: u64) -> StepPlan {
    let mut sampler =
        TaskSampler::new(stream_seed(cfg.seed, step, TAG_TASKS), cfg.task_mix());
    StepPlan {
        step,
        tasks: sampler.batch(cfg.rl.prompts_per_step),
        rng_rollout: Rng::new(stream_seed(cfg.seed, step, TAG_ROLLOUT)),
        rng_mask: mask_rng(cfg, step),
    }
}

/// The NAT mask stream for a step — same stream [`plan_step`] embeds, so the
/// pipelined learner (which receives rollout groups, not plans) re-derives
/// it identically.
pub fn mask_rng(cfg: &RunConfig, step: u64) -> Rng {
    Rng::new(stream_seed(cfg.seed, step, TAG_MASK))
}

/// A completed rollout batch for one optimizer step, ready for the learner.
pub struct RolloutGroup {
    /// 0-based optimizer step this group feeds.
    pub step: u64,
    pub seqs: Vec<RolloutSeq>,
    pub t_rollout_s: f64,
    /// Scheduler cost accounting for the group's rollouts (zeroed under the
    /// fixed engine). Carried so `learn_stage` can price the prefix-cache
    /// savings into the step ledger without re-touching the scheduler.
    pub sched_stats: SchedStats,
}

/// Stage 1 — inference. Pure with respect to `params`: the caller decides
/// which parameter snapshot the behaviour policy uses (the pipelined trainer
/// passes a possibly-stale published snapshot).
///
/// Engine dispatch: the bucketed scheduler derives per-slot seeds from
/// `(cfg.seed, step, flat_id)` — the rollout is a pure function of the plan
/// regardless of routing or refill order. The fixed engine replays the
/// legacy chunk-order scalar-seed stream (`plan.rng_rollout`); it is also
/// the automatic fallback when the artifact set predates `generate_buckets`.
///
/// `param_version` identifies the parameter snapshot behind `params` for the
/// scheduler's prefix cache (serial trainer: the step number, since params
/// change every step; pipelined trainer: the published snapshot version).
/// It never affects rollout content — only which cached KV blocks are
/// shareable.
pub fn rollout_stage(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    cfg: &RunConfig,
    sched: &RolloutScheduler,
    param_version: u64,
    plan: &mut StepPlan,
    tracer: &Tracer,
) -> Result<RolloutGroup> {
    // natlint: allow(wallclock, reason = "feeds only the t_rollout_s timing stat, which is excluded from golden-trace lines and all training math")
    let t0 = Instant::now();
    // span step is the 1-based optimizer step, matching `learn.step`
    let mut sp = tracer.span("rollout", plan.step + 1);
    let bucketed = cfg.rollout.engine == RolloutEngine::Bucketed
        && !rt.manifest.generate_files.is_empty();
    let (seqs, sched_stats) = if bucketed {
        rollout::run_group_rollouts_bucketed(
            rt,
            params,
            tok,
            &plan.tasks,
            cfg.rl.group_size,
            cfg.rl.temperature,
            cfg.seed,
            plan.step,
            sched,
            param_version,
        )?
    } else {
        let seqs = rollout::run_group_rollouts(
            rt,
            params,
            tok,
            &plan.tasks,
            cfg.rl.group_size,
            cfg.rl.temperature,
            &mut plan.rng_rollout,
        )?;
        // the fixed engine has no scheduler cost accounting
        (seqs, SchedStats::default())
    };
    for (k, v) in sched_stats.trace_args() {
        sp.arg(k, v);
    }
    sp.arg("seqs", seqs.len() as f64);
    sp.arg("gen_tokens", seqs.iter().map(|s| s.resp_len as f64).sum());
    drop(sp);
    Ok(RolloutGroup {
        step: plan.step,
        seqs,
        t_rollout_s: t0.elapsed().as_secs_f64(),
        sched_stats,
    })
}

/// The step's solved token selection. `budget_mode none|batch` share one
/// selector across every row (the per-row inputs flow through `ctx`);
/// `budget_mode neyman` solves a distinct inclusion rate per sequence from
/// `(|advantage|, length, behaviour surprisal)`, so sampling is dispatched
/// by row index against the solved allocation. Both arms draw in rollout
/// row order with the fixed per-row RNG consumption contract (zero draws
/// for empty rows), keeping the mask stream shard/replay-invariant.
enum StepSelection {
    Shared(Box<dyn Selector>),
    PerRow(selection::NeymanAllocation),
}

impl StepSelection {
    fn sample_row(
        &self,
        i: usize,
        t_i: usize,
        ctx: Option<&[f32]>,
        rng: &mut Rng,
    ) -> SelectionPlan {
        match self {
            StepSelection::Shared(sel) => sel.sample(t_i, ctx, rng),
            StepSelection::PerRow(alloc) => alloc.sample_row(i, t_i, rng),
        }
    }

    /// Closed-form per-epoch expectation Σ_i E[kept_i] — the
    /// `sel_tokens_exp` ledger input, independent of the realized draws.
    fn expected_sum(&self, rows: &[(usize, Option<&[f32]>)]) -> f64 {
        match self {
            StepSelection::Shared(sel) => selection::budget::expected_sum(sel.as_ref(), rows),
            StepSelection::PerRow(alloc) => alloc.expected_sum(),
        }
    }
}

/// Stage 2+3 — learner (forward + backward + apply), internally split into
/// shard plan → concurrent execute → fixed-order reduce → apply when
/// `cfg.train.shards > 1`. `step1` is the 1-based step number reported in
/// the stats; `t_total_s` is left at 0 for the caller to fill (serial:
/// elapsed incl. rollout; pipeline: apply-to-apply).
///
/// ppo_epochs >= 2 re-uses the rollout for multiple optimizer updates
/// (DAPO-style mini-batching): the first epoch is on-policy (ratio 1), later
/// epochs exercise the clipped off-policy path. Masks are re-sampled per
/// epoch, so every position keeps nonzero inclusion probability per update.
#[allow(clippy::too_many_arguments)]
pub fn learn_stage(
    rt: &Runtime,
    cfg: &RunConfig,
    params: &mut ParamStore,
    opt: &mut OptState,
    acc: &mut GradAccum,
    mut tuner: Option<&mut BucketTuner>,
    rng_mask: &mut Rng,
    step1: u64,
    seqs: &[RolloutSeq],
    sched_stats: &SchedStats,
    tracer: &Tracer,
) -> Result<StepStats> {
    // natlint: allow(wallclock, reason = "feeds only the t_learn_s timing stat, which is excluded from golden-trace lines and all training math")
    let t_learn_start = Instant::now();
    let mut sp_step = tracer.span("learn.step", step1);
    let d = &rt.manifest.dims;
    let g = cfg.rl.group_size;
    let rewards: Vec<f32> = seqs.iter().map(|s| s.reward).collect();
    let advs = advantage::grouped_advantages(&rewards, g);

    // Token selection for this step: the method literal's selector
    // (budget_mode none — bit-identical to the pre-subsystem code), the
    // batch controller's adjusted selector, or the Neyman per-sequence
    // allocation — each solved once per step from the group's actual
    // response lengths (lengths don't change across ppo epochs, so one
    // solve covers them all). Budget-solved π are floored at
    // `cfg.train.pi_floor`, which bounds every HT weight at `1/pi_floor`.
    let rows_ctx: Vec<(usize, Option<&[f32]>)> =
        seqs.iter().map(|s| (s.resp_len, Some(s.old_lp.as_slice()))).collect();
    let mut sp_solve = tracer.span("learn.select", step1);
    let (sel, budget_target): (StepSelection, f64) = match cfg.train.budget_mode {
        BudgetMode::Batch => {
            let out = selection::solve_batch(
                &cfg.method,
                &rows_ctx,
                cfg.train.token_budget,
                cfg.train.pi_floor,
            )?;
            for (k, v) in out.trace_args() {
                sp_solve.arg(k, v);
            }
            let target = out.target;
            (StepSelection::Shared(out.selector), target)
        }
        BudgetMode::Neyman => {
            let abs_adv: Vec<f64> = advs.iter().map(|&a| (a as f64).abs()).collect();
            let alloc = selection::solve_neyman(
                &rows_ctx,
                &abs_adv,
                cfg.train.token_budget,
                cfg.train.pi_floor,
            );
            for (k, v) in alloc.trace_args() {
                sp_solve.arg(k, v);
            }
            let target = alloc.target;
            (StepSelection::PerRow(alloc), target)
        }
        BudgetMode::None => (StepSelection::Shared(selection::selector_for(&cfg.method)), 0.0),
    };
    // The π floor actually in force this step, for the ledger/trace gate
    // (`w_max ≤ 1/pi_floor`). RPC is exempt by design: its prefix-survival
    // weights are bounded by `t - C + 1` already, and flooring survival
    // probabilities independently would change the sampling law.
    let pi_floor = match cfg.train.budget_mode {
        BudgetMode::Neyman => cfg.train.pi_floor,
        BudgetMode::Batch if !matches!(cfg.method, Method::Rpc { .. }) => cfg.train.pi_floor,
        _ => 0.0,
    };
    // Ledger: the closed-form per-epoch expectation Σ_i E[kept_i], through
    // `expected_sum` — an independent path from the per-plan probability
    // sums that feed `budget_realized`, which is what `nat trace --check`
    // compares it against (1% gate, no sampling noise on either side).
    let sel_tokens_exp = sel.expected_sum(&rows_ctx);
    drop(sp_solve);

    // Budget-packer routing state for this step. The tuned edges are a
    // function of PREVIOUS steps' observations only, so the step stays a
    // pure function of (params, group, tuner-state-in). Under budget_mode
    // batch/neyman the packer runs on its auto cap (`token_budget` is the
    // selection target there, not a packing cap).
    let budget = cfg.train.packer == Packer::Budget;
    // Gather-compacted grad layout: re-key scattered plans by kept-token
    // count when the config asks for it AND the manifest carries the
    // `grad_K<k>_B<r>` grid. Prefix-shaped plans always stay on the legacy
    // grid inside the packer, so prefix-method runs are bit-identical under
    // either setting.
    let compact = cfg.train.compact && budget && rt.manifest.has_compact();
    let pack_cap = packer_token_budget(&cfg.train);
    let row_grid = rt.manifest.row_grid();
    let edges: Vec<usize> = match tuner.as_deref() {
        Some(t) if budget => t.edges(&d.buckets, d.prompt_len, &row_grid, pack_cap),
        _ => d.buckets.clone(),
    };

    let mut metrics = GradMetrics::default();
    let mut grad_norm = 0.0;
    let mut sel_tokens = 0usize;
    let mut tot_tokens = 0usize;
    let mut exp_kept = 0.0f64;
    let mut sel_var_acc = 0.0f64;
    let mut alloc_toks = 0usize;
    let mut alloc_prefix_toks = 0usize;
    let mut compact_kept = 0usize;
    let mut compact_alloc = 0usize;
    let mut compact_bound = 0usize;
    let mut ideal_toks = 0usize;
    let mut backprop_toks = 0usize;
    let mut ht = HtMoments::default();
    let mut grad_flops = 0.0f64;
    let mut all_shapes: Vec<(usize, usize)> = Vec::new();
    let mut n_micro = 0usize;
    for _epoch in 0..cfg.rl.ppo_epochs {
        let mut sp_sel = tracer.span("learn.select", step1);
        let mut items = Vec::with_capacity(seqs.len());
        let mut empty_rows = 0usize;
        for (i, (seq, &adv)) in seqs.iter().zip(&advs).enumerate() {
            let plan = sel.sample_row(i, seq.resp_len, Some(&seq.old_lp), rng_mask);
            if seq.resp_len == 0 {
                // Degenerate empty response: nothing to select or forward
                // (the selector returned the empty plan without touching the
                // RNG stream), but the row stays in the 1/sequences apply
                // denominator like any other zero-contribution row.
                empty_rows += 1;
                continue;
            }
            let e = plan.expected_kept();
            exp_kept += e;
            sel_var_acc += (plan.kept as f64 - e) * (plan.kept as f64 - e);
            sel_tokens += plan.kept;
            tot_tokens += seq.resp_len;
            backprop_toks += plan.learn_len;
            ht.observe(&plan);
            items.push(LearnItem::from_plan(seq, plan, adv));
        }
        // Zero-contribution rows (no kept token / zero advantage) burn a
        // full forward for exactly nothing — drop them before packing.
        // `selected_ratio`/`resp_len_mean` above counted the full
        // population, and the dropped rows are restored into the apply
        // scale below, so the applied gradient and reward/selection series
        // match the unfiltered step exactly. Diagnostic token means
        // (entropy/clip_frac/kl) narrow to gradient-contributing tokens:
        // dropped kept==0 rows never had metric mass, and dropping
        // zero-variance-group rows is DAPO-style dynamic-sampling
        // semantics (documented in README). The fixed packer keeps the
        // pre-budget-packer path bit-for-bit, inert rows included.
        let (items, dropped) = if budget {
            split_zero_contribution(items)
        } else {
            (items, 0)
        };
        sp_sel.arg("items", items.len() as f64);
        sp_sel.arg("dropped", (dropped + empty_rows) as f64);
        drop(sp_sel);
        let mut sp_pack = tracer.span("learn.pack", step1);
        if let Some(t) = tuner.as_deref_mut() {
            let lens: Vec<usize> = items.iter().map(|i| i.learn_len).collect();
            t.observe(&lens);
        }
        let mbs: Vec<MicroBatch> = if budget {
            pack_budget_with(&items, &edges, d.prompt_len, &row_grid, pack_cap, compact)?
        } else {
            pack(&items, &d.buckets, d.prompt_len, d.batch_train)?
        };
        let epoch_alloc = allocated_tokens(&mbs, d.prompt_len);
        alloc_toks += epoch_alloc;
        // Realized-saving baseline: when anything actually compacted, price
        // the SAME items prefix-packed through the same packer; otherwise
        // the counterfactual IS the realized packing (saving reads 0).
        let (ck, ca, cb) = compact_stats(&mbs, &edges, &row_grid, d.prompt_len);
        compact_kept += ck;
        compact_alloc += ca;
        compact_bound += cb;
        alloc_prefix_toks += if ca > 0 {
            let prefix_mbs =
                pack_budget_with(&items, &edges, d.prompt_len, &row_grid, pack_cap, false)?;
            allocated_tokens(&prefix_mbs, d.prompt_len)
        } else {
            epoch_alloc
        };
        ideal_toks += ideal_tokens(&items, d.prompt_len);
        sp_pack.arg("micro_batches", mbs.len() as f64);
        sp_pack.arg("alloc_tokens", epoch_alloc as f64);
        sp_pack.arg("compact_alloc", ca as f64);
        drop(sp_pack);
        acc.reset();
        // Dropped inert and empty rows still count toward the 1/sequences
        // apply scale: they contributed zero gradient but a real
        // denominator row.
        acc.sequences += dropped + empty_rows;
        if !mbs.is_empty() {
            // §Perf opt-2: parameters are immutable within the epoch; build
            // the literals once and share across every shard worker.
            let sp_grad = tracer.span("learn.grad", step1);
            let param_lits = params.to_literals(&rt.manifest)?;
            // Shard plan → concurrent execute → fixed-order tree reduce.
            // The plan balances allocated token cost across
            // `cfg.train.shards` workers and the reduction order is keyed
            // by micro-batch id, so the summed gradient (and with it every
            // downstream stat) is bit-identical for every shard count.
            let plan = plan_shards(&mbs, d.prompt_len, cfg.train.shards);
            let leaves = execute_shards(rt, &mbs, &param_lits, &plan, tracer, step1)?;
            drop(sp_grad);
            let sp_reduce = tracer.span("learn.reduce", step1);
            tree_reduce_into(acc, &mut metrics, leaves);
            drop(sp_reduce);
        }
        let sp_apply = tracer.span("learn.apply", step1);
        grad_norm = rt.apply(params, opt, acc)?;
        drop(sp_apply);
        grad_flops += StepLedger::flops_of(d, &mbs);
        all_shapes.extend(micro_shapes(&mbs, d.prompt_len));
        n_micro += mbs.len();
    }
    let t_learn = t_learn_start.elapsed().as_secs_f64();

    let pc = rt.manifest.param_count;
    let mem_gb = memory::step_mean_bytes(d, pc, &all_shapes) as f64 / 1e9;
    let peak_bytes = memory::step_peak_bytes(d, pc, &all_shapes) as f64;

    // Savings ledger: price the full-token-GRPO counterfactual by re-packing
    // the SAME rollout group at `learn_len = resp_len` through the same
    // packer family on the manifest's bucket grid and auto token cap (the
    // baseline has no selection target to repurpose as a packing cap), so
    // `flop_saving`/`mem_saving` isolate what token selection bought.
    // Deterministic — always computed, tracing on or off.
    let mut sp_ledger = tracer.span("learn.ledger", step1);
    let cf_items = full_length_items(seqs);
    let cf_mbs: Vec<MicroBatch> = if budget {
        pack_budget(&cf_items, &d.buckets, d.prompt_len, &row_grid, 0)?
    } else {
        pack(&cf_items, &d.buckets, d.prompt_len, d.batch_train)?
    };
    let eps = cfg.rl.ppo_epochs as f64;
    let budget_realized = exp_kept / eps;
    let ledger = StepLedger {
        gen_tokens: seqs.iter().map(|s| s.resp_len as f64).sum(),
        sel_tokens: sel_tokens as f64 / eps,
        sel_tokens_exp,
        backprop_tokens: backprop_toks as f64 / eps,
        alloc_tokens: alloc_toks as f64 / eps,
        ideal_tokens: ideal_toks as f64 / eps,
        grad_flops: grad_flops / eps,
        grad_flops_full: StepLedger::flops_of(d, &cf_mbs),
        peak_bytes,
        peak_bytes_full: memory::step_peak_bytes(d, pc, &micro_shapes(&cf_mbs, d.prompt_len))
            as f64,
        ht_w_max: ht.w_max,
        ht_ess: ht.ess(),
        pi_floor,
        budget_realized,
        alloc_tokens_prefix: alloc_prefix_toks as f64 / eps,
        compact_kept: compact_kept as f64 / eps,
        compact_alloc: compact_alloc as f64 / eps,
        compact_bound: compact_bound as f64 / eps,
        // Prefix-cache pricing for this group's rollouts — not divided by
        // ppo_epochs: the rollout is generated once however many epochs
        // re-use it.
        prefill_steps_saved: sched_stats.prefill_steps_saved as f64,
        prefix_hits: sched_stats.prefill_hits as f64,
        prefix_lookups: sched_stats.prefill_lookups as f64,
        cache_bytes: sched_stats.cache_bytes as f64,
    };
    sp_ledger.arg("backprop_frac", ledger.backprop_frac());
    sp_ledger.arg("flop_saving", ledger.flop_saving());
    sp_ledger.arg("compact_saving", ledger.compact_saving());
    drop(sp_ledger);
    tracer.event("ledger", step1, &ledger.trace_args());
    sp_step.arg("micro_batches", n_micro as f64);
    sp_step.arg("sequences", seqs.len() as f64);
    drop(sp_step);

    Ok(StepStats {
        step: step1,
        reward_mean: rewards.iter().map(|&r| r as f64).sum::<f64>() / rewards.len() as f64,
        entropy: metrics.mean_entropy(),
        clip_frac: metrics.clip_frac(),
        kl: if metrics.tokens > 0.0 { metrics.kl_sum / metrics.tokens } else { 0.0 },
        grad_norm,
        selected_ratio: if tot_tokens > 0 {
            sel_tokens as f64 / tot_tokens as f64
        } else {
            0.0
        },
        budget_target,
        budget_realized,
        sel_var: if seqs.is_empty() {
            0.0
        } else {
            sel_var_acc / (seqs.len() * cfg.rl.ppo_epochs) as f64
        },
        resp_len_mean: tot_tokens as f64 / (seqs.len() * cfg.rl.ppo_epochs) as f64,
        padding_waste: if alloc_toks > 0 {
            1.0 - ideal_toks as f64 / alloc_toks as f64
        } else {
            0.0
        },
        mem_gb,
        peak_mem_gb: peak_bytes / 1e9,
        t_learn_s: t_learn,
        t_total_s: 0.0,
        micro_batches: n_micro,
        sequences: seqs.len(),
        ledger,
    })
}

/// Push one step's stats into the shared metric series. `ledger` gates the
/// savings-ledger series (`--obs.ledger`); the core series are unaffected so
/// existing exports stay schema-stable when it is off.
pub fn record_step(r: &mut Recorder, s: &StepStats, t_rollout_s: f64, ledger: bool) {
    r.push("reward", s.step, s.reward_mean);
    r.push("entropy", s.step, s.entropy);
    r.push("clip_frac", s.step, s.clip_frac);
    r.push("kl", s.step, s.kl);
    r.push("grad_norm", s.step, s.grad_norm);
    r.push("selected_ratio", s.step, s.selected_ratio);
    r.push("budget_target", s.step, s.budget_target);
    r.push("budget_realized", s.step, s.budget_realized);
    r.push("sel_var", s.step, s.sel_var);
    r.push("resp_len", s.step, s.resp_len_mean);
    r.push("padding_waste", s.step, s.padding_waste);
    r.push("mem_gb", s.step, s.mem_gb);
    r.push("peak_mem_gb", s.step, s.peak_mem_gb);
    r.push("t_learn_s", s.step, s.t_learn_s);
    r.push("t_rollout_s", s.step, t_rollout_s);
    r.push("t_total_s", s.step, s.t_total_s);
    if ledger {
        for (name, v) in s.ledger.series() {
            r.push(name, s.step, v);
        }
    }
}

/// Shared post-step bookkeeping: in-training evaluation every
/// `cfg.eval.every` steps and optional stdout logging. Used by both the
/// serial and pipelined trainers so their metric streams are identical.
pub(crate) fn post_step(
    rt: &Runtime,
    cfg: &RunConfig,
    recorder: &mut Recorder,
    params: &ParamStore,
    sched: Option<&RolloutScheduler>,
    s: &StepStats,
    verbose: bool,
) -> Result<()> {
    if cfg.eval.every > 0 && s.step % cfg.eval.every as u64 == 0 {
        let evals = crate::coordinator::evaluator::evaluate_all_tiers(
            rt,
            params,
            cfg.eval.tasks_per_tier,
            cfg.eval.k,
            cfg.rl.temperature,
            cfg.seed ^ s.step,
            sched,
            s.step,
        )?;
        for e in &evals {
            recorder.push(&format!("acc_{}", e.tier.benchmark_name()), s.step, e.acc_at_k);
            recorder.push(&format!("pass_{}", e.tier.benchmark_name()), s.step, e.pass_at_k);
        }
        if verbose {
            println!(
                "  eval @ step {}: {}",
                s.step,
                evals
                    .iter()
                    .map(|e| format!("{} {:.3}", e.tier.benchmark_name(), e.acc_at_k))
                    .collect::<Vec<_>>()
                    .join("  ")
            );
        }
    }
    if verbose {
        println!(
            "step {:>4} | reward {:.3} | ent {:.3} | gnorm {:.3} | sel {:.2} | \
             mem {:.3} GB | learn {:.2}s | total {:.2}s",
            s.step,
            s.reward_mean,
            s.entropy,
            s.grad_norm,
            s.selected_ratio,
            s.mem_gb,
            s.t_learn_s,
            s.t_total_s
        );
    }
    Ok(())
}

/// Mid-run checkpointing: every `cfg.rl.ckpt_every` completed steps, save
/// params + optimizer state + train meta (including the auto-tuner's EMA
/// state, the one cross-step learner state not derivable from
/// `(seed, step)`) to the run's rolling checkpoint path
/// (`nat train --resume <path>` continues from it). Returns the path
/// written, if any.
pub(crate) fn maybe_checkpoint(
    rt: &Runtime,
    cfg: &RunConfig,
    params: &ParamStore,
    opt: &OptState,
    tuner: Option<&BucketTuner>,
    completed_step: u64,
) -> Result<Option<String>> {
    if cfg.rl.ckpt_every == 0 || completed_step % cfg.rl.ckpt_every as u64 != 0 {
        return Ok(None);
    }
    let path = cfg.rolling_ckpt_path();
    Checkpoint::save_train(
        std::path::Path::new(&path),
        &rt.manifest,
        params,
        opt,
        &TrainMeta {
            step: completed_step,
            seed: cfg.seed,
            tuner: tuner.map(BucketTuner::state),
            shards: cfg.train.shards,
        },
    )?;
    Ok(Some(path))
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub tok: Tokenizer,
    pub params: ParamStore,
    pub opt: OptState,
    pub recorder: Recorder,
    acc: GradAccum,
    tuner: Option<BucketTuner>,
    sched: RolloutScheduler,
    /// Separate routing state for in-training evaluation: eval response
    /// lengths (different task mix, k samples) must not fold into the
    /// TRAINING predictor's EMA and skew rollout routing cost.
    eval_sched: RolloutScheduler,
    /// Structured-trace emitter (`--obs.trace`/`--obs.chrome`); off by
    /// default — the off tracer is a `None` branch taken before any clock
    /// read, so an untraced run is bit-identical to a no-obs build.
    tracer: Tracer,
    step: u64,
}

/// EMA blend factor for the optional bucket auto-tuner.
pub(crate) const TUNER_ALPHA: f64 = 0.2;

/// Build the learn-len auto-tuner when the config asks for it (budget
/// packer only: the fixed packer is the bit-exact compatibility path).
pub(crate) fn make_tuner(rt: &Runtime, cfg: &RunConfig) -> Option<BucketTuner> {
    (cfg.train.auto_buckets && cfg.train.packer == Packer::Budget)
        .then(|| BucketTuner::new(rt.manifest.dims.max_resp, TUNER_ALPHA))
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: RunConfig,
        params: ParamStore,
        opt: OptState,
    ) -> Trainer<'rt> {
        Trainer {
            rt,
            tok: Tokenizer::new(),
            params,
            opt,
            recorder: Recorder::new(),
            acc: GradAccum::zeros(rt.manifest.param_count),
            tuner: make_tuner(rt, &cfg),
            sched: RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &cfg.rollout),
            eval_sched: RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &cfg.rollout),
            tracer: Tracer::off(),
            cfg,
            step: 0,
        }
    }

    /// Install a trace emitter (built by the caller from `cfg.obs`, or
    /// injected directly in tests). The default is the no-op tracer.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Number of optimizer steps completed so far.
    pub fn completed_steps(&self) -> u64 {
        self.step
    }

    /// Continue a checkpointed run: steps before `step` are considered done
    /// (their plans are skipped deterministically, so the continuation
    /// reproduces the uninterrupted run).
    pub fn set_start_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Restore the auto-tuner's EMA state from a resumed checkpoint (no-op
    /// when the config does not use `--train.auto_buckets`).
    pub fn restore_tuner(&mut self, state: Option<&TunerState>) {
        if let (Some(t), Some(s)) = (self.tuner.as_mut(), state) {
            *t = BucketTuner::from_state(s.clone());
        }
    }

    /// Snapshot the auto-tuner's EMA state for checkpointing.
    pub fn tuner_state(&self) -> Option<TunerState> {
        self.tuner.as_ref().map(BucketTuner::state)
    }

    /// Scheduler handle for engine-aware evaluation (None under the fixed
    /// engine — evaluation then replays the legacy chunked loop). This is
    /// an eval-scoped scheduler, NOT the training one, so eval lengths
    /// never pollute training routing.
    pub fn eval_sched(&self) -> Option<&RolloutScheduler> {
        (self.cfg.rollout.engine == RolloutEngine::Bucketed).then_some(&self.eval_sched)
    }

    /// Run one optimizer step; returns its statistics.
    pub fn step(&mut self) -> Result<StepStats> {
        // natlint: allow(wallclock, reason = "feeds only the steps/s progress line, which is excluded from golden-trace lines and all training math")
        let t_start = Instant::now();
        let mut plan = plan_step(&self.cfg, self.step);
        // Serial trainer: parameters change every step, so the step number
        // IS the snapshot version for the scheduler's prefix cache.
        let group = rollout_stage(
            self.rt,
            &self.params,
            &self.tok,
            &self.cfg,
            &self.sched,
            self.step,
            &mut plan,
            &self.tracer,
        )?;
        let mut stats = learn_stage(
            self.rt,
            &self.cfg,
            &mut self.params,
            &mut self.opt,
            &mut self.acc,
            self.tuner.as_mut(),
            &mut plan.rng_mask,
            self.step + 1,
            &group.seqs,
            &group.sched_stats,
            &self.tracer,
        )?;
        self.step += 1;
        stats.t_total_s = t_start.elapsed().as_secs_f64();
        record_step(&mut self.recorder, &stats, group.t_rollout_s, self.cfg.obs.ledger);
        Ok(stats)
    }

    /// Run `n` steps, optionally logging to stdout. When cfg.eval.every > 0
    /// an in-training benchmark evaluation is recorded every that-many
    /// steps (series `acc_<benchmark>` / `pass_<benchmark>`); when
    /// cfg.rl.ckpt_every > 0 a resumable checkpoint is written every
    /// that-many steps.
    pub fn train(&mut self, n: usize, verbose: bool) -> Result<()> {
        for _ in 0..n {
            let s = self.step()?;
            let sched = (self.cfg.rollout.engine == RolloutEngine::Bucketed)
                .then_some(&self.eval_sched);
            post_step(self.rt, &self.cfg, &mut self.recorder, &self.params, sched, &s, verbose)?;
            if let Some(path) = maybe_checkpoint(
                self.rt,
                &self.cfg,
                &self.params,
                &self.opt,
                self.tuner.as_ref(),
                s.step,
            )? {
                if verbose {
                    println!("  checkpoint @ step {}: {path}", s.step);
                }
            }
        }
        Ok(())
    }
}
