//! The NAT×GRPO training loop — the L3 system the paper's learner-side
//! claims are measured on.
//!
//! One optimizer step:
//!   rollout (G completions per prompt) → verify rewards → group-relative
//!   advantages → NAT mask sampling + HT weights → bucketed micro-batching
//!   → per-bucket grad artifacts with host-side accumulation → AdamW apply.
//!
//! Timing is split exactly as in the paper's Table 3: `t_learn` is the
//! train-time-per-step *excluding inference*, `t_total` includes rollout.

use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::batcher::{micro_shapes, pack, LearnItem};
use crate::coordinator::{advantage, masking, rollout};
use crate::metrics::Recorder;
use crate::model::memory;
use crate::runtime::{GradAccum, GradMetrics, OptState, ParamStore, Runtime};
use crate::tasks::TaskSampler;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Per-step scalar statistics (the rows behind Figures 1-6).
#[derive(Clone, Debug)]
pub struct StepStats {
    pub step: u64,
    pub reward_mean: f64,
    pub entropy: f64,
    pub clip_frac: f64,
    pub kl: f64,
    pub grad_norm: f64,
    /// Fraction of response tokens selected for the update (Fig. 3).
    pub selected_ratio: f64,
    pub resp_len_mean: f64,
    /// Analytic mean allocated learner memory (Table 3 / Fig. 6 headline).
    pub mem_gb: f64,
    /// Analytic strict peak (largest single micro-batch).
    pub peak_mem_gb: f64,
    /// Train time per step WITHOUT inference (Table 3 col 2, Fig. 5).
    pub t_learn_s: f64,
    /// Total time per step including rollout (Table 3 col 3).
    pub t_total_s: f64,
    pub micro_batches: usize,
    pub sequences: usize,
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub tok: Tokenizer,
    pub params: ParamStore,
    pub opt: OptState,
    pub recorder: Recorder,
    sampler: TaskSampler,
    rng_rollout: Rng,
    rng_mask: Rng,
    acc: GradAccum,
    step: u64,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: RunConfig,
        params: ParamStore,
        opt: OptState,
    ) -> Trainer<'rt> {
        let mut root = Rng::new(cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));
        let sampler = TaskSampler::new(root.fork(1).next_u64(), cfg.task_mix());
        Trainer {
            rt,
            tok: Tokenizer::new(),
            params,
            opt,
            recorder: Recorder::new(),
            sampler,
            rng_rollout: root.fork(2),
            rng_mask: root.fork(3),
            acc: GradAccum::zeros(rt.manifest.param_count),
            cfg,
            step: 0,
        }
    }

    /// Run one optimizer step; returns its statistics.
    pub fn step(&mut self) -> Result<StepStats> {
        let t_start = Instant::now();
        let d = &self.rt.manifest.dims;
        let g = self.cfg.rl.group_size;
        let tasks = self.sampler.batch(self.cfg.rl.prompts_per_step);

        // --- Stage 1: rollout (inference) --------------------------------
        let seqs = rollout::run_group_rollouts(
            self.rt,
            &self.params,
            &self.tok,
            &tasks,
            g,
            self.cfg.rl.temperature,
            &mut self.rng_rollout,
        )?;
        let t_rollout = t_start.elapsed().as_secs_f64();

        // --- Stage 2+3: learner (forward + backward + apply) -------------
        // ppo_epochs >= 2 re-uses the rollout for multiple optimizer
        // updates (DAPO-style mini-batching): the first epoch is on-policy
        // (ratio 1), later epochs exercise the clipped off-policy path.
        // Masks are re-sampled per epoch, so every position keeps nonzero
        // inclusion probability per update.
        let t_learn_start = Instant::now();
        let rewards: Vec<f32> = seqs.iter().map(|s| s.reward).collect();
        let advs = advantage::grouped_advantages(&rewards, g);

        let mut metrics = GradMetrics::default();
        let mut grad_norm = 0.0;
        let mut sel_tokens = 0usize;
        let mut tot_tokens = 0usize;
        let mut all_shapes: Vec<(usize, usize)> = Vec::new();
        let mut n_micro = 0usize;
        for _epoch in 0..self.cfg.rl.ppo_epochs {
            let mut items = Vec::with_capacity(seqs.len());
            for (seq, &adv) in seqs.iter().zip(&advs) {
                let m = masking::sample_ctx(
                    &self.cfg.method,
                    seq.resp_len,
                    Some(&seq.old_lp),
                    &mut self.rng_mask,
                );
                sel_tokens += m.kept;
                tot_tokens += seq.resp_len;
                items.push(LearnItem {
                    tokens: seq.tokens.clone(),
                    pad_len: seq.pad_len,
                    resp_len: seq.resp_len,
                    ht_w: m.ht_w,
                    learn_len: m.learn_len,
                    adv,
                    old_lp: seq.old_lp.clone(),
                });
            }
            let mbs = pack(&items, &d.buckets, d.prompt_len, d.batch_train);
            self.acc.reset();
            // §Perf opt-2: parameters are immutable within the epoch; build
            // the literals once and share across every bucket micro-batch.
            let param_lits = self.params.to_literals(&self.rt.manifest)?;
            for mb in &mbs {
                let m = self.rt.grad_cached(mb, &param_lits, &mut self.acc)?;
                metrics.add(&m);
            }
            drop(param_lits);
            grad_norm = self.rt.apply(&mut self.params, &mut self.opt, &self.acc)?;
            all_shapes.extend(micro_shapes(&mbs, d.prompt_len));
            n_micro += mbs.len();
        }
        let t_learn = t_learn_start.elapsed().as_secs_f64();
        let t_total = t_start.elapsed().as_secs_f64();

        let pc = self.rt.manifest.param_count;
        let mem_gb = memory::step_mean_bytes(d, pc, &all_shapes) as f64 / 1e9;
        let peak_mem_gb = memory::step_peak_bytes(d, pc, &all_shapes) as f64 / 1e9;

        self.step += 1;
        let stats = StepStats {
            step: self.step,
            reward_mean: rewards.iter().map(|&r| r as f64).sum::<f64>()
                / rewards.len() as f64,
            entropy: metrics.mean_entropy(),
            clip_frac: metrics.clip_frac(),
            kl: if metrics.tokens > 0.0 { metrics.kl_sum / metrics.tokens } else { 0.0 },
            grad_norm,
            selected_ratio: if tot_tokens > 0 {
                sel_tokens as f64 / tot_tokens as f64
            } else {
                0.0
            },
            resp_len_mean: tot_tokens as f64
                / (seqs.len() * self.cfg.rl.ppo_epochs) as f64,
            mem_gb,
            peak_mem_gb,
            t_learn_s: t_learn,
            t_total_s: t_total,
            micro_batches: n_micro,
            sequences: seqs.len(),
        };
        self.record(&stats, t_rollout);
        Ok(stats)
    }

    fn record(&mut self, s: &StepStats, t_rollout: f64) {
        let r = &mut self.recorder;
        r.push("reward", s.step, s.reward_mean);
        r.push("entropy", s.step, s.entropy);
        r.push("clip_frac", s.step, s.clip_frac);
        r.push("kl", s.step, s.kl);
        r.push("grad_norm", s.step, s.grad_norm);
        r.push("selected_ratio", s.step, s.selected_ratio);
        r.push("resp_len", s.step, s.resp_len_mean);
        r.push("mem_gb", s.step, s.mem_gb);
        r.push("peak_mem_gb", s.step, s.peak_mem_gb);
        r.push("t_learn_s", s.step, s.t_learn_s);
        r.push("t_rollout_s", s.step, t_rollout);
        r.push("t_total_s", s.step, s.t_total_s);
    }

    /// Run `n` steps, optionally logging to stdout. When cfg.eval.every > 0
    /// an in-training benchmark evaluation is recorded every that-many
    /// steps (series `acc_<benchmark>` / `pass_<benchmark>`).
    pub fn train(&mut self, n: usize, verbose: bool) -> Result<()> {
        for _ in 0..n {
            let s = self.step()?;
            if self.cfg.eval.every > 0 && s.step % self.cfg.eval.every as u64 == 0 {
                let evals = crate::coordinator::evaluator::evaluate_all_tiers(
                    self.rt,
                    &self.params,
                    self.cfg.eval.tasks_per_tier,
                    self.cfg.eval.k,
                    self.cfg.rl.temperature,
                    self.cfg.seed ^ s.step,
                )?;
                for e in &evals {
                    self.recorder.push(
                        &format!("acc_{}", e.tier.benchmark_name()),
                        s.step,
                        e.acc_at_k,
                    );
                    self.recorder.push(
                        &format!("pass_{}", e.tier.benchmark_name()),
                        s.step,
                        e.pass_at_k,
                    );
                }
                if verbose {
                    println!(
                        "  eval @ step {}: {}",
                        s.step,
                        evals
                            .iter()
                            .map(|e| format!(
                                "{} {:.3}",
                                e.tier.benchmark_name(),
                                e.acc_at_k
                            ))
                            .collect::<Vec<_>>()
                            .join("  ")
                    );
                }
            }
            if verbose {
                println!(
                    "step {:>4} | reward {:.3} | ent {:.3} | gnorm {:.3} | sel {:.2} | \
                     mem {:.3} GB | learn {:.2}s | total {:.2}s",
                    s.step,
                    s.reward_mean,
                    s.entropy,
                    s.grad_norm,
                    s.selected_ratio,
                    s.mem_gb,
                    s.t_learn_s,
                    s.t_total_s
                );
            }
        }
        Ok(())
    }
}
