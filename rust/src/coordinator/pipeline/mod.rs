//! Async pipelined rollout/learner orchestration.
//!
//! The serial [`Trainer`](crate::coordinator::trainer::Trainer) alternates
//! rollout and learning in one thread, so rollout latency caps throughput no
//! matter how cheap NAT makes the update. This subsystem decouples them:
//!
//! * N **rollout workers** claim optimizer steps from an atomic counter,
//!   plan each step deterministically (`plan_step` is a pure function of
//!   `(seed, step)`), generate the step's `RolloutSeq` group against the
//!   freshest *published parameter snapshot* that satisfies the staleness
//!   bound, and push it into a bounded channel.
//! * The **learner** (caller's thread) consumes groups strictly in step
//!   order, runs the existing NAT mask → HT-weight → bucketed-microbatch →
//!   grad/apply path via `learn_stage`, then publishes the new parameters
//!   as snapshot version `step + 1`.
//!
//! Staleness is bounded per group: a group for step `k` is rolled out with
//! parameters at version `>= k - max_staleness`. The PPO clipped ratio
//! already corrects one-step-off-policy data (NAT leaves the rollout
//! pipeline untouched, which is what makes the overlap safe), and the
//! realized lag is recorded per step as the `staleness` metric series.
//!
//! Semantics by worker count:
//! * `workers == 1` — staleness is forced to 0: rollout `k` waits for apply
//!   `k-1`, making the run **bit-identical to the serial trainer** for the
//!   same seed (the validation mode; asserted in `tests/runtime_e2e.rs`).
//! * `workers >= 2` — rollout of step `k` overlaps learning of step `k-1`
//!   (up to `max_staleness` steps of lag), trading strict on-policyness for
//!   throughput; runs are reward-equivalent, not bit-identical.
//!
//! The learner clones the parameter store once per publish; for the paper's
//! model sizes this is microseconds against a multi-second step, and it
//! keeps workers lock-free on the fast path (they share `Arc`s, never the
//! live mutable params).
//!
//! The learn stage itself may additionally be data-parallel: with
//! `--train.shards K` the consumed group's micro-batches execute across K
//! grad workers inside `learn_stage` (scoped threads, joined before the
//! apply), composing with rollout pipelining — rollout workers keep
//! producing while the learner's shards crunch the current step. Because
//! the shard reduction order is derived from the step plan, pipelined runs
//! stay bit-identical across shard counts exactly like serial runs.

pub mod engine;
pub mod sync;

pub use engine::{GroupMeta, PipelineOpts};

use std::cell::RefCell;
use std::time::Instant;

use anyhow::Result;

use crate::config::{RolloutEngine, RunConfig};
use crate::coordinator::bucket_tuner::{BucketTuner, TunerState};
use crate::coordinator::rollout::scheduler::RolloutScheduler;
use crate::coordinator::trainer::{
    learn_stage, make_tuner, mask_rng, maybe_checkpoint, plan_step, post_step, record_step,
    rollout_stage, RolloutGroup,
};
use crate::metrics::Recorder;
use crate::obs::Tracer;
use crate::runtime::{GradAccum, OptState, ParamStore, Runtime};
use crate::tokenizer::Tokenizer;

/// Pipelined counterpart of `Trainer`: same fields, same metric series
/// (plus `staleness`), different execution schedule.
pub struct PipelineTrainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub tok: Tokenizer,
    pub params: ParamStore,
    pub opt: OptState,
    pub recorder: Recorder,
    acc: GradAccum,
    tuner: Option<BucketTuner>,
    /// Shared across rollout workers (routing state behind a mutex; output
    /// stays a pure function of the slot plan, so sharing is benign).
    sched: RolloutScheduler,
    /// Eval-scoped routing state (see `Trainer::eval_sched`): in-training
    /// evaluation must not fold its lengths into the training predictor.
    eval_sched: RolloutScheduler,
    /// Structured-trace emitter (off by default). `Tracer` is `Sync`, so
    /// rollout workers share it with the learner thread; producer spans
    /// land on worker time anyway because spans carry their own clocks.
    tracer: Tracer,
    step: u64,
}

impl<'rt> PipelineTrainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg: RunConfig,
        params: ParamStore,
        opt: OptState,
    ) -> PipelineTrainer<'rt> {
        PipelineTrainer {
            rt,
            tok: Tokenizer::new(),
            params,
            opt,
            recorder: Recorder::new(),
            acc: GradAccum::zeros(rt.manifest.param_count),
            tuner: make_tuner(rt, &cfg),
            sched: RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &cfg.rollout),
            eval_sched: RolloutScheduler::from_cfg(rt.manifest.dims.max_resp, &cfg.rollout),
            tracer: Tracer::off(),
            cfg,
            step: 0,
        }
    }

    /// Install a tracer built from `--obs.trace` / `--obs.chrome` (see
    /// `Tracer::from_cfg`). Purely observational: spans never alter the
    /// training computation.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Number of optimizer steps completed so far.
    pub fn completed_steps(&self) -> u64 {
        self.step
    }

    /// Continue a checkpointed run from `step` (see `Trainer::set_start_step`).
    pub fn set_start_step(&mut self, step: u64) {
        self.step = step;
    }

    /// Restore the auto-tuner's EMA state from a resumed checkpoint (no-op
    /// when the config does not use `--train.auto_buckets`).
    pub fn restore_tuner(&mut self, state: Option<&TunerState>) {
        if let (Some(t), Some(s)) = (self.tuner.as_mut(), state) {
            *t = BucketTuner::from_state(s.clone());
        }
    }

    /// Snapshot the auto-tuner's EMA state for checkpointing.
    pub fn tuner_state(&self) -> Option<TunerState> {
        self.tuner.as_ref().map(BucketTuner::state)
    }

    /// Scheduler handle for engine-aware evaluation (None under the fixed
    /// engine — evaluation then replays the legacy chunked loop). This is
    /// an eval-scoped scheduler, NOT the training one, so eval lengths
    /// never pollute training routing.
    pub fn eval_sched(&self) -> Option<&RolloutScheduler> {
        (self.cfg.rollout.engine == RolloutEngine::Bucketed).then_some(&self.eval_sched)
    }

    /// The effective engine options for this config: a single worker is
    /// forced synchronous so it stays bit-identical to the serial trainer.
    pub fn engine_opts(&self) -> PipelineOpts {
        let workers = self.cfg.pipeline.workers.max(1);
        PipelineOpts {
            workers,
            queue_depth: self.cfg.pipeline.queue_depth,
            max_staleness: if workers <= 1 { 0 } else { self.cfg.pipeline.max_staleness },
        }
    }

    /// Run `n` optimizer steps through the pipeline. Records the same series
    /// as the serial trainer plus `staleness`; honours `cfg.eval.every` and
    /// `cfg.rl.ckpt_every` identically (both run on the learner thread).
    pub fn train(&mut self, n: usize, verbose: bool) -> Result<()> {
        let opts = self.engine_opts();
        let start = self.step;
        let end = start + n as u64;
        if verbose {
            println!(
                "pipeline: {} rollout worker(s), queue {}, max staleness {}, {} learner shard(s)",
                opts.workers, opts.queue_depth, opts.max_staleness, self.cfg.train.shards
            );
        }

        // The producer closure (shared across worker threads) captures only
        // immutable handles; all learner-side mutable state lives behind one
        // RefCell shared by `consume` and `after_publish` — both run
        // sequentially on this thread, never nested.
        let rt = self.rt;
        let cfg = &self.cfg;
        let tok = &self.tok;
        let sched = &self.sched;
        let tracer = &self.tracer;
        let eval_sched =
            (cfg.rollout.engine == RolloutEngine::Bucketed).then_some(&self.eval_sched);
        struct LearnerState<'s> {
            params: &'s mut ParamStore,
            opt: &'s mut OptState,
            acc: &'s mut GradAccum,
            recorder: &'s mut Recorder,
            tuner: &'s mut Option<BucketTuner>,
            step: &'s mut u64,
            last_apply: Instant,
            /// Stats of the step consumed but not yet post-processed.
            pending: Option<crate::coordinator::trainer::StepStats>,
        }
        let state = RefCell::new(LearnerState {
            params: &mut self.params,
            opt: &mut self.opt,
            acc: &mut self.acc,
            recorder: &mut self.recorder,
            tuner: &mut self.tuner,
            step: &mut self.step,
            // natlint: allow(wallclock, reason = "learner-throughput metric (t_total_s); excluded from golden traces and training math")
            last_apply: Instant::now(),
            pending: None,
        });
        let init = state.borrow().params.clone();

        // `version` is the engine's snapshot version for `snap` — the prefix
        // cache keys KV blocks by it, so groups rolled out against different
        // published snapshots never share prefills while concurrent workers
        // on the SAME snapshot do.
        let produce = |step: u64, version: u64, snap: &ParamStore| -> Result<RolloutGroup> {
            let mut plan = plan_step(cfg, step);
            rollout_stage(rt, snap, tok, cfg, sched, version, &mut plan, tracer)
        };
        let consume = |meta: &GroupMeta, group: RolloutGroup| -> Result<ParamStore> {
            let mut guard = state.borrow_mut();
            let st = &mut *guard;
            let mut rng_mask = mask_rng(cfg, meta.step);
            // Queue health as a trace event: how deep the learner's wait ran
            // and how stale the group's behaviour snapshot was.
            tracer.event(
                "pipeline.consume",
                meta.step + 1,
                &[
                    ("staleness", meta.staleness() as f64),
                    ("wait_s", meta.wait_s),
                    ("produce_s", meta.produce_s),
                ],
            );
            let mut stats = learn_stage(
                rt,
                cfg,
                st.params,
                st.opt,
                st.acc,
                st.tuner.as_mut(),
                &mut rng_mask,
                meta.step + 1,
                &group.seqs,
                &group.sched_stats,
                tracer,
            )?;
            // Learner throughput: wall-clock between consecutive applies
            // (rollout ran concurrently, so serial-style "rollout + learn"
            // would double-count overlapped time).
            stats.t_total_s = st.last_apply.elapsed().as_secs_f64();
            // natlint: allow(wallclock, reason = "learner-throughput metric (t_total_s); excluded from golden traces and training math")
            st.last_apply = Instant::now();
            record_step(st.recorder, &stats, group.t_rollout_s, cfg.obs.ledger);
            st.recorder.push("staleness", stats.step, meta.staleness() as f64);
            // Worker-side wall-clock for the whole produce stage (planning +
            // generation); `t_rollout_s` above is the generate call alone.
            st.recorder.push("t_produce_s", stats.step, meta.produce_s);
            // Learner-side block time waiting on the queue for this group.
            st.recorder.push("t_wait_s", stats.step, meta.wait_s);
            *st.step += 1;
            let snap = st.params.clone();
            st.pending = Some(stats);
            Ok(snap)
        };
        // Runs after the engine publishes the new snapshot, so rollout
        // workers resume immediately while the learner does its slow
        // bookkeeping (in-training eval, checkpoint I/O).
        let after_publish = |_meta: &GroupMeta| -> Result<()> {
            let mut guard = state.borrow_mut();
            let st = &mut *guard;
            let stats = st.pending.take().expect("after_publish without a consumed step");
            post_step(rt, cfg, st.recorder, st.params, eval_sched, &stats, verbose)?;
            if let Some(path) =
                maybe_checkpoint(rt, cfg, st.params, st.opt, st.tuner.as_ref(), stats.step)?
            {
                if verbose {
                    println!("  checkpoint @ step {}: {path}", stats.step);
                }
            }
            Ok(())
        };
        engine::run(&opts, start, end, init, produce, consume, after_publish)?;

        if verbose {
            if let Some(mean) = self.recorder.mean("staleness") {
                println!("pipeline: mean staleness {mean:.2} optimizer steps");
            }
        }
        Ok(())
    }
}
