//! The generic bounded-staleness producer/consumer engine.
//!
//! N worker threads produce one *group* per step against the freshest
//! published snapshot that satisfies the staleness bound; the caller's
//! thread consumes groups strictly in step order (a reorder buffer absorbs
//! worker completion jitter) and publishes a new snapshot after each one.
//!
//! The engine is deliberately independent of the trainer: `produce` and
//! `consume` are closures, so the scheduling, back-pressure, ordering and
//! shutdown logic is testable host-side with synthetic stages (see the
//! tests below) — no PJRT runtime or artifacts required. The trainer glue
//! lives in `coordinator::pipeline` (the parent module).
//!
//! ## Progress & shutdown invariants
//!
//! * Steps are claimed from an atomic counter, so claims are contiguous;
//!   a worker blocked on the staleness gate for step `k` can only be
//!   waiting on steps `< k`, all of which are claimed by other workers or
//!   already queued — no circular waits.
//! * The consumer always drains the channel (stashing out-of-order groups),
//!   so producers blocked on a full queue always make progress.
//! * Worker exits — normal, error, or panic — release the channel via a
//!   drop guard; consumer exits close both primitives, so no side can
//!   deadlock the other.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::sync::{Channel, ProducerGuard, SnapshotBoard};

/// Engine parameters (a validated subset of `config::PipelineCfg`).
#[derive(Clone, Copy, Debug)]
pub struct PipelineOpts {
    /// Producer threads (>= 1).
    pub workers: usize,
    /// Bounded channel capacity.
    pub queue_depth: usize,
    /// Max allowed `step - behaviour_version` for any produced group.
    /// 0 = fully synchronous: producing step `k` waits until every step
    /// `< k` has been consumed.
    pub max_staleness: u64,
}

/// Per-group provenance handed to the consumer.
#[derive(Clone, Copy, Debug)]
pub struct GroupMeta {
    /// The 0-based step this group feeds.
    pub step: u64,
    /// Snapshot version (= consumed-step count) the producer used.
    pub behaviour_version: u64,
    /// Wall-clock seconds the producer spent on this group.
    pub produce_s: f64,
    /// Wall-clock seconds the consumer blocked waiting for this group
    /// (filled in by the consumer loop; 0 when the group was already
    /// queued or stashed in the reorder buffer).
    pub wait_s: f64,
}

impl GroupMeta {
    /// How many optimizer steps behind the behaviour snapshot was.
    pub fn staleness(&self) -> u64 {
        self.step - self.behaviour_version
    }
}

/// Run steps `start..end` through the pipeline.
///
/// * `produce(step, version, &snapshot)` runs on worker threads; the
///   snapshot is guaranteed to satisfy
///   `version >= max(start, step - max_staleness)`, and `version` names it
///   (so producers can key snapshot-scoped caches without hashing `S`).
/// * `consume(&meta, group)` runs on the calling thread, strictly in step
///   order, and returns the snapshot to publish as `version = step + 1`.
/// * `after_publish(&meta)` runs on the calling thread AFTER the snapshot
///   is published — slow per-step bookkeeping (evaluation, checkpoint I/O)
///   belongs here so workers waiting at the staleness gate are released
///   first and keep rolling out while the learner does its housekeeping.
///
/// The first error from any stage aborts the run and is returned; a
/// worker panic propagates after shutdown.
pub fn run<S, G, P, C, A>(
    opts: &PipelineOpts,
    start: u64,
    end: u64,
    init: S,
    produce: P,
    mut consume: C,
    mut after_publish: A,
) -> Result<()>
where
    S: Send + Sync,
    G: Send,
    P: Fn(u64, u64, &S) -> Result<G> + Sync,
    C: FnMut(&GroupMeta, G) -> Result<S>,
    A: FnMut(&GroupMeta) -> Result<()>,
{
    if start >= end {
        return Ok(());
    }
    let workers = opts.workers.max(1);
    let chan: Channel<(GroupMeta, Result<G>)> =
        Channel::bounded(opts.queue_depth.max(1), workers);
    let board: SnapshotBoard<S> = SnapshotBoard::new(start, init);
    let next = AtomicU64::new(start);
    let abort = AtomicBool::new(false);

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..workers {
            let (chan, board, next, abort, produce) =
                (&chan, &board, &next, &abort, &produce);
            scope.spawn(move || {
                let _release = ProducerGuard(chan);
                loop {
                    if abort.load(Ordering::Acquire) {
                        break;
                    }
                    let k = next.fetch_add(1, Ordering::SeqCst);
                    if k >= end {
                        break;
                    }
                    let min_v = start.max(k.saturating_sub(opts.max_staleness));
                    let Ok((v, snap)) = board.wait_min(min_v) else { break };
                    // natlint: allow(wallclock, reason = "produce_s is a queue-health metric; no training output reads it")
                    let t0 = Instant::now();
                    let res = produce(k, v, &snap);
                    let failed = res.is_err();
                    let meta = GroupMeta {
                        step: k,
                        behaviour_version: v,
                        produce_s: t0.elapsed().as_secs_f64(),
                        wait_s: 0.0,
                    };
                    if chan.send((meta, res)).is_err() || failed {
                        break;
                    }
                }
            });
        }

        // Consumer side (this thread). Closes both primitives on every exit
        // path — including an unwinding `consume` — so workers never hang.
        struct ShutdownGuard<'a, S, T> {
            board: &'a SnapshotBoard<S>,
            chan: &'a Channel<T>,
            abort: &'a AtomicBool,
        }
        impl<S, T> Drop for ShutdownGuard<'_, S, T> {
            fn drop(&mut self) {
                self.abort.store(true, Ordering::Release);
                self.board.close();
                self.chan.close();
            }
        }
        let _shutdown = ShutdownGuard { board: &board, chan: &chan, abort: &abort };

        let mut pending: BTreeMap<u64, (GroupMeta, Result<G>)> = BTreeMap::new();
        let mut expected = start;
        while expected < end {
            // natlint: allow(wallclock, reason = "wait_s is a queue-health metric; no training output reads it")
            let t_wait = Instant::now();
            let (mut meta, group) = loop {
                if let Some(item) = pending.remove(&expected) {
                    break item;
                }
                match chan.recv() {
                    Some(item) => {
                        if item.0.step == expected {
                            break item;
                        }
                        pending.insert(item.0.step, item);
                    }
                    None => {
                        return Err(anyhow!(
                            "pipeline: workers exited before producing step {expected}"
                        ));
                    }
                }
            };
            meta.wait_s = t_wait.elapsed().as_secs_f64();
            debug_assert!(meta.staleness() <= opts.max_staleness);
            let snap = group.and_then(|g| consume(&meta, g))?;
            expected += 1;
            board.publish(expected, Arc::new(snap));
            after_publish(&meta)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn opts(workers: usize, queue_depth: usize, max_staleness: u64) -> PipelineOpts {
        PipelineOpts { workers, queue_depth, max_staleness }
    }

    /// workers=1, staleness=0 must behave exactly like the serial loop:
    /// every group is produced from the snapshot the previous consume
    /// published — the pipelined-equals-serial contract.
    #[test]
    fn synchronous_mode_matches_serial_fold() {
        let fold = |state: u64, k: u64| state.wrapping_mul(31).wrapping_add(k ^ 0xA5);
        // Serial reference.
        let mut serial = 1u64;
        for k in 0..20 {
            serial = fold(serial, k);
        }
        // Pipelined: produce captures the snapshot it saw; consume checks
        // it is the exact serial state and folds the step in.
        let mut state = 1u64;
        let seen = Mutex::new(Vec::new());
        run(
            &opts(1, 2, 0),
            0,
            20,
            1u64,
            |k, _v, snap: &u64| Ok((k, *snap)),
            |meta, (k, snap): (u64, u64)| {
                assert_eq!(meta.step, k);
                assert_eq!(meta.behaviour_version, k, "staleness 0 must be on-policy");
                assert_eq!(snap, state, "step {k} rolled out against a stale snapshot");
                state = fold(state, k);
                seen.lock().unwrap().push(k);
                Ok(state)
            },
            |_| Ok(()),
        )
        .unwrap();
        assert_eq!(state, serial);
        assert_eq!(*seen.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_consumption_and_staleness_bound_with_many_workers() {
        let stal = 2u64;
        let mut next_expected = 5u64;
        let mut consumed = 0u64;
        run(
            &opts(4, 3, stal),
            5,
            60,
            0u64,
            |k, v, _snap: &u64| {
                assert!(v <= k, "snapshot version cannot be from the future");
                Ok(k)
            },
            |meta, k: u64| {
                assert_eq!(k, next_expected, "groups must arrive in step order");
                assert!(meta.behaviour_version <= meta.step);
                assert!(
                    meta.behaviour_version >= 5u64.max(meta.step.saturating_sub(stal)),
                    "step {} used version {} (bound {})",
                    meta.step,
                    meta.behaviour_version,
                    stal
                );
                next_expected += 1;
                consumed += 1;
                Ok(consumed)
            },
            |_| Ok(()),
        )
        .unwrap();
        assert_eq!(consumed, 55);
    }

    #[test]
    fn produce_error_aborts_without_hanging() {
        let err = run(
            &opts(3, 2, 1),
            0,
            100,
            0u64,
            |k, _v, _snap: &u64| {
                if k == 7 {
                    Err(anyhow!("rollout worker exploded at step {k}"))
                } else {
                    Ok(k)
                }
            },
            |_meta, k: u64| Ok(k),
            |_| Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("step 7"), "{err:?}");
    }

    #[test]
    fn consume_error_aborts_without_hanging() {
        let err = run(
            &opts(3, 2, 1),
            0,
            100,
            0u64,
            |k, _v, _snap: &u64| Ok(k),
            |_meta, k: u64| {
                if k == 5 {
                    Err(anyhow!("learner rejected step {k}"))
                } else {
                    Ok(k)
                }
            },
            |_| Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("step 5"), "{err:?}");
    }

    #[test]
    fn empty_and_offset_ranges() {
        // start == end: no work, no threads needed.
        run(
            &opts(2, 2, 1),
            3,
            3,
            0u64,
            |_, _, _: &u64| Ok(()),
            |_, _: ()| Ok(0u64),
            |_| Ok(()),
        )
        .unwrap();
        // Resumed range: steps and versions begin at `start`; after_publish
        // fires once per step, after its consume.
        let mut steps = Vec::new();
        let mut after_steps = Vec::new();
        run(
            &opts(2, 2, 1),
            10,
            14,
            0u64,
            |k, _v, _: &u64| Ok(k),
            |meta, k: u64| {
                assert!(meta.behaviour_version >= 10);
                steps.push(k);
                Ok(k)
            },
            |meta| {
                after_steps.push(meta.step);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(steps, vec![10, 11, 12, 13]);
        assert_eq!(after_steps, steps);
    }
}
