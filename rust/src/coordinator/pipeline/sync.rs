//! Thread-coordination primitives for the rollout/learner pipeline.
//!
//! The offline vendor set has no `crossbeam`, so this module provides the
//! two primitives the engine needs, built on `Mutex` + `Condvar`:
//!
//! * [`Channel`] — a bounded MPSC queue. Producers block when the queue is
//!   full (back-pressure bounds rollout-ahead memory), the consumer blocks
//!   when it is empty, and the channel drains cleanly once every registered
//!   producer has finished.
//! * [`SnapshotBoard`] — a versioned publish/subscribe cell. The learner
//!   publishes `(version, Arc<snapshot>)` after each optimizer apply;
//!   rollout workers wait until the published version is fresh enough for
//!   their step's staleness bound.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// The peer closed the channel/board (shutdown or error propagation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Closed;

struct ChanState<T> {
    queue: VecDeque<T>,
    producers: usize,
    closed: bool,
}

/// Bounded multi-producer single-consumer queue.
pub struct Channel<T> {
    cap: usize,
    state: Mutex<ChanState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> Channel<T> {
    /// A channel holding at most `cap` items, with `producers` registered
    /// senders (each must eventually call [`Channel::producer_done`]).
    pub fn bounded(cap: usize, producers: usize) -> Channel<T> {
        assert!(cap >= 1, "channel capacity must be >= 1");
        Channel {
            cap,
            state: Mutex::new(ChanState {
                queue: VecDeque::with_capacity(cap),
                producers,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Blocking send; returns `Err(Closed)` if the consumer closed the
    /// channel (the item is dropped).
    pub fn send(&self, item: T) -> Result<(), Closed> {
        let mut st = self.state.lock().expect("channel poisoned");
        loop {
            if st.closed {
                return Err(Closed);
            }
            if st.queue.len() < self.cap {
                st.queue.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).expect("channel poisoned");
        }
    }

    /// Blocking receive. `None` once the channel is closed, or empty with
    /// no live producers left.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.state.lock().expect("channel poisoned");
        loop {
            if let Some(item) = st.queue.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed || st.producers == 0 {
                return None;
            }
            st = self.not_empty.wait(st).expect("channel poisoned");
        }
    }

    /// A producer finished (normally or by unwinding — see
    /// [`ProducerGuard`]). When the last one leaves, a blocked consumer
    /// wakes and drains.
    pub fn producer_done(&self) {
        let mut st = self.state.lock().expect("channel poisoned");
        st.producers = st.producers.saturating_sub(1);
        if st.producers == 0 {
            self.not_empty.notify_all();
        }
    }

    /// Close from the consumer side: pending and future sends fail, blocked
    /// peers wake immediately. Queued items are discarded.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("channel poisoned");
        st.closed = true;
        st.queue.clear();
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("channel poisoned").queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Decrements the channel's producer count on drop, so a panicking worker
/// still releases the consumer (no deadlocked `recv`).
pub struct ProducerGuard<'a, T>(pub &'a Channel<T>);

impl<T> Drop for ProducerGuard<'_, T> {
    fn drop(&mut self) {
        self.0.producer_done();
    }
}

struct BoardState<S> {
    version: u64,
    snap: Arc<S>,
    closed: bool,
}

/// Versioned single-slot publish/subscribe cell: readers wait for a minimum
/// version, writers monotonically replace the snapshot.
pub struct SnapshotBoard<S> {
    state: Mutex<BoardState<S>>,
    advanced: Condvar,
}

impl<S> SnapshotBoard<S> {
    pub fn new(version: u64, snap: S) -> SnapshotBoard<S> {
        SnapshotBoard {
            state: Mutex::new(BoardState { version, snap: Arc::new(snap), closed: false }),
            advanced: Condvar::new(),
        }
    }

    /// Publish a newer snapshot. Versions must be monotonic.
    pub fn publish(&self, version: u64, snap: Arc<S>) {
        let mut st = self.state.lock().expect("board poisoned");
        debug_assert!(version >= st.version, "board version went backwards");
        st.version = version;
        st.snap = snap;
        self.advanced.notify_all();
    }

    /// Current `(version, snapshot)` without waiting.
    pub fn latest(&self) -> (u64, Arc<S>) {
        let st = self.state.lock().expect("board poisoned");
        (st.version, st.snap.clone())
    }

    /// Block until the published version is at least `min_version`
    /// (the staleness gate). `Err(Closed)` on shutdown.
    pub fn wait_min(&self, min_version: u64) -> Result<(u64, Arc<S>), Closed> {
        let mut st = self.state.lock().expect("board poisoned");
        loop {
            if st.closed {
                return Err(Closed);
            }
            if st.version >= min_version {
                return Ok((st.version, st.snap.clone()));
            }
            st = self.advanced.wait(st).expect("board poisoned");
        }
    }

    /// Wake all waiters with `Err(Closed)` (shutdown or error propagation).
    pub fn close(&self) {
        let mut st = self.state.lock().expect("board poisoned");
        st.closed = true;
        self.advanced.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn channel_is_fifo_and_drains_after_producers_finish() {
        let ch: Channel<u32> = Channel::bounded(4, 1);
        std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = ProducerGuard(&ch);
                for i in 0..100 {
                    ch.send(i).unwrap();
                }
            });
            let mut got = Vec::new();
            while let Some(x) = ch.recv() {
                got.push(x);
            }
            assert_eq!(got, (0..100).collect::<Vec<_>>());
        });
    }

    #[test]
    fn channel_bounds_producers() {
        // With capacity 2 the producer cannot run ahead of the consumer by
        // more than 2 items + 1 in flight.
        let ch: Channel<usize> = Channel::bounded(2, 1);
        let sent = AtomicUsize::new(0);
        let max_lead = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = ProducerGuard(&ch);
                for i in 0..50 {
                    ch.send(i).unwrap();
                    sent.store(i + 1, Ordering::SeqCst);
                }
            });
            let mut received = 0usize;
            while ch.recv().is_some() {
                received += 1;
                let lead = sent.load(Ordering::SeqCst).saturating_sub(received);
                max_lead.fetch_max(lead, Ordering::SeqCst);
            }
            assert_eq!(received, 50);
        });
        assert!(max_lead.load(Ordering::SeqCst) <= 3, "{:?}", max_lead);
    }

    #[test]
    fn channel_close_unblocks_producer() {
        let ch: Channel<u32> = Channel::bounded(1, 1);
        ch.send(1).unwrap();
        std::thread::scope(|s| {
            s.spawn(|| {
                // Fills the queue, then blocks until close.
                assert_eq!(ch.send(2), Err(Closed));
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            ch.close();
        });
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn producer_guard_releases_on_panic() {
        let ch: Channel<u32> = Channel::bounded(1, 1);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _guard = ProducerGuard(&ch);
                panic!("worker died");
            });
            assert!(h.join().is_err());
            // No items, no producers: recv must not hang.
            assert_eq!(ch.recv(), None);
        });
    }

    #[test]
    fn board_waits_for_version() {
        let board: SnapshotBoard<u64> = SnapshotBoard::new(0, 100);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(10));
                board.publish(1, Arc::new(101));
                std::thread::sleep(std::time::Duration::from_millis(10));
                board.publish(3, Arc::new(103));
            });
            let (v, snap) = board.wait_min(0).unwrap();
            assert!(v <= 3);
            assert_eq!(*snap, 100 + v);
            let (v, snap) = board.wait_min(2).unwrap();
            assert_eq!(v, 3);
            assert_eq!(*snap, 103);
        });
        assert_eq!(board.latest().0, 3);
    }

    #[test]
    fn board_close_unblocks_waiters() {
        let board: SnapshotBoard<()> = SnapshotBoard::new(0, ());
        std::thread::scope(|s| {
            let h = s.spawn(|| board.wait_min(10));
            std::thread::sleep(std::time::Duration::from_millis(10));
            board.close();
            assert_eq!(h.join().unwrap(), Err(Closed));
        });
    }
}
