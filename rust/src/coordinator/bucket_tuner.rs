//! EMA auto-tuning of the sequence-bucket routing edges.
//!
//! The manifest ships a fixed ascending bucket set, but which of those
//! compiled artifacts are *worth routing into* depends on the run's
//! `learn_len` distribution: RPC's cut is ~uniform over `[min_cut, T]`, so
//! for a large `min_cut` the short buckets never fill and every micro-batch
//! that lands in one is mostly row padding. Static edges are therefore
//! always wrong for some `min_cut` — this tuner watches the realised
//! distribution and keeps only the edges that reduce expected allocated
//! tokens.
//!
//! The tuner maintains an exponential moving average of (a) the per-step
//! `learn_len` histogram and (b) the items-per-step count, and selects the
//! subset of manifest buckets (always retaining the top bucket — dropping
//! it would reject long items) that minimises the expected allocated tokens
//! of a step under the budget packer's cost model: mass routed to an edge
//! pays `(P + edge)` per row, rounded up through the compiled row grid.
//!
//! Routing edges are always a subset of the manifest buckets, so every
//! tuned choice maps to an existing compiled artifact. The tuner only
//! *removes* fragmentation, never shapes.
//!
//! The histogram substrate ([`EmaHist`]) is shared with the rollout
//! scheduler's response-length predictor
//! (`coordinator::rollout::scheduler`), and the tuner's full EMA state is
//! serializable ([`TunerState`]) so resumable checkpoints reproduce the
//! uninterrupted run's routing exactly.

use crate::coordinator::batcher::alloc_rows;

/// EMA histogram over observed lengths in `1..=max_len` (index = length-1).
///
/// Each `observe` folds one step's normalized length-frequency vector into
/// the moving average (the first observation replaces the zero state).
/// Shared by the learner-side [`BucketTuner`] and the rollout scheduler's
/// response-length predictor.
#[derive(Clone, Debug, PartialEq)]
pub struct EmaHist {
    /// EMA of the per-step length frequency, index = length - 1.
    hist: Vec<f64>,
    /// Blend factor for new observations (0 < alpha <= 1).
    alpha: f64,
    /// Observations folded in so far (cold-start gate for consumers).
    steps: u64,
}

impl EmaHist {
    pub fn new(max_len: usize, alpha: f64) -> EmaHist {
        EmaHist { hist: vec![0.0; max_len.max(1)], alpha: alpha.clamp(1e-3, 1.0), steps: 0 }
    }

    /// Rebuild from serialized state (checkpoint resume).
    pub fn from_parts(hist: Vec<f64>, alpha: f64, steps: u64) -> EmaHist {
        EmaHist { hist, alpha: alpha.clamp(1e-3, 1.0), steps }
    }

    /// Fold one step's observed lengths into the EMA (no-op when empty).
    pub fn observe(&mut self, lens: &[usize]) {
        if lens.is_empty() {
            return;
        }
        let mut freq = vec![0.0f64; self.hist.len()];
        for &l in lens {
            let i = l.clamp(1, self.hist.len()) - 1;
            freq[i] += 1.0 / lens.len() as f64;
        }
        let a = if self.steps == 0 { 1.0 } else { self.alpha };
        for (h, f) in self.hist.iter_mut().zip(&freq) {
            *h = (1.0 - a) * *h + a * f;
        }
        self.steps += 1;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Histogram capacity (the max observable length).
    pub fn len(&self) -> usize {
        self.hist.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hist.is_empty()
    }

    /// Σ hist over the index range `[lo, hi)` (index = length - 1).
    pub fn mass(&self, lo: usize, hi: usize) -> f64 {
        let hi = hi.min(self.hist.len());
        if lo >= hi {
            return 0.0;
        }
        self.hist[lo..hi].iter().sum()
    }

    pub fn total(&self) -> f64 {
        self.hist.iter().sum()
    }

    /// P(observed length > `len`) under the EMA histogram (0 when empty).
    pub fn tail(&self, len: usize) -> f64 {
        let total = self.total();
        if total <= 0.0 {
            return 0.0;
        }
        self.mass(len, self.hist.len()) / total
    }

    pub fn values(&self) -> &[f64] {
        &self.hist
    }
}

/// Serializable snapshot of a [`BucketTuner`]: everything a `--resume`
/// continuation needs to reproduce the uninterrupted run's routing edges
/// (carried by `runtime::TrainMeta` in the checkpoint sidecar).
#[derive(Clone, Debug, PartialEq)]
pub struct TunerState {
    pub hist: Vec<f64>,
    pub items_per_step: f64,
    pub alpha: f64,
    pub steps: u64,
}

/// EMA histogram of observed `learn_len` plus the edge selector.
#[derive(Clone, Debug)]
pub struct BucketTuner {
    hist: EmaHist,
    /// EMA of items per optimizer step.
    items_per_step: f64,
}

/// Observations before the tuner trusts its histogram and starts pruning
/// edges (cold start routes over the full manifest bucket set).
const WARMUP_STEPS: u64 = 2;

impl BucketTuner {
    pub fn new(max_len: usize, alpha: f64) -> BucketTuner {
        BucketTuner { hist: EmaHist::new(max_len, alpha), items_per_step: 0.0 }
    }

    /// Snapshot the EMA state for checkpointing.
    pub fn state(&self) -> TunerState {
        TunerState {
            hist: self.hist.values().to_vec(),
            items_per_step: self.items_per_step,
            alpha: self.hist.alpha(),
            steps: self.hist.steps(),
        }
    }

    /// Rebuild from a checkpointed snapshot; continuing to `observe` from
    /// here reproduces the uninterrupted run's state exactly.
    pub fn from_state(s: TunerState) -> BucketTuner {
        BucketTuner {
            hist: EmaHist::from_parts(s.hist, s.alpha, s.steps),
            items_per_step: s.items_per_step,
        }
    }

    /// Fold one optimizer step's packed `learn_len`s into the EMA state.
    pub fn observe(&mut self, lens: &[usize]) {
        if lens.is_empty() {
            return;
        }
        let a = if self.hist.steps() == 0 { 1.0 } else { self.hist.alpha() };
        self.hist.observe(lens);
        self.items_per_step = (1.0 - a) * self.items_per_step + a * lens.len() as f64;
    }

    pub fn steps_observed(&self) -> u64 {
        self.hist.steps()
    }

    /// Expected allocated rows for `n` expected items in one edge: full
    /// `batch_train` micro-batches plus a tail rounded up in the row grid.
    fn expected_rows(row_grid: &[usize], n: f64) -> f64 {
        let bt = *row_grid.last().unwrap() as f64;
        let full = (n / bt).floor() * bt;
        let rem = (n - full).ceil() as usize;
        full + if rem == 0 { 0.0 } else { alloc_rows(row_grid, rem) as f64 }
    }

    /// The routing-edge subset of `buckets` minimising expected allocated
    /// tokens per step for the observed distribution. Always contains the
    /// top bucket; returns the full set during warm-up.
    ///
    /// `token_budget` is the packer's per-micro-batch limit (0 = auto, as
    /// in `pack_budget`): pruning an edge re-routes its mass upward, and a
    /// subset that would push observed mass into an edge too expensive for
    /// even a single allocated row under the budget is rejected — the tuner
    /// must never turn a feasible config into a packing error.
    pub fn edges(
        &self,
        buckets: &[usize],
        prompt_len: usize,
        row_grid: &[usize],
        token_budget: usize,
    ) -> Vec<usize> {
        let k = buckets.len();
        if self.hist.steps() < WARMUP_STEPS || k <= 1 || k > 16 || row_grid.is_empty() {
            return buckets.to_vec();
        }
        let top = *buckets.last().unwrap();
        let max_rows = *row_grid.last().unwrap();
        let budget =
            if token_budget == 0 { max_rows * (prompt_len + top) } else { token_budget };
        let one_row = |e: usize| alloc_rows(row_grid, 1) * (prompt_len + e);
        let mut best: Option<(f64, Vec<usize>)> = None;
        // Exhaustive over subsets of the non-top buckets (k <= ~8 in
        // practice); the top bucket is always an edge.
        for mask in 0u32..(1 << (k - 1)) {
            let edges: Vec<usize> = (0..k)
                .filter(|&i| i == k - 1 || mask & (1 << i) != 0)
                .map(|i| buckets[i])
                .collect();
            // Feasibility is mass-independent (future items can land where
            // the histogram is empty): any item that fits its own minimal
            // bucket under the budget must still fit the edge covering it.
            let covering = |b: usize| edges.iter().copied().find(|&e| e >= b).unwrap_or(top);
            if buckets.iter().any(|&b| one_row(b) <= budget && one_row(covering(b)) > budget) {
                continue;
            }
            // Expected mass routed to each edge: histogram mass in
            // (previous edge, edge].
            let mut cost = 0.0;
            let mut lo = 0usize; // exclusive lower learn_len bound
            for &e in &edges {
                let hi = e.min(self.hist.len());
                let mass = self.hist.mass(lo, hi);
                lo = hi;
                let n = mass * self.items_per_step;
                if n > 0.0 {
                    cost += Self::expected_rows(row_grid, n) * (prompt_len + e) as f64;
                }
            }
            // Mass above the top bucket (clamped observations) pays top.
            if best.as_ref().map(|(c, _)| cost < *c).unwrap_or(true) {
                best = Some((cost, edges));
            }
        }
        best.map(|(_, e)| e).unwrap_or_else(|| buckets.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: [usize; 4] = [32, 64, 96, 128];
    const GRID: [usize; 4] = [1, 2, 4, 8];
    const P: usize = 48;

    #[test]
    fn cold_start_routes_over_all_buckets() {
        let t = BucketTuner::new(128, 0.2);
        assert_eq!(t.edges(&BUCKETS, P, &GRID, 0), BUCKETS.to_vec());
        let mut t = t;
        t.observe(&[10, 20, 30]);
        assert_eq!(t.edges(&BUCKETS, P, &GRID, 0), BUCKETS.to_vec());
    }

    #[test]
    fn high_min_cut_distribution_drops_dead_short_buckets() {
        // RPC with a large min_cut: learn_len ~ uniform [100, 128]. All
        // mass lands in the top edge; the dead short buckets are pruned so
        // no stray micro-batch ever allocates into them.
        let mut t = BucketTuner::new(128, 0.2);
        for _ in 0..10 {
            let lens: Vec<usize> = (0..16).map(|i| 100 + (i * 28) / 15).collect();
            t.observe(&lens);
        }
        assert_eq!(t.edges(&BUCKETS, P, &GRID, 0), vec![128]);
    }

    #[test]
    fn merges_thin_mid_bucket_into_neighbour() {
        // ~2 items/step at learn_len<=64 against 14 at <=128: a 2-row
        // micro-batch in bucket 64 costs 2×112=224 extra; merging them into
        // the top bucket's full batches costs 2×176 but saves the
        // fragment — the tuner decides by expected allocated tokens.
        let mut t = BucketTuner::new(128, 0.5);
        for _ in 0..10 {
            let mut lens = vec![60usize, 62];
            lens.resize(16, 120);
            t.observe(&lens);
        }
        let edges = t.edges(&BUCKETS, P, &GRID, 0);
        assert_eq!(*edges.last().unwrap(), 128);
        assert!(!edges.contains(&32), "{edges:?}");
    }

    #[test]
    fn broad_distribution_keeps_multiple_edges() {
        // learn_len ~ uniform over [1, 128] with plenty of items: every
        // bucket earns its keep.
        let mut t = BucketTuner::new(128, 0.3);
        for s in 0..10 {
            let lens: Vec<usize> = (0..64).map(|i| 1 + (i * 2 + s) % 128).collect();
            t.observe(&lens);
        }
        let edges = t.edges(&BUCKETS, P, &GRID, 0);
        assert!(edges.len() >= 3, "{edges:?}");
        assert_eq!(*edges.last().unwrap(), 128);
    }

    #[test]
    fn budget_constraint_blocks_pruning_into_unaffordable_edges() {
        // one_row: 32→80, 64→112, 96→144, 128→176. Budget 150 affords a
        // single row of every bucket except the top, so edge 96 must
        // survive pruning no matter what the histogram says — dropping it
        // would re-route bucket-96 items into an unpackable 128-row.
        let mut t = BucketTuner::new(128, 0.3);
        for _ in 0..10 {
            t.observe(&[90; 16]);
        }
        let edges = t.edges(&BUCKETS, P, &GRID, 150);
        assert!(edges.contains(&96), "{edges:?}");
        // unconstrained, the same history keeps only the mass-bearing edge
        let free = t.edges(&BUCKETS, P, &GRID, 0);
        assert_eq!(free, vec![96, 128]);
    }

    #[test]
    fn expected_rows_rounds_through_grid() {
        assert_eq!(BucketTuner::expected_rows(&GRID, 3.2), 4.0);
        assert_eq!(BucketTuner::expected_rows(&GRID, 8.0), 8.0);
        assert_eq!(BucketTuner::expected_rows(&GRID, 11.0), 8.0 + 4.0);
        assert_eq!(BucketTuner::expected_rows(&GRID, 0.0), 0.0);
    }

    #[test]
    fn ema_hist_mass_tail_and_cold_start() {
        let mut h = EmaHist::new(8, 0.5);
        assert_eq!(h.total(), 0.0);
        assert_eq!(h.tail(4), 0.0);
        // first observation replaces the zero state (a = 1)
        h.observe(&[1, 1, 5, 9 /* clamps to 8 */]);
        assert!((h.total() - 1.0).abs() < 1e-12);
        assert!((h.mass(0, 1) - 0.5).abs() < 1e-12);
        assert!((h.tail(4) - 0.5).abs() < 1e-12, "{}", h.tail(4));
        assert!((h.tail(8) - 0.0).abs() < 1e-12);
        assert_eq!(h.steps(), 1);
        // out-of-range / empty queries are safe
        assert_eq!(h.mass(7, 3), 0.0);
        assert_eq!(h.mass(100, 200), 0.0);
        h.observe(&[]);
        assert_eq!(h.steps(), 1);
    }

    /// Satellite regression: restoring the serialized tuner state and
    /// continuing must be bit-identical to the uninterrupted run — the
    /// `--resume` + `--train.auto_buckets` determinism contract.
    #[test]
    fn state_roundtrip_continues_bit_identically() {
        let step_lens = |s: usize| -> Vec<usize> {
            (0..16).map(|i| 1 + (i * 7 + s * 13) % 128).collect()
        };
        let mut full = BucketTuner::new(128, 0.2);
        let mut first = BucketTuner::new(128, 0.2);
        for s in 0..3 {
            full.observe(&step_lens(s));
            first.observe(&step_lens(s));
        }
        // "checkpoint" at step 3, restore, and continue both runs
        let mut resumed = BucketTuner::from_state(first.state());
        for s in 3..8 {
            full.observe(&step_lens(s));
            resumed.observe(&step_lens(s));
        }
        assert_eq!(resumed.state(), full.state());
        assert_eq!(
            resumed.edges(&BUCKETS, P, &GRID, 0),
            full.edges(&BUCKETS, P, &GRID, 0)
        );
        assert_eq!(resumed.steps_observed(), full.steps_observed());
    }
}
