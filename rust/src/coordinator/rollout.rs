//! Rollout scheduling: grouped sampling through the `generate` artifact.
//!
//! For each prompt we draw G completions (GRPO groups). Prompts are encoded
//! and LEFT-padded to the fixed prompt window; responses are trimmed at the
//! first EOS. Rewards are verified on the FULL decoded response — NAT never
//! touches the reward path.

use anyhow::{bail, Result};

use crate::runtime::{ParamStore, Runtime};
use crate::tasks::verify::reward_tokens;
use crate::tasks::Task;
use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;

/// One completed rollout sequence.
#[derive(Clone, Debug)]
pub struct RolloutSeq {
    /// Index into the step's task list (groups are contiguous).
    pub task_idx: usize,
    /// Full [P + T] row (left-padded prompt + response).
    pub tokens: Vec<i32>,
    pub pad_len: usize,
    /// Response length after EOS trim (1..=T, EOS included).
    pub resp_len: usize,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
    pub reward: f32,
}

/// Encode and left-pad a prompt into a fixed window.
pub fn encode_prompt(tok: &Tokenizer, prompt: &str, window: usize) -> Result<(Vec<i32>, usize)> {
    let ids = tok
        .try_encode(prompt)
        .ok_or_else(|| anyhow::anyhow!("prompt has untokenizable chars: {prompt}"))?;
    if ids.len() > window {
        bail!("prompt of {} tokens exceeds window {window}: {prompt}", ids.len());
    }
    let pad = window - ids.len();
    let mut row = vec![PAD; window];
    row[pad..].copy_from_slice(&ids);
    Ok((row, pad))
}

/// Trim a response at the first EOS (inclusive). For a non-empty window the
/// result is always in `1..=T` (length-1 floor: the first sampled token
/// always exists), which is what the masker's `t_i > 0` invariant relies on.
/// A degenerate empty window reports 0 — callers slice `&resp[..len]`, so
/// inventing a length there would be out of bounds.
pub fn trim_at_eos(resp: &[i32]) -> usize {
    if resp.is_empty() {
        return 0;
    }
    match resp.iter().position(|&t| t == EOS) {
        Some(i) => i + 1,
        None => resp.len().max(1),
    }
}

/// Split `total` flat rollout slots into generate-call chunks of at most
/// `batch` real rows each (the device batch is fixed at `batch`; the tail
/// chunk's remaining rows are padded with duplicates of the chunk's first
/// slot and discarded by the scatter loop, which iterates real slots only).
pub fn plan_chunks(total: usize, batch: usize) -> Vec<Vec<usize>> {
    assert!(batch > 0, "rollout batch must be positive");
    (0..total)
        .collect::<Vec<usize>>()
        .chunks(batch)
        .map(|c| c.to_vec())
        .collect()
}

/// Sample G completions per task. Returns sequences grouped task-major:
/// `out[i * g + j]` is completion j of task i.
pub fn run_group_rollouts(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    tasks: &[Task],
    g: usize,
    temp: f32,
    rng: &mut Rng,
) -> Result<Vec<RolloutSeq>> {
    let d = &rt.manifest.dims;
    let (b_roll, p, t_max) = (d.batch_rollout, d.prompt_len, d.max_resp);
    let total = tasks.len() * g;
    // encode each distinct prompt once
    let encoded: Vec<(Vec<i32>, usize)> = tasks
        .iter()
        .map(|t| encode_prompt(tok, &t.prompt, p))
        .collect::<Result<_>>()?;
    let mut out: Vec<Option<RolloutSeq>> = vec![None; total];
    // flat id = task_idx * g + j; process in chunks of the rollout batch.
    // The tail chunk is padded with repeats of the first prompt and the
    // padding rows are discarded by the scatter loop below.
    for chunk in plan_chunks(total, b_roll) {
        let mut prompts = Vec::with_capacity(b_roll * p);
        let mut pads = Vec::with_capacity(b_roll);
        for row in 0..b_roll {
            let flat_id = chunk.get(row).copied().unwrap_or(chunk[0]);
            let (ref ids, pad) = encoded[flat_id / g];
            prompts.extend_from_slice(ids);
            pads.push(pad as i32);
        }
        let gen = rt.generate(params, &prompts, &pads, rng.next_i32_seed(), temp)?;
        for (row, &flat_id) in chunk.iter().enumerate() {
            let task_idx = flat_id / g;
            let s = p + t_max;
            let tokens = gen.tokens[row * s..(row + 1) * s].to_vec();
            let resp = &tokens[p..];
            let resp_len = trim_at_eos(resp);
            let old_lp = gen.lp[row * t_max..row * t_max + resp_len].to_vec();
            let reward = reward_tokens(tok, &tasks[task_idx], &resp[..resp_len]);
            out[flat_id] = Some(RolloutSeq {
                task_idx,
                tokens,
                pad_len: pads[row] as usize,
                resp_len,
                old_lp,
                reward,
            });
        }
    }
    Ok(out.into_iter().map(|o| o.expect("rollout slot unfilled")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_prompt_left_pads() {
        let tok = Tokenizer::new();
        let (row, pad) = encode_prompt(&tok, "a:1+2=", 10).unwrap();
        assert_eq!(row.len(), 10);
        assert_eq!(pad, 4);
        assert!(row[..4].iter().all(|&t| t == PAD));
        assert_eq!(tok.decode(&row), "a:1+2=");
    }

    #[test]
    fn encode_prompt_rejects_oversize() {
        let tok = Tokenizer::new();
        assert!(encode_prompt(&tok, "a:11111+22222=", 5).is_err());
    }

    #[test]
    fn trim_at_eos_variants() {
        assert_eq!(trim_at_eos(&[5, 6, EOS, 9]), 3);
        assert_eq!(trim_at_eos(&[EOS]), 1);
        assert_eq!(trim_at_eos(&[5, 6, 7]), 3); // no EOS -> full length
        assert_eq!(trim_at_eos(&[EOS, EOS, 5]), 1);
    }

    #[test]
    fn trim_at_eos_has_length_one_floor_for_nonempty_windows() {
        // Regression for the documented `1..=T` contract: every non-empty
        // window reports at least 1 (the masker asserts `t_i > 0`), while an
        // empty window reports 0 so callers' `&resp[..len]` stays in bounds.
        assert_eq!(trim_at_eos(&[7]), 1);
        assert_eq!(trim_at_eos(&[PAD]), 1);
        assert_eq!(trim_at_eos(&[]), 0);
    }

    #[test]
    fn plan_chunks_covers_every_slot_exactly_once() {
        for (total, batch) in [(8, 4), (10, 4), (3, 4), (4, 4), (1, 3), (13, 5)] {
            let chunks = plan_chunks(total, batch);
            let mut seen = vec![0usize; total];
            for c in &chunks {
                assert!(!c.is_empty() && c.len() <= batch);
                for &id in c {
                    seen[id] += 1;
                }
            }
            assert!(
                seen.iter().all(|&n| n == 1),
                "total={total} batch={batch}: {seen:?}"
            );
            // Only the final chunk may be short (the padded tail).
            for c in &chunks[..chunks.len().saturating_sub(1)] {
                assert_eq!(c.len(), batch);
            }
            assert_eq!(chunks.len(), total.div_ceil(batch));
        }
    }

    #[test]
    fn tail_chunk_scatter_discards_padding_rows() {
        // Mirror of the scatter loop in `run_group_rollouts`: the device
        // batch has `batch` rows, rows beyond the chunk's real slots repeat
        // slot chunk[0] and must never be written back.
        let (total, batch) = (10usize, 4usize);
        let mut out: Vec<Option<usize>> = vec![None; total];
        for chunk in plan_chunks(total, batch) {
            // rows 0..batch exist on-device; enumerate only real slots
            for (row, &flat_id) in chunk.iter().enumerate() {
                assert!(row < batch);
                assert!(out[flat_id].is_none(), "slot {flat_id} written twice");
                out[flat_id] = Some(row);
            }
            // padding rows (chunk.len()..batch) duplicate chunk[0]'s prompt
            for row in chunk.len()..batch {
                let dup_of = chunk[0];
                assert!(out[dup_of].is_some(), "padding duplicated an unfilled slot");
                let _ = row; // rows are generated on-device but never scattered
            }
        }
        assert!(out.iter().all(Option::is_some), "{out:?}");
    }
}
