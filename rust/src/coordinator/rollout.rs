//! Rollout scheduling: grouped sampling through the `generate` artifacts.
//!
//! For each prompt we draw G completions (GRPO groups). Prompts are encoded
//! and LEFT-padded to the fixed prompt window; responses are trimmed at the
//! first EOS. Rewards are verified on the FULL decoded response — NAT never
//! touches the reward path.
//!
//! Two engines produce the same `RolloutSeq` layout (see [`scheduler`]):
//!
//! * [`run_group_rollouts`] — the legacy **fixed** engine: full-window
//!   generate calls, one scalar seed per chunk drawn in chunk order, tail
//!   chunks padded with duplicate rows (`--rollout.engine fixed`).
//! * [`run_group_rollouts_bucketed`] — the length-bucketed
//!   continuous-batching engine: per-slot seeds derived from
//!   `(seed, step, flat_id)`, EMA-predicted bucket routing, refill instead
//!   of padding, and overflow escalation. Output is a pure function of the
//!   plan — bit-identical across batch composition and refill order.

pub mod prefix_cache;
pub mod scheduler;

use anyhow::{bail, Result};

use crate::runtime::{ParamStore, Runtime};
use crate::tasks::verify::reward_tokens;
use crate::tasks::Task;
use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;

use self::scheduler::{
    run_slots_fixed, slot_seed, RolloutScheduler, RuntimeBackend, SlotOut, SlotSpec,
};

/// One completed rollout sequence.
#[derive(Clone, Debug)]
pub struct RolloutSeq {
    /// Index into the step's task list (groups are contiguous).
    pub task_idx: usize,
    /// Full [P + T] row (left-padded prompt + response).
    pub tokens: Vec<i32>,
    pub pad_len: usize,
    /// Response length after EOS trim (1..=T, EOS included).
    pub resp_len: usize,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
    pub reward: f32,
}

/// Encode and left-pad a prompt into a fixed window.
pub fn encode_prompt(tok: &Tokenizer, prompt: &str, window: usize) -> Result<(Vec<i32>, usize)> {
    let ids = tok
        .try_encode(prompt)
        .ok_or_else(|| anyhow::anyhow!("prompt has untokenizable chars: {prompt}"))?;
    if ids.len() > window {
        bail!("prompt of {} tokens exceeds window {window}: {prompt}", ids.len());
    }
    let pad = window - ids.len();
    let mut row = vec![PAD; window];
    row[pad..].copy_from_slice(&ids);
    Ok((row, pad))
}

/// Trim a response at the first EOS (inclusive). For a non-empty window the
/// result is always in `1..=T` (length-1 floor: the first sampled token
/// always exists), which is what the masker's `t_i > 0` invariant relies on.
/// A degenerate empty window reports 0 — callers slice `&resp[..len]`, so
/// inventing a length there would be out of bounds.
pub fn trim_at_eos(resp: &[i32]) -> usize {
    if resp.is_empty() {
        return 0;
    }
    match resp.iter().position(|&t| t == EOS) {
        Some(i) => i + 1,
        None => resp.len().max(1),
    }
}

/// Split `total` flat rollout slots into generate-call chunks of at most
/// `batch` real rows each (the device batch is fixed at `batch`; the tail
/// chunk's remaining rows are padded with duplicates of the chunk's first
/// slot and discarded by the scatter loop, which iterates real slots only).
pub fn plan_chunks(total: usize, batch: usize) -> Vec<Vec<usize>> {
    assert!(batch > 0, "rollout batch must be positive");
    (0..total)
        .collect::<Vec<usize>>()
        .chunks(batch)
        .map(|c| c.to_vec())
        .collect()
}

/// Encode each distinct task prompt once.
fn encode_tasks(
    tok: &Tokenizer,
    tasks: &[Task],
    window: usize,
) -> Result<Vec<(Vec<i32>, usize)>> {
    tasks.iter().map(|t| encode_prompt(tok, &t.prompt, window)).collect()
}

/// Turn completed slots (flat order, `flat_id = task_idx * g + j`) into
/// verified rollout sequences.
fn finish_slots(
    slots: Vec<SlotOut>,
    tok: &Tokenizer,
    tasks: &[Task],
    g: usize,
    prompt_len: usize,
    encoded: &[(Vec<i32>, usize)],
) -> Vec<RolloutSeq> {
    slots
        .into_iter()
        .map(|o| {
            let task_idx = o.flat_id / g;
            let resp = &o.tokens[prompt_len..];
            let reward = reward_tokens(tok, &tasks[task_idx], &resp[..o.resp_len]);
            RolloutSeq {
                task_idx,
                pad_len: encoded[task_idx].1,
                resp_len: o.resp_len,
                old_lp: o.lp,
                reward,
                tokens: o.tokens,
            }
        })
        .collect()
}

/// Sample G completions per task with the legacy fixed engine. Returns
/// sequences grouped task-major: `out[i * g + j]` is completion j of task i.
pub fn run_group_rollouts(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    tasks: &[Task],
    g: usize,
    temp: f32,
    rng: &mut Rng,
) -> Result<Vec<RolloutSeq>> {
    let d = &rt.manifest.dims;
    let encoded = encode_tasks(tok, tasks, d.prompt_len)?;
    let prompt_idx: Vec<usize> = (0..tasks.len() * g).map(|f| f / g).collect();
    let slots = run_slots_fixed(
        d.batch_rollout,
        d.prompt_len,
        d.max_resp,
        &encoded,
        &prompt_idx,
        rng,
        |prompts, pads, seed| rt.generate(params, prompts, pads, seed, temp),
    )?;
    Ok(finish_slots(slots, tok, tasks, g, d.prompt_len, &encoded))
}

/// Sample G completions per task with the bucketed continuous-batching
/// engine. Per-slot seeds derive from `(run_seed, step, flat_id)`, so the
/// returned sequences are a pure function of the plan — independent of the
/// scheduler's routing, refill order, worker count, and prefix-cache state.
///
/// `param_version` keys the scheduler's shared-prefix prefill cache: the
/// pipeline passes the snapshot version the rollout runs against, the serial
/// trainer passes the step, so KV blocks from retired snapshots can never
/// serve a fresh lookup.
///
/// Also returns the scheduler's [`scheduler::SchedStats`] so the trainer's
/// `rollout` trace span can report generate calls, decode-token steps,
/// escalations, padded rows, and prefix-cache accounting without a second
/// bookkeeping path.
pub fn run_group_rollouts_bucketed(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    tasks: &[Task],
    g: usize,
    temp: f32,
    run_seed: u64,
    step: u64,
    sched: &RolloutScheduler,
    param_version: u64,
) -> Result<(Vec<RolloutSeq>, scheduler::SchedStats)> {
    let d = &rt.manifest.dims;
    let encoded = encode_tasks(tok, tasks, d.prompt_len)?;
    let slots: Vec<SlotSpec> = (0..tasks.len() * g)
        .map(|f| SlotSpec {
            flat_id: f,
            prompt_idx: f / g,
            seed: slot_seed(run_seed, step, f as u64),
        })
        .collect();
    let backend = RuntimeBackend { rt, params };
    let (outs, stats) = sched.run(&backend, &encoded, &slots, temp, param_version)?;
    Ok((finish_slots(outs, tok, tasks, g, d.prompt_len, &encoded), stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_prompt_left_pads() {
        let tok = Tokenizer::new();
        let (row, pad) = encode_prompt(&tok, "a:1+2=", 10).unwrap();
        assert_eq!(row.len(), 10);
        assert_eq!(pad, 4);
        assert!(row[..4].iter().all(|&t| t == PAD));
        assert_eq!(tok.decode(&row), "a:1+2=");
    }

    #[test]
    fn encode_prompt_rejects_oversize() {
        let tok = Tokenizer::new();
        assert!(encode_prompt(&tok, "a:11111+22222=", 5).is_err());
    }

    #[test]
    fn trim_at_eos_variants() {
        assert_eq!(trim_at_eos(&[5, 6, EOS, 9]), 3);
        assert_eq!(trim_at_eos(&[EOS]), 1);
        assert_eq!(trim_at_eos(&[5, 6, 7]), 3); // no EOS -> full length
        assert_eq!(trim_at_eos(&[EOS, EOS, 5]), 1);
    }

    #[test]
    fn trim_at_eos_has_length_one_floor_for_nonempty_windows() {
        // Regression for the documented `1..=T` contract: every non-empty
        // window reports at least 1 (the masker asserts `t_i > 0`), while an
        // empty window reports 0 so callers' `&resp[..len]` stays in bounds.
        assert_eq!(trim_at_eos(&[7]), 1);
        assert_eq!(trim_at_eos(&[PAD]), 1);
        assert_eq!(trim_at_eos(&[]), 0);
    }

    #[test]
    fn plan_chunks_covers_every_slot_exactly_once() {
        for (total, batch) in [(8, 4), (10, 4), (3, 4), (4, 4), (1, 3), (13, 5)] {
            let chunks = plan_chunks(total, batch);
            let mut seen = vec![0usize; total];
            for c in &chunks {
                assert!(!c.is_empty() && c.len() <= batch);
                for &id in c {
                    seen[id] += 1;
                }
            }
            assert!(
                seen.iter().all(|&n| n == 1),
                "total={total} batch={batch}: {seen:?}"
            );
            // Only the final chunk may be short (the padded tail).
            for c in &chunks[..chunks.len().saturating_sub(1)] {
                assert_eq!(c.len(), batch);
            }
            assert_eq!(chunks.len(), total.div_ceil(batch));
        }
    }

    #[test]
    fn tail_chunk_scatter_discards_padding_rows() {
        // Mirror of the scatter loop in `run_slots_fixed`: the device batch
        // has `batch` rows, rows beyond the chunk's real slots repeat slot
        // chunk[0] and must never be written back.
        let (total, batch) = (10usize, 4usize);
        let mut out: Vec<Option<usize>> = vec![None; total];
        for chunk in plan_chunks(total, batch) {
            // rows 0..batch exist on-device; enumerate only real slots
            for (row, &flat_id) in chunk.iter().enumerate() {
                assert!(row < batch);
                assert!(out[flat_id].is_none(), "slot {flat_id} written twice");
                out[flat_id] = Some(row);
            }
            // padding rows (chunk.len()..batch) duplicate chunk[0]'s prompt
            for row in chunk.len()..batch {
                let dup_of = chunk[0];
                assert!(out[dup_of].is_some(), "padding duplicated an unfilled slot");
                let _ = row; // rows are generated on-device but never scattered
            }
        }
        assert!(out.iter().all(Option::is_some), "{out:?}");
    }
}
