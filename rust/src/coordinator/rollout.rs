//! Rollout scheduling: grouped sampling through the `generate` artifact.
//!
//! For each prompt we draw G completions (GRPO groups). Prompts are encoded
//! and LEFT-padded to the fixed prompt window; responses are trimmed at the
//! first EOS. Rewards are verified on the FULL decoded response — NAT never
//! touches the reward path.

use anyhow::{bail, Result};

use crate::runtime::{ParamStore, Runtime};
use crate::tasks::verify::reward_tokens;
use crate::tasks::Task;
use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;

/// One completed rollout sequence.
#[derive(Clone, Debug)]
pub struct RolloutSeq {
    /// Index into the step's task list (groups are contiguous).
    pub task_idx: usize,
    /// Full [P + T] row (left-padded prompt + response).
    pub tokens: Vec<i32>,
    pub pad_len: usize,
    /// Response length after EOS trim (1..=T, EOS included).
    pub resp_len: usize,
    /// Behaviour logprobs over 0..resp_len.
    pub old_lp: Vec<f32>,
    pub reward: f32,
}

/// Encode and left-pad a prompt into a fixed window.
pub fn encode_prompt(tok: &Tokenizer, prompt: &str, window: usize) -> Result<(Vec<i32>, usize)> {
    let ids = tok
        .try_encode(prompt)
        .ok_or_else(|| anyhow::anyhow!("prompt has untokenizable chars: {prompt}"))?;
    if ids.len() > window {
        bail!("prompt of {} tokens exceeds window {window}: {prompt}", ids.len());
    }
    let pad = window - ids.len();
    let mut row = vec![PAD; window];
    row[pad..].copy_from_slice(&ids);
    Ok((row, pad))
}

/// Trim a response at the first EOS (inclusive). Empty -> length 1 floor
/// (the first token always exists; T >= 1).
pub fn trim_at_eos(resp: &[i32]) -> usize {
    match resp.iter().position(|&t| t == EOS) {
        Some(i) => i + 1,
        None => resp.len(),
    }
}

/// Sample G completions per task. Returns sequences grouped task-major:
/// `out[i * g + j]` is completion j of task i.
pub fn run_group_rollouts(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    tasks: &[Task],
    g: usize,
    temp: f32,
    rng: &mut Rng,
) -> Result<Vec<RolloutSeq>> {
    let d = &rt.manifest.dims;
    let (b_roll, p, t_max) = (d.batch_rollout, d.prompt_len, d.max_resp);
    let total = tasks.len() * g;
    // encode each distinct prompt once
    let encoded: Vec<(Vec<i32>, usize)> = tasks
        .iter()
        .map(|t| encode_prompt(tok, &t.prompt, p))
        .collect::<Result<_>>()?;
    let mut out: Vec<Option<RolloutSeq>> = vec![None; total];
    let mut flat: Vec<usize> = (0..total).collect(); // flat id = task_idx * g + j
    // process in chunks of the rollout batch; the tail chunk is padded with
    // repeats of the first prompt and the padding rows are discarded.
    while !flat.is_empty() {
        let chunk: Vec<usize> = flat.drain(..flat.len().min(b_roll)).collect();
        let mut prompts = Vec::with_capacity(b_roll * p);
        let mut pads = Vec::with_capacity(b_roll);
        for row in 0..b_roll {
            let flat_id = chunk.get(row).copied().unwrap_or(chunk[0]);
            let (ref ids, pad) = encoded[flat_id / g];
            prompts.extend_from_slice(ids);
            pads.push(pad as i32);
        }
        let gen = rt.generate(params, &prompts, &pads, rng.next_i32_seed(), temp)?;
        for (row, &flat_id) in chunk.iter().enumerate() {
            let task_idx = flat_id / g;
            let s = p + t_max;
            let tokens = gen.tokens[row * s..(row + 1) * s].to_vec();
            let resp = &tokens[p..];
            let resp_len = trim_at_eos(resp);
            let old_lp = gen.lp[row * t_max..row * t_max + resp_len].to_vec();
            let reward = reward_tokens(tok, &tasks[task_idx], &resp[..resp_len]);
            out[flat_id] = Some(RolloutSeq {
                task_idx,
                tokens,
                pad_len: pads[row] as usize,
                resp_len,
                old_lp,
                reward,
            });
        }
    }
    Ok(out.into_iter().map(|o| o.expect("rollout slot unfilled")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_prompt_left_pads() {
        let tok = Tokenizer::new();
        let (row, pad) = encode_prompt(&tok, "a:1+2=", 10).unwrap();
        assert_eq!(row.len(), 10);
        assert_eq!(pad, 4);
        assert!(row[..4].iter().all(|&t| t == PAD));
        assert_eq!(tok.decode(&row), "a:1+2=");
    }

    #[test]
    fn encode_prompt_rejects_oversize() {
        let tok = Tokenizer::new();
        assert!(encode_prompt(&tok, "a:11111+22222=", 5).is_err());
    }

    #[test]
    fn trim_at_eos_variants() {
        assert_eq!(trim_at_eos(&[5, 6, EOS, 9]), 3);
        assert_eq!(trim_at_eos(&[EOS]), 1);
        assert_eq!(trim_at_eos(&[5, 6, 7]), 3); // no EOS -> full length
        assert_eq!(trim_at_eos(&[EOS, EOS, 5]), 1);
    }
}
