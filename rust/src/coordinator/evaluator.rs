//! Benchmark evaluation: Acc@k and pass@k at temperature 1.0 (paper §5.1:
//! 16 independent responses per question).

use anyhow::Result;

use crate::coordinator::rollout::{encode_prompt, trim_at_eos};
use crate::runtime::{ParamStore, Runtime};
use crate::tasks::verify::reward_tokens;
use crate::tasks::{EvalSet, Task, Tier};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub tier: Tier,
    /// Mean over tasks of (correct draws / k).
    pub acc_at_k: f64,
    /// Mean over tasks of 1[any draw correct].
    pub pass_at_k: f64,
    pub mean_resp_len: f64,
    pub tasks: usize,
    pub k: usize,
}

/// Count correct completions for every task with k samples each.
pub fn evaluate(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    eval: &EvalSet,
    k: usize,
    temp: f32,
    rng: &mut Rng,
) -> Result<EvalResult> {
    let d = &rt.manifest.dims;
    let (b_roll, p, t_max) = (d.batch_rollout, d.prompt_len, d.max_resp);
    let n = eval.tasks.len();
    let mut correct = vec![0usize; n];
    let mut len_sum = 0usize;
    let mut len_cnt = 0usize;

    // flat sample ids: task i, draw j -> i * k + j; chunked into B_roll rows
    let total = n * k;
    let encoded: Vec<(Vec<i32>, usize)> = eval
        .tasks
        .iter()
        .map(|t: &Task| encode_prompt(tok, &t.prompt, p))
        .collect::<Result<_>>()?;
    let mut cursor = 0usize;
    while cursor < total {
        let chunk: Vec<usize> = (cursor..total.min(cursor + b_roll)).collect();
        cursor += chunk.len();
        let mut prompts = Vec::with_capacity(b_roll * p);
        let mut pads = Vec::with_capacity(b_roll);
        for row in 0..b_roll {
            let flat_id = chunk.get(row).copied().unwrap_or(chunk[0]);
            let (ref ids, pad) = encoded[flat_id / k];
            prompts.extend_from_slice(ids);
            pads.push(pad as i32);
        }
        let gen = rt.generate(params, &prompts, &pads, rng.next_i32_seed(), temp)?;
        for (row, &flat_id) in chunk.iter().enumerate() {
            let task_idx = flat_id / k;
            let s = p + t_max;
            let resp = &gen.tokens[row * s + p..(row + 1) * s];
            let resp_len = trim_at_eos(resp);
            len_sum += resp_len;
            len_cnt += 1;
            if reward_tokens(tok, &eval.tasks[task_idx], &resp[..resp_len]) > 0.5 {
                correct[task_idx] += 1;
            }
        }
    }

    let acc = correct.iter().map(|&c| c as f64 / k as f64).sum::<f64>() / n as f64;
    let pass = correct.iter().filter(|&&c| c > 0).count() as f64 / n as f64;
    Ok(EvalResult {
        tier: eval.tier,
        acc_at_k: acc,
        pass_at_k: pass,
        mean_resp_len: len_sum as f64 / len_cnt.max(1) as f64,
        tasks: n,
        k,
    })
}

/// Evaluate all three benchmark tiers.
pub fn evaluate_all_tiers(
    rt: &Runtime,
    params: &ParamStore,
    tasks_per_tier: usize,
    k: usize,
    temp: f32,
    seed: u64,
) -> Result<Vec<EvalResult>> {
    let tok = Tokenizer::new();
    let mut rng = Rng::new(seed ^ 0xEAA1);
    Tier::ALL
        .iter()
        .map(|&tier| {
            let set = EvalSet::build(tier, tasks_per_tier, 1234);
            evaluate(rt, params, &tok, &set, k, temp, &mut rng)
        })
        .collect()
}
