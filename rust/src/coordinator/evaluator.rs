//! Benchmark evaluation: Acc@k and pass@k at temperature 1.0 (paper §5.1:
//! 16 independent responses per question).
//!
//! Both rollout engines are supported and share their implementation with
//! the training path (`rollout::scheduler`) — the evaluator used to
//! hand-roll its own copy of the chunk/pad-with-duplicates/scatter loop;
//! that invariant now lives in one place:
//!
//! * fixed — [`run_slots_fixed`]: the legacy chunked loop, one scalar seed
//!   per chunk in chunk order (bit-identical to the pre-scheduler
//!   evaluator).
//! * bucketed — per-slot seeds are drawn upfront in flat order, so the
//!   correctness counts are scheduling-invariant: independent of bucket
//!   routing, refill interleaving, and batch composition.

use anyhow::Result;

use crate::coordinator::rollout::encode_prompt;
use crate::coordinator::rollout::scheduler::{
    run_slots_fixed, RolloutScheduler, RuntimeBackend, SlotOut, SlotSpec,
};
use crate::runtime::{ParamStore, Runtime};
use crate::tasks::verify::reward_tokens;
use crate::tasks::{EvalSet, Task, Tier};
use crate::tokenizer::Tokenizer;
use crate::util::rng::{xor_stream, Rng};

#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub tier: Tier,
    /// Mean over tasks of (correct draws / k).
    pub acc_at_k: f64,
    /// Mean over tasks of 1[any draw correct].
    pub pass_at_k: f64,
    pub mean_resp_len: f64,
    pub tasks: usize,
    pub k: usize,
}

/// Count correct completions for every task with k samples each. `sched`
/// selects the engine: Some(_) runs the bucketed scheduler (falling back to
/// the fixed path when the artifact set has no `generate_buckets` grid);
/// None replays the legacy fixed loop exactly. `param_version` names the
/// snapshot behind `params` for the scheduler's prefix cache; eval prompts
/// repeat each task k times, so the cache collapses their prefills too.
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    rt: &Runtime,
    params: &ParamStore,
    tok: &Tokenizer,
    eval: &EvalSet,
    k: usize,
    temp: f32,
    rng: &mut Rng,
    sched: Option<&RolloutScheduler>,
    param_version: u64,
) -> Result<EvalResult> {
    let d = &rt.manifest.dims;
    let n = eval.tasks.len();
    let total = n * k;
    // flat sample ids: task i, draw j -> i * k + j
    let encoded: Vec<(Vec<i32>, usize)> = eval
        .tasks
        .iter()
        .map(|t: &Task| encode_prompt(tok, &t.prompt, d.prompt_len))
        .collect::<Result<_>>()?;

    let use_bucketed = sched.is_some() && !rt.manifest.generate_files.is_empty();
    let slots: Vec<SlotOut> = if use_bucketed {
        // Per-slot seeds drawn upfront in flat order: the draw sequence —
        // and therefore every completion — is independent of how the
        // scheduler batches, routes, or refills the slots.
        let specs: Vec<SlotSpec> = (0..total)
            .map(|f| SlotSpec { flat_id: f, prompt_idx: f / k, seed: rng.next_i32_seed() })
            .collect();
        let backend = RuntimeBackend { rt, params };
        sched.expect("use_bucketed").run(&backend, &encoded, &specs, temp, param_version)?.0
    } else {
        let prompt_idx: Vec<usize> = (0..total).map(|f| f / k).collect();
        run_slots_fixed(
            d.batch_rollout,
            d.prompt_len,
            d.max_resp,
            &encoded,
            &prompt_idx,
            rng,
            |prompts, pads, seed| rt.generate(params, prompts, pads, seed, temp),
        )?
    };

    let mut correct = vec![0usize; n];
    let mut len_sum = 0usize;
    let mut len_cnt = 0usize;
    for o in &slots {
        let task_idx = o.flat_id / k;
        let resp = &o.tokens[d.prompt_len..];
        len_sum += o.resp_len;
        len_cnt += 1;
        if reward_tokens(tok, &eval.tasks[task_idx], &resp[..o.resp_len]) > 0.5 {
            correct[task_idx] += 1;
        }
    }

    let acc = correct.iter().map(|&c| c as f64 / k as f64).sum::<f64>() / n as f64;
    let pass = correct.iter().filter(|&&c| c > 0).count() as f64 / n as f64;
    Ok(EvalResult {
        tier: eval.tier,
        acc_at_k: acc,
        pass_at_k: pass,
        mean_resp_len: len_sum as f64 / len_cnt.max(1) as f64,
        tasks: n,
        k,
    })
}

/// Evaluate all three benchmark tiers.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_all_tiers(
    rt: &Runtime,
    params: &ParamStore,
    tasks_per_tier: usize,
    k: usize,
    temp: f32,
    seed: u64,
    sched: Option<&RolloutScheduler>,
    param_version: u64,
) -> Result<Vec<EvalResult>> {
    let tok = Tokenizer::new();
    let mut rng = xor_stream(seed, 0xEAA1);
    Tier::ALL
        .iter()
        .map(|&tier| {
            let set = EvalSet::build(tier, tasks_per_tier, 1234);
            evaluate(rt, params, &tok, &set, k, temp, &mut rng, sched, param_version)
        })
        .collect()
}
