//! L3 coordinator: the paper's system contribution.
//!
//! * [`selection`] — first-class NAT token selection: the [`Selector`]
//!                   trait (per-token inclusion probabilities + HT weights
//!                   + `learn_len`), one module per scheme (full / URS /
//!                   DetTrunc / RPC / saliency / stratified / poisson) and
//!                   the batch-level adaptive token-budget controller.
//! * [`masking`]   — legacy façade over [`selection`] (bit-identical RNG
//!                   streams; kept for the pre-refactor call sites).
//! * [`advantage`] — group-relative advantages (GRPO Eq. 2).
//! * [`rollout`]   — grouped sampling through the AOT generate artifact.
//! * [`batcher`]   — 2-D (length × rows) bucketed micro-batching with a
//!                   token-budget packer (RPC's compute savings), packing
//!                   off `SelectionPlan::learn_len`.
//! * [`bucket_tuner`] — EMA auto-tuning of sequence-bucket routing edges.
//! * [`trainer`]   — the NAT×GRPO optimizer loop with paper-aligned metrics.
//! * [`pipeline`]  — async pipelined rollout/learner orchestration with
//!                   bounded staleness (the serial loop, overlapped).
//! * [`pretrainer`]— SFT base-model phase.
//! * [`evaluator`] — Acc@k / pass@k benchmark evaluation.
//!
//! [`Selector`]: selection::Selector
pub mod advantage;
pub mod batcher;
pub mod bucket_tuner;
pub mod evaluator;
pub mod masking;
pub mod pipeline;
pub mod pretrainer;
pub mod rollout;
pub mod selection;
pub mod trainer;
