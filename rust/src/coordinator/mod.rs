//! L3 coordinator: the paper's system contribution.
//!
//! * [`masking`]   — NAT token selection (URS / RPC / DetTrunc / full) with
//!                   Horvitz-Thompson weights: the core algorithm.
//! * [`advantage`] — group-relative advantages (GRPO Eq. 2).
//! * [`rollout`]   — grouped sampling through the AOT generate artifact.
//! * [`batcher`]   — 2-D (length × rows) bucketed micro-batching with a
//!                   token-budget packer (RPC's compute savings).
//! * [`bucket_tuner`] — EMA auto-tuning of sequence-bucket routing edges.
//! * [`trainer`]   — the NAT×GRPO optimizer loop with paper-aligned metrics.
//! * [`pipeline`]  — async pipelined rollout/learner orchestration with
//!                   bounded staleness (the serial loop, overlapped).
//! * [`pretrainer`]— SFT base-model phase.
//! * [`evaluator`] — Acc@k / pass@k benchmark evaluation.
pub mod advantage;
pub mod batcher;
pub mod bucket_tuner;
pub mod evaluator;
pub mod masking;
pub mod pipeline;
pub mod pretrainer;
pub mod rollout;
pub mod trainer;
