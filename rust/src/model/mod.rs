//! Model-side metadata: the artifact manifest contract and the analytic
//! memory/FLOP model used to reproduce the paper's system-efficiency
//! numbers (Table 3, Fig. 6) on simulated hardware.
pub mod manifest;
pub mod memory;

pub use manifest::{Manifest, ModelDims, ParamEntry};
