//! Analytic activation-memory and FLOP model (DESIGN.md §7).
//!
//! Reproduces the paper's Table 3 / Fig. 6 memory comparison on hardware we
//! do not have: peak learner memory per optimizer step is a deterministic
//! function of the micro-batch shape (B, S = P + bucket) and the model dims,
//! because activations residing for the backward pass dominate. The same
//! token-length scaling that gives RPC its ~18% GPU saving appears here
//! directly. Numbers are exact byte counts for OUR f32 stack (not the
//! paper's bf16+checkpointing stack); EXPERIMENTS.md compares ratios.

use super::manifest::ModelDims;

/// Bytes of activations materialised by one fwd+bwd micro-batch of shape
/// [batch, seq]. Term-by-term count of every tensor the backward pass
/// retains for our L2 graph (see python/compile/model.py::forward).
pub fn activation_bytes(d: &ModelDims, batch: usize, seq: usize) -> usize {
    let b = batch;
    let s = seq;
    let dm = d.d_model;
    let h = d.n_heads;
    let f = d.d_ff;
    let v = d.vocab;
    let per_layer =
        // attn_norm out, q, k, v, attn out, wo out
        6 * b * s * dm
        // attention score + softmax matrices
        + 2 * b * h * s * s
        // mlp_norm out, gate, up (silu input kept), gated product, down out
        + b * s * dm + 3 * b * s * f + b * s * dm;
    let embeds = b * s * dm;
    let final_norm = b * s * dm;
    let logits = 2 * b * s * v; // logits + log_softmax
    4 * (embeds + d.n_layers * per_layer + final_norm + logits)
}

/// Static bytes: params + grads + Adam moments (f32 each).
pub fn static_bytes(param_count: usize) -> usize {
    4 * param_count * 4
}

/// Peak learner bytes for a step whose micro-batches have the given
/// (batch, seq) shapes: static state + the largest single micro-batch
/// activation set (micro-batches run sequentially; activations are freed
/// between them, grads accumulate in place).
pub fn step_peak_bytes(
    d: &ModelDims,
    param_count: usize,
    micro_shapes: &[(usize, usize)],
) -> usize {
    let act = micro_shapes
        .iter()
        .map(|&(b, s)| activation_bytes(d, b, s))
        .max()
        .unwrap_or(0);
    static_bytes(param_count) + act
}

/// Mean allocated learner bytes across the step's micro-batches: static
/// state + the average activation set. This is the Table 3 / Fig. 6
/// headline metric: VERL's per-step `allocated_memory_gb` tracks allocator
/// residency across the (sequential) micro-batches, which follows the mean
/// rather than the strict instantaneous maximum; the strict maximum is
/// logged separately as `peak_mem_gb`. See EXPERIMENTS.md §Memory-metric.
pub fn step_mean_bytes(
    d: &ModelDims,
    param_count: usize,
    micro_shapes: &[(usize, usize)],
) -> usize {
    if micro_shapes.is_empty() {
        return static_bytes(param_count);
    }
    let act: usize = micro_shapes
        .iter()
        .map(|&(b, s)| activation_bytes(d, b, s))
        .sum::<usize>()
        / micro_shapes.len();
    static_bytes(param_count) + act
}

/// Forward FLOPs of one micro-batch [batch, seq] (dense attention).
pub fn forward_flops(d: &ModelDims, batch: usize, seq: usize) -> u64 {
    let b = batch as u64;
    let s = seq as u64;
    let dm = d.d_model as u64;
    let h = d.n_heads as u64;
    let hd = dm / h;
    let f = d.d_ff as u64;
    let v = d.vocab as u64;
    // per layer: qkv+wo projections, attention matmuls, mlp
    let proj = 2 * b * s * dm * dm * 4;
    let attn = 2 * b * h * s * s * hd * 2;
    let mlp = 2 * b * s * dm * f * 3;
    d.n_layers as u64 * (proj + attn + mlp) + 2 * b * s * dm * v
}

/// fwd+bwd FLOPs (standard 3x forward approximation).
pub fn train_flops(d: &ModelDims, batch: usize, seq: usize) -> u64 {
    3 * forward_flops(d, batch, seq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "t".into(),
            vocab: 64,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 352,
            prompt_len: 48,
            max_resp: 128,
            buckets: vec![32, 64, 96, 128],
            batch_rollout: 16,
            batch_train: 8,
            pretrain_len: 176,
            batch_pretrain: 16,
            lr: 3e-4,
            clip_eps: 0.2,
            grad_clip: 1.0,
            pretrain_lr: 1e-3,
        }
    }

    #[test]
    fn activations_grow_superlinearly_in_seq() {
        let d = dims();
        let a1 = activation_bytes(&d, 8, 88); // P + 40
        let a2 = activation_bytes(&d, 8, 176); // P + 128
        assert!(a2 > 2 * a1, "{a1} {a2}"); // attention S^2 term
    }

    #[test]
    fn activations_linear_in_batch() {
        let d = dims();
        assert_eq!(activation_bytes(&d, 16, 100), 2 * activation_bytes(&d, 8, 100));
    }

    #[test]
    fn rpc_bucket_mixture_saves_vs_full() {
        let d = dims();
        let pc = 820_352;
        let full = step_peak_bytes(&d, pc, &[(8, 176), (8, 176), (8, 176), (8, 176)]);
        // RPC: micro-batches land in shorter buckets; peak set by the
        // largest bucket that actually occurs in the step.
        let rpc = step_peak_bytes(&d, pc, &[(8, 80), (8, 112), (8, 144), (8, 144)]);
        assert!(rpc < full);
        let ratio = rpc as f64 / full as f64;
        assert!(ratio < 0.95, "{ratio}");
        assert!(ratio > 0.4, "{ratio}");
    }

    #[test]
    fn det_trunc_is_cheapest() {
        let d = dims();
        let pc = 820_352;
        let det = step_peak_bytes(&d, pc, &[(8, 112); 4]); // always 50%
        let rpc = step_peak_bytes(&d, pc, &[(8, 80), (8, 176), (8, 112), (8, 144)]);
        let full = step_peak_bytes(&d, pc, &[(8, 176); 4]);
        assert!(det < rpc || rpc == full); // det <= rpc <= full typical case
        assert!(det < full);
    }

    #[test]
    fn flops_scale_with_seq_and_bwd_factor() {
        let d = dims();
        assert!(forward_flops(&d, 8, 176) > 2 * forward_flops(&d, 8, 88));
        assert_eq!(train_flops(&d, 8, 100), 3 * forward_flops(&d, 8, 100));
    }

    #[test]
    fn row_grid_shapes_cut_residency_vs_full_rows() {
        // The budget packer's micro-batches carry their allocated row count,
        // so a 2-row tail costs 1/4 of a full 8-row batch in activations —
        // the (rows, seq) dimension the fixed packer always maxed out.
        let d = dims();
        let pc = 820_352;
        let fixed = step_mean_bytes(&d, pc, &[(8, 80), (8, 112), (8, 144), (8, 176)]);
        let budget = step_mean_bytes(&d, pc, &[(4, 80), (2, 112), (2, 144), (4, 176)]);
        assert!(budget < fixed, "{budget} !< {fixed}");
        assert!(
            step_peak_bytes(&d, pc, &[(4, 176)]) < step_peak_bytes(&d, pc, &[(8, 176)])
        );
    }

    #[test]
    fn empty_step_has_static_floor() {
        let d = dims();
        assert_eq!(step_peak_bytes(&d, 100, &[]), static_bytes(100));
        assert_eq!(step_mean_bytes(&d, 100, &[]), static_bytes(100));
    }

    #[test]
    fn mean_residency_orders_methods_like_the_paper() {
        // Det < RPC < URS = GRPO (Table 3 qualitative ordering)
        let d = dims();
        let pc = 820_352;
        let full = step_mean_bytes(&d, pc, &[(8, 176); 4]);
        let urs = step_mean_bytes(&d, pc, &[(8, 176); 4]);
        let rpc = step_mean_bytes(&d, pc, &[(8, 80), (8, 112), (8, 144), (8, 176)]);
        let det = step_mean_bytes(&d, pc, &[(8, 112); 4]);
        assert_eq!(urs, full);
        assert!(det < rpc, "{det} {rpc}");
        assert!(rpc < full, "{rpc} {full}");
    }
}
