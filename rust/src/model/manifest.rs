//! Artifact manifest: the shape/ordering contract between python/compile
//! (which writes artifacts/<cfg>/manifest.json) and the Rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
}

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub prompt_len: usize,
    pub max_resp: usize,
    pub buckets: Vec<usize>,
    pub batch_rollout: usize,
    pub batch_train: usize,
    pub pretrain_len: usize,
    pub batch_pretrain: usize,
    pub lr: f64,
    pub clip_eps: f64,
    pub grad_clip: f64,
    pub pretrain_lr: f64,
}

impl ModelDims {
    /// Modeled resident footprint of one prompt's prefill KV block: f32
    /// K and V over the prompt window for every layer and head. The prefix
    /// cache's byte-budget LRU prices entries with this when the engine
    /// (e.g. the sim) does not materialize host KV.
    pub fn kv_block_bytes(&self) -> usize {
        let head_dim = self.d_model / self.n_heads.max(1);
        self.prompt_len * self.n_layers * 2 * self.n_heads * head_dim * 4
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub params: Vec<ParamEntry>,
    pub param_count: usize,
    pub generate_file: String,
    /// Fixed-trip-count rollout variant (perf A/B; §Perf opt-1).
    pub generate_full_file: Option<String>,
    /// (bucket, filename), ascending by bucket: per-response-bucket
    /// generate artifacts (`generate_T<b>`) with PER-ROW sampling seeds —
    /// the bucketed rollout scheduler's grid. Empty in legacy manifests,
    /// where only the fixed engine can run.
    pub generate_files: Vec<(usize, String)>,
    /// Prompt-window prefill artifact (`prefill_P`): one forward pass over
    /// a single left-padded prompt row, returning its KV block. Half of the
    /// prefill/decode split the shared-prefix cache rides on; absent in
    /// manifests built before the split.
    pub prefill_file: Option<String>,
    /// (bucket, filename), ascending by bucket: KV-consuming bucketed
    /// decode artifacts (`decode_T<b>`) — the other half of the split. Same
    /// grid contract as `generate_files` (keys ⊆ config buckets, a
    /// non-empty grid includes the top bucket). Empty when the manifest
    /// predates the split; the scheduler then keeps fused generate.
    pub decode_files: Vec<(usize, String)>,
    pub apply_file: String,
    pub pretrain_file: String,
    /// (bucket, filename), ascending by bucket. Full-row (`batch_train`)
    /// grad artifacts — the fixed packer's (and legacy manifests') grid.
    pub grad_files: Vec<(usize, String)>,
    /// ((bucket, rows), filename): the 2-D grad-artifact grid the
    /// token-budget packer routes into. Rows are the compiled batch
    /// dimensions below `batch_train` (e.g. {1, 2, 4}); absent in legacy
    /// manifests, where only full-row micro-batches can execute.
    pub grad_row_files: Vec<((usize, usize), String)>,
    /// ((kept-bucket, rows), filename): the gather-compacted grad grid.
    /// Micro-batches here are keyed by KEPT-TOKEN count, not prefix
    /// length — rows are gathered to the kept positions, the NAT loss
    /// runs on the compacted layout, and gradients scatter back by the
    /// recorded original positions. Kept buckets reuse the sequence
    /// bucket edges. Absent in legacy manifests, where scattered plans
    /// must pay their full prefix.
    pub grad_compact_files: Vec<((usize, usize), String)>,
    pub score_files: Vec<(usize, String)>,
    /// Scorer variant whose forward runs the L1 Pallas flash-attention
    /// kernel (integration proof; may be absent in older artifact sets).
    pub score_pallas_files: Vec<(usize, String)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let cfg = j.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let us = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let fl = |k: &str| -> Result<f64> {
            cfg.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let buckets: Vec<usize> = cfg
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("config.buckets missing"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
            bail!("buckets must be non-empty ascending: {buckets:?}");
        }
        let dims = ModelDims {
            name: cfg.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            vocab: us("vocab")?,
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            d_ff: us("d_ff")?,
            prompt_len: us("prompt_len")?,
            max_resp: us("max_resp")?,
            buckets: buckets.clone(),
            batch_rollout: us("batch_rollout")?,
            batch_train: us("batch_train")?,
            pretrain_len: us("pretrain_len")?,
            batch_pretrain: us("batch_pretrain")?,
            lr: fl("lr")?,
            clip_eps: fl("clip_eps")?,
            grad_clip: fl("grad_clip")?,
            pretrain_lr: fl("pretrain_lr")?,
        };
        if *buckets.last().unwrap() != dims.max_resp {
            bail!("top bucket {} != max_resp {}", buckets.last().unwrap(), dims.max_resp);
        }

        let mut params = Vec::new();
        let mut expect_offset = 0usize;
        for p in j.get("params").and_then(Json::as_arr).ok_or_else(|| anyhow!("params"))? {
            let name = p.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("p.name"))?;
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("p.shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let size = p.get("size").and_then(Json::as_usize).ok_or_else(|| anyhow!("p.size"))?;
            let offset =
                p.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("p.offset"))?;
            if shape.iter().product::<usize>() != size {
                bail!("param {name}: shape {shape:?} does not match size {size}");
            }
            if offset != expect_offset {
                bail!("param {name}: non-contiguous offset {offset} != {expect_offset}");
            }
            expect_offset += size;
            params.push(ParamEntry { name: name.to_string(), shape, size, offset });
        }
        let param_count =
            j.get("param_count").and_then(Json::as_usize).ok_or_else(|| anyhow!("param_count"))?;
        if param_count != expect_offset {
            bail!("param_count {param_count} != sum of sizes {expect_offset}");
        }

        let arts = j.get("artifacts").ok_or_else(|| anyhow!("artifacts"))?;
        let file = |k: &str| -> Result<String> {
            arts.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("artifacts.{k}"))
        };
        let bucket_map = |k: &str| -> Result<Vec<(usize, String)>> {
            let obj = arts.get(k).and_then(Json::as_obj).ok_or_else(|| anyhow!("artifacts.{k}"))?;
            let mut v: Vec<(usize, String)> = obj
                .iter()
                .map(|(b, f)| {
                    Ok((
                        b.parse::<usize>().map_err(|_| anyhow!("bad bucket {b}"))?,
                        f.as_str().ok_or_else(|| anyhow!("bad file"))?.to_string(),
                    ))
                })
                .collect::<Result<_>>()?;
            v.sort();
            Ok(v)
        };
        let grad_files = bucket_map("grad")?;
        if grad_files.iter().map(|(b, _)| *b).collect::<Vec<_>>() != buckets {
            bail!("grad buckets do not match config buckets");
        }
        // Optional per-bucket generate grid. Every key must be a config
        // bucket, and a non-empty grid must include the top bucket — the
        // scheduler's escalation chain terminates there (a grid without it
        // could never finish a full-length response).
        let generate_files = if arts.get("generate_buckets").is_some() {
            let files = bucket_map("generate_buckets")?;
            for &(b, _) in &files {
                if !buckets.contains(&b) {
                    bail!("generate bucket {b} is not a config bucket {buckets:?}");
                }
            }
            if files.last().map(|&(b, _)| b) != Some(dims.max_resp) {
                bail!(
                    "generate_buckets must include the top bucket {} (max_resp)",
                    dims.max_resp
                );
            }
            files
        } else {
            Vec::new()
        };
        // Optional prefill/decode split. The decode grid obeys the same
        // rules as generate_buckets (keys are config buckets, the top
        // bucket terminates escalation), and the two halves come together:
        // a decode grid with no prefill artifact (or vice versa) can never
        // execute, so it is a build defect, not a degraded mode.
        let prefill_file =
            arts.get("prefill").and_then(Json::as_str).map(str::to_string);
        let decode_files = if arts.get("decode_buckets").is_some() {
            let files = bucket_map("decode_buckets")?;
            for &(b, _) in &files {
                if !buckets.contains(&b) {
                    bail!("decode bucket {b} is not a config bucket {buckets:?}");
                }
            }
            if files.last().map(|&(b, _)| b) != Some(dims.max_resp) {
                bail!(
                    "decode_buckets must include the top bucket {} (max_resp)",
                    dims.max_resp
                );
            }
            files
        } else {
            Vec::new()
        };
        if prefill_file.is_some() != !decode_files.is_empty() {
            bail!("prefill and decode_buckets must be present together");
        }
        // Optional 2-D grid: {"<bucket>x<rows>": file}. Every key must name
        // a real sequence bucket and a batch dimension <= batch_train.
        let mut grad_row_files: Vec<((usize, usize), String)> = Vec::new();
        if let Some(obj) = arts.get("grad_rows").and_then(Json::as_obj) {
            for (key, f) in obj {
                let (b, r) = key
                    .split_once('x')
                    .and_then(|(b, r)| Some((b.parse::<usize>().ok()?, r.parse::<usize>().ok()?)))
                    .ok_or_else(|| anyhow!("bad grad_rows key '{key}' (want '<bucket>x<rows>')"))?;
                if !buckets.contains(&b) {
                    bail!("grad_rows bucket {b} is not a config bucket {buckets:?}");
                }
                if r == 0 || r > dims.batch_train {
                    bail!("grad_rows rows {r} outside 1..={}", dims.batch_train);
                }
                let file = f.as_str().ok_or_else(|| anyhow!("bad grad_rows file"))?;
                grad_row_files.push(((b, r), file.to_string()));
            }
            grad_row_files.sort();
        }
        // Optional gather-compacted grid: {"<kept-bucket>x<rows>": file}.
        // Kept buckets reuse the sequence bucket edges (a kept count is
        // always <= its sequence's learn_len, so the same grid covers it).
        // Unlike grad_rows there is no full-row legacy fallback — every
        // (k, rows) cell the packer can route to must be listed.
        let mut grad_compact_files: Vec<((usize, usize), String)> = Vec::new();
        if let Some(obj) = arts.get("grad_compact").and_then(Json::as_obj) {
            for (key, f) in obj {
                let (k, r) = key
                    .split_once('x')
                    .and_then(|(k, r)| Some((k.parse::<usize>().ok()?, r.parse::<usize>().ok()?)))
                    .ok_or_else(|| {
                        anyhow!("bad grad_compact key '{key}' (want '<kept-bucket>x<rows>')")
                    })?;
                if !buckets.contains(&k) {
                    bail!("grad_compact kept-bucket {k} is not a config bucket {buckets:?}");
                }
                if r == 0 || r > dims.batch_train {
                    bail!("grad_compact rows {r} outside 1..={}", dims.batch_train);
                }
                let file = f.as_str().ok_or_else(|| anyhow!("bad grad_compact file"))?;
                grad_compact_files.push(((k, r), file.to_string()));
            }
            grad_compact_files.sort();
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dims,
            params,
            param_count,
            generate_file: file("generate")?,
            generate_full_file: arts
                .get("generate_full")
                .and_then(Json::as_str)
                .map(str::to_string),
            generate_files,
            prefill_file,
            decode_files,
            apply_file: file("apply")?,
            pretrain_file: file("pretrain")?,
            grad_files,
            grad_row_files,
            grad_compact_files,
            score_files: bucket_map("score")?,
            score_pallas_files: bucket_map("score_pallas").unwrap_or_default(),
        })
    }

    /// Smallest bucket >= learn_len (falls back to the top bucket).
    pub fn bucket_for(&self, learn_len: usize) -> usize {
        for &b in &self.dims.buckets {
            if b >= learn_len {
                return b;
            }
        }
        *self.dims.buckets.last().unwrap()
    }

    /// The row-count grid compiled grad artifacts exist for: every batch
    /// dimension available for ALL sequence buckets, plus `batch_train`
    /// (ascending). Legacy manifests yield `[batch_train]`, so the budget
    /// packer still works — it just cannot shrink rows.
    pub fn row_grid(&self) -> Vec<usize> {
        let mut grid: Vec<usize> = Vec::new();
        let rows: std::collections::BTreeSet<usize> =
            self.grad_row_files.iter().map(|&((_, r), _)| r).collect();
        for r in rows {
            if self
                .dims
                .buckets
                .iter()
                .all(|&b| self.grad_row_files.iter().any(|&((bb, rr), _)| bb == b && rr == r))
            {
                grid.push(r);
            }
        }
        if grid.last() != Some(&self.dims.batch_train) {
            grid.push(self.dims.batch_train);
        }
        grid
    }

    /// Grad artifact for a (sequence bucket, rows) micro-batch shape.
    pub fn grad_file_for(&self, bucket: usize, rows: usize) -> Result<&str> {
        if rows == self.dims.batch_train {
            if let Some((_, f)) = self.grad_files.iter().find(|(b, _)| *b == bucket) {
                return Ok(f);
            }
        }
        self.grad_row_files
            .iter()
            .find(|&&((b, r), _)| b == bucket && r == rows)
            .map(|(_, f)| f.as_str())
            .ok_or_else(|| {
                anyhow!(
                    "no grad artifact for bucket {bucket} × rows {rows}; rebuild \
                     artifacts (make artifacts) or run with --train.packer fixed"
                )
            })
    }

    /// True when the manifest carries the gather-compacted grad grid —
    /// the precondition for the batcher routing scattered plans to
    /// kept-count micro-batches.
    pub fn has_compact(&self) -> bool {
        !self.grad_compact_files.is_empty()
    }

    /// Compacted grad artifact for a (kept-bucket, rows) micro-batch
    /// shape. No full-row fallback: the compact grid must list every
    /// cell explicitly.
    pub fn grad_compact_file_for(&self, kept_bucket: usize, rows: usize) -> Result<&str> {
        self.grad_compact_files
            .iter()
            .find(|&&((k, r), _)| k == kept_bucket && r == rows)
            .map(|(_, f)| f.as_str())
            .ok_or_else(|| {
                anyhow!(
                    "no compacted grad artifact for kept-bucket {kept_bucket} × rows {rows}; \
                     rebuild artifacts (make artifacts) or run with --train.compact false"
                )
            })
    }

    /// Per-row-seed generate artifact for one response bucket.
    pub fn generate_file_for(&self, bucket: usize) -> Result<&str> {
        self.generate_files
            .iter()
            .find(|&&(b, _)| b == bucket)
            .map(|(_, f)| f.as_str())
            .ok_or_else(|| {
                anyhow!(
                    "no generate artifact for bucket {bucket}; rebuild artifacts \
                     (make artifacts) or run with --rollout.engine fixed"
                )
            })
    }

    /// True when the manifest carries the prefill/decode split — the
    /// precondition for the rollout scheduler routing through the
    /// shared-prefix prefill cache.
    pub fn has_prefill_split(&self) -> bool {
        self.prefill_file.is_some() && !self.decode_files.is_empty()
    }

    /// KV-consuming decode artifact for one response bucket.
    pub fn decode_file_for(&self, bucket: usize) -> Result<&str> {
        self.decode_files
            .iter()
            .find(|&&(b, _)| b == bucket)
            .map(|(_, f)| f.as_str())
            .ok_or_else(|| {
                anyhow!(
                    "no decode artifact for bucket {bucket}; rebuild artifacts \
                     (make artifacts) or run with --rollout.prefix_cache off"
                )
            })
    }

    pub fn seq_total(&self) -> usize {
        self.dims.prompt_len + self.dims.max_resp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        r#"{
          "config": {"name":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
            "d_ff":8,"prompt_len":4,"max_resp":8,"buckets":[4,8],
            "batch_rollout":2,"batch_train":2,"pretrain_len":12,
            "batch_pretrain":2,"lr":0.001,"clip_eps":0.2,"grad_clip":1.0,
            "pretrain_lr":0.001},
          "param_count": 40,
          "params": [
            {"name":"embed","shape":[8,4],"size":32,"offset":0},
            {"name":"head","shape":[4,2],"size":8,"offset":32}],
          "artifacts": {"generate":"g.txt","apply":"a.txt","pretrain":"p.txt",
            "grad":{"4":"g4.txt","8":"g8.txt"},"score":{"8":"s8.txt"}}
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.param_count, 40);
        assert_eq!(m.params[1].offset, 32);
        assert_eq!(m.grad_files, vec![(4, "g4.txt".into()), (8, "g8.txt".into())]);
        assert_eq!(m.dims.buckets, vec![4, 8]);
        assert_eq!(m.seq_total(), 12);
        // legacy manifest: no grad_rows → only full-row micro-batches
        assert!(m.grad_row_files.is_empty());
        assert_eq!(m.row_grid(), vec![2]);
        assert_eq!(m.grad_file_for(4, 2).unwrap(), "g4.txt");
        assert!(m.grad_file_for(4, 1).is_err());
        // legacy manifest: no generate_buckets → only the fixed engine
        assert!(m.generate_files.is_empty());
        assert!(m.generate_file_for(8).is_err());
    }

    #[test]
    fn parses_generate_bucket_grid() {
        let with = toy_manifest_json().replace(
            r#""generate":"g.txt""#,
            r#""generate":"g.txt",
               "generate_buckets":{"4":"gen4.txt","8":"gen8.txt"}"#,
        );
        let j = Json::parse(&with).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(
            m.generate_files,
            vec![(4, "gen4.txt".into()), (8, "gen8.txt".into())]
        );
        assert_eq!(m.generate_file_for(4).unwrap(), "gen4.txt");
        assert_eq!(m.generate_file_for(8).unwrap(), "gen8.txt");
        assert!(m.generate_file_for(5).is_err());
    }

    #[test]
    fn parses_prefill_decode_split() {
        let with = toy_manifest_json().replace(
            r#""generate":"g.txt""#,
            r#""generate":"g.txt",
               "prefill":"pf.txt",
               "decode_buckets":{"4":"dec4.txt","8":"dec8.txt"}"#,
        );
        let j = Json::parse(&with).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert!(m.has_prefill_split());
        assert_eq!(m.prefill_file.as_deref(), Some("pf.txt"));
        assert_eq!(m.decode_file_for(4).unwrap(), "dec4.txt");
        assert_eq!(m.decode_file_for(8).unwrap(), "dec8.txt");
        assert!(m.decode_file_for(5).is_err());
        // dims-modeled KV footprint: P * layers * 2 * heads * head_dim * 4
        assert_eq!(m.dims.kv_block_bytes(), 4 * 1 * 2 * 1 * 4 * 4);
        // legacy manifest: no split → fused generate only
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let legacy = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert!(!legacy.has_prefill_split());
        assert!(legacy.decode_file_for(8).is_err());
    }

    #[test]
    fn rejects_bad_prefill_decode_split() {
        for grid in [
            // decode grid without the prefill artifact
            r#""decode_buckets":{"4":"d4.txt","8":"d8.txt"}"#,
            // prefill without a decode grid
            r#""prefill":"pf.txt""#,
            // missing the top bucket: escalation cannot terminate
            r#""prefill":"pf.txt","decode_buckets":{"4":"d4.txt"}"#,
            // bucket not in the config set
            r#""prefill":"pf.txt","decode_buckets":{"5":"d5.txt","8":"d8.txt"}"#,
        ] {
            let bad = toy_manifest_json().replace(
                r#""generate":"g.txt""#,
                &format!(r#""generate":"g.txt",{grid}"#),
            );
            let j = Json::parse(&bad).unwrap();
            assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err(), "{grid}");
        }
    }

    #[test]
    fn rejects_bad_generate_buckets() {
        for grid in [
            // missing the top bucket: the escalation chain cannot terminate
            r#""generate_buckets":{"4":"gen4.txt"}"#,
            // bucket not in the config set
            r#""generate_buckets":{"5":"gen5.txt","8":"gen8.txt"}"#,
        ] {
            let bad = toy_manifest_json().replace(
                r#""generate":"g.txt""#,
                &format!(r#""generate":"g.txt",{grid}"#),
            );
            let j = Json::parse(&bad).unwrap();
            assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err(), "{grid}");
        }
    }

    fn grid_manifest_json() -> String {
        toy_manifest_json().replace(
            r#""grad":{"4":"g4.txt","8":"g8.txt"}"#,
            r#""grad":{"4":"g4.txt","8":"g8.txt"},
               "grad_rows":{"4x1":"g4b1.txt","8x1":"g8b1.txt"}"#,
        )
    }

    #[test]
    fn parses_grad_row_grid() {
        let j = Json::parse(&grid_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.row_grid(), vec![1, 2]);
        assert_eq!(m.grad_file_for(8, 1).unwrap(), "g8b1.txt");
        assert_eq!(m.grad_file_for(8, 2).unwrap(), "g8.txt");
        assert!(m.grad_file_for(8, 3).is_err());
    }

    #[test]
    fn row_grid_requires_every_bucket() {
        // rows=1 exists only for bucket 4 → not a usable grid entry.
        let partial = toy_manifest_json().replace(
            r#""grad":{"4":"g4.txt","8":"g8.txt"}"#,
            r#""grad":{"4":"g4.txt","8":"g8.txt"},
               "grad_rows":{"4x1":"g4b1.txt"}"#,
        );
        let j = Json::parse(&partial).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.row_grid(), vec![2]);
        // but a direct (bucket, rows) lookup still finds the artifact
        assert_eq!(m.grad_file_for(4, 1).unwrap(), "g4b1.txt");
    }

    #[test]
    fn parses_grad_compact_grid() {
        let with = toy_manifest_json().replace(
            r#""grad":{"4":"g4.txt","8":"g8.txt"}"#,
            r#""grad":{"4":"g4.txt","8":"g8.txt"},
               "grad_compact":{"4x1":"k4b1.txt","4x2":"k4b2.txt",
                               "8x1":"k8b1.txt","8x2":"k8b2.txt"}"#,
        );
        let j = Json::parse(&with).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert!(m.has_compact());
        assert_eq!(m.grad_compact_file_for(4, 2).unwrap(), "k4b2.txt");
        assert_eq!(m.grad_compact_file_for(8, 1).unwrap(), "k8b1.txt");
        // no legacy-grad fallback for full rows: every cell is explicit
        assert!(m.grad_compact_file_for(8, 3).is_err());
        // legacy manifest: no grad_compact → prefix path only
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let legacy = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert!(!legacy.has_compact());
        assert!(legacy.grad_compact_file_for(4, 2).is_err());
    }

    #[test]
    fn rejects_bad_grad_compact() {
        for grid in [
            // rows beyond batch_train
            r#""grad_compact":{"4x3":"k.txt"}"#,
            // kept-bucket not in config
            r#""grad_compact":{"5x1":"k.txt"}"#,
            // malformed key
            r#""grad_compact":{"4-1":"k.txt"}"#,
        ] {
            let bad = toy_manifest_json().replace(
                r#""grad":{"4":"g4.txt","8":"g8.txt"}"#,
                &format!(r#""grad":{{"4":"g4.txt","8":"g8.txt"}},{grid}"#),
            );
            let j = Json::parse(&bad).unwrap();
            assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err(), "{grid}");
        }
    }

    #[test]
    fn rejects_bad_grad_rows() {
        for (from, to) in [
            // rows beyond batch_train
            (r#""4x1":"g4b1.txt""#, r#""4x3":"g4b1.txt""#),
            // bucket not in config
            (r#""4x1":"g4b1.txt""#, r#""5x1":"g4b1.txt""#),
            // malformed key
            (r#""4x1":"g4b1.txt""#, r#""4-1":"g4b1.txt""#),
        ] {
            let bad = grid_manifest_json().replace(from, to);
            let j = Json::parse(&bad).unwrap();
            assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err(), "{to}");
        }
    }

    #[test]
    fn bucket_routing() {
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.bucket_for(1), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(8), 8);
        assert_eq!(m.bucket_for(99), 8); // clamps to top
    }

    #[test]
    fn rejects_inconsistent_manifests() {
        let base = toy_manifest_json();
        // wrong param_count
        let bad = base.replace("\"param_count\": 40", "\"param_count\": 41");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // non-contiguous offset
        let bad = base.replace("\"offset\":32", "\"offset\":33");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // shape/size mismatch
        let bad = base.replace("\"shape\":[4,2],\"size\":8", "\"shape\":[4,2],\"size\":9");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // descending buckets
        let bad = base.replace("\"buckets\":[4,8]", "\"buckets\":[8,4]");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn loads_real_tiny_manifest_if_built() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.dims.name, "tiny");
            assert_eq!(m.param_count, 108_864);
            assert_eq!(m.dims.buckets, vec![16, 32, 48, 64]);
        }
    }
}
