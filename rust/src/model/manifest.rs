//! Artifact manifest: the shape/ordering contract between python/compile
//! (which writes artifacts/<cfg>/manifest.json) and the Rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
}

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub prompt_len: usize,
    pub max_resp: usize,
    pub buckets: Vec<usize>,
    pub batch_rollout: usize,
    pub batch_train: usize,
    pub pretrain_len: usize,
    pub batch_pretrain: usize,
    pub lr: f64,
    pub clip_eps: f64,
    pub grad_clip: f64,
    pub pretrain_lr: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub params: Vec<ParamEntry>,
    pub param_count: usize,
    pub generate_file: String,
    /// Fixed-trip-count rollout variant (perf A/B; §Perf opt-1).
    pub generate_full_file: Option<String>,
    pub apply_file: String,
    pub pretrain_file: String,
    /// (bucket, filename), ascending by bucket.
    pub grad_files: Vec<(usize, String)>,
    pub score_files: Vec<(usize, String)>,
    /// Scorer variant whose forward runs the L1 Pallas flash-attention
    /// kernel (integration proof; may be absent in older artifact sets).
    pub score_pallas_files: Vec<(usize, String)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let cfg = j.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let us = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let fl = |k: &str| -> Result<f64> {
            cfg.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let buckets: Vec<usize> = cfg
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("config.buckets missing"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        if buckets.is_empty() || buckets.windows(2).any(|w| w[0] >= w[1]) {
            bail!("buckets must be non-empty ascending: {buckets:?}");
        }
        let dims = ModelDims {
            name: cfg.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            vocab: us("vocab")?,
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            d_ff: us("d_ff")?,
            prompt_len: us("prompt_len")?,
            max_resp: us("max_resp")?,
            buckets: buckets.clone(),
            batch_rollout: us("batch_rollout")?,
            batch_train: us("batch_train")?,
            pretrain_len: us("pretrain_len")?,
            batch_pretrain: us("batch_pretrain")?,
            lr: fl("lr")?,
            clip_eps: fl("clip_eps")?,
            grad_clip: fl("grad_clip")?,
            pretrain_lr: fl("pretrain_lr")?,
        };
        if *buckets.last().unwrap() != dims.max_resp {
            bail!("top bucket {} != max_resp {}", buckets.last().unwrap(), dims.max_resp);
        }

        let mut params = Vec::new();
        let mut expect_offset = 0usize;
        for p in j.get("params").and_then(Json::as_arr).ok_or_else(|| anyhow!("params"))? {
            let name = p.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("p.name"))?;
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("p.shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let size = p.get("size").and_then(Json::as_usize).ok_or_else(|| anyhow!("p.size"))?;
            let offset =
                p.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("p.offset"))?;
            if shape.iter().product::<usize>() != size {
                bail!("param {name}: shape {shape:?} does not match size {size}");
            }
            if offset != expect_offset {
                bail!("param {name}: non-contiguous offset {offset} != {expect_offset}");
            }
            expect_offset += size;
            params.push(ParamEntry { name: name.to_string(), shape, size, offset });
        }
        let param_count =
            j.get("param_count").and_then(Json::as_usize).ok_or_else(|| anyhow!("param_count"))?;
        if param_count != expect_offset {
            bail!("param_count {param_count} != sum of sizes {expect_offset}");
        }

        let arts = j.get("artifacts").ok_or_else(|| anyhow!("artifacts"))?;
        let file = |k: &str| -> Result<String> {
            arts.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("artifacts.{k}"))
        };
        let bucket_map = |k: &str| -> Result<Vec<(usize, String)>> {
            let obj = arts.get(k).and_then(Json::as_obj).ok_or_else(|| anyhow!("artifacts.{k}"))?;
            let mut v: Vec<(usize, String)> = obj
                .iter()
                .map(|(b, f)| {
                    Ok((
                        b.parse::<usize>().map_err(|_| anyhow!("bad bucket {b}"))?,
                        f.as_str().ok_or_else(|| anyhow!("bad file"))?.to_string(),
                    ))
                })
                .collect::<Result<_>>()?;
            v.sort();
            Ok(v)
        };
        let grad_files = bucket_map("grad")?;
        if grad_files.iter().map(|(b, _)| *b).collect::<Vec<_>>() != buckets {
            bail!("grad buckets do not match config buckets");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dims,
            params,
            param_count,
            generate_file: file("generate")?,
            generate_full_file: arts
                .get("generate_full")
                .and_then(Json::as_str)
                .map(str::to_string),
            apply_file: file("apply")?,
            pretrain_file: file("pretrain")?,
            grad_files,
            score_files: bucket_map("score")?,
            score_pallas_files: bucket_map("score_pallas").unwrap_or_default(),
        })
    }

    /// Smallest bucket >= learn_len (falls back to the top bucket).
    pub fn bucket_for(&self, learn_len: usize) -> usize {
        for &b in &self.dims.buckets {
            if b >= learn_len {
                return b;
            }
        }
        *self.dims.buckets.last().unwrap()
    }

    pub fn seq_total(&self) -> usize {
        self.dims.prompt_len + self.dims.max_resp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        r#"{
          "config": {"name":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
            "d_ff":8,"prompt_len":4,"max_resp":8,"buckets":[4,8],
            "batch_rollout":2,"batch_train":2,"pretrain_len":12,
            "batch_pretrain":2,"lr":0.001,"clip_eps":0.2,"grad_clip":1.0,
            "pretrain_lr":0.001},
          "param_count": 40,
          "params": [
            {"name":"embed","shape":[8,4],"size":32,"offset":0},
            {"name":"head","shape":[4,2],"size":8,"offset":32}],
          "artifacts": {"generate":"g.txt","apply":"a.txt","pretrain":"p.txt",
            "grad":{"4":"g4.txt","8":"g8.txt"},"score":{"8":"s8.txt"}}
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.param_count, 40);
        assert_eq!(m.params[1].offset, 32);
        assert_eq!(m.grad_files, vec![(4, "g4.txt".into()), (8, "g8.txt".into())]);
        assert_eq!(m.dims.buckets, vec![4, 8]);
        assert_eq!(m.seq_total(), 12);
    }

    #[test]
    fn bucket_routing() {
        let j = Json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        assert_eq!(m.bucket_for(1), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(8), 8);
        assert_eq!(m.bucket_for(99), 8); // clamps to top
    }

    #[test]
    fn rejects_inconsistent_manifests() {
        let base = toy_manifest_json();
        // wrong param_count
        let bad = base.replace("\"param_count\": 40", "\"param_count\": 41");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // non-contiguous offset
        let bad = base.replace("\"offset\":32", "\"offset\":33");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // shape/size mismatch
        let bad = base.replace("\"shape\":[4,2],\"size\":8", "\"shape\":[4,2],\"size\":9");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
        // descending buckets
        let bad = base.replace("\"buckets\":[4,8]", "\"buckets\":[8,4]");
        let j = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn loads_real_tiny_manifest_if_built() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.dims.name, "tiny");
            assert_eq!(m.param_count, 108_864);
            assert_eq!(m.dims.buckets, vec![16, 32, 48, 64]);
        }
    }
}
