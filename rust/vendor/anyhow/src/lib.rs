//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors no registry crates, so this package
//! re-implements exactly the surface `nat_rl` uses: [`Error`], [`Result`],
//! the [`Context`] extension trait (on both `Result<T, E: StdError>` and
//! `Result<T, anyhow::Error>` and `Option<T>`), and the `anyhow!` / `bail!`
//! / `ensure!` macros. Error causes are captured as a message chain rather
//! than live trait objects — enough for CLI diagnostics and tests.

use std::fmt;

/// Error type: a context/cause chain of rendered messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (the `context()` mechanism).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// The full context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Private conversion trait so `Context` covers both `E: std::error::Error`
/// sources and `anyhow::Error` itself (mirrors anyhow's `ext::StdError`).
mod ext {
    use super::Error;

    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_on_std_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x.bin")).unwrap_err();
        assert_eq!(e.root_message(), "reading x.bin");
        assert!(format!("{e:?}").contains("Caused by"));
        assert!(format!("{e:?}").contains("missing thing"));
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_message(), "outer");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
        let v: Option<u32> = Some(3);
        assert_eq!(v.context("empty").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big: 12"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
        // Display-expression form
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
    }
}
