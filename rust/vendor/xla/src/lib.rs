//! Offline stub of the `xla` PJRT binding.
//!
//! This environment cannot link the real XLA/PJRT runtime, but the
//! coordinator crate must still build and its host-side logic must still be
//! testable. The split is:
//!
//! * [`Literal`] — fully functional host-side tensor container (typed
//!   storage, `vec1`, `reshape`, `to_vec`, scalars, tuples). Everything the
//!   coordinator does between device calls works for real.
//! * [`PjRtClient`] / [`PjRtLoadedExecutable`] — construction fails with an
//!   explanatory error. All call sites in `nat_rl` gate on the artifact
//!   directory existing and skip cleanly, so builds and tests pass without
//!   a device runtime; linking the real binding restores execution with the
//!   same API.
//!
//! Types are `Send + Sync` so the coordinator's pipelined trainer can share
//! runtime handles across rollout worker threads.

use std::fmt;
use std::sync::Mutex;

/// Stub error type (implements `std::error::Error`, so `?` converts into
/// `anyhow::Error` at call sites).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_runtime<T>() -> Result<T> {
    Err(Error::new(
        "PJRT execution is unavailable in this offline build (vendored xla stub); \
         link the real xla crate to run against compiled artifacts",
    ))
}

/// Element storage for [`Literal`].
#[derive(Clone, Debug, PartialEq)]
enum Data {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::I32(v) => v.len(),
            Data::F32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }
}

/// Element trait for the typed `Literal` accessors. Only public types appear
/// in its signatures; implementations touch `Literal`'s private storage.
pub trait NativeType: Copy + 'static {
    fn vec1(v: &[Self]) -> Literal
    where
        Self: Sized;
    fn extract(lit: &Literal) -> Option<Vec<Self>>
    where
        Self: Sized;
}

impl NativeType for i32 {
    fn vec1(v: &[i32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: Data::I32(v.to_vec()) }
    }
    fn extract(lit: &Literal) -> Option<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn vec1(v: &[f32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: Data::F32(v.to_vec()) }
    }
    fn extract(lit: &Literal) -> Option<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor value: typed flat storage plus a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::vec1(v)
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], data: Data::Tuple(elems) }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Flat typed copy of the storage.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
            .ok_or_else(|| Error::new(format!("to_vec: wrong element type for {:?}", self.dims)))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error::new("to_tuple on a non-tuple literal")),
        }
    }
}

impl From<i32> for Literal {
    fn from(x: i32) -> Literal {
        Literal { dims: vec![], data: Data::I32(vec![x]) }
    }
}

impl From<f32> for Literal {
    fn from(x: f32) -> Literal {
        Literal { dims: vec![], data: Data::F32(vec![x]) }
    }
}

/// Parsed HLO module (stub: retains the text so parse errors surface at the
/// right place — a missing or unreadable artifact file fails here).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub: never constructed).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_runtime()
    }
}

/// Compiled executable handle (stub: never constructed; `Mutex` documents
/// that the real handle is used behind shared references from many threads).
pub struct PjRtLoadedExecutable {
    _guard: Mutex<()>,
}

impl PjRtLoadedExecutable {
    /// Execute with owned or borrowed literal arguments
    /// (`execute::<Literal>` / `execute::<&Literal>` both work).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_runtime()
    }
}

/// PJRT client handle. `cpu()` is the stub's failure point: everything in
/// `nat_rl` that needs a device goes through `Runtime::load`, which calls
/// this after checking the artifact manifest exists.
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        no_runtime()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_runtime()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn typed_access_is_checked() {
        let l = Literal::vec1(&[1.5f32, 2.5]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.5, 2.5]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalars_and_tuples() {
        let s: Literal = 7i32.into();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![s.clone(), 1.0f32.into()]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn runtime_paths_fail_with_clear_error() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"));
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
