//! Microbench: NAT mask sampling throughput (the per-sequence host-side
//! cost the coordinator adds on top of vanilla GRPO — must be negligible
//! next to a grad call).
use nat_rl::config::Method;
use nat_rl::coordinator::masking::{rpc_survival, sample};
use nat_rl::util::bench::Bench;
use nat_rl::util::rng::Rng;

fn main() {
    let mut b = Bench::new("masking");
    let mut rng = Rng::new(0);
    for t_i in [64usize, 192, 1024, 4096] {
        b.iter(&format!("grpo/T={t_i}"), || sample(&Method::Grpo, t_i, &mut rng));
        b.iter(&format!("urs_p0.5/T={t_i}"), || {
            sample(&Method::Urs { p: 0.5 }, t_i, &mut rng)
        });
        b.iter(&format!("det_trunc/T={t_i}"), || {
            sample(&Method::DetTrunc { frac: 0.5 }, t_i, &mut rng)
        });
        b.iter(&format!("rpc_c8/T={t_i}"), || {
            sample(&Method::Rpc { min_cut: 8 }, t_i, &mut rng)
        });
        b.iter(&format!("rpc_survival/T={t_i}"), || rpc_survival(t_i, 8));
        // the selection-subsystem plug-ins: stratified should beat URS
        // (one RNG draw per sequence instead of T)
        b.iter(&format!("stratified_p0.5/T={t_i}"), || {
            sample(&Method::Stratified { p: 0.5 }, t_i, &mut rng)
        });
        b.iter(&format!("poisson_k8/T={t_i}"), || {
            sample(&Method::Poisson { k: 8 }, t_i, &mut rng)
        });
    }
    b.report();
}
