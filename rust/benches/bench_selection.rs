//! Selection-subsystem bench (sim tier — always runs, no artifacts).
//!
//! Measures the batch budget controller and the plug-in selectors on the
//! ONE shared workload (`selection::bench_workload`, the same population
//! the tier-1 gate in `tests/selection.rs` asserts on) and writes the
//! machine-readable `BENCH_selection.json` record:
//!
//! * controller solve cost per scheme (ns/solve on the 64-row population);
//! * the budget acceptance: for every adaptive scheme the achieved
//!   expectation lands within 2% of the target — asserted here too, AFTER
//!   the JSON is on disk so a failure still leaves the measurements;
//! * the selection-v2 variance story: the Neyman per-sequence allocation
//!   vs the Poisson batch controller at EQUAL realized budget on the same
//!   population — mean HT effective sample size and per-row selection
//!   variance over 32 deterministic draws, with the "neyman raises ht_ess
//!   and lowers sel_var" acceptance asserted after the JSON is written;
//! * end-to-end `learn_stage` steps under `--train.budget_mode batch` (and
//!   one `neyman` step) on the sim runtime, checked against a full-token
//!   GRPO step for matching `StepStats` shape (same step/sequence
//!   accounting, finite metrics) — the controller changes *how much* is
//!   selected, never the step's observable structure.

use nat_rl::config::{BudgetMode, Method, RunConfig};
use nat_rl::coordinator::rollout::scheduler::SchedStats;
use nat_rl::coordinator::selection::{self, bench_workload, HtMoments, SelectionPlan};
use nat_rl::coordinator::trainer::{learn_stage, StepStats};
use nat_rl::obs::Tracer;
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{GradAccum, OptState, Runtime};
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{obj, Json};
use nat_rl::util::rng::Rng;

fn controller_bench(b: &mut Bench, records: &mut Vec<Json>) {
    let lens = bench_workload::lens();
    let lps: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &t)| bench_workload::old_lp(i, t))
        .collect();
    let rows: Vec<(usize, Option<&[f32]>)> =
        lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
    let total: f64 = lens.iter().map(|&t| t as f64).sum();

    for (method, frac) in [
        (Method::Urs { p: 0.9 }, 0.4f64),
        (Method::Stratified { p: 0.9 }, 0.4),
        (Method::Poisson { k: 4 }, 0.4),
        (Method::Saliency { floor: 0.25 }, 0.4),
        (Method::Rpc { min_cut: 8 }, 0.65),
    ] {
        let target = (total * frac).round() as usize;
        b.iter(&format!("solve/{}", method.id()), || {
            selection::solve_batch(&method, &rows, target, PI_FLOOR).unwrap()
        });
        let out = selection::solve_batch(&method, &rows, target, PI_FLOOR).unwrap();
        let rel = (out.expected - target as f64).abs() / target as f64;
        records.push(obj(vec![
            ("scheme", Json::Str(method.id().into())),
            ("target", Json::Num(target as f64)),
            ("expected", Json::Num(out.expected)),
            ("rel_err", Json::Num(rel)),
        ]));
    }

    let abs_adv = vec![1.0f64; rows.len()];
    let target = (total * 0.4).round() as usize;
    b.iter("solve/neyman", || {
        selection::solve_neyman(&rows, &abs_adv, target, PI_FLOOR)
    });
    let alloc = selection::solve_neyman(&rows, &abs_adv, target, PI_FLOOR);
    let rel = (alloc.expected_sum() - target as f64).abs() / target as f64;
    records.push(obj(vec![
        ("scheme", Json::Str("neyman".into())),
        ("target", Json::Num(target as f64)),
        ("expected", Json::Num(alloc.expected_sum())),
        ("rel_err", Json::Num(rel)),
    ]));
}

/// `--train.pi_floor` default — the bench measures the production guard.
const PI_FLOOR: f64 = 1e-3;

/// Mean (HT effective sample size, per-row selection variance) over
/// `draws` deterministic draws of a full 64-row selection round.
fn mc_stats<F>(
    rows: &[(usize, Option<&[f32]>)],
    draws: usize,
    seed: u64,
    mut sample: F,
) -> (f64, f64)
where
    F: FnMut(usize, usize, Option<&[f32]>, &mut Rng) -> SelectionPlan,
{
    let mut rng = Rng::new(seed);
    let (mut ess_acc, mut var_acc) = (0.0f64, 0.0f64);
    for _ in 0..draws {
        let mut ht = HtMoments::default();
        let mut var = 0.0f64;
        for (i, &(t, lp)) in rows.iter().enumerate() {
            let plan = sample(i, t, lp, &mut rng);
            let e = plan.expected_kept();
            var += (plan.kept as f64 - e) * (plan.kept as f64 - e);
            ht.observe(&plan);
        }
        ess_acc += ht.ess();
        var_acc += var / rows.len() as f64;
    }
    (ess_acc / draws as f64, var_acc / draws as f64)
}

/// Neyman allocation vs the Poisson batch controller at EQUAL realized
/// budget on the shared controller workload — the selection-v2 acceptance
/// numbers (`ht_ess` up, `sel_var` down). Returns the JSON record plus the
/// gate inputs `(batch_ess, neyman_ess, batch_var, neyman_var)`.
fn allocation_comparison() -> (Json, (f64, f64, f64, f64)) {
    let lens = bench_workload::lens();
    let lps: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &t)| bench_workload::old_lp(i, t))
        .collect();
    let rows: Vec<(usize, Option<&[f32]>)> =
        lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
    let total: f64 = lens.iter().map(|&t| t as f64).sum();
    let target = (total * 0.4).round() as usize;

    let batch =
        selection::solve_batch(&Method::Poisson { k: 4 }, &rows, target, PI_FLOOR).unwrap();
    // the workload's groups alternate rewards, so every |advantage| is equal
    // — the Neyman solve then allocates on length × surprisal alone
    let abs_adv = vec![1.0f64; rows.len()];
    let neyman = selection::solve_neyman(&rows, &abs_adv, target, PI_FLOOR);

    const DRAWS: usize = 32;
    let (b_ess, b_var) =
        mc_stats(&rows, DRAWS, 0xA110_C001, |_, t, lp, rng| batch.selector.sample(t, lp, rng));
    let (n_ess, n_var) =
        mc_stats(&rows, DRAWS, 0xA110_C002, |i, t, _, rng| neyman.sample_row(i, t, rng));

    let record = obj(vec![
        ("comparison", Json::Str("neyman_vs_poisson_batch".into())),
        ("target", Json::Num(target as f64)),
        ("draws", Json::Num(DRAWS as f64)),
        ("pi_floor", Json::Num(PI_FLOOR)),
        ("batch_expected", Json::Num(batch.expected)),
        ("neyman_expected", Json::Num(neyman.expected_sum())),
        ("batch_ht_ess", Json::Num(b_ess)),
        ("neyman_ht_ess", Json::Num(n_ess)),
        ("batch_sel_var", Json::Num(b_var)),
        ("neyman_sel_var", Json::Num(n_var)),
        ("ht_ess_gain", Json::Num(n_ess / b_ess - 1.0)),
        ("sel_var_ratio", Json::Num(n_var / b_var)),
    ]);
    (record, (b_ess, n_ess, b_var, n_var))
}

fn step_with(
    rt: &Runtime,
    method: Method,
    mode: BudgetMode,
    budget: usize,
    seqs: &[nat_rl::coordinator::rollout::RolloutSeq],
) -> StepStats {
    let mut cfg = RunConfig::default();
    cfg.method = method;
    cfg.rl.group_size = bench_workload::GROUP_SIZE;
    if budget > 0 {
        cfg.train.token_budget = budget;
        cfg.train.budget_mode = mode;
    }
    let mut params = init_params(&rt.manifest);
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut rng_mask = Rng::new(0xBE9C);
    learn_stage(
        rt,
        &cfg,
        &mut params,
        &mut opt,
        &mut acc,
        None,
        &mut rng_mask,
        1,
        seqs,
        &SchedStats::default(),
        &Tracer::off(),
    )
    .unwrap()
}

/// "Same StepStats shape as full-token GRPO": identical step/sequence
/// accounting, live micro-batching, every float finite — the controller
/// must not change the step's observable structure, only its token count.
fn assert_shape_matches(grpo: &StepStats, s: &StepStats, scheme: &str) {
    assert_eq!(s.step, grpo.step, "{scheme}");
    assert_eq!(s.sequences, grpo.sequences, "{scheme}");
    assert!(s.micro_batches > 0, "{scheme}");
    for (name, v) in [
        ("reward_mean", s.reward_mean),
        ("entropy", s.entropy),
        ("clip_frac", s.clip_frac),
        ("kl", s.kl),
        ("grad_norm", s.grad_norm),
        ("selected_ratio", s.selected_ratio),
        ("budget_realized", s.budget_realized),
        ("sel_var", s.sel_var),
        ("padding_waste", s.padding_waste),
        ("mem_gb", s.mem_gb),
        ("peak_mem_gb", s.peak_mem_gb),
    ] {
        assert!(v.is_finite(), "{scheme}: {name} not finite");
    }
    assert_eq!(s.reward_mean.to_bits(), grpo.reward_mean.to_bits(), "{scheme}");
    assert!(s.selected_ratio <= 1.0 + 1e-12, "{scheme}");
}

fn main() {
    let mut b = Bench::new("selection");
    let mut solve_records = Vec::new();
    controller_bench(&mut b, &mut solve_records);

    // End-to-end sim steps: GRPO reference vs budget-controlled schemes.
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = bench_workload::seqs(d.prompt_len, d.max_resp);
    let total: usize = seqs.iter().map(|s| s.resp_len).sum();
    let budget = (total as f64 * 0.4).round() as usize;

    let (alloc_record, (b_ess, n_ess, b_var, n_var)) = allocation_comparison();

    let grpo = step_with(&rt, Method::Grpo, BudgetMode::None, 0, &seqs);
    let mut step_records = vec![obj(vec![
        ("scheme", Json::Str("grpo".into())),
        ("selected_ratio", Json::Num(grpo.selected_ratio)),
        ("budget_realized", Json::Num(grpo.budget_realized)),
    ])];
    let mut worst_rel = 0.0f64;
    for method in [
        Method::Urs { p: 0.9 },
        Method::Stratified { p: 0.9 },
        Method::Poisson { k: 4 },
        Method::Saliency { floor: 0.25 },
    ] {
        b.iter(&format!("step_budget/{}", method.id()), || {
            step_with(&rt, method, BudgetMode::Batch, budget, &seqs)
        });
        let s = step_with(&rt, method, BudgetMode::Batch, budget, &seqs);
        assert_shape_matches(&grpo, &s, method.id());
        let rel = (s.budget_realized - budget as f64).abs() / budget as f64;
        worst_rel = worst_rel.max(rel);
        // The savings ledger gives each scheme its token/FLOP story vs the
        // full-token GRPO counterfactual — the same numbers `nat trace`
        // reports from a live run.
        step_records.push(obj(vec![
            ("scheme", Json::Str(method.id().into())),
            ("target", Json::Num(budget as f64)),
            ("budget_realized", Json::Num(s.budget_realized)),
            ("rel_err", Json::Num(rel)),
            ("selected_ratio", Json::Num(s.selected_ratio)),
            ("sel_var", Json::Num(s.sel_var)),
            (
                "ledger",
                obj(vec![
                    ("gen_tokens", Json::Num(s.ledger.gen_tokens)),
                    ("sel_tokens_exp", Json::Num(s.ledger.sel_tokens_exp)),
                    ("backprop_tokens", Json::Num(s.ledger.backprop_tokens)),
                    ("alloc_tokens", Json::Num(s.ledger.alloc_tokens)),
                    ("flop_saving", Json::Num(s.ledger.flop_saving())),
                    ("mem_saving", Json::Num(s.ledger.mem_saving())),
                    ("ht_ess", Json::Num(s.ledger.ht_ess)),
                ]),
            ),
        ]));
    }

    // End-to-end selection-v2 step: the Neyman allocation through the full
    // learn_stage path, same shape/accuracy contract as the batch schemes
    // (the per-row allocation changes the rates, not the step structure).
    b.iter("step_budget/neyman", || {
        step_with(&rt, Method::Stratified { p: 0.9 }, BudgetMode::Neyman, budget, &seqs)
    });
    let ney = step_with(&rt, Method::Stratified { p: 0.9 }, BudgetMode::Neyman, budget, &seqs);
    assert_shape_matches(&grpo, &ney, "neyman");
    let ney_rel = (ney.budget_realized - budget as f64).abs() / budget as f64;
    worst_rel = worst_rel.max(ney_rel);
    step_records.push(obj(vec![
        ("scheme", Json::Str("neyman".into())),
        ("target", Json::Num(budget as f64)),
        ("budget_realized", Json::Num(ney.budget_realized)),
        ("rel_err", Json::Num(ney_rel)),
        ("selected_ratio", Json::Num(ney.selected_ratio)),
        ("sel_var", Json::Num(ney.sel_var)),
        ("ht_w_max", Json::Num(ney.ledger.ht_w_max)),
        ("pi_floor", Json::Num(ney.ledger.pi_floor)),
    ]));

    let record = obj(vec![
        ("bench", Json::Str("selection".into())),
        (
            "workload",
            obj(vec![
                ("controller_rows", Json::Num(bench_workload::N_LENS as f64)),
                ("sim_seqs", Json::Num(seqs.len() as f64)),
                ("sim_total_tokens", Json::Num(total as f64)),
                ("sim_budget", Json::Num(budget as f64)),
            ]),
        ),
        ("controller", Json::Arr(solve_records.clone())),
        ("allocation", alloc_record),
        ("steps", Json::Arr(step_records)),
        ("worst_step_rel_err", Json::Num(worst_rel)),
    ]);
    let path = write_record("selection", &record).unwrap();
    println!("wrote {path}");

    // Acceptance gates, AFTER the JSON record is on disk.
    for r in &solve_records {
        let rel = r.get("rel_err").and_then(Json::as_f64).unwrap();
        assert!(rel <= 0.02, "controller off target: {}", r.to_string());
    }
    assert!(
        worst_rel <= 0.02,
        "acceptance: budget-solved selection must land within 2% of \
         --train.token_budget at the shared sim workload (worst rel err {worst_rel:.4})"
    );
    // Selection v2 acceptance: at equal realized budget the Neyman
    // allocation must beat the Poisson batch controller on both variance
    // axes — higher kept-token effective sample size, lower per-row
    // selection variance.
    assert!(
        n_ess > b_ess,
        "acceptance: neyman ht_ess {n_ess:.1} must exceed poisson-batch {b_ess:.1} \
         at equal realized budget"
    );
    assert!(
        n_var < b_var,
        "acceptance: neyman sel_var {n_var:.3} must undercut poisson-batch {b_var:.3} \
         at equal realized budget"
    );
    // HT-weight health through the end-to-end step: the floor bounds 1/π.
    assert!(
        ney.ledger.pi_floor > 0.0
            && ney.ledger.ht_w_max <= (1.0 + 1e-6) / ney.ledger.pi_floor,
        "acceptance: neyman step ht_w_max {:.1} must respect 1/pi_floor {:.1}",
        ney.ledger.ht_w_max,
        1.0 / ney.ledger.pi_floor
    );

    b.report();
}
