//! End-to-end rollout bench: one generate call (B_rollout sequences,
//! prefill + max_resp KV-cache decode steps) per model config present.
//! This is the paper's "inference" stage — NAT leaves it untouched, which
//! Table 3's total-vs-learner split depends on.
use std::path::Path;

use nat_rl::coordinator::rollout::encode_prompt;
use nat_rl::runtime::{ParamStore, Runtime};
use nat_rl::tokenizer::Tokenizer;
use nat_rl::util::bench::Bench;

fn main() {
    let mut b = Bench::new("rollout").slow();
    for model in ["tiny", "small", "base"] {
        let dir = format!("artifacts/{model}");
        if !Path::new(&dir).join("manifest.json").exists() {
            eprintln!("skip {model}: artifacts not built");
            continue;
        }
        let rt = Runtime::load(Path::new(&dir)).unwrap();
        let params = ParamStore::load_init(&rt.manifest).unwrap();
        let d = rt.manifest.dims.clone();
        let tok = Tokenizer::new();
        let (row, pad) = encode_prompt(&tok, "e:3+4*2%7=", d.prompt_len).unwrap();
        let prompts: Vec<i32> =
            row.iter().cycle().take(d.batch_rollout * d.prompt_len).copied().collect();
        let pads = vec![pad as i32; d.batch_rollout];
        // warm the executables so compile time is not measured
        rt.generate(&params, &prompts, &pads, 0, 1.0).unwrap();
        let mut seed = 0;
        b.iter(&format!("generate/{model}/B={}xT={}", d.batch_rollout, d.max_resp), || {
            seed += 1;
            rt.generate(&params, &prompts, &pads, seed, 1.0).unwrap()
        });
        // §Perf opt-1 A/B: fixed-trip-count decode (the pre-optimization
        // rollout). With a random-init policy both run full length; with a
        // trained policy (checkpoints/<model>_sft.bin) the early-exit
        // variant stops at the batch's longest response.
        if rt.generate_full(&params, &prompts, &pads, 0, 1.0).is_ok() {
            let mut seed = 0;
            b.iter(
                &format!("generate_full/{model}/B={}xT={}", d.batch_rollout, d.max_resp),
                || {
                    seed += 1;
                    rt.generate_full(&params, &prompts, &pads, seed, 1.0).unwrap()
                },
            );
        }
        // trained-policy A/B (realistic response-length distribution)
        let ckpt = format!("checkpoints/{model}_sft.bin");
        if Path::new(&ckpt).exists() {
            if let Ok((trained, _)) = nat_rl::runtime::Checkpoint::load(
                Path::new(&ckpt),
                &rt.manifest,
            ) {
                let mut seed = 0;
                b.iter(&format!("generate_sft/{model}/early_exit"), || {
                    seed += 1;
                    rt.generate(&trained, &prompts, &pads, seed, 1.0).unwrap()
                });
                let mut seed = 0;
                b.iter(&format!("generate_sft/{model}/full"), || {
                    seed += 1;
                    rt.generate_full(&trained, &prompts, &pads, seed, 1.0).unwrap()
                });
            }
        }
    }
    b.report();
}
