//! Rollout engine bench: fixed vs bucketed+refill scheduling.
//!
//! Two tiers:
//!
//! * `sim/*` — always runs: the real scheduler core drives a simulated
//!   per-row-seeded policy ([`SimBackend`]) at the default workload
//!   (buckets [32,64,96,128], B=8, short-response RPC-trained length mix),
//!   comparing allocated decode-token-steps and wall-time between the
//!   legacy fixed engine and the bucketed+refill engine. This is the
//!   acceptance metric: bucketed must allocate >= 25% fewer decode-token
//!   steps than fixed. Results are also written to `BENCH_rollout.json`
//!   (machine-readable, for in-repo perf tracking).
//! * `generate/*` — artifact-gated: one real generate call per model
//!   config (prefill + KV-cache decode through PJRT), fixed vs early-exit,
//!   as before.
use std::path::Path;
use std::time::Instant;

use nat_rl::coordinator::rollout::encode_prompt;
use nat_rl::coordinator::rollout::scheduler::{sim_workload, RolloutScheduler, SchedStats};
use nat_rl::runtime::{ParamStore, Runtime};
use nat_rl::tokenizer::Tokenizer;
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{obj, Json};

/// One bucketed run over the shared default workload; returns accumulated
/// stats (the predictor warms over the first steps exactly as in training).
fn run_bucketed() -> SchedStats {
    let backend = sim_workload::backend();
    let encoded = sim_workload::prompts();
    let sched = RolloutScheduler::new(*sim_workload::BUCKETS.last().unwrap());
    let mut total = SchedStats::default();
    for step in 0..sim_workload::STEPS {
        let slots = sim_workload::slots(step);
        let (_, stats) = sched.run(&backend, &encoded, &slots, 1.0, step).unwrap();
        total.calls += stats.calls;
        total.decode_token_steps += stats.decode_token_steps;
        total.escalations += stats.escalations;
        total.padded_rows += stats.padded_rows;
    }
    total
}

/// The fixed engine's accounting for the same workload.
fn fixed_stats() -> SchedStats {
    let calls_per_step = sim_workload::SLOTS_PER_STEP.div_ceil(sim_workload::BATCH);
    let calls = calls_per_step * sim_workload::STEPS as usize;
    SchedStats {
        calls,
        decode_token_steps: sim_workload::fixed_decode_steps(),
        escalations: 0,
        padded_rows: (calls_per_step * sim_workload::BATCH - sim_workload::SLOTS_PER_STEP)
            * sim_workload::STEPS as usize,
        ..SchedStats::default()
    }
}

fn sim_bench(b: &mut Bench) {
    b.iter("sim/bucketed+refill/schedule", run_bucketed);

    let t0 = Instant::now();
    let bucketed = run_bucketed();
    let bucketed_wall_s = t0.elapsed().as_secs_f64();
    let fixed = fixed_stats();
    let saving = 1.0 - bucketed.decode_token_steps as f64 / fixed.decode_token_steps as f64;
    println!(
        "sim decode-token-steps: fixed {} | bucketed+refill {} | saving {:.1}% \
         (escalations {}, padded rows {} vs {})",
        fixed.decode_token_steps,
        bucketed.decode_token_steps,
        100.0 * saving,
        bucketed.escalations,
        bucketed.padded_rows,
        fixed.padded_rows,
    );
    assert!(
        saving >= 0.25,
        "acceptance: bucketed+refill must allocate >= 25% fewer decode-token-steps \
         than fixed at the default workload (got {:.1}%)",
        100.0 * saving
    );

    // Machine-readable record for in-repo perf tracking (CI keeps
    // `cargo bench --no-run` green; a full run refreshes this file).
    let side = |s: &SchedStats, wall_s: f64| {
        obj(vec![
            ("calls", Json::Num(s.calls as f64)),
            ("decode_token_steps", Json::Num(s.decode_token_steps as f64)),
            ("escalations", Json::Num(s.escalations as f64)),
            ("padded_rows", Json::Num(s.padded_rows as f64)),
            ("wall_s", Json::Num(wall_s)),
        ])
    };
    let buckets_json = nat_rl::util::json::arr_f64(
        &sim_workload::BUCKETS.iter().map(|&b| b as f64).collect::<Vec<_>>(),
    );
    let record = obj(vec![
        ("bench", Json::Str("rollout".into())),
        (
            "workload",
            obj(vec![
                ("batch", Json::Num(sim_workload::BATCH as f64)),
                ("prompt_len", Json::Num(sim_workload::PROMPT_LEN as f64)),
                ("buckets", buckets_json),
                ("mean_resp_len", Json::Num(sim_workload::MEAN_RESP_LEN as f64)),
                ("slots_per_step", Json::Num(sim_workload::SLOTS_PER_STEP as f64)),
                ("steps", Json::Num(sim_workload::STEPS as f64)),
            ]),
        ),
        // fixed wall-time is not meaningful in sim (no device): report 0.
        ("fixed", side(&fixed, 0.0)),
        ("bucketed", side(&bucketed, bucketed_wall_s)),
        ("decode_step_saving", Json::Num(saving)),
    ]);
    let path = write_record("rollout", &record).unwrap();
    println!("wrote {path}");
}

fn generate_bench(b: &mut Bench) {
    for model in ["tiny", "small", "base"] {
        let dir = format!("artifacts/{model}");
        if !Path::new(&dir).join("manifest.json").exists() {
            eprintln!("skip {model}: artifacts not built");
            continue;
        }
        let rt = Runtime::load(Path::new(&dir)).unwrap();
        let params = ParamStore::load_init(&rt.manifest).unwrap();
        let d = rt.manifest.dims.clone();
        let tok = Tokenizer::new();
        let (row, pad) = encode_prompt(&tok, "e:3+4*2%7=", d.prompt_len).unwrap();
        let prompts: Vec<i32> =
            row.iter().cycle().take(d.batch_rollout * d.prompt_len).copied().collect();
        let pads = vec![pad as i32; d.batch_rollout];
        // warm the executables so compile time is not measured
        rt.generate(&params, &prompts, &pads, 0, 1.0).unwrap();
        let mut seed = 0;
        b.iter(&format!("generate/{model}/B={}xT={}", d.batch_rollout, d.max_resp), || {
            seed += 1;
            rt.generate(&params, &prompts, &pads, seed, 1.0).unwrap()
        });
        // Bucketed grid: the shortest per-row-seeded bucket artifact is the
        // unit the scheduler refills with.
        if let Some(&(bucket, _)) = rt.manifest.generate_files.first() {
            let seeds: Vec<i32> = (0..d.batch_rollout as i32).collect();
            rt.generate_bucketed(&params, bucket, &prompts, &pads, &seeds, 1.0).unwrap();
            let mut s = 0;
            b.iter(&format!("generate_bucketed/{model}/T={bucket}"), || {
                s += 1;
                let seeds: Vec<i32> = (s..s + d.batch_rollout as i32).collect();
                rt.generate_bucketed(&params, bucket, &prompts, &pads, &seeds, 1.0).unwrap()
            });
        }
        // §Perf opt-1 A/B: fixed-trip-count decode (the pre-optimization
        // rollout). With a random-init policy both run full length; with a
        // trained policy (checkpoints/<model>_sft.bin) the early-exit
        // variant stops at the batch's longest response.
        if rt.generate_full(&params, &prompts, &pads, 0, 1.0).is_ok() {
            let mut seed = 0;
            b.iter(
                &format!("generate_full/{model}/B={}xT={}", d.batch_rollout, d.max_resp),
                || {
                    seed += 1;
                    rt.generate_full(&params, &prompts, &pads, seed, 1.0).unwrap()
                },
            );
        }
        // trained-policy A/B (realistic response-length distribution)
        let ckpt = format!("checkpoints/{model}_sft.bin");
        if Path::new(&ckpt).exists() {
            if let Ok((trained, _)) = nat_rl::runtime::Checkpoint::load(
                Path::new(&ckpt),
                &rt.manifest,
            ) {
                let mut seed = 0;
                b.iter(&format!("generate_sft/{model}/early_exit"), || {
                    seed += 1;
                    rt.generate(&trained, &prompts, &pads, seed, 1.0).unwrap()
                });
                let mut seed = 0;
                b.iter(&format!("generate_sft/{model}/full"), || {
                    seed += 1;
                    rt.generate_full(&trained, &prompts, &pads, seed, 1.0).unwrap()
                });
            }
        }
    }
}

fn main() {
    let mut b = Bench::new("rollout").slow();
    sim_bench(&mut b);
    generate_bench(&mut b);
    b.report();
}
