//! Gather-compaction bench (sim tier — always runs, no artifacts).
//!
//! Prices the kept-count (`grad_K<k>_B<r>`) layout against prefix-packing
//! on the ONE shared workload (`batcher::compaction_workload`, the same
//! population the tier-1 gate in batcher's tests asserts on): scattered
//! ~50%-keep selections (URS / stratified / Poisson) over 64..=128-token
//! responses. Writes the machine-readable `BENCH_compaction.json` record:
//!
//! * per-method allocated grad tokens for both layouts, averaged over many
//!   mask draws, plus the packer's kept/alloc/bound accounting;
//! * the acceptance: every scattered method allocates >= 30% fewer grad
//!   tokens compacted than prefix-packed — asserted AFTER the JSON is on
//!   disk so a failure still leaves the measurements;
//! * end-to-end sim `learn_stage` steps with `--train.compact` on vs off:
//!   the realized `StepLedger::compact_saving()` the `nat trace` gate
//!   reports, from the same code path a real run takes;
//! * packing throughput for both layouts (the compact pass adds a gather
//!   build per micro-batch; it must stay noise next to a grad execution).

use nat_rl::config::{BudgetMode, Method, RunConfig};
use nat_rl::coordinator::batcher::{
    allocated_tokens, compact_stats, compaction_workload as w, pack_budget, pack_budget_with,
    split_zero_contribution,
};
use nat_rl::coordinator::rollout::scheduler::SchedStats;
use nat_rl::coordinator::rollout::RolloutSeq;
use nat_rl::coordinator::trainer::{learn_stage, StepStats};
use nat_rl::obs::Tracer;
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{GradAccum, OptState, Runtime};
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{obj, Json};
use nat_rl::util::rng::Rng;

const DRAWS: usize = 20;

fn step_with(rt: &Runtime, method: Method, compact: bool, seqs: &[RolloutSeq]) -> StepStats {
    let mut cfg = RunConfig::default();
    cfg.method = method;
    cfg.rl.group_size = 4;
    cfg.train.budget_mode = BudgetMode::Batch;
    cfg.train.token_budget = 40;
    cfg.train.compact = compact;
    let mut params = init_params(&rt.manifest);
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut rng_mask = Rng::new(0xC0FFEE);
    learn_stage(
        rt,
        &cfg,
        &mut params,
        &mut opt,
        &mut acc,
        None,
        &mut rng_mask,
        1,
        seqs,
        &SchedStats::default(),
        &Tracer::off(),
    )
    .unwrap()
}

/// A deterministic sim-scale rollout group (the sim runtime's 16-token
/// response window, scattered lengths) for the end-to-end leg.
fn sim_seqs(prompt_len: usize, max_resp: usize) -> Vec<RolloutSeq> {
    let mut rng = Rng::new(0x5EED);
    (0..8)
        .map(|i| {
            let resp_len = 1 + rng.below(max_resp as u64) as usize;
            RolloutSeq {
                task_idx: i / 4,
                tokens: (0..(prompt_len + max_resp) as i32).map(|x| 3 + x % 40).collect(),
                pad_len: 2,
                resp_len,
                old_lp: (0..resp_len).map(|t| -0.2 - 0.01 * t as f32).collect(),
                reward: if i % 2 == 0 { 1.0 } else { 0.0 },
            }
        })
        .collect()
}

fn main() {
    let mut b = Bench::new("compaction");

    // ---- Layout pricing on the shared workload (the acceptance metric).
    println!("== allocated grad tokens: prefix-packed vs gather-compacted ==");
    println!(
        "{:<12} {:>10} {:>10} {:>9} {:>10} {:>10}",
        "method", "prefix", "compact", "saving", "kept", "bound"
    );
    let mut layout_records = Vec::new();
    for (name, method) in w::methods() {
        let mut rng = Rng::new(w::SEED);
        let (mut prefix_alloc, mut compact_alloc) = (0usize, 0usize);
        let (mut kept_sum, mut bound_sum) = (0usize, 0usize);
        for _ in 0..DRAWS {
            let items = w::items(&method, &mut rng);
            let (items, _) = split_zero_contribution(items);
            let (prefix, compact) = w::both_layouts(&items);
            prefix_alloc += allocated_tokens(&prefix, w::PROMPT_LEN);
            compact_alloc += allocated_tokens(&compact, w::PROMPT_LEN);
            let (kept, alloc, bound) =
                compact_stats(&compact, &w::BUCKETS, &w::ROW_GRID, w::PROMPT_LEN);
            assert!(kept <= alloc && alloc <= bound, "{name}: {kept}/{alloc}/{bound}");
            kept_sum += kept;
            bound_sum += bound;
        }
        let saving = 1.0 - compact_alloc as f64 / prefix_alloc as f64;
        println!(
            "{:<12} {:>10} {:>10} {:>8.1}% {:>10} {:>10}",
            name,
            prefix_alloc,
            compact_alloc,
            100.0 * saving,
            kept_sum,
            bound_sum
        );
        layout_records.push(obj(vec![
            ("scheme", Json::Str(name.into())),
            ("prefix_alloc", Json::Num(prefix_alloc as f64)),
            ("compact_alloc", Json::Num(compact_alloc as f64)),
            ("saving", Json::Num(saving)),
            ("kept", Json::Num(kept_sum as f64)),
            ("bound", Json::Num(bound_sum as f64)),
        ]));
    }

    // ---- End-to-end: the realized ledger saving through learn_stage.
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = sim_seqs(d.prompt_len, d.max_resp);
    let mut step_records = Vec::new();
    for method in [Method::Urs { p: 0.9 }, Method::Stratified { p: 0.9 }] {
        let on = step_with(&rt, method, true, &seqs);
        let off = step_with(&rt, method, false, &seqs);
        b.iter(&format!("step_compact/{}", method.id()), || {
            step_with(&rt, method, true, &seqs)
        });
        // The off-path ledger must price compaction as inactive (saving 0),
        // and the on-path counterfactual must reproduce the off-path
        // allocation — same items, same packer, compact disabled.
        assert_eq!(off.ledger.compact_saving(), 0.0, "{}", method.id());
        assert!(on.ledger.compact_saving() >= 0.0, "{}", method.id());
        if on.ledger.compact_alloc > 0.0 {
            assert_eq!(
                on.ledger.alloc_tokens_prefix.to_bits(),
                off.ledger.alloc_tokens.to_bits(),
                "{}: prefix counterfactual drifted from the real prefix step",
                method.id()
            );
        }
        step_records.push(obj(vec![
            ("scheme", Json::Str(method.id().into())),
            ("alloc_tokens", Json::Num(on.ledger.alloc_tokens)),
            ("alloc_tokens_prefix", Json::Num(on.ledger.alloc_tokens_prefix)),
            ("compact_saving", Json::Num(on.ledger.compact_saving())),
            ("compact_kept", Json::Num(on.ledger.compact_kept)),
            ("compact_alloc", Json::Num(on.ledger.compact_alloc)),
            ("compact_bound", Json::Num(on.ledger.compact_bound)),
        ]));
    }

    // ---- Packing throughput: the gather build must stay host-side noise.
    let mut rng = Rng::new(w::SEED);
    let items = {
        let items = w::items(&w::methods()[0].1, &mut rng);
        split_zero_contribution(items).0
    };
    b.iter("pack_prefix/urs", || {
        pack_budget(&items, &w::BUCKETS, w::PROMPT_LEN, &w::ROW_GRID, 0).unwrap()
    });
    b.iter("pack_compact/urs", || {
        pack_budget_with(&items, &w::BUCKETS, w::PROMPT_LEN, &w::ROW_GRID, 0, true).unwrap()
    });

    let record = obj(vec![
        ("bench", Json::Str("compaction".into())),
        (
            "workload",
            obj(vec![
                ("items", Json::Num(w::ITEMS as f64)),
                ("draws", Json::Num(DRAWS as f64)),
                ("prompt_len", Json::Num(w::PROMPT_LEN as f64)),
                ("max_resp", Json::Num(w::MAX_RESP as f64)),
            ]),
        ),
        ("layouts", Json::Arr(layout_records.clone())),
        ("steps", Json::Arr(step_records)),
    ]);
    let path = write_record("compaction", &record).unwrap();
    println!("wrote {path}");

    // Acceptance gate, AFTER the JSON record is on disk: every scattered
    // ~50%-keep method must allocate >= 30% fewer grad tokens compacted.
    for r in &layout_records {
        let saving = r.get("saving").and_then(Json::as_f64).unwrap();
        assert!(
            saving >= 0.30,
            "acceptance: compacted layout must save >= 30% allocated grad \
             tokens vs prefix-packing ({})",
            r.to_string()
        );
    }

    b.report();
}
