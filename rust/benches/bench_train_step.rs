//! The Table 3 / Fig. 5 bench: learner cost per micro-batch bucket and the
//! end-to-end optimizer step per NAT method.
//!
//! Regenerates the paper's key system rows on this host:
//!   * grad/<model>/T=<bucket>  — forward+backward cost vs bucket length
//!     (RPC's savings = the gap between buckets; URS/GRPO always pay the top
//!     bucket).
//!   * step/<model>/<method>    — full rollout->grad->apply step.
use std::path::Path;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::batcher::{pack, LearnItem};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::runtime::{GradAccum, OptState, ParamStore, Runtime};
use nat_rl::tasks::Tier;
use nat_rl::util::bench::Bench;
use nat_rl::util::rng::Rng;

fn grad_bench(b: &mut Bench, model: &str) {
    let dir = format!("artifacts/{model}");
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skip {model}: artifacts not built");
        return;
    }
    let rt = Runtime::load(Path::new(&dir)).unwrap();
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    rt.warmup(&d.buckets).unwrap();
    let mut rng = Rng::new(0);
    for &bucket in &d.buckets {
        let items: Vec<LearnItem> = (0..d.batch_train)
            .map(|_| LearnItem {
                tokens: (0..(d.prompt_len + d.max_resp))
                    .map(|_| 3 + rng.below(40) as i32)
                    .collect(),
                pad_len: 4,
                resp_len: bucket,
                ht_w: vec![1.0; bucket],
                learn_len: bucket,
                adv: 0.5,
                old_lp: vec![-1.5; bucket],
            })
            .collect();
        let mbs = pack(&items, &d.buckets, d.prompt_len, d.batch_train).unwrap();
        assert_eq!(mbs.len(), 1);
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        b.iter(&format!("grad/{model}/T={bucket}"), || {
            acc.reset();
            rt.grad(&mbs[0], &params, &mut acc).unwrap()
        });
    }
    // apply cost (params+moments roundtrip + AdamW)
    let mut p = params.clone();
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    acc.flat.iter_mut().for_each(|g| *g = 1e-3);
    acc.sequences = 8;
    b.iter(&format!("apply/{model}"), || rt.apply(&mut p, &mut opt, &acc).unwrap());
}

fn step_bench(b: &mut Bench, model: &str) {
    let dir = format!("artifacts/{model}");
    if !Path::new(&dir).join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::load(Path::new(&dir)).unwrap();
    rt.warmup(&rt.manifest.dims.buckets.clone()).unwrap();
    rt.warmup_generate_buckets().unwrap(); // default cfg rolls out bucketed
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    for method in [
        Method::Grpo,
        Method::Urs { p: 0.5 },
        Method::DetTrunc { frac: 0.5 },
        Method::Rpc { min_cut: 8 },
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.method = method;
        cfg.rl.tiers = if model == "tiny" { vec![Tier::Easy] } else { Tier::ALL.to_vec() };
        cfg.rl.prompts_per_step = 2;
        cfg.rl.group_size = 8;
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        b.iter(&format!("step/{model}/{}", method.id()), || tr.step().unwrap());
    }
}

fn main() {
    let mut b = Bench::new("train_step").slow();
    for model in ["tiny", "small"] {
        grad_bench(&mut b, model);
    }
    for model in ["tiny", "small"] {
        step_bench(&mut b, model);
    }
    b.report();
}
