//! The Table 3 / Fig. 5 bench: learner cost per micro-batch bucket and the
//! end-to-end optimizer step per NAT method — plus the sharded learn
//! stage's scaling record.
//!
//! Two tiers:
//!
//! * `sim/*` — always runs: the real shard plan → concurrent execute →
//!   tree-reduce pipeline (`coordinator::batcher::plan_shards` +
//!   `runtime::shard`) over the sim runtime with per-token busy-work
//!   standing in for the device forward/backward. This is the acceptance
//!   gate: the K=4 sharded learn stage must beat K=1 wall-clock by ≥ 1.5×,
//!   and the reduced gradients must be bit-identical (the order-invariance
//!   contract). Results land in `BENCH_train_step.json` (machine-readable,
//!   like `bench_rollout`'s `BENCH_rollout.json`).
//! * `grad`/`step`/`apply` — artifact-gated: real PJRT costs per bucket and
//!   per method, as before.
use std::path::Path;
use std::time::Instant;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::batcher::{
    allocated_tokens, pack, plan_shards, shard_workload, LearnItem,
};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::obs::Tracer;
use nat_rl::runtime::shard::{execute_shards, tree_reduce_into};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{GradAccum, GradMetrics, OptState, ParamStore, Runtime, SimSpec};
use nat_rl::tasks::Tier;
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{obj, Json};
use nat_rl::util::rng::Rng;

/// Per-token busy-work standing in for the device fwd+bwd (~0.5 ms per
/// full micro-batch on a laptop core).
const SPIN_PER_TOKEN: u64 = 4_000;
const SHARD_REPS: u32 = 5;

fn sim_shard_bench(b: &mut Bench) {
    let rt = Runtime::sim_with(sim_manifest(), SimSpec { spin_per_token: SPIN_PER_TOKEN });
    let d = rt.manifest.dims.clone();
    // The shared workload (`batcher::shard_workload`): 32 RPC-shaped
    // responses packing into 10 micro-batches across all three sequence
    // buckets; ideal K=4 speedup ≈ 3.8×, so the 1.5× gate has margin for
    // thread overhead. The same workload's deterministic cost-balance bound
    // is asserted in tier-1 (`tests/sharding.rs`).
    let items = shard_workload::items();
    let mbs = shard_workload::micro_batches();
    let params = init_params(&rt.manifest);
    let lits = params.to_literals(&rt.manifest).unwrap();
    let run_k = |k: usize| -> GradAccum {
        let plan = plan_shards(&mbs, d.prompt_len, k);
        let leaves = execute_shards(&rt, &mbs, &lits, &plan, &Tracer::off(), 1).unwrap();
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut met = GradMetrics::default();
        tree_reduce_into(&mut acc, &mut met, leaves);
        acc
    };

    // Order-invariance sanity on the bench workload itself.
    let a1 = run_k(1);
    let a4 = run_k(4);
    assert_eq!(
        a1.flat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        a4.flat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "sharded reduction is not bit-identical to K=1"
    );

    for k in [1usize, 2, 4] {
        b.iter(&format!("sim/learn_shards/K={k}"), || run_k(k));
    }

    let wall = |k: usize| -> f64 {
        let t0 = Instant::now();
        for _ in 0..SHARD_REPS {
            std::hint::black_box(run_k(k));
        }
        t0.elapsed().as_secs_f64() / SHARD_REPS as f64
    };
    let (w1, w2, w4) = (wall(1), wall(2), wall(4));
    let speedup = w1 / w4;
    println!(
        "sim sharded learn stage: K=1 {:.2} ms | K=2 {:.2} ms | K=4 {:.2} ms | \
         K=4 speedup {speedup:.2}x over {} micro-batches",
        w1 * 1e3,
        w2 * 1e3,
        w4 * 1e3,
        mbs.len()
    );

    // Stage breakdown at K=4 — the same plan/grad/reduce decomposition the
    // `shard.grad` / `learn.reduce` trace spans report during training.
    let plan4 = plan_shards(&mbs, d.prompt_len, 4);
    let t0 = Instant::now();
    let leaves = execute_shards(&rt, &mbs, &lits, &plan4, &Tracer::off(), 1).unwrap();
    let grad_s = t0.elapsed().as_secs_f64();
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut met = GradMetrics::default();
    let t0 = Instant::now();
    tree_reduce_into(&mut acc, &mut met, leaves);
    let reduce_s = t0.elapsed().as_secs_f64();

    let record = obj(vec![
        ("bench", Json::Str("train_step".into())),
        (
            "workload",
            obj(vec![
                ("items", Json::Num(items.len() as f64)),
                ("micro_batches", Json::Num(mbs.len() as f64)),
                (
                    "allocated_tokens",
                    Json::Num(allocated_tokens(&mbs, d.prompt_len) as f64),
                ),
                ("spin_per_token", Json::Num(SPIN_PER_TOKEN as f64)),
            ]),
        ),
        (
            "stages",
            obj(vec![
                ("grad_s", Json::Num(grad_s)),
                ("reduce_s", Json::Num(reduce_s)),
            ]),
        ),
        ("k1_wall_s", Json::Num(w1)),
        ("k2_wall_s", Json::Num(w2)),
        ("k4_wall_s", Json::Num(w4)),
        ("k4_speedup", Json::Num(speedup)),
    ]);
    let path = write_record("train_step", &record).unwrap();
    println!("wrote {path}");

    // Wall-clock acceptance gate, AFTER the JSON record is on disk so a
    // failure still leaves the measurements. Only meaningful when the host
    // can actually run 4 shards in parallel — on fewer cores the number
    // measures the machine, not the code (tier-1 asserts the deterministic
    // cost-balance bound on this same workload regardless of host).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 4 {
        assert!(
            speedup >= 1.5,
            "acceptance: the K=4 sharded learn stage must be >= 1.5x faster than K=1 \
             at the sim workload (got {speedup:.2}x on {cores} cores)"
        );
    } else {
        eprintln!("skip K=4 speedup gate: only {cores} cores available");
    }
}

fn grad_bench(b: &mut Bench, model: &str) {
    let dir = format!("artifacts/{model}");
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skip {model}: artifacts not built");
        return;
    }
    let rt = Runtime::load(Path::new(&dir)).unwrap();
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    rt.warmup(&d.buckets).unwrap();
    let mut rng = Rng::new(0);
    for &bucket in &d.buckets {
        let items: Vec<LearnItem> = (0..d.batch_train)
            .map(|_| LearnItem {
                tokens: (0..(d.prompt_len + d.max_resp))
                    .map(|_| 3 + rng.below(40) as i32)
                    .collect(),
                pad_len: 4,
                resp_len: bucket,
                ht_w: vec![1.0; bucket],
                learn_len: bucket,
                adv: 0.5,
                old_lp: vec![-1.5; bucket],
            })
            .collect();
        let mbs = pack(&items, &d.buckets, d.prompt_len, d.batch_train).unwrap();
        assert_eq!(mbs.len(), 1);
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        b.iter(&format!("grad/{model}/T={bucket}"), || {
            acc.reset();
            rt.grad(&mbs[0], &params, &mut acc).unwrap()
        });
    }
    // apply cost (params+moments roundtrip + AdamW)
    let mut p = params.clone();
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    acc.flat.iter_mut().for_each(|g| *g = 1e-3);
    acc.sequences = 8;
    b.iter(&format!("apply/{model}"), || rt.apply(&mut p, &mut opt, &acc).unwrap());
}

fn step_bench(b: &mut Bench, model: &str) {
    let dir = format!("artifacts/{model}");
    if !Path::new(&dir).join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::load(Path::new(&dir)).unwrap();
    rt.warmup(&rt.manifest.dims.buckets.clone()).unwrap();
    rt.warmup_generate_buckets().unwrap(); // default cfg rolls out bucketed
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    for method in [
        Method::Grpo,
        Method::Urs { p: 0.5 },
        Method::DetTrunc { frac: 0.5 },
        Method::Rpc { min_cut: 8 },
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.method = method;
        cfg.rl.tiers = if model == "tiny" { vec![Tier::Easy] } else { Tier::ALL.to_vec() };
        cfg.rl.prompts_per_step = 2;
        cfg.rl.group_size = 8;
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        b.iter(&format!("step/{model}/{}", method.id()), || tr.step().unwrap());
    }
}

fn main() {
    let mut b = Bench::new("train_step").slow();
    sim_shard_bench(&mut b);
    for model in ["tiny", "small"] {
        grad_bench(&mut b, model);
    }
    for model in ["tiny", "small"] {
        step_bench(&mut b, model);
    }
    b.report();
}
