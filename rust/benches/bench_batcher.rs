//! Batcher bench: packing throughput AND padded-token waste for the fixed
//! vs token-budget packers, per NAT method (the host hot loop between
//! rollout and the grad artifacts).
//!
//! The waste table is the acceptance metric for the budget packer: at equal
//! batch config it must allocate >= 30% fewer padding tokens than the fixed
//! packer for RPC (the paper's method), and never more for GRPO/URS.
use nat_rl::config::Method;
use nat_rl::coordinator::batcher::{pack, pack_budget, padding_waste, LearnItem};
use nat_rl::coordinator::masking::sample;
use nat_rl::util::bench::Bench;
use nat_rl::util::rng::Rng;

const P: usize = 48;
const T_MAX: usize = 128;
const BUCKETS: [usize; 4] = [32, 64, 96, 128];
const ROW_GRID: [usize; 4] = [1, 2, 4, 8];
const BATCH: usize = 8;

fn items(n: usize, method: &Method, rng: &mut Rng) -> Vec<LearnItem> {
    (0..n)
        .map(|_| {
            let resp_len = 1 + rng.below(T_MAX as u64) as usize;
            let m = sample(method, resp_len, rng);
            LearnItem {
                tokens: vec![7; P + T_MAX],
                pad_len: 5,
                resp_len,
                ht_w: m.ht_w,
                learn_len: m.learn_len,
                adv: rng.normal() as f32,
                old_lp: vec![-1.2; resp_len],
            }
        })
        .collect()
}

fn main() {
    let methods = [
        ("grpo", Method::Grpo),
        ("urs", Method::Urs { p: 0.5 }),
        ("rpc", Method::Rpc { min_cut: 8 }),
    ];

    // Padded-token waste at realistic per-step scale (prompts_per_step x G
    // = 16 rows) and at bulk scale, averaged over many mask draws.
    println!("== padded-token waste (1 - ideal/allocated) ==");
    println!("{:<8} {:>6} {:>12} {:>12} {:>10}", "method", "n", "fixed", "budget", "saving");
    for (name, method) in &methods {
        for n in [16usize, 64, 256] {
            let mut rng = Rng::new(1);
            let (mut wf, mut wb) = (0.0, 0.0);
            let draws = 40;
            for _ in 0..draws {
                let it = items(n, method, &mut rng);
                let fixed = pack(&it, &BUCKETS, P, BATCH).unwrap();
                let budget = pack_budget(&it, &BUCKETS, P, &ROW_GRID, 0).unwrap();
                wf += padding_waste(&fixed, &it, P) / draws as f64;
                wb += padding_waste(&budget, &it, P) / draws as f64;
            }
            println!(
                "{:<8} {:>6} {:>11.1}% {:>11.1}% {:>9.1}%",
                name,
                n,
                100.0 * wf,
                100.0 * wb,
                100.0 * (1.0 - wb / wf.max(1e-12))
            );
        }
    }

    // Packing throughput (ns/op): the packer must stay negligible next to
    // a grad-artifact execution.
    let mut b = Bench::new("batcher");
    let mut rng = Rng::new(1);
    for (name, method) in &methods {
        for n in [16usize, 64, 256] {
            let it = items(n, method, &mut rng);
            b.iter(&format!("pack_fixed/{name}/n={n}"), || {
                pack(&it, &BUCKETS, P, BATCH).unwrap()
            });
            b.iter(&format!("pack_budget/{name}/n={n}"), || {
                pack_budget(&it, &BUCKETS, P, &ROW_GRID, 0).unwrap()
            });
        }
    }
    b.report();
}
