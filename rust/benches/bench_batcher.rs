//! Microbench: bucket routing + micro-batch packing (host hot loop between
//! rollout and the grad artifacts).
use nat_rl::config::Method;
use nat_rl::coordinator::batcher::{pack, LearnItem};
use nat_rl::coordinator::masking::sample;
use nat_rl::util::bench::Bench;
use nat_rl::util::rng::Rng;

fn items(n: usize, method: &Method, t_max: usize, rng: &mut Rng) -> Vec<LearnItem> {
    (0..n)
        .map(|_| {
            let resp_len = 1 + rng.below(t_max as u64) as usize;
            let m = sample(method, resp_len, rng);
            LearnItem {
                tokens: vec![7; 48 + t_max],
                pad_len: 5,
                resp_len,
                ht_w: m.ht_w,
                learn_len: m.learn_len,
                adv: rng.normal() as f32,
                old_lp: vec![-1.2; resp_len],
            }
        })
        .collect()
}

fn main() {
    let buckets = [32usize, 64, 96, 128];
    let mut b = Bench::new("batcher");
    let mut rng = Rng::new(1);
    for n in [16usize, 64, 256] {
        let grpo = items(n, &Method::Grpo, 128, &mut rng);
        let rpc = items(n, &Method::Rpc { min_cut: 8 }, 128, &mut rng);
        b.iter(&format!("pack_grpo/n={n}"), || pack(&grpo, &buckets, 48, 8));
        b.iter(&format!("pack_rpc/n={n}"), || pack(&rpc, &buckets, 48, 8));
    }
    b.report();
}
