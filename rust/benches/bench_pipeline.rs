//! Serial vs pipelined optimizer-step throughput.
//!
//! Two tiers:
//!
//! * `sim/*` — always runs: synthetic produce/consume stages with a fixed
//!   compute cost drive the real pipeline engine (scheduler, bounded queue,
//!   staleness gate, reorder buffer), isolating orchestration overhead and
//!   demonstrating the overlap win without artifacts. With rollout ~2x the
//!   learner cost (the paper's regime — NAT makes the update cheap), the
//!   ideal 2-worker pipelined speedup over serial is ~1.5x wall-clock.
//! * `train/*` — artifact-gated: the full `Trainer` vs `PipelineTrainer`
//!   on `artifacts/tiny`, measuring end-to-end steps/sec.
//!
//! Run: `cargo bench --bench bench_pipeline` (BENCH_MS=200 for a quick pass).

use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::pipeline::engine::{self, PipelineOpts};
use nat_rl::coordinator::pipeline::PipelineTrainer;
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::runtime::{OptState, ParamStore, Runtime};
use nat_rl::tasks::Tier;
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{obj, Json};

/// Deterministic busy-work: ~`units` multiply-add kernels.
fn spin(units: u64) -> u64 {
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    for i in 0..units {
        x = x.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(i);
    }
    black_box(x)
}

/// Tuned so one "rollout" is a few hundred microseconds on a laptop core.
const ROLLOUT_UNITS: u64 = 400_000;
const LEARN_UNITS: u64 = 200_000;
const SIM_STEPS: u64 = 24;

fn sim_serial() -> u64 {
    let mut acc = 0u64;
    for k in 0..SIM_STEPS {
        acc ^= spin(ROLLOUT_UNITS).wrapping_add(k);
        acc ^= spin(LEARN_UNITS);
    }
    acc
}

fn sim_pipelined(workers: usize, max_staleness: u64) -> u64 {
    let mut acc = 0u64;
    engine::run(
        &PipelineOpts { workers, queue_depth: 2, max_staleness },
        0,
        SIM_STEPS,
        0u64,
        |k, _version, _snap: &u64| Ok(spin(ROLLOUT_UNITS).wrapping_add(k)),
        |_meta, g: u64| {
            acc ^= g;
            acc ^= spin(LEARN_UNITS);
            Ok(acc)
        },
        |_| Ok(()),
    )
    .expect("sim pipeline failed");
    acc
}

fn sim_bench(b: &mut Bench) {
    b.iter("sim/serial", sim_serial);
    b.iter("sim/pipelined/w=1 sync", || sim_pipelined(1, 0));
    b.iter("sim/pipelined/w=2 s=1", || sim_pipelined(2, 1));
    b.iter("sim/pipelined/w=4 s=2", || sim_pipelined(4, 2));

    // Headline comparison in plain steps/sec.
    let t0 = Instant::now();
    black_box(sim_serial());
    let serial_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    black_box(sim_pipelined(2, 1));
    let piped_s = t0.elapsed().as_secs_f64();
    println!(
        "sim summary: serial {:.1} steps/s | pipelined(w=2) {:.1} steps/s | speedup {:.2}x",
        SIM_STEPS as f64 / serial_s,
        SIM_STEPS as f64 / piped_s,
        serial_s / piped_s
    );

    // Machine-readable record for in-repo perf tracking, mirroring
    // BENCH_rollout.json / BENCH_train_step.json (CI keeps
    // `cargo bench --no-run` green; a full run refreshes this file).
    let record = obj(vec![
        ("bench", Json::Str("pipeline".into())),
        (
            "workload",
            obj(vec![
                ("steps", Json::Num(SIM_STEPS as f64)),
                ("rollout_units", Json::Num(ROLLOUT_UNITS as f64)),
                ("learn_units", Json::Num(LEARN_UNITS as f64)),
            ]),
        ),
        ("serial_wall_s", Json::Num(serial_s)),
        ("pipelined_w2_wall_s", Json::Num(piped_s)),
        ("serial_steps_per_s", Json::Num(SIM_STEPS as f64 / serial_s)),
        ("pipelined_w2_steps_per_s", Json::Num(SIM_STEPS as f64 / piped_s)),
        ("w2_speedup", Json::Num(serial_s / piped_s)),
    ]);
    let path = write_record("pipeline", &record).unwrap();
    println!("wrote {path}");
}

fn tiny_cfg(workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "tiny".into();
    cfg.method = Method::Rpc { min_cut: 8 };
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 8;
    cfg.pipeline.workers = workers;
    cfg
}

fn train_bench(b: &mut Bench) {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skip train/*: artifacts/tiny not built (make artifacts)");
        return;
    }
    let rt = Runtime::load(dir).unwrap();
    rt.warmup(&rt.manifest.dims.buckets.clone()).unwrap();
    rt.warmup_generate_buckets().unwrap(); // default cfg rolls out bucketed
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    const STEPS: usize = 3;

    let mut serial = Trainer::new(&rt, tiny_cfg(0), base.clone(), OptState::zeros(&rt.manifest));
    b.iter(&format!("train/tiny/serial x{STEPS}"), || {
        serial.train(STEPS, false).unwrap()
    });
    for workers in [1usize, 2] {
        let mut tr = PipelineTrainer::new(
            &rt,
            tiny_cfg(workers),
            base.clone(),
            OptState::zeros(&rt.manifest),
        );
        b.iter(&format!("train/tiny/pipelined w={workers} x{STEPS}"), || {
            tr.train(STEPS, false).unwrap()
        });
    }
}

fn main() {
    let mut b = Bench::new("pipeline").slow();
    sim_bench(&mut b);
    train_bench(&mut b);
    b.report();
}
