//! Shared-prefix prefill cache bench: fused prefill-per-row vs
//! prefill-once-per-prompt with KV-consuming bucketed decode.
//!
//! Runs the GRPO-shaped default workload (`sim_workload::grouped_slots`,
//! G=8: 8 group siblings per prompt) through the real scheduler twice —
//! uncached (every generate call re-prefills its whole `B × P` window) and
//! with the prefix cache on — and compares prefill token-steps. This is the
//! acceptance metric: the cached engine must pay >= 60% fewer prefill
//! token-steps at G=8 (the tier-1 test
//! `cached_run_cuts_prefill_steps_over_60pct_at_g8` gates the same
//! workload, so this record and CI can never disagree about the claim).
//! Outputs are asserted byte-identical on both paths before any number is
//! reported. Results land in `BENCH_prefix.json`.

use std::time::Instant;

use nat_rl::coordinator::rollout::scheduler::{sim_workload, RolloutScheduler, SchedStats, SlotOut};
use nat_rl::util::bench::{write_record, Bench};
use nat_rl::util::json::{arr_f64, obj, Json};

const G: usize = 8;
const CACHE_BYTES: usize = 64 << 20;

/// One full multi-step run of the grouped workload; the snapshot version
/// advances with the step exactly as in serial training.
fn run_engine(sched: &RolloutScheduler) -> (Vec<Vec<SlotOut>>, SchedStats) {
    let backend = sim_workload::backend();
    let encoded = sim_workload::prompts();
    let mut outs = Vec::new();
    let mut total = SchedStats::default();
    for step in 0..sim_workload::STEPS {
        let slots = sim_workload::grouped_slots(step, G);
        let (o, stats) = sched.run(&backend, &encoded, &slots, 1.0, step).unwrap();
        outs.push(o);
        total.calls += stats.calls;
        total.decode_token_steps += stats.decode_token_steps;
        total.escalations += stats.escalations;
        total.padded_rows += stats.padded_rows;
        total.prefill_token_steps += stats.prefill_token_steps;
        total.prefill_hits += stats.prefill_hits;
        total.prefill_lookups += stats.prefill_lookups;
        total.prefill_steps_saved += stats.prefill_steps_saved;
        total.cache_bytes = total.cache_bytes.max(stats.cache_bytes);
    }
    (outs, total)
}

fn canon(outs: &[Vec<SlotOut>]) -> Vec<(usize, usize, Vec<i32>, Vec<u32>)> {
    let mut v: Vec<_> = outs
        .iter()
        .enumerate()
        .flat_map(|(s, os)| {
            os.iter().map(move |o| {
                (
                    s * sim_workload::SLOTS_PER_STEP + o.flat_id,
                    o.resp_len,
                    o.tokens.clone(),
                    o.lp.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                )
            })
        })
        .collect();
    v.sort();
    v
}

fn main() {
    let mut b = Bench::new("prefix").slow();
    b.iter("sim/uncached/schedule", || run_engine(&RolloutScheduler::new(128)));
    b.iter("sim/prefix_cache/schedule", || {
        run_engine(&RolloutScheduler::with_cache(128, CACHE_BYTES))
    });

    let t0 = Instant::now();
    let (base_outs, base) = run_engine(&RolloutScheduler::new(128));
    let base_wall_s = t0.elapsed().as_secs_f64();
    let cached_sched = RolloutScheduler::with_cache(128, CACHE_BYTES);
    let t1 = Instant::now();
    let (opt_outs, opt) = run_engine(&cached_sched);
    let opt_wall_s = t1.elapsed().as_secs_f64();

    // Bit-identity first: a saving measured on diverging outputs is void.
    assert_eq!(canon(&base_outs), canon(&opt_outs), "cache on/off rollouts diverged");
    assert_eq!(
        base.decode_token_steps, opt.decode_token_steps,
        "the cache must not change decode scheduling"
    );

    let saving = 1.0 - opt.prefill_token_steps as f64 / base.prefill_token_steps as f64;
    let hit_rate = opt.prefill_hits as f64 / opt.prefill_lookups.max(1) as f64;
    println!(
        "sim prefill-token-steps at G={G}: fused {} | prefix cache {} | saving {:.1}% \
         (hit rate {:.1}%, {} steps saved, peak cache {} B)",
        base.prefill_token_steps,
        opt.prefill_token_steps,
        100.0 * saving,
        100.0 * hit_rate,
        opt.prefill_steps_saved,
        opt.cache_bytes,
    );
    assert!(
        saving >= 0.60,
        "acceptance: the prefix cache must cut prefill token-steps >= 60% at G={G} \
         on the default workload (got {:.1}%)",
        100.0 * saving
    );
    assert!(
        hit_rate > 0.5,
        "acceptance: group siblings must mostly hit (hit rate {:.1}%)",
        100.0 * hit_rate
    );

    let side = |s: &SchedStats, wall_s: f64| {
        obj(vec![
            ("calls", Json::Num(s.calls as f64)),
            ("prefill_token_steps", Json::Num(s.prefill_token_steps as f64)),
            ("prefill_hits", Json::Num(s.prefill_hits as f64)),
            ("prefill_lookups", Json::Num(s.prefill_lookups as f64)),
            ("prefill_steps_saved", Json::Num(s.prefill_steps_saved as f64)),
            ("decode_token_steps", Json::Num(s.decode_token_steps as f64)),
            ("cache_bytes", Json::Num(s.cache_bytes as f64)),
            ("wall_s", Json::Num(wall_s)),
        ])
    };
    let record = obj(vec![
        ("bench", Json::Str("prefix".into())),
        (
            "workload",
            obj(vec![
                ("batch", Json::Num(sim_workload::BATCH as f64)),
                ("prompt_len", Json::Num(sim_workload::PROMPT_LEN as f64)),
                (
                    "buckets",
                    arr_f64(&sim_workload::BUCKETS.iter().map(|&b| b as f64).collect::<Vec<_>>()),
                ),
                ("group_size", Json::Num(G as f64)),
                ("slots_per_step", Json::Num(sim_workload::SLOTS_PER_STEP as f64)),
                ("steps", Json::Num(sim_workload::STEPS as f64)),
                ("cache_bytes", Json::Num(CACHE_BYTES as f64)),
            ]),
        ),
        ("fused", side(&base, base_wall_s)),
        ("prefix_cache", side(&opt, opt_wall_s)),
        ("prefill_step_saving", Json::Num(saving)),
        ("hit_rate", Json::Num(hit_rate)),
    ]);
    let path = write_record("prefix", &record).unwrap();
    println!("wrote {path}");
    b.report();
}
