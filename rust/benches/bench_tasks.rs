//! Microbench: synthetic task substrate (generation, CoT rendering,
//! verification, SFT corpus building).
use nat_rl::tasks::gen::gen_task;
use nat_rl::tasks::render::render_cot;
use nat_rl::tasks::verify::reward_text;
use nat_rl::tasks::{Kind, SftCorpus, TaskMix, Tier};
use nat_rl::tokenizer::Tokenizer;
use nat_rl::util::bench::Bench;
use nat_rl::util::rng::Rng;

fn main() {
    let mut b = Bench::new("tasks");
    let mut rng = Rng::new(2);
    for kind in Kind::ALL {
        b.iter(&format!("gen/{kind:?}/hard"), || {
            gen_task(&mut rng, kind, Tier::Hard, 0)
        });
    }
    let task = gen_task(&mut rng, Kind::Expr, Tier::Hard, 0);
    let cot = render_cot(&task);
    b.iter("render_cot/expr_hard", || render_cot(&task));
    b.iter("verify/expr_hard", || reward_text(&task, &cot));
    let tok = Tokenizer::new();
    b.iter("tokenize/cot", || tok.encode(&cot));
    let mut b2 = Bench::new("sft_corpus").slow();
    b2.iter("build_256_examples", || {
        SftCorpus::build(&tok, 256, 48, 176, 0.15, 3, &TaskMix::default())
    });
    b.report();
    b2.report();
}
