//! Observability contract tests (issue satellite).
//!
//! Two promises the `obs` subsystem makes:
//!
//! 1. **Zero observer effect** — running the exact same 3-step sim training
//!    run with `--obs.trace`/`--obs.chrome` on vs off yields bit-identical
//!    `StepStats` (including the savings ledger) and bit-identical
//!    post-step parameter hashes. Tracing is allowed to cost wall-clock,
//!    never semantics.
//! 2. **The trace is honest** — the NDJSON the run produced, fed through
//!    the same `nat trace` analyzer CI uses, passes the gates: learner
//!    stage coverage ≥ 90% of `learn.step`, and the ledger's closed-form
//!    E[selected tokens] agrees with the realized `budget_realized` within
//!    1% of generated tokens.

use std::path::PathBuf;

use nat_rl::config::{BudgetMode, Method, ObsCfg, RunConfig};
use nat_rl::coordinator::trainer::{StepStats, Trainer};
use nat_rl::obs::{analyze, Tracer};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{OptState, Runtime};
use nat_rl::tasks::Tier;
use nat_rl::util::json::Json;

mod common;
use common::fnv1a;

/// The CI trace-smoke configuration: URS under a batch token budget on the
/// deterministic sim runtime — the regime where the ledger's budget gate
/// is a real statement (GRPO would make it vacuous).
fn smoke_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "sim".into();
    cfg.seed = 0;
    cfg.method = Method::Urs { p: 0.9 };
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.prompts_per_step = 2;
    cfg.rl.group_size = 4;
    cfg.train.token_budget = 64;
    cfg.train.budget_mode = BudgetMode::Batch;
    cfg
}

/// Every non-timing `StepStats` field in shortest-roundtrip decimal, plus
/// the full ledger (`StepLedger` is all-f64 and deterministic, so its Debug
/// form is canonical). Timing fields are excluded on purpose — they differ
/// run to run regardless of tracing.
fn line(s: &StepStats) -> String {
    format!(
        "step {} reward {} entropy {} clip {} kl {} gnorm {} sel {} btgt {} breal {} \
         svar {} rlen {} waste {} mem {} peak {} mb {} seqs {} ledger {:?}",
        s.step,
        s.reward_mean,
        s.entropy,
        s.clip_frac,
        s.kl,
        s.grad_norm,
        s.selected_ratio,
        s.budget_target,
        s.budget_realized,
        s.sel_var,
        s.resp_len_mean,
        s.padding_waste,
        s.mem_gb,
        s.peak_mem_gb,
        s.micro_batches,
        s.sequences,
        s.ledger,
    )
}

/// Run 3 steps from the fixed seed, with the given tracer (or the no-op
/// default), returning the canonical step lines and the final param hash.
fn run3(tracer: Option<Tracer>) -> (Vec<String>, u64) {
    let rt = Runtime::sim(sim_manifest());
    let mut tr = Trainer::new(
        &rt,
        smoke_cfg(),
        init_params(&rt.manifest),
        OptState::zeros(&rt.manifest),
    );
    if let Some(t) = tracer {
        tr.set_tracer(t);
    }
    let mut lines = Vec::new();
    for _ in 0..3 {
        let s = tr.step().unwrap();
        lines.push(line(&s));
    }
    (lines, fnv1a(&tr.params.flat))
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nat_rl_obs_{tag}_{}", std::process::id()))
}

#[test]
fn tracing_on_vs_off_is_bit_identical_and_trace_passes_gates() {
    let dir = tmp_dir("smoke");
    let nd = dir.join("trace.ndjson");
    let ch = dir.join("trace.chrome.json");
    let tracer = Tracer::from_cfg(&ObsCfg {
        trace: nd.display().to_string(),
        chrome: ch.display().to_string(),
        ledger: true,
    })
    .unwrap();
    assert!(tracer.enabled());

    let (on_lines, on_hash) = run3(Some(tracer.clone()));
    tracer.flush().unwrap();
    let (off_lines, off_hash) = run3(None);

    // 1) zero observer effect: StepStats (incl. ledger) and parameters are
    //    bit-identical with tracing on vs off.
    assert_eq!(on_lines, off_lines, "tracing perturbed StepStats");
    assert_eq!(
        format!("{on_hash:016x}"),
        format!("{off_hash:016x}"),
        "tracing perturbed the trained parameters"
    );

    // 2) the produced NDJSON passes the analyzer's CI gates.
    let text = std::fs::read_to_string(&nd).unwrap();
    let report = analyze::analyze(&text).unwrap();
    let cov = report.coverage().expect("trace has learn.step spans");
    assert!(cov >= 0.90, "stage coverage {:.1}% below the 90% gate", 100.0 * cov);
    assert_eq!(report.ledger.steps, 3);
    assert!(
        report.budget_gap() <= 0.01,
        "E[selected] vs budget_realized gap {:.4} above 1%",
        report.budget_gap()
    );
    if let Err(e) = report.check() {
        panic!("analyzer check failed: {e}");
    }
    // the rendered table names every pipeline stage
    let table = report.render();
    for stage in ["rollout", "learn.select", "learn.pack", "learn.grad", "learn.apply"] {
        assert!(table.contains(stage), "report table missing stage {stage}:\n{table}");
    }

    // 3) the Chrome export is well-formed and non-empty.
    let chrome = Json::parse(&std::fs::read_to_string(&ch).unwrap()).unwrap();
    let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty(), "chrome trace has no events");
    assert!(events
        .iter()
        .any(|e| e.get("name").and_then(Json::as_str) == Some("learn.step")));

    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn ledger_tracks_the_budget_per_step() {
    // Independent of the analyzer: straight from StepStats, the ledger's
    // closed-form expectation must agree with the realized budget within 1%
    // of generated tokens on every step, and the savings story must be
    // internally consistent (selected ⊆ backpropped ⊆ allocated tokens,
    // both sides of the FLOP/memory counterfactual priced and positive).
    let rt = Runtime::sim(sim_manifest());
    let mut tr = Trainer::new(
        &rt,
        smoke_cfg(),
        init_params(&rt.manifest),
        OptState::zeros(&rt.manifest),
    );
    for _ in 0..3 {
        let s = tr.step().unwrap();
        let l = &s.ledger;
        assert!(l.gen_tokens > 0.0);
        let gap = (l.sel_tokens_exp - s.budget_realized).abs() / l.gen_tokens;
        assert!(gap <= 0.01, "step {}: budget gap {gap:.4} above 1%", s.step);
        // the controller respected the cap: expected selection never
        // exceeds min(budget, generated) — when the rollout generated
        // fewer tokens than the budget, the solve saturates at p = 1
        let cap = l.gen_tokens.min(64.0);
        assert!(
            l.sel_tokens_exp <= cap * 1.02 + 1e-9,
            "step {}: E[selected] {} exceeds cap {cap}",
            s.step,
            l.sel_tokens_exp
        );
        assert!(l.sel_tokens <= l.backprop_tokens + 1e-9, "kept tokens exceed backprop");
        assert!(l.backprop_tokens <= l.alloc_tokens + 1e-9, "backprop exceeds allocation");
        // both sides of the counterfactual are priced (savings may be small
        // for URS — spread-out kept positions keep full-length prefixes —
        // but the comparison must exist and be finite)
        assert!(l.grad_flops > 0.0 && l.grad_flops_full > 0.0);
        assert!(l.peak_bytes > 0.0 && l.peak_bytes_full > 0.0);
        assert!(l.flop_saving().is_finite() && l.flop_saving() <= 1.0);
        assert!(l.mem_saving().is_finite() && l.mem_saving() <= 1.0);
        assert!(l.ht_w_max >= 1.0, "HT weights are 1/π ≥ 1 for kept tokens");
        assert!(l.ht_ess > 0.0);
    }
}
