//! Helpers shared across integration-test binaries (`mod common;` pattern —
//! this directory is not compiled as a test target of its own).

/// FNV-1a over parameter bit patterns — THE param-hash contract used by both
/// the sharding proptest's "post-step param hash" and the golden-trace
/// fixture lines; keeping one definition means the two tests can never
/// disagree about what "identical parameters" means.
pub fn fnv1a(flat: &[f32]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &x in flat {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01B3);
        }
    }
    h
}
