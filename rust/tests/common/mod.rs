//! Helpers shared across integration-test binaries (`mod common;` pattern —
//! this directory is not compiled as a test target of its own).

// The param-hash contract moved into the library (`nat_rl::golden`) so the
// `nat golden` subcommand and the tests share one definition; re-exported
// here so every test keeps its `common::fnv1a` spelling.
pub use nat_rl::golden::fnv1a;
