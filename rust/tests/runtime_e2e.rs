//! End-to-end runtime tests against the real `artifacts/tiny` AOT set.
//!
//! These exercise the full L1+L2+L3 composition: the Pallas NAT-loss kernel
//! inside the grad artifact, the KV-cache generate scan, AdamW apply, the
//! SFT step, and the complete Trainer loop. Skipped (cleanly) if artifacts
//! have not been built — `make artifacts` first.

use std::path::Path;

use nat_rl::config::{Method, RunConfig};
use nat_rl::coordinator::batcher::{pack, LearnItem};
use nat_rl::coordinator::pipeline::PipelineTrainer;
use nat_rl::coordinator::rollout::{encode_prompt, run_group_rollouts};
use nat_rl::coordinator::trainer::Trainer;
use nat_rl::coordinator::{evaluator, masking, pretrainer};
use nat_rl::runtime::{Checkpoint, GradAccum, OptState, ParamStore, Runtime};
use nat_rl::tasks::{EvalSet, TaskMix, TaskSampler, Tier};
use nat_rl::tokenizer::Tokenizer;
use nat_rl::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny"));
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/tiny not built");
        return None;
    }
    Some(Runtime::load(dir).expect("loading tiny artifacts"))
}

fn tiny_cfg(method: Method, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "tiny".into();
    cfg.method = method;
    cfg.seed = seed;
    cfg.rl.tiers = vec![Tier::Easy];
    cfg.rl.steps = 2;
    cfg.rl.prompts_per_step = 1;
    cfg.rl.group_size = 4;
    cfg.pretrain.steps = 10;
    cfg.pretrain.corpus_size = 128;
    cfg
}

#[test]
fn generate_is_deterministic_and_prompts_preserved() {
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let tok = Tokenizer::new();
    let (row, pad) = encode_prompt(&tok, "e:3+4%5=", d.prompt_len).unwrap();
    let mut prompts = Vec::new();
    let mut pads = Vec::new();
    for _ in 0..d.batch_rollout {
        prompts.extend_from_slice(&row);
        pads.push(pad as i32);
    }
    let a = rt.generate(&params, &prompts, &pads, 42, 1.0).unwrap();
    let b = rt.generate(&params, &prompts, &pads, 42, 1.0).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.lp, b.lp);
    let c = rt.generate(&params, &prompts, &pads, 43, 1.0).unwrap();
    assert_ne!(a.tokens, c.tokens);
    // prompt region preserved verbatim
    let s = d.prompt_len + d.max_resp;
    for r in 0..d.batch_rollout {
        assert_eq!(&a.tokens[r * s..r * s + d.prompt_len], &row[..]);
    }
    // behaviour logprobs are valid logprobs
    assert!(a.lp.iter().all(|&x| x <= 1e-4 && x > -30.0));
}

#[test]
fn score_reproduces_generate_logprobs() {
    // The on-policy consistency contract across TWO different artifacts
    // (generate's KV-cache decode vs score's full-sequence forward).
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let tok = Tokenizer::new();
    let (row, pad) = encode_prompt(&tok, "a:12+34=", d.prompt_len).unwrap();
    let prompts: Vec<i32> = row.iter().cycle().take(d.batch_rollout * d.prompt_len).copied().collect();
    let pads = vec![pad as i32; d.batch_rollout];
    let gen = rt.generate(&params, &prompts, &pads, 7, 1.0).unwrap();
    let (lp, ent) = rt.score(&params, &gen.tokens, &pads, d.max_resp).unwrap();
    for (i, (&a, &b)) in gen.lp.iter().zip(&lp).enumerate() {
        assert!((a - b).abs() < 3e-3, "pos {i}: generate {a} vs score {b}");
    }
    assert!(ent.iter().all(|&e| e >= -1e-4));
}

fn make_learn_items(
    rt: &Runtime,
    params: &ParamStore,
    method: &Method,
    rng: &mut Rng,
) -> Vec<LearnItem> {
    let tok = Tokenizer::new();
    let mut sampler = TaskSampler::new(3, TaskMix { tiers: vec![Tier::Easy], ..Default::default() });
    let tasks = sampler.batch(1);
    let seqs = run_group_rollouts(rt, params, &tok, &tasks, 4, 1.0, rng).unwrap();
    seqs.iter()
        .map(|s| {
            let m = masking::sample(method, s.resp_len, rng);
            LearnItem {
                tokens: s.tokens.clone(),
                pad_len: s.pad_len,
                resp_len: s.resp_len,
                ht_w: m.ht_w,
                learn_len: m.learn_len,
                adv: if s.reward > 0.5 { 1.0 } else { -0.4 },
                old_lp: s.old_lp.clone(),
            }
        })
        .collect()
}

#[test]
fn grad_metrics_and_zero_mask_behaviour() {
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let mut rng = Rng::new(5);
    let items = make_learn_items(&rt, &params, &Method::Grpo, &mut rng);
    let mbs = pack(&items, &d.buckets, d.prompt_len, d.batch_train).unwrap();
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut toks = 0.0;
    for mb in &mbs {
        let m = rt.grad(mb, &params, &mut acc).unwrap();
        toks += m.tokens;
        assert!(m.entropy_sum >= 0.0);
        assert!(m.clip_frac() >= 0.0 && m.clip_frac() <= 1.0);
    }
    // GRPO: every response token participates
    let expect: usize = items.iter().map(|i| i.resp_len).sum();
    assert_eq!(toks as usize, expect);
    assert!(acc.flat.iter().any(|&g| g != 0.0));
    assert_eq!(acc.sequences, items.len());

    // zero-mask micro-batch contributes exactly nothing
    let mut zero_items = items.clone();
    for it in &mut zero_items {
        it.ht_w = vec![0.0; it.resp_len];
        it.adv = 0.0;
    }
    let mbs0 = pack(&zero_items, &d.buckets, d.prompt_len, d.batch_train).unwrap();
    let mut acc0 = GradAccum::zeros(rt.manifest.param_count);
    for mb in &mbs0 {
        rt.grad(mb, &params, &mut acc0).unwrap();
    }
    let gmax = acc0.flat.iter().fold(0.0f32, |m, &g| m.max(g.abs()));
    assert!(gmax < 1e-6, "zero-mask grad leaked: {gmax}");
}

#[test]
fn ratio_one_on_policy_is_never_clipped() {
    // On-policy first pass: new_lp == old_lp => ratio 1 => clip_frac == 0.
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let mut rng = Rng::new(11);
    let items = make_learn_items(&rt, &params, &Method::Grpo, &mut rng);
    let mbs = pack(&items, &d.buckets, d.prompt_len, d.batch_train).unwrap();
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    for mb in &mbs {
        let m = rt.grad(mb, &params, &mut acc).unwrap();
        assert!(
            m.clip_frac() < 0.02,
            "on-policy ratio should be ~1 (clip_frac {})",
            m.clip_frac()
        );
        assert!(m.kl_sum.abs() / m.tokens.max(1.0) < 0.01);
    }
}

#[test]
fn apply_updates_params_and_respects_scale() {
    let Some(rt) = runtime() else { return };
    let mut params = ParamStore::load_init(&rt.manifest).unwrap();
    let before = params.flat.clone();
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    acc.flat.iter_mut().for_each(|g| *g = 0.01);
    acc.sequences = 4;
    let gnorm = rt.apply(&mut params, &mut opt, &acc).unwrap();
    assert!(gnorm > 0.0);
    assert_eq!(opt.step, 1);
    let moved = params
        .flat
        .iter()
        .zip(&before)
        .filter(|(a, b)| (**a - **b).abs() > 0.0)
        .count();
    assert!(moved > rt.manifest.param_count / 2, "only {moved} params moved");
    // moments populated
    assert!(opt.m.flat.iter().any(|&x| x != 0.0));
    assert!(opt.v.flat.iter().any(|&x| x != 0.0));
}

#[test]
fn pretrain_reduces_loss_on_fixed_corpus() {
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(Method::Grpo, 0);
    let res = pretrainer::pretrain(&rt, &cfg, false).unwrap();
    let losses = res.recorder.values("sft_loss");
    assert_eq!(losses.len(), cfg.pretrain.steps);
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "no learning: {losses:?}"
    );
}

#[test]
fn trainer_runs_all_methods_and_records_metrics() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    for method in [
        Method::Grpo,
        Method::Urs { p: 0.5 },
        Method::DetTrunc { frac: 0.5 },
        Method::Rpc { min_cut: 4 },
    ] {
        let cfg = tiny_cfg(method, 1);
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        tr.train(2, false).unwrap();
        for series in
            ["reward", "entropy", "grad_norm", "selected_ratio", "mem_gb", "t_learn_s"]
        {
            assert_eq!(tr.recorder.get(series).len(), 2, "{method:?} {series}");
        }
        let sel = tr.recorder.values("selected_ratio");
        match method {
            Method::Grpo => assert!(sel.iter().all(|&r| (r - 1.0).abs() < 1e-9)),
            Method::Urs { p } => {
                assert!(sel.iter().all(|&r| (r - p).abs() < 0.25), "{sel:?}")
            }
            Method::DetTrunc { .. } => {
                assert!(sel.iter().all(|&r| r < 0.62), "{sel:?}")
            }
            Method::Rpc { .. } => assert!(sel.iter().all(|&r| r > 0.4 && r <= 1.0)),
            Method::Saliency { floor } => {
                assert!(sel.iter().all(|&r| r >= floor * 0.8 && r <= 1.0))
            }
        }
    }
}

#[test]
fn trainer_is_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    let run = |seed| {
        let cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, seed);
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        tr.train(2, false).unwrap();
        (
            (tr.recorder.values("reward"), tr.recorder.values("entropy"),
             tr.recorder.values("selected_ratio")),
            tr.params.flat,
        )
    };
    let (r1, p1) = run(7);
    let (r2, p2) = run(7);
    assert_eq!(r1, r2);
    assert_eq!(p1, p2);
    // A different seed changes rollouts and masks; reward values alone can
    // coincide (binary rewards), but the entropy/selected-ratio traces are
    // continuous functions of the sampled tokens and masks.
    let (r3, _) = run(8);
    assert!(r1.1 != r3.1 || r1.2 != r3.2, "seed 8 reproduced seed 7 traces");
}

#[test]
fn evaluator_bounds_and_consistency() {
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let tok = Tokenizer::new();
    let set = EvalSet::build(Tier::Easy, 4, 99);
    let mut rng = Rng::new(3);
    let e = evaluator::evaluate(&rt, &params, &tok, &set, 4, 1.0, &mut rng, None, 0).unwrap();
    assert!(e.acc_at_k >= 0.0 && e.acc_at_k <= 1.0);
    assert!(e.pass_at_k >= e.acc_at_k - 1e-9); // pass@k dominates acc@k
    assert_eq!(e.tasks, 4);
    assert_eq!(e.k, 4);
    assert!(e.mean_resp_len >= 1.0);
}

#[test]
fn det_trunc_uses_less_simulated_memory_than_grpo() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    let mem = |method| {
        let cfg = tiny_cfg(method, 2);
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        tr.train(2, false).unwrap();
        tr.recorder.values("mem_gb").iter().sum::<f64>() / 2.0
    };
    let grpo = mem(Method::Grpo);
    let det = mem(Method::DetTrunc { frac: 0.5 });
    assert!(det < grpo, "det {det} !< grpo {grpo}");
}

/// Acceptance: `--train.packer fixed` is the pre-budget-packer layout, and
/// the budget packer computes the same estimator through smaller artifacts.
/// Host-side mask/selection streams are packer-independent (exact equality);
/// the applied gradients agree mathematically, so rewards stay in the same
/// band while the budget packer strictly reduces padded tokens.
#[test]
fn fixed_and_budget_packers_agree_for_seeds_0_and_1() {
    let Some(rt) = runtime() else { return };
    if rt.manifest.grad_row_files.is_empty() {
        eprintln!("SKIP: artifacts have no grad_rows grid (rebuild with make artifacts)");
        return;
    }
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    for seed in [0u64, 1] {
        // One optimizer step: both packers see the SAME rollout (identical
        // starting params) and the SAME mask stream, so every host-side
        // series must match exactly and the applied gradients are the same
        // estimator. (From step 2 on, float reduction-order differences
        // across artifact shapes could flip a sampled token, so strict
        // comparisons stop being meaningful.)
        let run = |packer: &str| {
            let mut cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, seed);
            cfg.set("train.packer", packer).unwrap();
            let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
            tr.train(1, false).unwrap();
            tr
        };
        let fixed = run("fixed");
        let budget = run("budget");
        for series in ["selected_ratio", "resp_len", "reward"] {
            assert_eq!(
                fixed.recorder.values(series),
                budget.recorder.values(series),
                "seed {seed} series {series} diverged"
            );
        }
        // the budget packer only removes padding, never adds it
        let w = |tr: &Trainer, s: &str| tr.recorder.values(s).iter().sum::<f64>();
        assert!(
            w(&budget, "padding_waste") <= w(&fixed, "padding_waste") + 1e-9,
            "seed {seed}: budget packer wasted more than fixed"
        );
        // same estimator: parameters agree to float tolerance. Not
        // bit-equality — reduction order differs across artifact shapes,
        // and where a gradient sum is pure roundoff Adam's first step is
        // ~lr·sign(g), so allow a few lr of slack.
        let max_dp = fixed
            .params
            .flat
            .iter()
            .zip(&budget.params.flat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dp < 1e-2, "seed {seed}: params diverged by {max_dp}");
    }
}

/// Acceptance (sharded learner tentpole, real artifacts): the fixed-order
/// tree reduction is keyed by micro-batch id, so `--train.shards K` must be
/// BIT-identical to `shards = 1` — parameters and every recorded series —
/// for any K, on the real PJRT grad artifacts exactly as in the sim tier.
#[test]
fn sharded_learner_is_bit_identical_on_real_artifacts() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    let run = |k: usize| {
        let mut cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, 11);
        cfg.train.shards = k;
        let mut tr = Trainer::new(&rt, cfg, base.clone(), OptState::zeros(&rt.manifest));
        tr.train(2, false).unwrap();
        (
            tr.params.flat,
            tr.recorder.values("grad_norm"),
            tr.recorder.values("entropy"),
            tr.recorder.values("kl"),
        )
    };
    let (p1, g1, e1, k1) = run(1);
    for k in [2usize, 3, 4] {
        let (pk, gk, ek, kk) = run(k);
        assert_eq!(p1, pk, "shards={k}: parameters diverged from shards=1");
        assert_eq!(g1, gk, "shards={k}: grad_norm series diverged");
        assert_eq!(e1, ek, "shards={k}: entropy series diverged");
        assert_eq!(k1, kk, "shards={k}: kl series diverged");
    }
}

/// Acceptance: the single-worker pipeline is forced synchronous, so for the
/// same seed it must be BIT-identical to the serial trainer — parameters
/// and every metric series.
#[test]
fn pipelined_workers1_is_bit_identical_to_serial() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    let mut cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, 5);
    let mut serial = Trainer::new(&rt, cfg.clone(), base.clone(), OptState::zeros(&rt.manifest));
    serial.train(3, false).unwrap();

    cfg.pipeline.workers = 1;
    let mut piped = PipelineTrainer::new(&rt, cfg, base, OptState::zeros(&rt.manifest));
    piped.train(3, false).unwrap();

    assert_eq!(serial.params.flat, piped.params.flat, "parameter divergence");
    for series in ["reward", "entropy", "selected_ratio", "grad_norm", "kl"] {
        assert_eq!(
            serial.recorder.values(series),
            piped.recorder.values(series),
            "series {series} diverged"
        );
    }
    // Synchronous schedule: staleness must be exactly 0 at every step.
    assert!(piped.recorder.values("staleness").iter().all(|&s| s == 0.0));
}

/// Acceptance: with overlap (workers=2, staleness 1) the run is off-policy
/// by at most one optimizer step per group. It must complete, respect the
/// staleness bound, and stay reward-equivalent to serial within tolerance
/// (binary rewards on a tiny model: mean rewards live in the same band).
#[test]
fn pipelined_workers2_bounds_staleness_and_matches_rewards() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    let steps = 4usize;
    let mut cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, 6);
    let mut serial = Trainer::new(&rt, cfg.clone(), base.clone(), OptState::zeros(&rt.manifest));
    serial.train(steps, false).unwrap();

    cfg.pipeline.workers = 2;
    cfg.pipeline.max_staleness = 1;
    let mut piped = PipelineTrainer::new(&rt, cfg, base, OptState::zeros(&rt.manifest));
    piped.train(steps, false).unwrap();

    let stal = piped.recorder.values("staleness");
    assert_eq!(stal.len(), steps);
    assert!(stal.iter().all(|&s| (0.0..=1.0).contains(&s)), "{stal:?}");
    assert_eq!(piped.recorder.values("reward").len(), steps);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (rs, rp) = (mean(&serial.recorder.values("reward")), mean(&piped.recorder.values("reward")));
    assert!(
        (rs - rp).abs() <= 0.5,
        "pipelined rewards diverged from serial: serial {rs:.3} vs pipelined {rp:.3}"
    );
    // Parameters must still be finite and actually trained.
    assert!(piped.params.flat.iter().all(|p| p.is_finite()));
    assert_ne!(piped.params.flat, ParamStore::load_init(&rt.manifest).unwrap().flat);
}

/// Acceptance: a mid-run checkpoint + `--resume` continuation reproduces
/// the uninterrupted run exactly (per-step streams are derived from
/// (seed, step); the `--train.auto_buckets` tuner — the one piece of
/// cross-step learner state outside that scheme — rides along in
/// `TrainMeta`, which is the satellite bugfix this test also covers).
#[test]
fn resume_from_mid_run_checkpoint_reproduces_uninterrupted_run() {
    let Some(rt) = runtime() else { return };
    let base = ParamStore::load_init(&rt.manifest).unwrap();
    for auto_buckets in [false, true] {
        let dir = std::env::temp_dir().join(format!("nat_rl_resume_e2e_{auto_buckets}"));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tiny_cfg(Method::Rpc { min_cut: 4 }, 9);
        cfg.checkpoints_dir = dir.to_string_lossy().into_owned();
        cfg.rl.ckpt_every = 2;
        cfg.train.auto_buckets = auto_buckets;
        if auto_buckets && rt.manifest.grad_row_files.is_empty() {
            eprintln!("SKIP auto_buckets leg: artifacts have no grad_rows grid");
            continue;
        }

        // Uninterrupted 4-step run.
        let mut full =
            Trainer::new(&rt, cfg.clone(), base.clone(), OptState::zeros(&rt.manifest));
        full.train(4, false).unwrap();

        // Interrupted: 2 steps (writes the rolling checkpoint), then resume.
        let mut first = Trainer::new(&rt, cfg.clone(), base.clone(), OptState::zeros(&rt.manifest));
        first.train(2, false).unwrap();
        let ckpt = cfg.rolling_ckpt_path();
        let (params, opt, meta) =
            Checkpoint::load_full(Path::new(&ckpt), &rt.manifest).unwrap();
        let meta = meta.expect("rolling checkpoint must carry train state");
        assert_eq!(meta.step, 2);
        assert_eq!(meta.seed, cfg.seed);
        assert_eq!(
            meta.tuner.is_some(),
            auto_buckets,
            "tuner state must be checkpointed exactly when auto_buckets is on"
        );
        let mut resumed = Trainer::new(&rt, cfg.clone(), params, opt.unwrap());
        resumed.set_start_step(meta.step);
        resumed.restore_tuner(meta.tuner.as_ref());
        resumed.train(2, false).unwrap();

        assert_eq!(
            full.params.flat, resumed.params.flat,
            "resume diverged (auto_buckets={auto_buckets})"
        );
        assert_eq!(
            full.tuner_state(),
            resumed.tuner_state(),
            "tuner EMA state diverged after resume"
        );
        let _ = std::fs::remove_dir_all(dir);
    }
}

/// Tail-chunk coverage: when total rollouts are not divisible by the device
/// rollout batch, the padded duplicate rows must be discarded and every
/// flat slot filled exactly once with its own task's completion.
#[test]
fn run_group_rollouts_tail_chunk_fills_every_slot_once() {
    let Some(rt) = runtime() else { return };
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let tok = Tokenizer::new();
    let mut sampler = TaskSampler::new(21, TaskMix { tiers: vec![Tier::Easy], ..Default::default() });
    // 2 tasks x (batch_rollout + 1) completions: guaranteed ragged tail.
    let g = d.batch_rollout + 1;
    let tasks = sampler.batch(2);
    let mut rng = Rng::new(13);
    let seqs = run_group_rollouts(&rt, &params, &tok, &tasks, g, 1.0, &mut rng).unwrap();
    assert_eq!(seqs.len(), 2 * g);
    for (flat, s) in seqs.iter().enumerate() {
        assert_eq!(s.task_idx, flat / g, "slot {flat} carries the wrong task");
        // Prompt region must be this task's encoded prompt, not the padding
        // duplicate of the chunk's first row.
        let (row, pad) = encode_prompt(&tok, &tasks[s.task_idx].prompt, d.prompt_len).unwrap();
        assert_eq!(&s.tokens[..d.prompt_len], &row[..]);
        assert_eq!(s.pad_len, pad);
        assert!(s.resp_len >= 1 && s.resp_len <= d.max_resp);
        assert_eq!(s.old_lp.len(), s.resp_len);
    }
}

/// Acceptance (tentpole): on real artifacts, bucketed rollouts are a pure
/// function of `(seed, step, flat_id)` — a scheduler whose predictor was
/// warmed on a different workload (different routing → different batching,
/// refill, and escalation) must produce byte-identical sequences.
#[test]
fn bucketed_rollouts_are_scheduling_invariant_on_real_artifacts() {
    use nat_rl::coordinator::rollout::run_group_rollouts_bucketed;
    use nat_rl::coordinator::rollout::scheduler::RolloutScheduler;

    let Some(rt) = runtime() else { return };
    if rt.manifest.generate_files.is_empty() {
        eprintln!("SKIP: artifacts have no generate_buckets grid (rebuild with make artifacts)");
        return;
    }
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let tok = Tokenizer::new();
    let mut sampler =
        TaskSampler::new(31, TaskMix { tiers: vec![Tier::Easy], ..Default::default() });
    let g = d.batch_rollout + 1; // guaranteed ragged batching
    let tasks = sampler.batch(2);

    let run = |sched: &RolloutScheduler| {
        run_group_rollouts_bucketed(&rt, &params, &tok, &tasks, g, 1.0, 7, 3, sched, 0)
            .unwrap()
            .0
    };
    let cold = RolloutScheduler::new(d.max_resp);
    let a = run(&cold);
    // warm a second scheduler on an unrelated workload so its routing —
    // and therefore the batch composition and refill order — differs
    let warm = RolloutScheduler::new(d.max_resp);
    for step in 0..3u64 {
        let _ = run_group_rollouts_bucketed(
            &rt, &params, &tok, &tasks, g, 1.0, 999, step, &warm, 0,
        )
        .unwrap();
    }
    let b = run(&warm);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "scheduling changed sampled tokens");
        assert_eq!(x.resp_len, y.resp_len);
        assert_eq!(x.old_lp, y.old_lp);
        assert_eq!(x.reward, y.reward);
        assert_eq!(x.task_idx, y.task_idx);
    }
    // and the per-slot layout matches the legacy contract
    for (flat, s) in a.iter().enumerate() {
        assert_eq!(s.task_idx, flat / g);
        assert_eq!(s.tokens.len(), d.prompt_len + d.max_resp);
        assert!(s.resp_len >= 1 && s.resp_len <= d.max_resp);
        assert_eq!(s.old_lp.len(), s.resp_len);
    }
}

#[test]
fn pallas_attention_scorer_matches_dense_scorer() {
    // The L1 flash-attention kernel, lowered inside the score artifact and
    // executed through rust PJRT, must agree with the dense-attention
    // scorer on real rollout tokens.
    let Some(rt) = runtime() else { return };
    if rt.manifest.score_pallas_files.is_empty() {
        eprintln!("SKIP: score_pallas artifact not built");
        return;
    }
    let params = ParamStore::load_init(&rt.manifest).unwrap();
    let d = rt.manifest.dims.clone();
    let tok = Tokenizer::new();
    let (row, pad) = encode_prompt(&tok, "s:9216=", d.prompt_len).unwrap();
    let prompts: Vec<i32> =
        row.iter().cycle().take(d.batch_rollout * d.prompt_len).copied().collect();
    let pads = vec![pad as i32; d.batch_rollout];
    let gen = rt.generate(&params, &prompts, &pads, 3, 1.0).unwrap();
    let (lp_dense, ent_dense) = rt.score(&params, &gen.tokens, &pads, d.max_resp).unwrap();
    let (lp_pallas, ent_pallas) =
        rt.score_pallas(&params, &gen.tokens, &pads, d.max_resp).unwrap();
    for (i, (&a, &b)) in lp_dense.iter().zip(&lp_pallas).enumerate() {
        assert!((a - b).abs() < 5e-3, "lp {i}: dense {a} vs pallas {b}");
    }
    for (&a, &b) in ent_dense.iter().zip(&ent_pallas) {
        assert!((a - b).abs() < 5e-3, "entropy: dense {a} vs pallas {b}");
    }
}
