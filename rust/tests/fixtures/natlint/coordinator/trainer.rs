//! natlint self-test fixture (never compiled): R2 wallclock, two R5
//! hot-panic findings (an `.unwrap()` and a bare slice index), and one
//! malformed pragma that must surface as a P0 finding, not a waiver.

use std::time::Instant;

pub fn step(xs: &[f32], i: usize) -> f32 {
    let t0 = Instant::now();
    let y = xs[i];
    let z = head(xs).unwrap();
    y + z + t0.elapsed().as_secs_f32()
}

fn head(xs: &[f32]) -> Option<f32> {
    xs.first().copied()
}

// natlint: allow(wallclock)
pub fn noted() {}
