//! natlint self-test fixture (never compiled): one R3 rng-discipline
//! finding (ad-hoc data-dependent seed) and one R6 lossy-cast finding
//! (an `as f32` outside the blessed pi_w32 quantization point).

use crate::util::rng::Rng;

pub fn plan(seed: u64, idx: u64, p: f64) -> f32 {
    let mut rng = Rng::new(seed + idx);
    let pi = p as f32;
    let _ = rng.next_u64();
    pi
}
