//! natlint self-test fixture (never compiled): one live R1 unordered-iter
//! finding plus one correctly waived occurrence, proving that a pragma
//! silences exactly the line and rule it names.

use std::collections::HashMap;

// natlint: allow(unordered-iter, reason = "fixture: demonstrates a correctly waived finding")
pub type Waived = std::collections::HashSet<u64>;

pub fn pack(order: &[u64]) -> usize {
    order.len()
}
