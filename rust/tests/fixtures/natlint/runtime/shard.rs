//! natlint self-test fixture (never compiled): two R4 float-accum findings
//! (a `sum::<f32>` turbofish and a `.fold(` chain) in the reduce path,
//! plus a `#[cfg(test)]` duplicate that the pass must leave silent.

pub fn reduce(xs: &[f32]) -> f32 {
    let a = xs.iter().sum::<f32>();
    let b = xs.iter().fold(0.0f32, |m, &x| m + x);
    a + b
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_region_duplicate_stays_silent() {
        let xs = [1.0f32, 2.0];
        let s = xs.iter().sum::<f32>();
        assert!(s > 0.0);
    }
}
