//! Selection-subsystem acceptance tests (tier-1, no artifacts needed).
//!
//! * **Legacy parity** — the tentpole's compatibility contract: every
//!   `Selector` must reproduce the pre-refactor `masking::sample_ctx`
//!   bit-for-bit (outputs AND RNG draw counts) across method × t_i × seed.
//!   The reference is a frozen copy of the old code, kept in this file so
//!   the shim can never drift to "parity with itself".
//! * **Budget controller** — `budget_mode=batch` hits the expected
//!   selected-token target within 2% on the shared bench workload
//!   (`selection::bench_workload`, the same population
//!   `benches/bench_selection.rs` measures), both at the controller level
//!   and end-to-end through `learn_stage` on the sim runtime.
//! * **New selectors** — stratified sampling's variance reduction over URS
//!   and poisson's length-aware rates.
//! * **π-floor guard** — every budget-solved inclusion probability lies in
//!   `[pi_floor, 1]` across schemes × random populations (the runaway
//!   1/π-weight regression), and `HtMoments` matches brute-force
//!   recomputation from the realized plans.
//! * **Selection v2 (`budget_mode neyman`)** — the per-sequence allocation
//!   flows through `learn_stage` shard-invariantly and hits the budget.
//! * **HT unbiasedness under the controller** — the ignored Monte-Carlo
//!   lane proves the reweighted estimator stays unbiased through the FULL
//!   pack → shard → reduce path with controller-adjusted probabilities
//!   (batch and neyman).

use nat_rl::config::{BudgetMode, Method, RunConfig};
use nat_rl::coordinator::batcher::{pack_budget, plan_shards, split_zero_contribution, LearnItem};
use nat_rl::coordinator::masking;
use nat_rl::coordinator::rollout::scheduler::SchedStats;
use nat_rl::obs::Tracer;
use nat_rl::coordinator::selection::{self, bench_workload, HtMoments, Selector, Stratified, Urs};
use nat_rl::coordinator::trainer::learn_stage;
use nat_rl::runtime::shard::{execute_shards, tree_reduce_into};
use nat_rl::runtime::sim::{init_params, sim_manifest};
use nat_rl::runtime::{GradAccum, GradMetrics, OptState, Runtime};
use nat_rl::tokenizer::PAD;
use nat_rl::util::rng::Rng;

/// Frozen pre-refactor implementation of `masking::sample_ctx` (verbatim
/// copy of the code the `selection/` subsystem replaced). DO NOT "fix" or
/// modernise this module: its entire value is being a fossil.
mod legacy {
    use nat_rl::config::Method;
    use nat_rl::util::rng::Rng;

    pub struct Sample {
        pub ht_w: Vec<f32>,
        pub kept: usize,
        pub learn_len: usize,
    }

    fn rpc_survival(t_i: usize, min_cut: usize) -> Vec<f32> {
        let c = min_cut.clamp(1, t_i);
        (1..=t_i)
            .map(|t| {
                if t <= c {
                    1.0
                } else {
                    (t_i - t + 1) as f32 / (t_i - c + 1) as f32
                }
            })
            .collect()
    }

    fn saliency_probs(old_lp: &[f32], floor: f64) -> Vec<f32> {
        let max_u = old_lp.iter().map(|&lp| -lp).fold(1e-6f32, f32::max);
        old_lp
            .iter()
            .map(|&lp| {
                let u = (-lp / max_u).clamp(0.0, 1.0);
                (floor as f32 + (1.0 - floor as f32) * u).clamp(floor as f32, 1.0)
            })
            .collect()
    }

    pub fn sample_ctx(
        method: &Method,
        t_i: usize,
        old_lp: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Sample {
        if t_i == 0 {
            return Sample { ht_w: Vec::new(), kept: 0, learn_len: 0 };
        }
        match *method {
            Method::Grpo => Sample { ht_w: vec![1.0; t_i], kept: t_i, learn_len: t_i },
            Method::Urs { p } => {
                let w = (1.0 / p) as f32;
                let mut ht_w = vec![0.0f32; t_i];
                let mut kept = 0;
                let mut last_kept = 0usize;
                for (t, slot) in ht_w.iter_mut().enumerate() {
                    if rng.bernoulli(p) {
                        *slot = w;
                        kept += 1;
                        last_kept = t + 1;
                    }
                }
                Sample { ht_w, kept, learn_len: last_kept.max(1) }
            }
            Method::DetTrunc { frac } => {
                let k = ((frac * t_i as f64).floor() as usize).clamp(1, t_i);
                let mut ht_w = vec![0.0f32; t_i];
                for slot in ht_w.iter_mut().take(k) {
                    *slot = 1.0;
                }
                Sample { ht_w, kept: k, learn_len: k }
            }
            Method::Rpc { min_cut } => {
                let c = min_cut.clamp(1, t_i);
                let cut = rng.range_inclusive(c as u64, t_i as u64) as usize;
                let p = rpc_survival(t_i, min_cut);
                let mut ht_w = vec![0.0f32; t_i];
                for t in 0..cut {
                    ht_w[t] = 1.0 / p[t];
                }
                Sample { ht_w, kept: cut, learn_len: cut }
            }
            Method::Saliency { floor } => {
                let p = saliency_probs(
                    old_lp.expect("Saliency masking needs behaviour logprobs"),
                    floor,
                );
                let mut ht_w = vec![0.0f32; t_i];
                let mut kept = 0;
                let mut last_kept = 0usize;
                for (t, (slot, &pt)) in ht_w.iter_mut().zip(&p).enumerate() {
                    if rng.bernoulli(pt as f64) {
                        *slot = 1.0 / pt;
                        kept += 1;
                        last_kept = t + 1;
                    }
                }
                Sample { ht_w, kept, learn_len: last_kept.max(1) }
            }
            _ => unreachable!("legacy reference only covers the pre-refactor methods"),
        }
    }
}

/// THE parity proptest: for every legacy method × random t_i × random
/// parameters × random seed, the new `Selector` path (via the
/// `masking::sample_ctx` shim) must return identical `ht_w` bits, `kept`
/// and `learn_len`, AND leave the RNG in the identical state (same number
/// of draws consumed — resume/replay ride on this).
#[test]
fn selectors_match_frozen_legacy_bit_for_bit_including_rng_streams() {
    for case in 0..400u64 {
        let mut meta = Rng::new(0x1E6A_C7 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let t_i = meta.below(301) as usize; // 0 included: the degenerate path
        let old_lp: Vec<f32> =
            (0..t_i).map(|_| -0.02 - meta.uniform() as f32).collect();
        let methods = [
            Method::Grpo,
            Method::Urs { p: 0.05 + 0.95 * meta.uniform() },
            Method::DetTrunc { frac: 0.05 + 0.95 * meta.uniform() },
            Method::Rpc { min_cut: 1 + meta.below(64) as usize },
            Method::Saliency { floor: 0.05 + 0.9 * meta.uniform() },
        ];
        for method in methods {
            let seed = meta.next_u64();
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            let old = legacy::sample_ctx(&method, t_i, Some(&old_lp), &mut ra);
            let new = masking::sample_ctx(&method, t_i, Some(&old_lp), &mut rb);
            assert_eq!(
                old.ht_w.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
                new.ht_w.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
                "case {case} {method:?} t={t_i}: ht_w diverged"
            );
            assert_eq!(old.kept, new.kept, "case {case} {method:?} t={t_i}");
            assert_eq!(old.learn_len, new.learn_len, "case {case} {method:?} t={t_i}");
            // identical post-state ⇒ identical draw count ⇒ downstream
            // streams (later sequences in the step) stay aligned
            assert_eq!(
                ra.next_u64(),
                rb.next_u64(),
                "case {case} {method:?} t={t_i}: RNG stream diverged"
            );
        }
    }
}

/// Budget gate (acceptance criterion): on the shared bench workload the
/// controller's achieved expectation is within 2% of the target for every
/// adaptive scheme, at an attainable target.
#[test]
fn budget_controller_hits_target_within_2pct_on_shared_workload() {
    let lens = bench_workload::lens();
    let lps: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &t)| bench_workload::old_lp(i, t))
        .collect();
    let rows: Vec<(usize, Option<&[f32]>)> =
        lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
    let total: f64 = lens.iter().map(|&t| t as f64).sum();

    // RPC cannot go below Σ(1+t)/2 ≈ 0.5·Σt, so it gets a 0.65 target;
    // the Bernoulli-family schemes get a 0.4 target.
    for (method, frac) in [
        (Method::Urs { p: 0.9 }, 0.4),
        (Method::Stratified { p: 0.9 }, 0.4),
        (Method::Poisson { k: 4 }, 0.4),
        (Method::Saliency { floor: 0.25 }, 0.4),
        (Method::Rpc { min_cut: 8 }, 0.65),
    ] {
        let target = (total * frac).round() as usize;
        let out = selection::solve_batch(&method, &rows, target, 1e-3).unwrap();
        assert!(out.adapted, "{method:?}");
        let rel = (out.expected - target as f64).abs() / target as f64;
        assert!(
            rel <= 0.02,
            "{method:?}: expected {} vs target {target} (rel err {rel:.4})",
            out.expected
        );
    }
}

/// End-to-end: `--train.budget_mode batch` through the real `learn_stage`
/// on the sim runtime — `budget_realized` lands within 2% of
/// `--train.token_budget`, the stats record the target, and the whole
/// thing stays bit-identical across shard counts.
#[test]
fn budget_mode_batch_flows_through_learn_stage_and_stays_shard_invariant() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = bench_workload::seqs(d.prompt_len, d.max_resp);
    let total: usize = seqs.iter().map(|s| s.resp_len).sum();
    let budget = (total as f64 * 0.4).round() as usize;

    for method in [
        Method::Urs { p: 0.9 },
        Method::Stratified { p: 0.9 },
        Method::Poisson { k: 4 },
        Method::Saliency { floor: 0.25 },
    ] {
        let run = |shards: usize| {
            let mut cfg = RunConfig::default();
            cfg.method = method;
            cfg.rl.group_size = bench_workload::GROUP_SIZE;
            cfg.train.token_budget = budget;
            cfg.train.budget_mode = BudgetMode::Batch;
            cfg.train.shards = shards;
            let mut params = init_params(&rt.manifest);
            let mut opt = OptState::zeros(&rt.manifest);
            let mut acc = GradAccum::zeros(rt.manifest.param_count);
            let mut rng_mask = Rng::new(0xB0D6E7);
            let s = learn_stage(
                &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1, &seqs,
                &SchedStats::default(), &Tracer::off(),
            )
            .unwrap();
            (s, params.flat)
        };
        let (stats, params1) = run(1);
        assert_eq!(stats.budget_target, budget as f64, "{method:?}");
        let rel = (stats.budget_realized - budget as f64).abs() / budget as f64;
        assert!(
            rel <= 0.02,
            "{method:?}: budget_realized {} vs target {budget} (rel err {rel:.4})",
            stats.budget_realized
        );
        assert!(stats.sel_var.is_finite() && stats.sel_var >= 0.0);
        assert!(stats.grad_norm.is_finite());
        // controller composes with the sharded learner bit-identically
        let (stats3, params3) = run(3);
        assert_eq!(params1, params3, "{method:?}: shards=3 diverged under budget mode");
        assert_eq!(stats.budget_realized.to_bits(), stats3.budget_realized.to_bits());
        assert_eq!(stats.sel_var.to_bits(), stats3.sel_var.to_bits());
    }
}

/// `budget_mode=none` leaves the step bit-identical to the legacy path:
/// same parameters, and the budget series report "controller off"
/// (target 0) while still exposing the expected-kept diagnostic.
#[test]
fn budget_mode_none_matches_legacy_masking_streams_exactly() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = bench_workload::seqs(d.prompt_len, d.max_resp);
    let mut cfg = RunConfig::default();
    cfg.method = Method::Rpc { min_cut: 4 };
    cfg.rl.group_size = bench_workload::GROUP_SIZE;
    let mut params = init_params(&rt.manifest);
    let mut opt = OptState::zeros(&rt.manifest);
    let mut acc = GradAccum::zeros(rt.manifest.param_count);
    let mut rng_mask = Rng::new(0x0FF);
    let s = learn_stage(
        &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1, &seqs,
        &SchedStats::default(), &Tracer::off(),
    )
    .unwrap();
    assert_eq!(s.budget_target, 0.0);
    assert!(s.budget_realized > 0.0, "expected-kept diagnostic should be live");

    // Replicate the legacy item construction by hand (frozen masking module
    // above) and verify the packed population is identical.
    let mut rng_mask = Rng::new(0x0FF);
    let mut legacy_kept = Vec::new();
    for seq in &seqs {
        let m = legacy::sample_ctx(&cfg.method, seq.resp_len, Some(&seq.old_lp), &mut rng_mask);
        legacy_kept.push((m.kept, m.learn_len));
    }
    let mut rng_mask = Rng::new(0x0FF);
    for (seq, &(kept, ll)) in seqs.iter().zip(&legacy_kept) {
        let plan = selection::selector_for(&cfg.method).sample(
            seq.resp_len,
            Some(&seq.old_lp),
            &mut rng_mask,
        );
        assert_eq!((plan.kept, plan.learn_len), (kept, ll));
    }
}

/// Stratified sampling: URS's marginals (same expected kept count) with the
/// realized kept-count variance collapsed — the variance-reduction claim —
/// at one RNG draw per sequence instead of T.
#[test]
fn stratified_reduces_selection_variance_at_equal_expected_cost() {
    let (t_i, p, n) = (160usize, 0.35f64, 4000);
    let mut rng = Rng::new(0x57A7);
    let stats = |sel: &dyn Selector, rng: &mut Rng| -> (f64, f64) {
        let mut mean = 0.0f64;
        let mut m2 = 0.0f64;
        for _ in 0..n {
            let kept = sel.sample(t_i, None, rng).kept as f64;
            mean += kept;
            m2 += kept * kept;
        }
        mean /= n as f64;
        (mean, m2 / n as f64 - mean * mean)
    };
    let (mean_u, var_u) = stats(&Urs { p }, &mut rng);
    let (mean_s, var_s) = stats(&Stratified { p }, &mut rng);
    let expect = p * t_i as f64;
    assert!((mean_u - expect).abs() < 1.0, "URS mean {mean_u}");
    assert!((mean_s - expect).abs() < 0.5, "stratified mean {mean_s}");
    // URS kept-count variance is T·p·(1-p) ≈ 36.4; stratified is ≤ 1/4.
    assert!(var_u > 20.0, "URS variance degenerate: {var_u}");
    assert!(
        var_s < 0.05 * var_u,
        "stratified variance {var_s} not ≪ URS {var_u}"
    );
    // the per-step `sel_var` metric sees exactly this collapse
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = bench_workload::seqs(d.prompt_len, d.max_resp);
    let run = |method: Method| {
        let mut cfg = RunConfig::default();
        cfg.method = method;
        cfg.rl.group_size = bench_workload::GROUP_SIZE;
        let mut params = init_params(&rt.manifest);
        let mut opt = OptState::zeros(&rt.manifest);
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut rng_mask = Rng::new(0x5E1);
        learn_stage(
            &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1, &seqs,
            &SchedStats::default(), &Tracer::off(),
        )
        .unwrap()
    };
    let s_urs = run(Method::Urs { p: 0.5 });
    let s_str = run(Method::Stratified { p: 0.5 });
    assert!(
        s_str.sel_var < s_urs.sel_var,
        "sel_var: stratified {} vs urs {}",
        s_str.sel_var,
        s_urs.sel_var
    );
}

struct PopRow {
    t_r: usize,
    tokens: Vec<i32>,
    old_lp: Vec<f32>,
    adv: f32,
    pad_len: usize,
}

/// Monte-Carlo HT-unbiasedness of the CONTROLLER-REWEIGHTED estimator,
/// measured through the FULL pack → shard → reduce path: the sim grad's
/// first parameter is linear in the HT weights, so its expectation over
/// mask draws has the closed form `Σ_r adv_r / t_r · Σ_t (old_lp_t +
/// tok_t / 1024)` — independent of the inclusion probabilities, which is
/// precisely the unbiasedness claim for the adjusted probabilities. Slow:
/// runs in the CI `cargo test -- --ignored` lane.
#[test]
#[ignore = "slow Monte-Carlo lane: cargo test -q -- --ignored"]
fn budget_adjusted_estimator_is_ht_unbiased_through_pack_shard_reduce_path() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let (p, top) = (d.prompt_len, *d.buckets.last().unwrap());
    let row_grid = rt.manifest.row_grid();

    // Fixed population: 8 responses, varied lengths, positive advantages so
    // the expectation is safely away from zero.
    let mut pop_rng = Rng::new(0xB0D6_E7A1);
    let rows: Vec<PopRow> = (0..8)
        .map(|r| {
            let t_r = 2 + pop_rng.below((top - 1) as u64) as usize; // 2..=top
            let mut tokens = vec![PAD; p + top];
            for (i, slot) in tokens.iter_mut().enumerate().take(p + t_r) {
                *slot = 3 + ((r * 13 + i * 7) % 50) as i32;
            }
            let old_lp: Vec<f32> =
                (0..t_r).map(|_| -0.02 - pop_rng.uniform() as f32).collect();
            PopRow { t_r, tokens, old_lp, adv: 0.5 + 0.25 * r as f32, pad_len: r % 5 }
        })
        .collect();
    let expected: f64 = rows
        .iter()
        .map(|row| {
            let sum: f64 = (0..row.t_r)
                .map(|t| row.old_lp[t] as f64 + row.tokens[p + t] as f64 / 1024.0)
                .sum();
            row.adv as f64 * sum / row.t_r as f64
        })
        .sum();
    assert!(expected.abs() > 0.5, "degenerate population: E = {expected}");

    // Controller-adjusted selectors at a 50% batch budget — every trial
    // samples with the ADJUSTED inclusion probabilities.
    let total: usize = rows.iter().map(|r| r.t_r).sum();
    let budget = total / 2;
    let ctl_rows: Vec<(usize, Option<&[f32]>)> =
        rows.iter().map(|r| (r.t_r, Some(r.old_lp.as_slice()))).collect();

    let params = init_params(&rt.manifest);
    let lits = params.to_literals(&rt.manifest).unwrap();
    for method in [
        Method::Urs { p: 0.9 },
        Method::Poisson { k: 3 },
        Method::Saliency { floor: 0.3 },
    ] {
        let out = selection::solve_batch(&method, &ctl_rows, budget, 1e-3).unwrap();
        assert!(out.adapted);
        let rel = (out.expected - budget as f64).abs() / budget as f64;
        assert!(rel <= 0.02, "{method:?}: controller off target ({rel:.4})");
        let sel = out.selector;
        let trials = 4000u64;
        let mut est_sum = 0.0f64;
        for trial in 0..trials {
            let mut rng =
                Rng::new(0x7B1A_u64 ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let items: Vec<LearnItem> = rows
                .iter()
                .map(|row| {
                    let plan = sel.sample(row.t_r, Some(&row.old_lp), &mut rng);
                    LearnItem {
                        tokens: row.tokens.clone(),
                        pad_len: row.pad_len,
                        resp_len: row.t_r,
                        ht_w: plan.ht_w,
                        learn_len: plan.learn_len,
                        adv: row.adv,
                        old_lp: row.old_lp.clone(),
                    }
                })
                .collect();
            let (items, _dropped) = split_zero_contribution(items);
            let mbs = pack_budget(&items, &d.buckets, p, &row_grid, 0).unwrap();
            let plan = plan_shards(&mbs, p, 1 + (trial % 4) as usize);
            let leaves = execute_shards(&rt, &mbs, &lits, &plan, &Tracer::off(), 1).unwrap();
            let mut acc = GradAccum::zeros(rt.manifest.param_count);
            let mut met = GradMetrics::default();
            tree_reduce_into(&mut acc, &mut met, leaves);
            est_sum += acc.flat[0] as f64;
        }
        let mean = est_sum / trials as f64;
        let rel = ((mean - expected) / expected).abs();
        assert!(
            rel < 0.05,
            "{method:?}: HT estimate biased through pack/shard/reduce under the \
             budget controller: mean {mean:.4} vs E {expected:.4} (rel err {rel:.4})"
        );
    }
}

/// π-floor proptest (the runaway-weight regression): across every adaptable
/// scheme × random length populations × random (often unattainably low)
/// targets, every solved inclusion probability lies in `[pi_floor, 1]` —
/// which is exactly the `w_max ≤ 1/pi_floor` guarantee, since HT weights
/// divide by the probability sampled with. The Neyman allocation honours
/// the same contract through its solved rates.
#[test]
fn solved_inclusion_probabilities_always_lie_in_pi_floor_one() {
    for case in 0..60u64 {
        let mut meta = Rng::new(0xF1_0072 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let n_rows = 3 + meta.below(14) as usize;
        let lens: Vec<usize> = (0..n_rows)
            .map(|_| if meta.uniform() < 0.1 { 0 } else { 1 + meta.below(300) as usize })
            .collect();
        let lps: Vec<Vec<f32>> = lens
            .iter()
            .map(|&t| (0..t).map(|_| -0.02 - meta.uniform() as f32).collect())
            .collect();
        let rows: Vec<(usize, Option<&[f32]>)> =
            lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
        let total: usize = lens.iter().sum();
        let pi_floor = 10f64.powf(-1.3 - 2.7 * meta.uniform()); // ~[5e-5, 0.05]
        // targets from pathologically low (1 token) up to over-ask
        let target = match case % 3 {
            0 => 1,
            1 => 1 + meta.below(1 + total as u64 / 2) as usize,
            _ => total + 1 + meta.below(64) as usize,
        };
        let methods = [
            Method::Urs { p: 0.05 + 0.9 * meta.uniform() },
            Method::Stratified { p: 0.05 + 0.9 * meta.uniform() },
            Method::Poisson { k: 1 + meta.below(16) as usize },
            Method::Saliency { floor: 0.05 + 0.9 * meta.uniform() },
        ];
        let eps = 1e-6;
        for method in methods {
            let out = selection::solve_batch(&method, &rows, target, pi_floor).unwrap();
            for (&t, lp) in lens.iter().zip(&lps) {
                for &p in &out.selector.probs(t, Some(lp.as_slice())) {
                    assert!(
                        p as f64 >= pi_floor * (1.0 - eps) && p as f64 <= 1.0 + eps,
                        "case {case} {method:?} target {target} pf {pi_floor:.2e}: \
                         solved π {p} outside [pi_floor, 1]"
                    );
                }
            }
        }
        let abs_adv: Vec<f64> = (0..n_rows).map(|_| meta.uniform() * 2.0).collect();
        let alloc = selection::solve_neyman(&rows, &abs_adv, target, pi_floor);
        for i in 0..n_rows {
            let r = alloc.rate(i);
            assert!(
                r >= pi_floor * (1.0 - eps) && r <= 1.0 + eps,
                "case {case} neyman target {target} pf {pi_floor:.2e}: rate {r}"
            );
        }
    }
}

/// `HtMoments` (the `ht_w_max`/`ht_ess` ledger inputs) must agree with a
/// brute-force recomputation from the realized plans' weight vectors.
#[test]
fn ht_moments_match_brute_force_recomputation_from_plans() {
    let lens = bench_workload::lens();
    let lps: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &t)| bench_workload::old_lp(i, t))
        .collect();
    let rows: Vec<(usize, Option<&[f32]>)> =
        lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
    let total: f64 = lens.iter().map(|&t| t as f64).sum();
    let target = (total * 0.4).round() as usize;

    let mut rng = Rng::new(0x47E5);
    let abs_adv = vec![1.0f64; rows.len()];
    let alloc = selection::solve_neyman(&rows, &abs_adv, target, 1e-3);
    let batch =
        selection::solve_batch(&Method::Poisson { k: 4 }, &rows, target, 1e-3).unwrap();
    for per_row in [true, false] {
        let mut ht = HtMoments::default();
        let mut weights: Vec<f64> = Vec::new();
        for (i, &(t, lp)) in rows.iter().enumerate() {
            let plan = if per_row {
                alloc.sample_row(i, t, &mut rng)
            } else {
                batch.selector.sample(t, lp, &mut rng)
            };
            weights.extend(plan.ht_w.iter().filter(|&&w| w > 0.0).map(|&w| w as f64));
            ht.observe(&plan);
        }
        let w_max = weights.iter().copied().fold(0.0f64, f64::max);
        let w_sum: f64 = weights.iter().sum();
        let w2_sum: f64 = weights.iter().map(|w| w * w).sum();
        let ess = if w2_sum > 0.0 { w_sum * w_sum / w2_sum } else { 0.0 };
        assert_eq!(ht.kept as usize, weights.len(), "per_row={per_row}");
        assert!((ht.w_max - w_max).abs() <= 1e-12, "per_row={per_row}");
        assert!((ht.w_sum - w_sum).abs() <= 1e-9 * w_sum.max(1.0), "per_row={per_row}");
        assert!((ht.w2_sum - w2_sum).abs() <= 1e-9 * w2_sum.max(1.0), "per_row={per_row}");
        assert!((ht.ess() - ess).abs() <= 1e-9 * ess.max(1.0), "per_row={per_row}");
        assert!(ht.w_max <= 1e3 * (1.0 + 1e-6), "per_row={per_row}: floor breached");
    }
}

/// Tier-1 mirror of the `BENCH_selection.json` acceptance: at equal
/// realized budget on the shared controller workload, the Neyman
/// allocation beats the Poisson batch controller on both variance axes —
/// higher kept-token effective sample size (its near-uniform rates keep
/// the 1/π weights tight, where Poisson's `k/t` rates spread them across
/// the length distribution) and lower per-row selection variance
/// (systematic sampling pins each row's kept count to ⌊pT⌋/⌈pT⌉).
#[test]
fn neyman_beats_poisson_batch_on_ess_and_sel_var_at_equal_budget() {
    let lens = bench_workload::lens();
    let lps: Vec<Vec<f32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &t)| bench_workload::old_lp(i, t))
        .collect();
    let rows: Vec<(usize, Option<&[f32]>)> =
        lens.iter().zip(&lps).map(|(&t, lp)| (t, Some(lp.as_slice()))).collect();
    let total: f64 = lens.iter().map(|&t| t as f64).sum();
    let target = (total * 0.4).round() as usize;

    let batch =
        selection::solve_batch(&Method::Poisson { k: 4 }, &rows, target, 1e-3).unwrap();
    let abs_adv = vec![1.0f64; rows.len()];
    let alloc = selection::solve_neyman(&rows, &abs_adv, target, 1e-3);
    // equal realized budget: both solves hit the same target within 2%
    let gap = (batch.expected - alloc.expected_sum()).abs() / target as f64;
    assert!(gap <= 0.02, "unequal realized budgets: {gap:.4}");

    let mut rng = Rng::new(0x0E55_C0DE);
    let draws = 8;
    let (mut b_ess, mut b_var, mut n_ess, mut n_var) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for _ in 0..draws {
        let mut ht = HtMoments::default();
        let mut var = 0.0;
        for &(t, lp) in &rows {
            let plan = batch.selector.sample(t, lp, &mut rng);
            let e = plan.expected_kept();
            var += (plan.kept as f64 - e) * (plan.kept as f64 - e);
            ht.observe(&plan);
        }
        b_ess += ht.ess() / draws as f64;
        b_var += var / (rows.len() * draws) as f64;
        let mut ht = HtMoments::default();
        let mut var = 0.0;
        for (i, &(t, _)) in rows.iter().enumerate() {
            let plan = alloc.sample_row(i, t, &mut rng);
            let e = plan.expected_kept();
            var += (plan.kept as f64 - e) * (plan.kept as f64 - e);
            ht.observe(&plan);
        }
        n_ess += ht.ess() / draws as f64;
        n_var += var / (rows.len() * draws) as f64;
    }
    assert!(
        n_ess > b_ess,
        "neyman ht_ess {n_ess:.1} must exceed poisson-batch {b_ess:.1}"
    );
    assert!(
        n_var < b_var,
        "neyman sel_var {n_var:.3} must undercut poisson-batch {b_var:.3}"
    );
}

/// End-to-end selection v2: `--train.budget_mode neyman` through the real
/// `learn_stage` — `budget_realized` within 2% of the target, the ledger
/// records the π floor with `ht_w_max` under its bound, and the whole step
/// stays bit-identical across shard counts.
#[test]
fn budget_mode_neyman_flows_through_learn_stage_and_stays_shard_invariant() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let seqs = bench_workload::seqs(d.prompt_len, d.max_resp);
    let total: usize = seqs.iter().map(|s| s.resp_len).sum();
    let budget = (total as f64 * 0.4).round() as usize;

    let run = |shards: usize| {
        let mut cfg = RunConfig::default();
        cfg.method = Method::Stratified { p: 0.9 };
        cfg.rl.group_size = bench_workload::GROUP_SIZE;
        cfg.train.token_budget = budget;
        cfg.train.budget_mode = BudgetMode::Neyman;
        cfg.train.shards = shards;
        let mut params = init_params(&rt.manifest);
        let mut opt = OptState::zeros(&rt.manifest);
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut rng_mask = Rng::new(0x4E59_4D41);
        let s = learn_stage(
            &rt, &cfg, &mut params, &mut opt, &mut acc, None, &mut rng_mask, 1, &seqs,
            &SchedStats::default(), &Tracer::off(),
        )
        .unwrap();
        (s, params.flat)
    };
    let (stats, params1) = run(1);
    assert_eq!(stats.budget_target, budget as f64);
    let rel = (stats.budget_realized - budget as f64).abs() / budget as f64;
    assert!(
        rel <= 0.02,
        "neyman budget_realized {} vs target {budget} (rel err {rel:.4})",
        stats.budget_realized
    );
    assert!(stats.sel_var.is_finite() && stats.sel_var >= 0.0);
    assert!(stats.grad_norm.is_finite());
    // ledger contract: the default π floor is recorded and honoured
    assert_eq!(stats.ledger.pi_floor, 1e-3);
    assert!(
        stats.ledger.ht_w_max <= (1.0 + 1e-6) / 1e-3,
        "ht_w_max {} breaches 1/pi_floor",
        stats.ledger.ht_w_max
    );
    // the per-row allocation composes with the sharded learner bit-identically
    let (stats3, params3) = run(3);
    assert_eq!(params1, params3, "neyman: shards=3 diverged");
    assert_eq!(stats.budget_realized.to_bits(), stats3.budget_realized.to_bits());
    assert_eq!(stats.sel_var.to_bits(), stats3.sel_var.to_bits());
}

/// Monte-Carlo HT-unbiasedness for `budget_mode neyman` through the FULL
/// pack → shard → reduce path — same closed-form expectation and estimator
/// as the batch-controller MC test above, with the per-sequence Neyman
/// rates (solved from the rows' own |advantages|) driving selection.
#[test]
#[ignore = "slow Monte-Carlo lane: cargo test -q -- --ignored"]
fn neyman_estimator_is_ht_unbiased_through_pack_shard_reduce_path() {
    let rt = Runtime::sim(sim_manifest());
    let d = rt.manifest.dims.clone();
    let (p, top) = (d.prompt_len, *d.buckets.last().unwrap());
    let row_grid = rt.manifest.row_grid();

    let mut pop_rng = Rng::new(0xB0D6_E7A1);
    let rows: Vec<PopRow> = (0..8)
        .map(|r| {
            let t_r = 2 + pop_rng.below((top - 1) as u64) as usize;
            let mut tokens = vec![PAD; p + top];
            for (i, slot) in tokens.iter_mut().enumerate().take(p + t_r) {
                *slot = 3 + ((r * 13 + i * 7) % 50) as i32;
            }
            let old_lp: Vec<f32> =
                (0..t_r).map(|_| -0.02 - pop_rng.uniform() as f32).collect();
            PopRow { t_r, tokens, old_lp, adv: 0.5 + 0.25 * r as f32, pad_len: r % 5 }
        })
        .collect();
    let expected: f64 = rows
        .iter()
        .map(|row| {
            let sum: f64 = (0..row.t_r)
                .map(|t| row.old_lp[t] as f64 + row.tokens[p + t] as f64 / 1024.0)
                .sum();
            row.adv as f64 * sum / row.t_r as f64
        })
        .sum();
    assert!(expected.abs() > 0.5, "degenerate population: E = {expected}");

    let total: usize = rows.iter().map(|r| r.t_r).sum();
    let budget = total / 2;
    let ctl_rows: Vec<(usize, Option<&[f32]>)> =
        rows.iter().map(|r| (r.t_r, Some(r.old_lp.as_slice()))).collect();
    let abs_adv: Vec<f64> = rows.iter().map(|r| r.adv.abs() as f64).collect();
    let alloc = selection::solve_neyman(&ctl_rows, &abs_adv, budget, 1e-3);
    let rel = (alloc.expected_sum() - budget as f64).abs() / budget as f64;
    assert!(rel <= 0.02, "neyman solve off target ({rel:.4})");

    let params = init_params(&rt.manifest);
    let lits = params.to_literals(&rt.manifest).unwrap();
    let trials = 4000u64;
    let mut est_sum = 0.0f64;
    for trial in 0..trials {
        let mut rng = Rng::new(0x7B1A_u64 ^ trial.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let items: Vec<LearnItem> = rows
            .iter()
            .enumerate()
            .map(|(i, row)| {
                let plan = alloc.sample_row(i, row.t_r, &mut rng);
                LearnItem {
                    tokens: row.tokens.clone(),
                    pad_len: row.pad_len,
                    resp_len: row.t_r,
                    ht_w: plan.ht_w,
                    learn_len: plan.learn_len,
                    adv: row.adv,
                    old_lp: row.old_lp.clone(),
                }
            })
            .collect();
        let (items, _dropped) = split_zero_contribution(items);
        let mbs = pack_budget(&items, &d.buckets, p, &row_grid, 0).unwrap();
        let plan = plan_shards(&mbs, p, 1 + (trial % 4) as usize);
        let leaves = execute_shards(&rt, &mbs, &lits, &plan, &Tracer::off(), 1).unwrap();
        let mut acc = GradAccum::zeros(rt.manifest.param_count);
        let mut met = GradMetrics::default();
        tree_reduce_into(&mut acc, &mut met, leaves);
        est_sum += acc.flat[0] as f64;
    }
    let mean = est_sum / trials as f64;
    let rel = ((mean - expected) / expected).abs();
    assert!(
        rel < 0.05,
        "neyman: HT estimate biased through pack/shard/reduce: mean {mean:.4} vs \
         E {expected:.4} (rel err {rel:.4})"
    );
}
